"""LM serving launcher: prefill+decode loop for the transformer stack (CLI).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --prompt-len 16 --decode-steps 8

This is the *language-model* demo loop only. The matching service — the
paper's solver behind a request interface, with shard routing, size-class
batching, plan caching and warm-start rematching (DESIGN.md §11) — lives
in ``repro.serving``:

  PYTHONPATH=src python -m repro.serving --requests 256 --rate 400
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_defs
from repro.models.param import init_params


def serve_lm(cfg, batch, prompt_len, decode_steps):
    from repro.models import transformer as T

    params = init_params(build_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                         jnp.int32)
    smax = prompt_len + decode_steps
    t0 = time.perf_counter()
    logits, cache = jax.jit(lambda p, t: T.prefill(p, t, cfg))(params, tokens)

    def grow(kv):
        k, v = kv
        kb = jnp.zeros((k.shape[0], batch, smax, *k.shape[3:]), k.dtype)
        return (kb.at[:, :, :prompt_len].set(k),
                jnp.zeros_like(kb).at[:, :, :prompt_len].set(v))

    cache = {g: grow(kv) for g, kv in cache.items()}
    print(f"prefill: {batch}x{prompt_len} in "
          f"{(time.perf_counter() - t0) * 1e3:.0f} ms")
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(decode_steps - 1):
        lg, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = (time.perf_counter() - t0) / max(decode_steps - 1, 1)
    print(f"decode: {dt * 1e3:.1f} ms/token/batch; "
          f"sample ids {np.array(jnp.concatenate(out, 1)[0])[:8]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=8)
    args = ap.parse_args()
    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.family == "lm":
        serve_lm(cfg, args.batch, args.prompt_len, args.decode_steps)
    else:
        raise SystemExit("serving CLI supports LM archs; see "
                         "examples/serve_bert4rec.py for recsys")


if __name__ == "__main__":
    main()
