import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first initialization) — dry-run only; tests/benches see 1 device.

"""Multi-pod dry-run launcher.

For every (architecture x input shape) cell, lower + compile the step
function on the production mesh (16x16 single-pod AND 2x16x16 multi-pod),
then record memory_analysis / cost_analysis / collective-bytes into a JSON
file per cell (results/dryrun/). Failures here are bugs in the system.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import shapes_for
from repro.launch.input_specs import build_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import collective_bytes, roofline_terms, useful_flops

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _compile_metrics(cell, mesh):
    fn = cell.fn
    jitted = (fn if hasattr(fn, "lower") and hasattr(fn, "trace")
              else jax.jit(fn, in_shardings=cell.in_shardings,
                           donate_argnums=cell.donate_argnums))
    with mesh:
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return compiled, ma, float(ca.get("flops", 0.0)), \
        float(ca.get("bytes accessed", 0.0)), coll, hlo


def _lm_scan_correction(arch, shape_name, mesh, router, cfg, variants=()):
    """XLA's HloCostAnalysis counts while-loop (scan) bodies ONCE; probe the
    model UNROLLED at fd+1 and fd+2 layers to recover per-layer costs:
    corrected(L) = probe1 + (L - fd - 1) * (probe2 - probe1)."""
    import dataclasses

    fd = cfg.moe.first_dense if cfg.moe is not None else 0
    out = []
    for L in (fd + 1, fd + 2):
        c = dataclasses.replace(cfg, n_layers=L, scan=False)
        cell = build_cell(arch, shape_name, mesh, router=router,
                          cfg_override=c, variants=variants)
        _, _, fl, by, coll, _ = _compile_metrics(cell, mesh)
        out.append((fl, by, coll["total"]))
    (f1, b1, c1), (f2, b2, c2) = out
    L = cfg.n_layers
    k = L - fd - 1
    return (f1 + k * (f2 - f1), b1 + k * (b2 - b1), c1 + k * (c2 - c1))


def run_cell(arch: str, shape_name: str, mesh_kind: str, router=None,
             keep_hlo: bool = False, probe: bool = True,
             variants: tuple = ()) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": int(n_chips), "router": router or "default",
           "variants": list(variants), "ok": False}
    t0 = time.perf_counter()
    try:
        cell = build_cell(arch, shape_name, mesh, router=router,
                          variants=variants)
        t1 = time.perf_counter()
        compiled, ma, flops, bytes_acc, coll, hlo = _compile_metrics(cell, mesh)
        rec["compile_s"] = round(time.perf_counter() - t1, 2)
        cfg = get_config(arch) if not router else get_config(arch, router=router)
        raw = {"flops": flops, "bytes": bytes_acc, "coll": coll["total"]}
        if cfg.family == "lm" and probe and mesh_kind == "single":
            flops, bytes_acc, coll_total = _lm_scan_correction(
                arch, shape_name, mesh, router, cfg, variants)
            coll = dict(coll, total=coll_total)
            rec["scan_corrected"] = True
        rl = roofline_terms(flops, bytes_acc, coll["total"])
        shape = next(s for s in shapes_for(cfg) if s.name == shape_name)
        mf = useful_flops(arch, shape_name, cell.mode, cfg, shape)
        # jaxlib < 0.5 has no peak stat; args + outputs + temps bounds it
        peak = getattr(ma, "peak_memory_in_bytes", None)
        if peak is None:
            peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes)
        rec.update(
            raw_uncorrected=raw,
            ok=True,
            mode=cell.mode,
            note=cell.note,
            peak_memory_per_device=int(peak),
            argument_bytes=int(ma.argument_size_in_bytes),
            output_bytes=int(ma.output_size_in_bytes),
            temp_bytes=int(ma.temp_size_in_bytes),
            generated_code_bytes=int(ma.generated_code_size_in_bytes),
            flops_per_device=flops,
            bytes_per_device=bytes_acc,
            collectives=coll,
            roofline=rl.to_dict(),
            model_flops_global=mf,
            model_flops_ratio=(mf / (flops * n_chips) if flops else None),
            hlo_instructions=hlo.count("\n"),
        )
        if keep_hlo:
            (RESULTS / f"{arch}__{shape_name}__{mesh_kind}.hlo.txt").write_text(hlo)
    except Exception as e:  # noqa: BLE001 — recorded, not swallowed silently
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.perf_counter() - t0, 2)
    return rec


def save(rec: dict, suffix=""):
    RESULTS.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    (RESULTS / name).write_text(json.dumps(rec, indent=1))
    status = "OK " if rec.get("ok") else "FAIL"
    rl = rec.get("roofline", {})
    print(f"[{status}] {name} lower={rec.get('lower_s')}s "
          f"compile={rec.get('compile_s')}s dominant={rl.get('dominant')} "
          f"peakMB={rec.get('peak_memory_per_device', 0) // 2**20}"
          + ("" if rec.get("ok") else f" err={rec.get('error')}"),
          flush=True)
    return rec.get("ok", False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--router", default=None)
    ap.add_argument("--variants", default="",
                    help="comma-separated: fsdp_gather,moe_ep,packed_a2a,"
                         "escn_sub")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        targets = [(a, s.name) for a in ALL_ARCHS
                   for s in shapes_for(get_config(a))]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        targets = [(args.arch, args.shape)]

    variants = tuple(v for v in args.variants.split(",") if v)
    n_fail = 0
    for arch, shape in targets:
        for mk in meshes:
            suffix = f"__{args.router}" if args.router else ""
            if variants:
                suffix += "__v_" + "_".join(variants)
            out = RESULTS / f"{arch}__{shape}__{mk}{suffix}.json"
            if args.skip_existing and out.exists() \
                    and json.loads(out.read_text()).get("ok"):
                print(f"[skip] {out.name}", flush=True)
                continue
            rec = run_cell(arch, shape, mk, router=args.router,
                           keep_hlo=args.keep_hlo, variants=variants)
            if not save(rec, suffix):
                n_fail += 1
    print(f"done; failures: {n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
