"""Per-(arch x shape x mesh) dry-run cells: step function + ShapeDtypeStruct
stand-ins + input shardings. No device allocation happens here — everything
is abstract (the shannon/kernels pattern).

Sharded dims that don't divide the mesh axis product are PADDED UP — all
models use sentinel/mask semantics, so padding is semantically inert, and the
dry-run only lowers+compiles anyway.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ShapeSpec, shapes_for
from repro.data.graphs import TRIPLET_FACTOR, graphcast_sizes, sampled_sizes
from repro.launch import mesh as mesh_lib
from repro.models import build_defs, build_loss, gnn_out_dim
from repro.models.act_sharding import with_policy
from repro.models.param import abstract_params, partition_specs
from repro.training.loop import make_train_step
from repro.training.optimizer import AdamWConfig, abstract_opt_state, opt_specs


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    mode: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    donate_argnums: tuple = ()
    note: str = ""


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _pad_to(n: int, mesh, axes) -> int:
    k = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return -(-n // k) * k


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


OPT = AdamWConfig()


# --------------------------------- LM ---------------------------------------


def _lm_policy(cfg, mesh, b: int, rules, variants=()):
    """Activation-sharding policy for the LM family (mesh layout: DESIGN.md §1)."""
    bax = mesh_lib.batch_axes(mesh) if b > 1 else None
    kvdiv = (cfg.n_kv_heads * cfg.hd) % mesh.shape["model"] == 0
    ep_ax = rules.get("experts")
    grouped = cfg.moe is not None and (cfg.moe.router == "awpm"
                                       or cfg.moe.dispatch_groups > 1)
    pol = {
        "lm_act": P(bax, None, None),
        "lm_qkv": P(bax, None, "model", None),
        "lm_kv": P(bax, None, "model" if kvdiv else None, None),
        "lm_logits": P(bax, None, "model"),
        "mlp_hidden": {3: P(bax, None, "model"), 2: P(bax, "model")},
        "moe_buf4": (P(bax, ep_ax, None, None) if grouped
                     else P(None, ep_ax, "data", None)),
    }
    if "fsdp_gather" in variants:
        pol["w_fsdp"] = {2: P(None, "model")}
        pol["w_expert"] = {3: P(ep_ax, None, rules.get("expert_mlp"))}
    return pol


def _lm_cells(arch, cfg, shape: ShapeSpec, mesh, variants=()):
    import dataclasses

    from repro.models import transformer as T

    gsz = next((int(v.split(":")[1]) for v in variants
                if v.startswith("moe_ep:")), 2048)
    if any(v.startswith("moe_ep") for v in variants) and cfg.moe is not None:
        t_tokens = shape.d("global_batch") * (shape.d("seq_len")
                                              if shape.mode != "decode" else 1)
        groups = max(1, t_tokens // gsz)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=groups))
    if "loss_chunk" in variants:
        cfg = dataclasses.replace(cfg, loss_chunks=8)
    rules = mesh_lib.lm_param_rules(cfg, mesh, variants)
    defs = build_defs(cfg)
    aparams = abstract_params(defs)
    pspecs = partition_specs(defs, rules)
    batch_ax = mesh_lib.batch_axes(mesh)
    all_ax = mesh_lib.all_axes(mesh)
    s = shape.d("seq_len")
    b = shape.d("global_batch")
    pol = with_policy(mesh, _lm_policy(cfg, mesh, b, rules, variants))

    if shape.mode == "train":
        loss = build_loss(cfg)
        step = pol(make_train_step(loss, OPT))
        abatch = {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
            "mask": _sds((b, s), jnp.float32),
        }
        bspec = {k: P(batch_ax, None) for k in abatch}
        return Cell(arch, shape.name, "train", step,
                    (aparams, abstract_opt_state(aparams), abatch),
                    _ns(mesh, (pspecs, opt_specs(pspecs), bspec)),
                    donate_argnums=(0, 1))

    if shape.mode == "prefill":
        fn = pol(functools.partial(_prefill_fn, cfg=cfg))
        atok = _sds((b, s), jnp.int32)
        return Cell(arch, shape.name, "prefill", fn, (aparams, atok),
                    _ns(mesh, (pspecs, P(batch_ax, None))))

    # decode: one new token against a seq-length-s KV cache
    acache = T.cache_shapes(cfg, b, s)
    if b == 1:
        kv_spec = P(None, None, all_ax, None, None)  # SP: seq over all axes
        tok_spec = P()
        note = "long-context decode: KV sequence-sharded over ALL axes"
    else:
        kv_spec = P(None, batch_ax, "model", None, None)
        tok_spec = P(batch_ax, None)
        note = "decode: batch over data axes, KV seq over model"
    cspec = jax.tree.map(lambda _: kv_spec, acache)
    fn = pol(functools.partial(_decode_fn, cfg=cfg))
    atok = _sds((b, 1), jnp.int32)
    apos = _sds((), jnp.int32)
    return Cell(arch, shape.name, "decode", fn,
                (aparams, acache, atok, apos),
                _ns(mesh, (pspecs, cspec, tok_spec, P())),
                donate_argnums=(1,), note=note)


def _prefill_fn(params, tokens, *, cfg):
    from repro.models import transformer as T

    return T.prefill(params, tokens, cfg)


def _decode_fn(params, cache, token, pos, *, cfg):
    from repro.models import transformer as T

    return T.decode_step(params, cache, token, pos, cfg)


# --------------------------------- GNN --------------------------------------


def _gnn_sizes(shape: ShapeSpec):
    if shape.name == "minibatch_lg":
        n, e = sampled_sizes(shape.d("batch_nodes"),
                             (shape.d("fanout1"), shape.d("fanout2")))
        return n, e, shape.d("d_feat", 602)
    if shape.name == "molecule":
        bsz = shape.d("batch")
        return shape.d("n_nodes") * bsz, shape.d("n_edges") * bsz, \
            shape.d("d_feat", 16)
    return shape.d("n_nodes"), shape.d("n_edges"), shape.d("d_feat", 100)


def _gnn_cells(arch, cfg, shape: ShapeSpec, mesh, variants=()):
    from repro.models.gnn.common import GraphBatch
    from repro.models.gnn.graphcast import GraphCastBatch

    fdt = jnp.bfloat16 if "gnn_bf16" in variants else jnp.float32
    all_ax = mesh_lib.all_axes(mesh)
    shard_ax = all_ax  # graph entities shard over every axis
    rules = mesh_lib.gnn_param_rules(cfg, mesh)

    if cfg.kind == "graphcast":
        ng0, _, _ = _gnn_sizes(shape)
        ng = _pad_to(ng0, mesh, shard_ax)
        sz = graphcast_sizes(ng)
        nm = _pad_to(sz["n_mesh"], mesh, shard_ax)
        nv = cfg.opt("n_vars", 227)
        sp = P(shard_ax)
        ab = GraphCastBatch(
            grid_feat=_sds((ng, nv), jnp.float32),
            g2m_src=_sds((_pad_to(sz["e_g2m"], mesh, shard_ax),), jnp.int32),
            g2m_dst=_sds((_pad_to(sz["e_g2m"], mesh, shard_ax),), jnp.int32),
            mesh_src=_sds((_pad_to(sz["e_mesh"], mesh, shard_ax),), jnp.int32),
            mesh_dst=_sds((_pad_to(sz["e_mesh"], mesh, shard_ax),), jnp.int32),
            m2g_src=_sds((_pad_to(sz["e_m2g"], mesh, shard_ax),), jnp.int32),
            m2g_dst=_sds((_pad_to(sz["e_m2g"], mesh, shard_ax),), jnp.int32),
            target=_sds((ng, nv), jnp.float32),
            n_mesh=nm,
        )
        bspec = GraphCastBatch(
            grid_feat=P(shard_ax, None), g2m_src=sp, g2m_dst=sp, mesh_src=sp,
            mesh_dst=sp, m2g_src=sp, m2g_dst=sp, target=P(shard_ax, None),
            n_mesh=nm,
        )
    else:
        n0, e0, d_feat = _gnn_sizes(shape)
        n = _pad_to(n0, mesh, shard_ax)
        e = _pad_to(e0, mesh, shard_ax)
        coords = cfg.kind in ("dimenet", "equiformer_v2")
        n_graphs = shape.d("batch", 1)
        n_out = gnn_out_dim(shape.name)
        labels = (_sds((n_graphs, 1), jnp.float32) if n_out == 1
                  else _sds((n,), jnp.int32))
        tri = None
        if cfg.kind == "dimenet":
            pcap = _pad_to(TRIPLET_FACTOR * e, mesh, shard_ax)
            tri = (_sds((pcap,), jnp.int32), _sds((pcap,), jnp.int32))
        ab = GraphBatch(
            node_feat=_sds((n, d_feat), fdt),
            edge_src=_sds((e,), jnp.int32),
            edge_dst=_sds((e,), jnp.int32),
            labels=labels,
            coords=_sds((n, 3), fdt) if coords else None,
            graph_id=_sds((n,), jnp.int32) if n_graphs > 1 else None,
            triplets=tri,
            n_graphs=n_graphs,
        )
        sp = P(shard_ax)
        bspec = GraphBatch(
            node_feat=P(shard_ax, None), edge_src=sp, edge_dst=sp,
            labels=(P(None, None) if n_out == 1 else sp),
            coords=P(shard_ax, None) if coords else None,
            graph_id=sp if n_graphs > 1 else None,
            triplets=(sp, sp) if tri is not None else None,
            n_graphs=n_graphs,
        )

    defs = build_defs(cfg, shape)
    aparams = abstract_params(defs)
    pspecs = partition_specs(defs, rules)
    loss = build_loss(cfg)
    gpol = {
        "nodes": P(shard_ax, None), "nodes3": P(shard_ax, None, None),
        "edges": P(shard_ax, None), "edges3": P(shard_ax, None, None),
    }
    step = with_policy(mesh, gpol)(make_train_step(loss, OPT))
    return Cell(arch, shape.name, "train", step,
                (aparams, abstract_opt_state(aparams), ab),
                _ns(mesh, (pspecs, opt_specs(pspecs), bspec)),
                donate_argnums=(0, 1))


# -------------------------------- RecSys ------------------------------------


def _recsys_cells(arch, cfg, shape: ShapeSpec, mesh):
    from repro.models.recsys import bert4rec

    rules = mesh_lib.recsys_param_rules(cfg, mesh)
    defs = build_defs(cfg)
    aparams = abstract_params(defs)
    pspecs = partition_specs(defs, rules)
    all_ax = mesh_lib.all_axes(mesh)
    b = _pad_to(shape.d("batch"), mesh, all_ax)
    sl = cfg.seq_len
    pol = with_policy(mesh, {"rec_act": P(all_ax, None, None)})

    if shape.mode == "train":
        loss = build_loss(cfg)
        step = pol(make_train_step(loss, OPT))
        ab = {
            "item_seq": _sds((b, sl), jnp.int32),
            "labels": _sds((b, sl), jnp.int32),
            "mask": _sds((b, sl), jnp.float32),
        }
        bspec = {k: P(all_ax, None) for k in ab}
        return Cell(arch, shape.name, "train", step,
                    (aparams, abstract_opt_state(aparams), ab),
                    _ns(mesh, (pspecs, opt_specs(pspecs), bspec)),
                    donate_argnums=(0, 1))

    if shape.mode == "serve":
        fn = pol(functools.partial(_serve_fn, cfg=cfg))
        aseq = _sds((b, sl), jnp.int32)
        return Cell(arch, shape.name, "serve", fn, (aparams, aseq),
                    _ns(mesh, (pspecs, P(all_ax, None))))

    # retrieval: 1 user x 1M candidates (batched dot, candidate-sharded)
    nc = _pad_to(shape.d("n_candidates"), mesh, all_ax)
    fn = functools.partial(_retrieval_fn, cfg=cfg)
    aseq = _sds((shape.d("batch"), sl), jnp.int32)
    acand = _sds((nc,), jnp.int32)
    return Cell(arch, shape.name, "retrieval", fn, (aparams, aseq, acand),
                _ns(mesh, (pspecs, P(None, None), P(all_ax))))


def _serve_fn(params, seq, *, cfg):
    from repro.models.recsys import bert4rec

    return bert4rec.serve_scores(params, seq, cfg)


def _retrieval_fn(params, seq, cands, *, cfg):
    from repro.models.recsys import bert4rec

    return bert4rec.retrieval_scores(params, seq, cands, cfg)


# ------------------------------- Matching -----------------------------------


def _matching_cells(arch, cfg, shape: ShapeSpec, mesh, packed: bool = False):
    from repro.core.dist import GridSpec, default_caps, make_dist_awac
    from repro.core.single import MatchState

    row_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = GridSpec(mesh, row_axes, "model")
    n = shape.d("n")
    m = int(n * shape.d("avg_degree"))
    cap = _pad_to(int(1.5 * m / (spec.pr * spec.pc)) + 64, mesh, ())
    caps = default_caps(n, m, spec.pr, spec.pc, slack=cfg.a2a_slack)
    run = make_dist_awac(spec, n, cap, caps, max_iter=cfg.max_iter,
                         packed=packed)
    blk = _sds((spec.pr, spec.pc, cap), jnp.int32)
    blkf = _sds((spec.pr, spec.pc, cap), jnp.float32)
    astate = MatchState(
        _sds((n + 1,), jnp.int32), _sds((n + 1,), jnp.int32),
        _sds((n + 1,), jnp.float32), _sds((n + 1,), jnp.float32),
    )
    bs = NamedSharding(mesh, spec.block_spec())
    rep = NamedSharding(mesh, P())
    # run is already jitted; expose the underlying callable + shardings
    return Cell(arch, shape.name, "match", run, (blk, blk, blkf, astate),
                (bs, bs, bs, MatchState(rep, rep, rep, rep)),
                note=f"AWAC distributed rounds, n={n}, m~{m}, cap/blk={cap}")


# --------------------------------- entry ------------------------------------


def build_cell(arch: str, shape_name: str, mesh, router: str | None = None,
               cfg_override=None, variants: tuple = ()):
    cfg = cfg_override or get_config(arch)
    if cfg_override is None and cfg.family == "lm" and cfg.moe is not None \
            and router:
        cfg = get_config(arch, router=router)
    if "escn_sub" in variants and cfg.family == "gnn" \
            and cfg.kind == "equiformer_v2":
        import dataclasses

        cfg = dataclasses.replace(
            cfg, extra=cfg.extra + (("escn_subspace", True),))
    shape = next(s for s in shapes_for(cfg) if s.name == shape_name)
    fam = cfg.family
    if fam == "lm":
        return _lm_cells(arch, cfg, shape, mesh, variants)
    if fam == "gnn":
        return _gnn_cells(arch, cfg, shape, mesh, variants)
    if fam == "recsys":
        return _recsys_cells(arch, cfg, shape, mesh)
    if fam == "matching":
        return _matching_cells(arch, cfg, shape, mesh,
                               packed=("packed_a2a" in variants))
    raise ValueError(fam)


def all_cells(arch: str):
    cfg = get_config(arch)
    return [s.name for s in shapes_for(cfg)]
