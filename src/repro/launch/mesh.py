"""Production mesh + per-family sharding rules.

Importing this module never touches jax device state (the mesh is built by a
FUNCTION, per the dry-run contract)."""
from __future__ import annotations

import jax

try:  # jax >= 0.6: meshes carry explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: every axis is Auto already
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def _divisible(dim: int, mesh, axes) -> bool:
    import numpy as np

    k = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple)
                                             else (axes,))]))
    return dim % k == 0


def lm_param_rules(cfg, mesh, variants=()) -> dict:
    """Logical-axis -> mesh-axis rules for LM parameter trees.
    TP over "model" (heads / mlp / vocab), FSDP over "data" (embed dim),
    EP over "pod" when the expert count divides; the "moe_ep" variant moves
    EP onto the "model" axis (expert_mlp then stays unsharded)."""
    rules = {
        "vocab": "model",
        "mlp": "model",
        "expert_mlp": "model",
        "embed": "data" if cfg.d_model % mesh.shape["data"] == 0 else None,
        "heads": "model" if _divisible(cfg.n_heads * cfg.hd, mesh, "model")
        else None,
        "kv_heads": "model"
        if _divisible(cfg.n_kv_heads * cfg.hd, mesh, "model") else None,
        "experts": None,
    }
    if cfg.moe is not None:
        if any(str(v).startswith("moe_ep") for v in variants) \
                and cfg.moe.n_experts % mesh.shape["model"] == 0:
            rules["experts"] = "model"
            rules["expert_mlp"] = None
        elif "pod" in mesh.axis_names \
                and cfg.moe.n_experts % mesh.shape["pod"] == 0:
            rules["experts"] = "pod"
    return rules


def gnn_param_rules(cfg, mesh) -> dict:
    d = cfg.d_hidden
    ok = d % mesh.shape["model"] == 0
    return {"embed": None, "mlp": "model" if ok else None, "experts": None,
            "vocab": None, "heads": None, "kv_heads": None}


def recsys_param_rules(cfg, mesh) -> dict:
    # tables are row-sharded over "model"; batch uses ALL axes (B >> d)
    return {"vocab": "model", "embed": None, "mlp": None, "heads": None,
            "kv_heads": None, "experts": None}


def matching_rules(mesh) -> dict:
    return {}


def param_rules_for(cfg, mesh) -> dict:
    return {
        "lm": lm_param_rules,
        "gnn": gnn_param_rules,
        "recsys": recsys_param_rules,
    }[cfg.family](cfg, mesh)
