"""Production training launcher (CLI).

On real hardware every host runs this with jax.distributed configured; here
it runs any --arch at reduced scale on CPU (full configs need the fleet; the
512-chip program itself is validated by launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
      --reduced --steps 50 [--router awpm] [--ckpt-dir /tmp/ckpt]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import shapes_for
from repro.data.tokens import TokenPipeline
from repro.models import build_defs, build_loss
from repro.models.param import count_params, init_params
from repro.runtime.straggler import StragglerMonitor
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig


def _data_fn(cfg, batch, seq, seed=0):
    if cfg.family == "lm":
        pipe = TokenPipeline(cfg.vocab, batch, seq, seed=seed)
        return pipe.batch
    if cfg.family == "recsys":
        def fn(step):
            rng = np.random.default_rng((seed, step))
            seqs = rng.integers(0, cfg.n_items, (batch, cfg.seq_len))
            mask = (rng.random((batch, cfg.seq_len)) < 0.2)
            return {"item_seq": seqs.astype(np.int32),
                    "labels": seqs.astype(np.int32),
                    "mask": mask.astype(np.float32)}
        return fn
    if cfg.family == "gnn":
        from repro.data import graphs as G

        def fn(step):
            if cfg.kind == "graphcast":
                return G.random_graphcast_batch(256, cfg.opt("n_vars", 12),
                                                seed=step)
            return G.random_graph(
                128, 512, 16, n_classes=7, seed=step,
                coords=cfg.kind in ("dimenet", "equiformer_v2"),
                triplets=cfg.kind == "dimenet")
        return fn
    raise ValueError(cfg.family)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--router", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    kw = {"router": args.router} if args.router else {}
    cfg = get_config(args.arch, reduced=args.reduced, **kw)
    shape = shapes_for(cfg)[0]
    if cfg.family == "gnn":  # reduced training uses small synthetic graphs
        from repro.configs.base import ShapeSpec

        shape = ShapeSpec(shape.name, "train", (("d_feat", 16),))
    defs = build_defs(cfg, shape)
    print(f"{cfg.name}: {count_params(defs) / 1e6:.2f}M params")
    params = init_params(defs, jax.random.PRNGKey(0))
    mgr = CheckpointManager(args.ckpt_dir, async_save=True) \
        if args.ckpt_dir else None
    data_fn = _data_fn(cfg, args.batch, args.seq)
    if cfg.family == "gnn":
        raw = data_fn

        def data_fn(step):  # noqa: F811 — to-device conversion for pytrees
            return jax.tree.map(jnp.asarray, raw(step))

    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    params, _, hist = train(params, build_loss(cfg), data_fn, opt,
                            n_steps=args.steps, log_every=10,
                            checkpoint_mgr=mgr,
                            checkpoint_every=max(args.steps // 2, 1),
                            straggler_monitor=StragglerMonitor())
    if mgr:
        mgr.wait()
    print(f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
