"""Dependency-light Matrix Market (``.mtx``) reader/writer (DESIGN.md §8).

The paper evaluates AWPM on SuiteSparse instances, which ship in Matrix
Market coordinate format; this module is the ingestion path from those
files into :class:`repro.core.MatchingProblem` — pure numpy + text parsing,
no scipy.io dependency, so the data layer works wherever the engine does.

Supported dialect (the one every SuiteSparse sparse matrix uses):

  %%MatrixMarket matrix coordinate {real|integer|pattern|complex}
                 {general|symmetric|skew-symmetric|hermitian}

- ``coordinate`` only (the dense ``array`` format is rejected — a dense
  dump is not a sparse-solver workload).
- ``complex`` entries carry four tokens (i j re im) and parse into a
  complex128 value array; matching weights stay real via the magnitude
  pre-transform in :func:`load_problem` (``w = |a_ij|`` feeds the weight
  transform) while the complex values ride along for the solver path
  (``repro.solver`` factorizes them as-is). ``hermitian`` storage
  requires the complex field, must keep a real diagonal, and expands by
  mirroring with the conjugate.
- symmetric storage holds one triangle; :func:`read_mtx` expands it to
  general by mirroring off-diagonal entries (skew-symmetric mirrors with
  negated value and must not carry diagonal entries).
- repeated coordinates are legal on read and assembled by summation
  (:func:`repro.sparse.csr.dedupe_coo_sum`) in :func:`load_problem`, the
  Matrix Market assembly convention.

Values are parsed into float64 exactly as written; :func:`write_mtx` emits
shortest round-tripping reprs, so read -> write -> read is bit-equal
(tests/test_mtx.py pins this).
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np

BANNER = "%%MatrixMarket"
FIELDS = ("real", "integer", "pattern", "complex")
SYMMETRIES = ("general", "symmetric", "skew-symmetric", "hermitian")

__all__ = [
    "FIELDS",
    "SYMMETRIES",
    "CooMatrix",
    "MatrixMarketError",
    "load_problem",
    "read_mtx",
    "write_mtx",
]


class MatrixMarketError(ValueError):
    """Malformed or unsupported .mtx content (always names the file/line)."""


@dataclasses.dataclass
class CooMatrix:
    """Parsed coordinate matrix: 0-based indices, float64 values.

    ``field``/``symmetry`` record the header as stored in the file;
    ``expanded`` says whether symmetric storage has already been mirrored
    into general form (the default on read). Entries keep file order —
    sorting/dedup happens in :func:`load_problem` via the repo's canonical
    COO pipeline.
    """

    nrows: int
    ncols: int
    row: np.ndarray  # [nnz] int64, 0-based
    col: np.ndarray  # [nnz] int64, 0-based
    val: np.ndarray  # [nnz] float64 (complex128 for the 'complex' field;
    # pattern entries read as 1.0)
    field: str
    symmetry: str
    expanded: bool

    @property
    def nnz(self) -> int:
        return int(self.row.shape[0])

    @property
    def is_square(self) -> bool:
        return self.nrows == self.ncols


def _err(path, lineno, msg) -> MatrixMarketError:
    return MatrixMarketError(f"{path}:{lineno}: {msg}")


def _parse_header(path, line: str) -> tuple[str, str]:
    tokens = line.split()
    if not line.startswith(BANNER) or len(tokens) != 5:
        raise _err(path, 1, f"bad Matrix Market banner {line.strip()!r}: "
                            f"expected '{BANNER} matrix coordinate "
                            f"<field> <symmetry>'")
    _, obj, fmt, field, symmetry = (t.lower() for t in tokens)
    if obj != "matrix":
        raise _err(path, 1, f"unsupported object {obj!r} (only 'matrix')")
    if fmt != "coordinate":
        raise _err(path, 1, f"unsupported format {fmt!r}: only the sparse "
                            f"'coordinate' format is supported (dense "
                            f"'array' dumps are not a sparse workload)")
    if field not in FIELDS:
        raise _err(path, 1, f"unsupported field {field!r}: expected one of "
                            f"{FIELDS}")
    if symmetry not in SYMMETRIES:
        raise _err(path, 1, f"unsupported symmetry {symmetry!r}: expected "
                            f"one of {SYMMETRIES}")
    if symmetry == "hermitian" and field != "complex":
        raise _err(path, 1, f"'hermitian' symmetry requires the 'complex' "
                            f"field (got {field!r}); real hermitian IS "
                            f"symmetric — declare it so")
    if field == "pattern" and symmetry == "skew-symmetric":
        raise _err(path, 1, "'pattern' entries carry no sign, so "
                            "'skew-symmetric' storage is meaningless")
    return field, symmetry


def read_mtx(path, expand_symmetry: bool = True) -> CooMatrix:
    """Parse a Matrix Market coordinate file (see module docstring for the
    supported dialect). With ``expand_symmetry`` (default), symmetric /
    skew-symmetric storage is mirrored into explicit general-form entries."""
    path = pathlib.Path(path)
    lines = path.read_text().splitlines()
    if not lines:
        raise _err(path, 1, "empty file (missing Matrix Market banner)")
    field, symmetry = _parse_header(path, lines[0])

    want = {"pattern": 2, "complex": 4}.get(field, 3)
    size = None
    rows, cols, vals = [], [], []
    for lineno, line in enumerate(lines[1:], start=2):
        stripped = line.strip()
        if not stripped or stripped.startswith("%"):
            continue
        tokens = stripped.split()
        if size is None:  # size line: nrows ncols nnz
            try:
                nrows, ncols, nnz = (int(t) for t in tokens)
            except ValueError:
                raise _err(path, lineno, f"bad size line {stripped!r}: "
                                         f"expected 'nrows ncols nnz'") from None
            if len(tokens) != 3 or min(nrows, ncols) < 0 or nnz < 0:
                raise _err(path, lineno, f"bad size line {stripped!r}: "
                                         f"expected 'nrows ncols nnz'")
            size = (nrows, ncols, nnz)
            continue
        if len(rows) >= size[2]:
            raise _err(path, lineno, f"more than the declared {size[2]} "
                                     f"entries (unexpected line {stripped!r})")
        if len(tokens) != want:
            raise _err(path, lineno, f"expected {want} tokens per "
                                     f"{field!r} entry, got {stripped!r}")
        try:
            i, j = int(tokens[0]), int(tokens[1])
            if field == "pattern":
                v = 1.0
            elif field == "integer":
                v = float(int(tokens[2]))
            elif field == "complex":
                v = complex(float(tokens[2]), float(tokens[3]))
            else:
                v = float(tokens[2])
        except ValueError:
            raise _err(path, lineno, f"bad {field!r} entry {stripped!r}") from None
        parts = (v.real, v.imag) if field == "complex" else (v,)
        if any(p != p or p in (float("inf"), float("-inf")) for p in parts):
            # python's float() happily parses 'nan'/'inf'; a non-finite
            # weight poisons every downstream comparison (preflight would
            # flag it later, but the file position is only known here)
            bad = next(t for p, t in zip(parts, tokens[2:])
                       if p != p or p in (float("inf"), float("-inf")))
            raise _err(path, lineno, f"non-finite value {bad!r}: matching "
                                     f"weights must be finite")
        if not (1 <= i <= size[0] and 1 <= j <= size[1]):
            raise _err(path, lineno, f"index ({i}, {j}) outside the declared "
                                     f"{size[0]} x {size[1]} shape (Matrix "
                                     f"Market indices are 1-based)")
        rows.append(i - 1)
        cols.append(j - 1)
        vals.append(v)
    if size is None:
        raise _err(path, len(lines), "missing size line 'nrows ncols nnz'")
    if len(rows) != size[2]:
        raise _err(path, len(lines), f"declared {size[2]} entries but "
                                     f"found {len(rows)}")

    row = np.asarray(rows, np.int64)
    col = np.asarray(cols, np.int64)
    val = np.asarray(vals,
                     np.complex128 if field == "complex" else np.float64)
    expanded = False
    if expand_symmetry and symmetry != "general":
        if size[0] != size[1]:
            raise _err(path, 1, f"{symmetry!r} matrix must be square, "
                                f"got {size[0]} x {size[1]}")
        # one-triangle storage is the contract (the MM spec says lower; we
        # accept either, but MIXED triangles would silently double every
        # mirrored weight after expansion + duplicate assembly)
        if (row > col).any() and (row < col).any():
            lo = int(np.nonzero(row > col)[0][0])
            up = int(np.nonzero(row < col)[0][0])
            raise _err(path, 1,
                       f"{symmetry!r} storage must hold ONE triangle, but "
                       f"both carry entries (lower: ({int(row[lo]) + 1}, "
                       f"{int(col[lo]) + 1}), upper: ({int(row[up]) + 1}, "
                       f"{int(col[up]) + 1})) — expanding would double "
                       f"mirrored weights")
        off = row != col
        if symmetry == "skew-symmetric":
            if (~off).any():
                k = int(np.nonzero(~off)[0][0])
                raise _err(path, 1, f"skew-symmetric file stores an explicit "
                                    f"diagonal entry ({int(row[k]) + 1}, "
                                    f"{int(col[k]) + 1}) — the diagonal is "
                                    f"implicitly zero")
            mirror_val = -val[off]
        elif symmetry == "hermitian":
            # A = A^H forces a real diagonal; a complex one is a malformed
            # file, not a representable matrix
            bad_diag = (~off) & (val.imag != 0.0)
            if bad_diag.any():
                k = int(np.nonzero(bad_diag)[0][0])
                raise _err(path, 1, f"hermitian diagonal entry "
                                    f"({int(row[k]) + 1}, {int(col[k]) + 1}) "
                                    f"has a nonzero imaginary part "
                                    f"({val[k].imag!r}) — A = A^H forces a "
                                    f"real diagonal")
            mirror_val = np.conj(val[off])
        else:
            mirror_val = val[off]
        row, col = (np.concatenate([row, col[off]]),
                    np.concatenate([col, row[off]]))
        val = np.concatenate([val, mirror_val])
        expanded = True
    return CooMatrix(nrows=size[0], ncols=size[1], row=row, col=col, val=val,
                     field=field, symmetry=symmetry, expanded=expanded)


def _fmt_value(v: float) -> str:
    # repr(float) is the shortest string that parses back to the same bits,
    # so the read -> write -> read round trip is exact
    return repr(float(v))


def write_mtx(path, row, col, val=None, shape=None, field: str | None = None,
              symmetry: str = "general", comment: str | None = None) -> None:
    """Write COO triples (0-based) as a Matrix Market coordinate file.

    ``val=None`` (or ``field="pattern"``) writes a pattern matrix. For
    symmetric/skew-symmetric output the caller passes one triangle — the
    entries are written exactly as given (matching how :func:`read_mtx`
    returns them under ``expand_symmetry=False``).
    """
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    if field is None:
        field = "pattern" if val is None else (
            "complex" if np.iscomplexobj(np.asarray(val)) else "real")
    if field not in FIELDS:
        raise MatrixMarketError(f"unsupported field {field!r}: expected one "
                                f"of {FIELDS}")
    if symmetry not in SYMMETRIES:
        raise MatrixMarketError(f"unsupported symmetry {symmetry!r}: "
                                f"expected one of {SYMMETRIES}")
    if symmetry == "hermitian" and field != "complex":
        raise MatrixMarketError(
            f"'hermitian' symmetry requires the 'complex' field (got "
            f"{field!r}) — read_mtx would reject the file")
    if field != "pattern":
        if val is None:
            raise MatrixMarketError(f"field {field!r} needs values")
        val = np.asarray(val)
        if val.shape != row.shape:
            raise MatrixMarketError(
                f"val shape {val.shape} != index shape {row.shape}")
        if not np.isfinite(val).all():
            k = int(np.nonzero(~np.isfinite(val))[0][0])
            raise MatrixMarketError(
                f"non-finite value {val[k]!r} at entry {k} — read_mtx "
                f"would reject the file")
        if field == "integer" and not np.all(val == np.trunc(val)):
            raise MatrixMarketError("field 'integer' needs integral values")
        if symmetry == "hermitian":
            bad = (row == col) & (np.asarray(val).imag != 0.0)
            if bad.any():
                k = int(np.nonzero(bad)[0][0])
                raise MatrixMarketError(
                    f"hermitian diagonal entry ({int(row[k]) + 1}, "
                    f"{int(col[k]) + 1}) has a nonzero imaginary part — "
                    f"read_mtx would reject the file")
    if shape is None:
        shape = (int(row.max()) + 1 if row.size else 0,
                 int(col.max()) + 1 if col.size else 0)
    nrows, ncols = (int(s) for s in shape)
    if row.size and (row.min() < 0 or col.min() < 0 or
                     row.max() >= nrows or col.max() >= ncols):
        raise MatrixMarketError(f"indices outside shape {nrows} x {ncols}")
    if symmetry != "general" and (row > col).any() and (row < col).any():
        raise MatrixMarketError(
            f"{symmetry!r} output must store ONE triangle, got entries in "
            f"both (read_mtx would reject the file)")

    out = [f"{BANNER} matrix coordinate {field} {symmetry}"]
    for line in (comment or "").splitlines():
        out.append(f"% {line}".rstrip())
    out.append(f"{nrows} {ncols} {row.shape[0]}")
    if field == "pattern":
        out.extend(f"{i + 1} {j + 1}" for i, j in zip(row, col))
    elif field == "integer":
        out.extend(f"{i + 1} {j + 1} {int(v)}"
                   for i, j, v in zip(row, col, val))
    elif field == "complex":
        out.extend(
            f"{i + 1} {j + 1} {_fmt_value(v.real)} {_fmt_value(v.imag)}"
            for i, j, v in zip(row, col, val))
    else:
        out.extend(f"{i + 1} {j + 1} {_fmt_value(v)}"
                   for i, j, v in zip(row, col, val))
    pathlib.Path(path).write_text("\n".join(out) + "\n")


def load_problem(path, transform="abs", capacity: int | None = None,
                 drop_zeros: bool = True):
    """Read ``path`` and build a :class:`repro.core.MatchingProblem`.

    Pipeline: parse (+ symmetric/hermitian expansion) -> assemble
    duplicates by summation -> drop explicit / cancelled zeros (MC64
    treats them as non-edges, and the log-scaled metric is undefined on
    them) -> magnitude pre-transform for complex fields (matching weights
    are ``|a_ij|``; the complex values stay on the returned ``coo`` for
    the solver path) -> apply the weight ``transform`` (a name from
    :data:`repro.data.weight_transforms.TRANSFORMS`, a callable
    ``(row, col, val, n) -> val``, or None for raw values) -> pad/sort via
    ``MatchingProblem.from_coo``.

    Returns ``(problem, coo)`` — the problem plus the parsed
    :class:`CooMatrix`. For real fields ``coo`` holds the file's values
    verbatim (pre-transform); for complex fields ``coo.val`` is
    complex128 after assembly, and only the matching-side weights are
    collapsed to magnitudes.
    """
    from repro.core.api import MatchingProblem
    from repro.data.weight_transforms import get_transform
    from repro.sparse.csr import dedupe_coo_sum

    coo = read_mtx(path, expand_symmetry=True)
    if not coo.is_square:
        raise MatrixMarketError(
            f"{path}: perfect matching needs a square matrix, got "
            f"{coo.nrows} x {coo.ncols}")
    n = coo.nrows
    row, col, val = dedupe_coo_sum(coo.row, coo.col, coo.val, n_cols=n)
    if drop_zeros:
        keep = val != 0.0
        row, col, val = row[keep], col[keep], val[keep]
    if np.iscomplexobj(val):
        # magnitude pre-transform: the matching engine needs real weights,
        # the solver path keeps the complex values (returned on coo after
        # assembly so downstream consumers see what load_problem matched on)
        coo = dataclasses.replace(coo, row=row, col=col, val=val)
        weights = np.abs(val)
    else:
        weights = val
    if transform is not None:
        weights = get_transform(transform)(row, col, weights, n)
    problem = MatchingProblem.from_coo(row, col, weights, n,
                                       capacity=capacity)
    return problem, coo
