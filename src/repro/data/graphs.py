"""Synthetic graph generators for the GNN shape cells (smoke tests, examples,
benchmarks). The dry-run never materializes these — launch/input_specs.py
computes the same SIZES symbolically (keep `sampled_sizes`/`graphcast_sizes`
in sync: they are shared here)."""
from __future__ import annotations

import numpy as np

from repro.models.gnn.common import GraphBatch
from repro.models.gnn.graphcast import GraphCastBatch

TRIPLET_FACTOR = 8


def sampled_sizes(batch_nodes: int, fanouts):
    """Node/edge counts of a SampledBlocks batch (leaf-to-root layers)."""
    layers = [batch_nodes]
    for f in fanouts:
        layers.append(layers[-1] * f)
    layers = layers[::-1]
    n_nodes = sum(layers)
    n_edges = sum(layers[:-1])
    return n_nodes, n_edges


def graphcast_sizes(n_grid: int):
    n_mesh = max(n_grid // 16, 4)
    return {"n_mesh": n_mesh, "e_g2m": n_grid * 2, "e_mesh": n_mesh * 7,
            "e_m2g": n_grid * 3}


def random_graph(n_nodes, n_edges, d_feat, n_classes=40, seed=0, coords=False,
                 n_graphs=1, triplets=False):
    """Uniform random directed graph; optional 3D coords + DimeNet triplet
    lists (capacity TRIPLET_FACTOR * n_edges)."""
    rng = np.random.default_rng(seed)
    if n_graphs > 1:
        per = n_nodes // n_graphs
        gid = np.repeat(np.arange(n_graphs), per).astype(np.int32)
        src = (rng.integers(0, per, n_edges)
               + np.repeat(np.arange(n_graphs), n_edges // n_graphs) * per)
        dst = (rng.integers(0, per, n_edges)
               + np.repeat(np.arange(n_graphs), n_edges // n_graphs) * per)
    else:
        gid = np.zeros(n_nodes, np.int32)
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
    src = src.astype(np.int32)
    dst = dst.astype(np.int32)
    feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    if n_graphs > 1:
        labels = rng.normal(size=(n_graphs, 1)).astype(np.float32)
    else:
        labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    xyz = rng.normal(size=(n_nodes, 3)).astype(np.float32) if coords else None
    tri = None
    if triplets:
        cap = TRIPLET_FACTOR * n_edges
        by_dst = {}
        for e, d in enumerate(dst):
            by_dst.setdefault(int(d), []).append(e)
        kj, ji = [], []
        for e, s in enumerate(src):
            for e2 in by_dst.get(int(s), [])[:TRIPLET_FACTOR]:
                if e2 != e:
                    kj.append(e2)
                    ji.append(e)
        kj = np.array(kj[:cap] + [n_edges] * max(0, cap - len(kj)), np.int32)
        ji = np.array(ji[:cap] + [n_edges] * max(0, cap - len(ji)), np.int32)
        tri = (kj, ji)
    return GraphBatch(node_feat=feat, edge_src=src, edge_dst=dst, labels=labels,
                      coords=xyz, graph_id=gid, triplets=tri, n_graphs=n_graphs)


def random_graphcast_batch(n_grid, n_vars, seed=0):
    rng = np.random.default_rng(seed)
    sz = graphcast_sizes(n_grid)
    nm = sz["n_mesh"]
    return GraphCastBatch(
        grid_feat=rng.normal(size=(n_grid, n_vars)).astype(np.float32),
        g2m_src=rng.integers(0, n_grid, sz["e_g2m"]).astype(np.int32),
        g2m_dst=rng.integers(0, nm, sz["e_g2m"]).astype(np.int32),
        mesh_src=rng.integers(0, nm, sz["e_mesh"]).astype(np.int32),
        mesh_dst=rng.integers(0, nm, sz["e_mesh"]).astype(np.int32),
        m2g_src=rng.integers(0, nm, sz["e_m2g"]).astype(np.int32),
        m2g_dst=rng.integers(0, n_grid, sz["e_m2g"]).astype(np.int32),
        target=rng.normal(size=(n_grid, n_vars)).astype(np.float32),
        n_mesh=nm,
    )
