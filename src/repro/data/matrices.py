"""Matrix pipeline for the matching core: generation, real-matrix ingestion,
weight metrics, and 2D distribution. (Generators live in repro.core.graph,
Matrix Market I/O in repro.data.mtx, transforms in
repro.data.weight_transforms; this module is the data-pipeline facade used
by benchmarks/examples/experiments.)"""
from repro.core.graph import SUITE_KINDS, generate, matrix_suite, normalize_rowcol_max
from repro.data.mtx import CooMatrix, MatrixMarketError, load_problem, read_mtx, write_mtx
from repro.data.weight_transforms import TRANSFORMS, compose, get_transform
from repro.sparse.partition import partition_coo_2d

__all__ = [
    "SUITE_KINDS",
    "TRANSFORMS",
    "CooMatrix",
    "MatrixMarketError",
    "compose",
    "generate",
    "get_transform",
    "load_problem",
    "matrix_suite",
    "normalize_rowcol_max",
    "partition_coo_2d",
    "read_mtx",
    "write_mtx",
]
