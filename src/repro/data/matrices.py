"""Matrix pipeline for the matching core: generation + 2D distribution.
(The generators live in repro.core.graph; this module is the data-pipeline
facade used by benchmarks/examples.)"""
from repro.core.graph import SUITE_KINDS, generate, matrix_suite, normalize_rowcol_max
from repro.sparse.partition import partition_coo_2d

__all__ = [
    "SUITE_KINDS",
    "generate",
    "matrix_suite",
    "normalize_rowcol_max",
    "partition_coo_2d",
]
