"""Opt-in SuiteSparse Matrix Collection downloader (DESIGN.md §12).

The paper evaluates AWPM on SuiteSparse instances (Table 6.2-style circuit
/ device / PDE families); the checked-in ``tests/data/*.mtx`` fixtures are
small synthetic stand-ins so CI never touches the network. This module is
the explicit escape hatch: ``experiments/run_paper_eval.py --download``
(and ``results/fill_experiments.py --download``) fetch the named instances
into a local cache and sweep them like any other ``.mtx`` case.

Design constraints, in order:

- **Opt-in only.** Nothing in this repo imports urllib at module scope or
  downloads implicitly; CI stays on fixtures. A download happens only when
  a user passes ``--download``.
- **Checksummed.** Every download is sha256-hashed. Instances with a
  pinned hash in :data:`PAPER_INSTANCES` are verified against it;
  unpinned instances are pinned trust-on-first-use into
  ``<cache>/checksums.json`` so any later re-download (or a tampered
  cache) fails loudly instead of silently shifting results.
- **Offline-friendly errors.** A network failure raises
  :class:`SuiteSparseUnavailable` naming the URL, the cache dir, and the
  fact that the fixture path needs no network — never a bare URLError
  half-way through a sweep.

Cache layout: ``<cache>/<Group>/<name>.tar.gz`` (as served) plus the
extracted ``<cache>/<Group>/<name>/<name>.mtx``. Default cache dir is
``$REPRO_SUITESPARSE_CACHE`` or ``~/.cache/repro-suitesparse``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import tarfile

__all__ = [
    "PAPER_INSTANCES",
    "SuiteSparseInstance",
    "SuiteSparseUnavailable",
    "cache_dir",
    "fetch",
    "fetch_paper_instances",
    "local_path",
]

BASE_URL = "https://sparse.tamu.edu/MM"


class SuiteSparseUnavailable(RuntimeError):
    """Download failed (offline runner, proxy, bad URL) or a checksum
    mismatched. The message always says how to proceed without the
    network (the checked-in fixtures need none)."""


@dataclasses.dataclass(frozen=True)
class SuiteSparseInstance:
    """One collection entry: ``group/name`` plus an optional pinned
    sha256 of the ``.tar.gz`` as served. ``sha256=None`` means
    trust-on-first-use: the first verified download records the hash in
    the cache's ``checksums.json``."""

    name: str
    group: str
    sha256: str | None = None

    @property
    def url(self) -> str:
        return f"{BASE_URL}/{self.group}/{self.name}.tar.gz"


#: The paper's evaluation families (Azad et al. §6, Table 6.2-style):
#: circuit-simulation matrices (the MC64-hard family with magnitudes
#: spanning many decades), device/EM, and large PDE instances. Hashes are
#: pinned trust-on-first-use per cache (the collection serves stable
#: tarballs but republishes occasionally; a pin here would rot, a pin in
#: the user's cache is exactly as fresh as their data).
PAPER_INSTANCES = (
    SuiteSparseInstance("Freescale1", "Freescale"),
    SuiteSparseInstance("memchip", "Freescale"),
    SuiteSparseInstance("rajat31", "Rajat"),
    SuiteSparseInstance("circuit5M", "Freescale"),
    SuiteSparseInstance("cage14", "vanHeukelum"),
    SuiteSparseInstance("torso1", "Norris"),
    SuiteSparseInstance("dielFilterV3real", "Dziekonski"),
    SuiteSparseInstance("nlpkkt80", "Schenk_IBMNA"),
    SuiteSparseInstance("Serena", "Janna"),
    SuiteSparseInstance("audikw_1", "GHS_psdef"),
    SuiteSparseInstance("ldoor", "GHS_psdef"),
    SuiteSparseInstance("HV15R", "Fluorem"),
)

_BY_NAME = {inst.name: inst for inst in PAPER_INSTANCES}


def cache_dir(override=None) -> pathlib.Path:
    """Resolve the cache directory (override > env > default)."""
    if override is not None:
        return pathlib.Path(override)
    env = os.environ.get("REPRO_SUITESPARSE_CACHE")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro-suitesparse"


def _resolve(name) -> SuiteSparseInstance:
    if isinstance(name, SuiteSparseInstance):
        return name
    if name in _BY_NAME:
        return _BY_NAME[name]
    if "/" in str(name):
        group, base = str(name).split("/", 1)
        return SuiteSparseInstance(base, group)
    raise KeyError(
        f"unknown SuiteSparse instance {name!r}: expected one of "
        f"{sorted(_BY_NAME)} or an explicit 'Group/name' spec")


def local_path(name, cache=None) -> pathlib.Path:
    """Where the extracted ``.mtx`` for ``name`` lives (existing or not)."""
    inst = _resolve(name)
    return cache_dir(cache) / inst.group / inst.name / f"{inst.name}.mtx"


def _sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _checksum_store(cache: pathlib.Path) -> pathlib.Path:
    return cache / "checksums.json"


def _verify(inst: SuiteSparseInstance, tarball: pathlib.Path,
            cache: pathlib.Path) -> None:
    """Registry pin > cached trust-on-first-use pin > record new pin."""
    digest = _sha256(tarball)
    store_path = _checksum_store(cache)
    store = {}
    if store_path.exists():
        store = json.loads(store_path.read_text())
    expected = inst.sha256 or store.get(f"{inst.group}/{inst.name}")
    if expected is not None:
        if digest != expected:
            raise SuiteSparseUnavailable(
                f"sha256 mismatch for {inst.group}/{inst.name}: got "
                f"{digest}, pinned {expected}. The collection republished "
                f"the tarball or the download was corrupted — delete "
                f"{tarball} (and the pin in {store_path} if you trust the "
                f"new file) to re-fetch.")
        return
    store[f"{inst.group}/{inst.name}"] = digest
    store_path.parent.mkdir(parents=True, exist_ok=True)
    store_path.write_text(json.dumps(store, indent=1, sort_keys=True))


def _download(url: str, dest: pathlib.Path, timeout: float) -> None:
    import urllib.error
    import urllib.request

    tmp = dest.with_suffix(dest.suffix + ".part")
    try:
        with urllib.request.urlopen(url, timeout=timeout) as resp, \
                open(tmp, "wb") as out:
            while True:
                chunk = resp.read(1 << 20)
                if not chunk:
                    break
                out.write(chunk)
        tmp.replace(dest)
    except (urllib.error.URLError, OSError, TimeoutError) as e:
        tmp.unlink(missing_ok=True)
        raise SuiteSparseUnavailable(
            f"could not download {url}: {e}. If this runner is offline "
            f"(CI is, by design), skip --download — the checked-in "
            f"tests/data fixtures cover the pipeline without any network. "
            f"A pre-populated cache at {dest.parent.parent} also works: "
            f"drop the extracted <name>.mtx files in place.") from e


def _extract_mtx(inst: SuiteSparseInstance, tarball: pathlib.Path,
                 out: pathlib.Path) -> None:
    """Pull ``<name>/<name>.mtx`` out of the collection tarball (which may
    also carry auxiliary ``<name>_b.mtx``-style files we ignore)."""
    want = f"{inst.name}/{inst.name}.mtx"
    with tarfile.open(tarball, "r:gz") as tf:
        member = next((m for m in tf.getmembers()
                       if m.isfile() and m.name.lstrip("./") == want), None)
        if member is None:
            names = [m.name for m in tf.getmembers()][:8]
            raise SuiteSparseUnavailable(
                f"{tarball} does not contain {want!r} (members: {names}...)")
        member.name = pathlib.Path(member.name).name  # no path traversal
        tf.extract(member, path=out.parent)


def fetch(name, cache=None, timeout: float = 120.0) -> pathlib.Path:
    """Return the local ``.mtx`` path for ``name``, downloading + verifying
    + extracting if the cache misses. ``name`` is a registry name, a
    ``Group/name`` spec, or a :class:`SuiteSparseInstance`."""
    inst = _resolve(name)
    cache_root = cache_dir(cache)
    mtx = local_path(inst, cache_root)
    if mtx.exists():
        return mtx
    mtx.parent.mkdir(parents=True, exist_ok=True)
    tarball = cache_root / inst.group / f"{inst.name}.tar.gz"
    if not tarball.exists():
        _download(inst.url, tarball, timeout)
    _verify(inst, tarball, cache_root)
    _extract_mtx(inst, tarball, mtx)
    if not mtx.exists():
        raise SuiteSparseUnavailable(
            f"extraction of {tarball} produced no {mtx}")
    return mtx


def fetch_paper_instances(names=None, cache=None) -> dict[str, pathlib.Path]:
    """Fetch several instances (default: the whole paper registry) and
    return ``{name: mtx_path}``. Failures are collected so one offline
    instance doesn't abort the rest — but if EVERY fetch failed, raise."""
    insts = [
        _resolve(n) for n in (names or [i.name for i in PAPER_INSTANCES])]
    out, errors = {}, []
    for inst in insts:
        try:
            out[inst.name] = fetch(inst, cache=cache)
        except SuiteSparseUnavailable as e:
            errors.append(str(e))
    if errors and not out:
        raise SuiteSparseUnavailable(
            "every SuiteSparse fetch failed:\n" + "\n".join(errors))
    for msg in errors:
        print(f"# suitesparse: SKIPPED — {msg}")
    return out
