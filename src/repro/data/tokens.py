"""Deterministic synthetic token pipeline for LM training.

Stateless per-step generation keyed on (seed, step) so restarts, elastic
re-sharding, and straggler skip-ahead all reproduce the same stream; each
host can generate only its data shard (host_index/host_count)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count

    def batch(self, step: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )
        # zipf-ish marginal + markov-ish structure so the loss is learnable
        base = rng.zipf(1.3, size=(self.local_batch, self.seq_len + 1))
        tokens = (base % self.vocab).astype(np.int32)
        tokens[:, 1::2] = (tokens[:, 0:-1:2] * 7 + 13) % self.vocab  # learnable
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:].astype(np.int32),
            "mask": np.ones((self.local_batch, self.seq_len), np.float32),
        }
