"""Composable edge-weight transforms (DESIGN.md §8).

The paper measures pivot quality in the MC64 log-scaled metric: MC64
minimizes the cost ``c_ij = log2(max_i |a_ij|) - log2(|a_ij|)`` (column max
over rows i), which is the same problem as maximizing
``w_ij = log2(|a_ij|) - log2(max_i |a_ij|)`` — the metric
:func:`log2_scaled` produces. Our engine maximizes, so that (non-positive)
weight plugs straight into ``solve()``; :func:`log2_scaled_nonneg` adds one
global constant so weights land in ``[0, shift]``, which changes NOTHING
the algorithm decides: every perfect matching has exactly n edges, so a
constant per-edge shift moves all perfect-matching weights by the same
``n * shift`` (ranking preserved), and every 4-cycle gain
``w1 + w2 - u - v`` is shift-invariant outright.

Every transform has the uniform signature ``(row, col, val, n) -> val`` on
host numpy arrays (float64 out), so they compose (:func:`compose`) and
thread through ``repro.data.mtx.load_problem(transform=...)`` by name.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import normalize_rowcol_max

__all__ = [
    "TRANSFORMS",
    "abs_value",
    "compose",
    "get_transform",
    "log2_scaled",
    "log2_scaled_nonneg",
    "mc64_cost",
    "rowcol_normalized",
]


def _colmax_abs(col, val, n):
    a = np.abs(np.asarray(val, np.float64))
    if (a == 0.0).any():
        raise ValueError(
            "log-scaled transform is undefined on zero entries — explicit "
            "zeros are non-edges (load_problem drops them by default)")
    cmax = np.zeros(n, np.float64)
    np.maximum.at(cmax, col, a)
    return a, cmax


def abs_value(row, col, val, n):
    """|a_ij| — the weight the synthetic suite uses pre-normalization."""
    return np.abs(np.asarray(val, np.float64))


def rowcol_normalized(row, col, val, n):
    """Paper §6.1 normalization: each row/column max is 1, entries <= 1."""
    return normalize_rowcol_max(np.asarray(row), np.asarray(col),
                                np.asarray(val)).astype(np.float64)


def log2_scaled(row, col, val, n):
    """``w_ij = log2|a_ij| - log2(max_i |a_ij|)`` (<= 0, column max = 0).

    Maximizing the sum of these weights over perfect matchings IS
    minimizing the MC64 cost :func:`mc64_cost` — the paper's quality
    metric for pivot selection."""
    a, cmax = _colmax_abs(col, val, n)
    return np.log2(a) - np.log2(cmax[col])


def log2_scaled_nonneg(row, col, val, n):
    """:func:`log2_scaled` lifted by one global constant into ``[0, shift]``.

    Decision-invariant (see module docstring), but keeps all weights
    non-negative so reported matching weights read naturally."""
    w = log2_scaled(row, col, val, n)
    return w - w.min() if w.size else w


def mc64_cost(row, col, val, n):
    """The MC64 minimization cost ``c_ij = log2(max_i|a_ij|) - log2|a_ij|``
    (>= 0). Exposed for reporting — feed :func:`log2_scaled` (its negation)
    to the maximizing engine instead."""
    return -log2_scaled(row, col, val, n)


TRANSFORMS = {
    "abs": abs_value,
    "rowcol": rowcol_normalized,
    "log2_scaled": log2_scaled,
    "log2_scaled_nonneg": log2_scaled_nonneg,
    "mc64_cost": mc64_cost,
}


def compose(*specs):
    """Left-to-right composition: ``compose("abs", "rowcol")`` applies abs
    first, then rowcol normalization. Each spec is a name or a callable."""
    fns = [get_transform(s) for s in specs]

    def composed(row, col, val, n):
        for fn in fns:
            val = fn(row, col, val, n)
        return val

    return composed


def get_transform(spec):
    """Resolve a transform spec: a callable passes through, a str looks up
    :data:`TRANSFORMS`, a sequence composes left-to-right."""
    if callable(spec):
        return spec
    if isinstance(spec, str):
        if spec not in TRANSFORMS:
            raise KeyError(f"unknown weight transform {spec!r}: expected "
                           f"one of {sorted(TRANSFORMS)} or a callable")
        return TRANSFORMS[spec]
    if isinstance(spec, (list, tuple)):
        return compose(*spec)
    raise TypeError(f"weight transform must be a name, callable, or "
                    f"sequence, got {type(spec).__name__}")
