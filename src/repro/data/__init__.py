"""Data pipelines: synthetic generators (graphs/matrices/tokens), real-matrix
ingestion (``repro.data.mtx``), the paper's weight metrics
(``repro.data.weight_transforms``), and the opt-in SuiteSparse downloader
(``repro.data.suitesparse`` — never touched by CI). The matching-side
facade is ``repro.data.matrices``."""
from repro.data import matrices, mtx, suitesparse, weight_transforms

__all__ = ["matrices", "mtx", "suitesparse", "weight_transforms"]
