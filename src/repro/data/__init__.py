"""Data pipelines: synthetic generators (graphs/matrices/tokens), real-matrix
ingestion (``repro.data.mtx``), and the paper's weight metrics
(``repro.data.weight_transforms``). The matching-side facade is
``repro.data.matrices``."""
from repro.data import matrices, mtx, weight_transforms

__all__ = ["matrices", "mtx", "weight_transforms"]
