"""Warm-start seed cache + seed-or-cold fallback.

Warm-start rematching is the streaming analogue of composable-coreset
seeding (Assadi et al., PAPERS.md): a caller's previous matching is a
near-perfect structure for its next, slightly-perturbed instance, so the
solve skips greedy + MCM and runs seed repair + bounded MCM top-up + AWAC
instead (``core.batch.warm_mates_batched`` via
``solve(..., warm_start=)``). This module holds the serving side of that:

  - :class:`WarmStartCache` — per-shard LRU of the last mate arrays per
    request key, stored at *size-class* padding so a seed drops straight
    into the next batch for the same class;
  - :func:`solve_with_seed` — call a matcher with a seed when one exists,
    falling back to the cold path (bit-identically — the cold call is the
    exact call an unseeded request would make) when the facade rejects the
    seed's shape as stale.

Seed *values* are never trusted anywhere: the engine-side repair unmatches
every pair that is stale against the current edge list, so a garbage seed
costs a wasted repair pass, never a wrong matching.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np


@dataclasses.dataclass
class WarmStats:
    """Seed-cache outcome counters."""

    served: int = 0  # lookups that returned a usable seed
    stale: int = 0  # entry existed but for a different size class
    absent: int = 0  # no entry for the key

    @property
    def hit_rate(self) -> float:
        total = self.served + self.stale + self.absent
        return self.served / total if total else 0.0


class WarmStartCache:
    """LRU of ``key -> (n_class, mate_row, mate_col)``.

    Mates are stored at the size-class padding ([n_class + 1], sentinel
    n_class) exactly as the batched engine emitted them, so ``seed_for``
    can hand them back into a same-class batch with zero reshaping. A
    lookup for a different ``n_class`` is *stale* (the caller's problem
    changed size class) and returns None — the facade would reject the
    shape anyway; staleness is decided here so the dispatcher can route
    the request down the cold lane up front.
    """

    def __init__(self, capacity: int = 4096):
        if not isinstance(capacity, int) or capacity < 1:
            raise ValueError(
                f"capacity must be a positive int, got {capacity!r}")
        self.capacity = capacity
        self.stats = WarmStats()
        self._entries: OrderedDict[str, tuple] = OrderedDict()

    def put(self, key: str, n_class: int, mate_row, mate_col) -> None:
        mr = np.array(mate_row, dtype=np.int32, copy=True)
        mc = np.array(mate_col, dtype=np.int32, copy=True)
        if mr.shape != (n_class + 1,) or mc.shape != (n_class + 1,):
            raise ValueError(
                f"seed mates must be [n_class + 1] = [{n_class + 1}], got "
                f"{mr.shape}/{mc.shape}")
        self._entries[key] = (int(n_class), mr, mc)
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def seed_for(self, key: str,
                 n_class: int) -> tuple[np.ndarray, np.ndarray] | None:
        entry = self._entries.get(key)
        if entry is None:
            self.stats.absent += 1
            return None
        if entry[0] != n_class:
            self.stats.stale += 1
            return None
        self._entries.move_to_end(key)
        self.stats.served += 1
        return entry[1], entry[2]

    def __len__(self) -> int:
        return len(self._entries)


def identity_mates(n: int) -> tuple[np.ndarray, np.ndarray]:
    """The diagonal matching (column j matched to row j) at padding n —
    the natural seed for the identity filler instances that pad a warm
    batch: a perfect AWAC fixed point, so fillers converge in one
    verification round."""
    eye = np.arange(n + 1, dtype=np.int32)
    return eye, eye.copy()


def solve_with_seed(matcher, problem, seed):
    """``matcher(problem, warm_start=seed)`` with a cold fallback.

    Returns ``(result, served_warm)``. A seed the facade rejects
    (ValueError: stale shape from a different n/batch; TypeError: not a
    mates-like object) falls back to the exact cold call an unseeded
    request would make — bit-identical to never having had a seed. Errors
    from the solve itself propagate: only *seed admission* is recoverable
    here.
    """
    if seed is not None:
        try:
            return matcher(problem, warm_start=seed), True
        except (TypeError, ValueError):
            pass
    return matcher(problem), False
