"""``python -m repro.serving`` — run the matching service against an
open-loop synthetic stream and print the serving report.

This is the service demo CLI the repo's launch story points at (the LM
serving stub in ``launch/serve.py`` is unrelated to matching). The knobs
mirror ``ServiceConfig`` + ``loadgen.StreamSpec``; the full measured
benchmark (with the warm-vs-cold differential and the JSON artifact the
CI gate checks) lives in ``benchmarks/bench_serving.py``.
"""
from __future__ import annotations

import argparse

from repro.serving.loadgen import StreamSpec, run_stream
from repro.serving.service import MatchingService, ServiceConfig


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving",
        description="open-loop demo of the matching service")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--users", type=int, default=16)
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--avg-degree", type=float, default=5.0)
    ap.add_argument("--rate", type=float, default=400.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--deadline-ms", type=float, default=2.0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--jitter", type=float, default=0.02,
                    help="relative weight perturbation per repeat")
    ap.add_argument("--churn", type=float, default=0.1,
                    help="P(drop one edge) per repeat")
    ap.add_argument("--kind", default="uniform")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warm", action="store_true",
                    help="disable warm-start rematching")
    ap.add_argument("--resilient", action="store_true",
                    help="serve through runtime.resilient rung chains")
    args = ap.parse_args(argv)

    service = MatchingService(ServiceConfig(
        num_shards=args.shards, deadline_s=args.deadline_ms / 1e3,
        max_batch=args.batch, warm_start=not args.no_warm,
        resilient=args.resilient))
    spec = StreamSpec(
        requests=args.requests, users=args.users, n=args.n,
        avg_degree=args.avg_degree, rate_rps=args.rate,
        weight_jitter=args.jitter, structure_churn=args.churn,
        kind=args.kind, seed=args.seed)
    summary = run_stream(service, spec)

    print(f"# open-loop stream: {spec.requests} requests, {spec.users} "
          f"users, n={spec.n}, {spec.rate_rps:.0f} rps offered")
    print(f"served        {summary['served']} "
          f"({summary['served_warm']} warm / {summary['served_cold']} cold, "
          f"{summary['degraded']} degraded, {summary['rejected']} rejected)")
    print(f"throughput    {summary['throughput_rps']:.1f} requests/s")
    print(f"latency       p50 {summary['p50_us']:.0f}us   "
          f"p95 {summary['p95_us']:.0f}us   p99 {summary['p99_us']:.0f}us")
    print(f"batch fill    {summary['mean_fill']:.2f} avg "
          f"(solve {summary['mean_solve_us']:.0f}us/batch avg)")
    stats = service.stats()
    print(f"plan cache    {stats['plan_resident']} resident, "
          f"{stats['plan_cache']['hits']} hits / "
          f"{stats['plan_cache']['misses']} misses")
    print(f"warm cache    {stats['warm_cache']['served']} seeds served, "
          f"{stats['warm_cache']['stale']} stale, "
          f"{stats['warm_cache']['absent']} absent")


if __name__ == "__main__":
    main()
