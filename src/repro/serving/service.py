"""The matching service front door: admission -> routing -> size-class
bucketing -> deadline batching -> batched (warm or cold) dispatch.

Request path (DESIGN.md §11):

  1. **Admission** — the instance is embedded into its size class and
     preflighted (``core.preflight``). Fatal data issues (NaN weights,
     duplicate edges) are sanitized (default) or rejected per
     ``ServiceConfig.admission``; structurally infeasible instances are
     admitted and served *degraded* — dispatch always runs with
     ``on_invalid="degrade"`` so one poisoned instance yields its own
     imperfect result instead of stalling (or poisoning) its batchmates.
  2. **Routing** — the request key is consistent-hashed to a shard
     (:class:`ShardRouter`, SNIPPETS.md §2 idiom). Shards model the units
     a real deployment would scale across: each shard has its own warm
     cache and its own batches (requests never co-batch across shards).
  3. **Size-class bucketing** — (n, nnz) maps onto a power-of-two ladder
     (:func:`size_class_for`): n is embedded up to the class n with
     degree-1 dummy diagonal edges of weight 0 (provably inert — a
     degree-1 row can never participate in a 4-cycle, and weight 0 adds
     nothing), cap is the padded-COO capacity. Bounding distinct classes
     bounds distinct XLA compiles; an oversize instance gets an exact
     class of batch 1 (dispatching immediately) rather than an unbounded
     padded one.
  4. **Deadline batching** — per (shard, class) queues fill [B, cap]
     batches until full or deadline (``serving.batcher``).
  5. **Dispatch** — the class's planned matcher comes from the LRU
     ``PlanCache``; the batch splits into a warm lane (requests holding a
     seed from the shard's ``WarmStartCache``) and a cold lane, each
     padded to B with identity filler instances; results are stripped
     back to each caller's true n and the fresh mates re-seed the warm
     cache.

Time is injected everywhere (``now=`` / a ``clock`` callable) so tests
and the open-loop benchmark drive a simulated clock through the exact
production code path; only the solve itself is measured on the real
clock.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any

import jax
import numpy as np

from repro.core import api as _api
from repro.core import graph as _graph
from repro.core import preflight as _preflight
from repro.serving.batcher import DeadlineBatcher, Flush
from repro.serving.plan_cache import PlanCache
from repro.serving.warm import WarmStartCache, identity_mates

_ALIGN = 8  # repo-wide COO pad alignment (graph.from_coo default)

#: admission policies for fatal preflight issues (non-finite weights,
#: duplicate edges): repair the data in place, or refuse the request.
ADMISSION = ("sanitize", "reject")


def _pow2_at_least(x: int, floor: int) -> int:
    x = max(int(x), int(floor), 1)
    return 1 << (x - 1).bit_length()


# --------------------------------------------------------------------------
# size classes + embedding
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, order=True)
class SizeClass:
    """One bucket of the compile ladder: instances embedded to ``n`` with
    edge capacity ``cap``, batched up to ``batch`` per dispatch."""

    n: int
    cap: int
    batch: int

    def __post_init__(self):
        if self.cap < self.n:
            raise ValueError(
                f"cap {self.cap} < n {self.n}: the class cannot hold its "
                f"own identity filler")


def size_class_for(n: int, nnz: int, *, min_class_n: int = 32,
                   max_class_n: int = 4096,
                   max_batch: int = 8) -> SizeClass:
    """Map an instance's (n, nnz) to its size class.

    Both n and cap ride a power-of-two ladder, so the number of distinct
    classes — and therefore compiled executables — grows logarithmically
    in the traffic's size spread. ``cap`` always covers the embedded edge
    count (nnz real + (class n - n) dummies) AND a full identity diagonal,
    so filler instances and infeasible-but-admitted instances always fit.
    An instance over ``max_class_n`` is served exactly (no embedding) in
    its own batch-1 class: padding it to the next power of two would cost
    more than the compile it saves.
    """
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if nnz < 0:
        raise ValueError(f"nnz must be >= 0, got {nnz}")
    if n > max_class_n:
        cap = max(_ALIGN, -(-max(nnz, n) // _ALIGN) * _ALIGN)
        return SizeClass(n=n, cap=cap, batch=1)
    n_class = _pow2_at_least(n, min_class_n)
    need = max(nnz + (n_class - n), n_class)
    return SizeClass(n=n_class, cap=_pow2_at_least(need, _ALIGN),
                     batch=max_batch)


def _real_edges(problem) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Unpack the real (non-padding) COO triples of a single instance
    (``BipartiteGraph`` or unbatched ``MatchingProblem``)."""
    if isinstance(problem, _graph.BipartiteGraph):
        n = problem.n
        row = np.asarray(problem.row, np.int32)
        col = np.asarray(problem.col, np.int32)
        val = np.asarray(problem.val, np.float32)
    elif isinstance(problem, _api.MatchingProblem):
        if problem.is_batched:
            raise ValueError(
                "the service batches for you — submit single instances, "
                f"got a batch of B={problem.batch_size}")
        n = problem.n
        row = np.asarray(problem.row, np.int32)
        col = np.asarray(problem.col, np.int32)
        val = np.asarray(problem.val, np.float32)
    else:
        raise TypeError(
            f"submit() takes a BipartiteGraph or MatchingProblem, got "
            f"{type(problem).__name__}")
    real = row < n
    return row[real], col[real], val[real], int(n)


def embed_instance(problem, cls: SizeClass) -> _api.MatchingProblem:
    """Embed a single instance into its size class: real edges plus a
    weight-0 dummy diagonal on rows/columns [n, class n). Dummies are
    degree-1 (their row and column carry exactly that one edge), so no
    4-cycle can route through them and the matching weight over real
    edges is untouched; the embedded instance is feasible iff the
    original is."""
    row, col, val, n = _real_edges(problem)
    if n > cls.n:
        raise ValueError(f"instance n={n} exceeds class n={cls.n}")
    extra = cls.n - n
    if extra:
        dummy = np.arange(n, cls.n, dtype=np.int32)
        row = np.concatenate([row, dummy])
        col = np.concatenate([col, dummy])
        val = np.concatenate([val, np.zeros(extra, np.float32)])
    if row.shape[0] > cls.cap:
        raise ValueError(
            f"embedded nnz {row.shape[0]} exceeds class cap {cls.cap}")
    g = _graph.from_coo(row, col, val, cls.n, capacity=cls.cap)
    return _api.MatchingProblem.from_graph(g)


def strip_instance(result: _api.MatchResult, index: int | None, n: int,
                   n_class: int) -> _api.MatchResult:
    """Undo the class embedding for one instance of a (batched) class
    result: slice mates back to [n + 1], remapping anything matched
    outside the real range (the class sentinel, or nothing at all for a
    degraded instance) to the sentinel n. Dummy edges weigh 0, so the
    reported weight is already the real-edge weight; ``perfect`` is
    recomputed over the real columns only."""
    def pick(x):
        a = np.asarray(x)
        return a[index] if index is not None else a

    mr_full, mc_full = pick(result.mate_row), pick(result.mate_col)
    mr = np.full(n + 1, n, np.int32)
    mc = np.full(n + 1, n, np.int32)
    mr[:n] = np.where(mr_full[:n] < n, mr_full[:n], n)
    mc[:n] = np.where(mc_full[:n] < n, mc_full[:n], n)
    return _api.MatchResult(
        mate_row=mr, mate_col=mc,
        weight=np.float32(pick(result.weight)),
        awac_iters=np.int32(pick(result.awac_iters)),
        perfect=bool((mr[:n] < n).all()),
        diagnosis=result.diagnosis, execution=result.execution)


# --------------------------------------------------------------------------
# consistent-hash shard routing
# --------------------------------------------------------------------------


class ShardRouter:
    """Consistent-hash routing of request keys onto shards.

    Keys hash into ``2**n_bits`` stable slots; slots map onto the current
    shard count by modulo. The two-level scheme (slots, then shards) is
    the standard trick: a key's *slot* never changes, so growing the
    shard fleet remaps only slots, not the hash space. blake2b rather
    than ``hash()`` because routing must be deterministic across
    processes and runs (PYTHONHASHSEED randomizes ``hash`` per process —
    a warm cache keyed by process-local routing would go cold on every
    restart).
    """

    def __init__(self, num_shards: int, n_bits: int = 12):
        if not isinstance(num_shards, int) or num_shards < 1:
            raise ValueError(
                f"num_shards must be a positive int, got {num_shards!r}")
        if not isinstance(n_bits, int) or n_bits < 1:
            raise ValueError(
                f"n_bits must be a positive int, got {n_bits!r}")
        self.num_shards = num_shards
        self.n_bits = n_bits
        self.total_slots = 1 << n_bits

    def slot_for(self, key: str) -> int:
        digest = hashlib.blake2b(key.encode("utf-8"),
                                 digest_size=8).digest()
        return int.from_bytes(digest, "big") % self.total_slots

    def shard_for(self, key: str) -> int:
        return self.slot_for(key) % self.num_shards

    def slots_for_shard(self, shard: int) -> list[int]:
        if not 0 <= shard < self.num_shards:
            raise ValueError(
                f"shard must be in [0, {self.num_shards - 1}], got {shard}")
        return [s for s in range(self.total_slots)
                if s % self.num_shards == shard]


# --------------------------------------------------------------------------
# the service
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Service knobs. ``options`` owns the algorithm (a ``SolveOptions``;
    its ``on_invalid`` is forced to "degrade" at dispatch — see module
    docstring); everything else owns the serving shape."""

    num_shards: int = 4
    deadline_s: float = 0.002
    max_batch: int = 8
    min_class_n: int = 32
    max_class_n: int = 4096
    plan_capacity: int = 32
    warm_capacity: int = 4096
    warm_start: bool = True
    admission: str = "sanitize"
    options: Any = None  # SolveOptions | None
    resilient: bool = False  # serve through runtime.resilient rung chains
    resilience: Any = None  # ResilientOptions | None (resilient=True only)

    def __post_init__(self):
        if self.admission not in ADMISSION:
            raise ValueError(
                f"unknown admission policy {self.admission!r}: expected "
                f"one of {ADMISSION}")
        if self.max_batch < 1:
            raise ValueError(
                f"max_batch must be >= 1, got {self.max_batch!r}")


@dataclasses.dataclass
class _Request:
    """One admitted request, queued for dispatch."""

    request_id: int
    key: str
    shard: int
    size_class: SizeClass
    n: int  # true instance size (pre-embedding)
    problem: _api.MatchingProblem  # embedded at class padding
    seed: tuple | None  # class-padded (mate_row, mate_col) or None
    submitted_at: float
    admission_note: str | None  # sanitize summary when admission repaired


@dataclasses.dataclass(frozen=True)
class Response:
    """What the caller gets back for one request."""

    request_id: int
    key: str
    shard: int
    size_class: SizeClass
    ok: bool  # False only for rejected admissions
    result: Any  # stripped MatchResult | None when rejected
    error: str | None
    served_warm: bool  # solved from a warm seed
    lane: str  # "warm" | "cold" | "rejected"
    batch_fill: int  # real requests in the dispatched batch
    flush_reason: str  # "full" | "deadline" | "drain" | "rejected"
    submitted_at: float
    dispatched_at: float
    completed_at: float
    solve_s: float  # measured batch solve wall time
    latency_s: float  # queueing delay + solve
    resilience: str | None = None  # ResilienceReport.summary() if resilient


class MatchingService:
    """Long-lived matching service over ``core.api`` (module docstring).

    Drive it with ``submit`` (admission + routing + queueing; dispatches
    any batch the submission filled or expired), ``pump`` (dispatch
    deadline-expired batches — an event loop would call this at
    ``batcher.next_deadline()``), ``drain`` (flush everything), and
    ``responses`` (pop completed responses). Single-threaded by design:
    determinism is a feature here, and the solves themselves already
    saturate the device.
    """

    def __init__(self, config: ServiceConfig | None = None,
                 clock=time.monotonic):
        self.config = config or ServiceConfig()
        cfg = self.config
        opts = cfg.options or _api.SolveOptions()
        if not isinstance(opts, _api.SolveOptions):
            raise TypeError(
                f"config.options must be SolveOptions or None, got "
                f"{type(opts).__name__}")
        # degrade, never raise, inside a batch: a poisoned instance gets
        # its own imperfect result; its batchmates are untouched
        self._options = dataclasses.replace(opts, on_invalid="degrade")
        self.router = ShardRouter(cfg.num_shards)
        self.plans = PlanCache(cfg.plan_capacity)
        self.batcher = DeadlineBatcher(cfg.deadline_s)
        self.warm_caches = [WarmStartCache(cfg.warm_capacity)
                            for _ in range(cfg.num_shards)]
        self._clock = clock
        self._next_id = 0
        self._completed: list[Response] = []
        self._fillers: dict[SizeClass, _api.MatchingProblem] = {}
        self.counters = {
            "submitted": 0, "rejected": 0, "served": 0, "served_warm": 0,
            "served_cold": 0, "flushes": 0, "fill_sum": 0, "degraded": 0,
        }

    # ---- admission ----

    def submit(self, key: str, problem, now: float | None = None) -> int:
        """Admit one instance under ``key`` (the caller's stable identity
        — warm seeds and shard affinity follow it). Returns the request
        id; the response arrives via ``responses()`` after the batch
        holding it dispatches."""
        now = self._clock() if now is None else now
        rid = self._next_id
        self._next_id += 1
        self.counters["submitted"] += 1
        cfg = self.config
        row, col, val, n = _real_edges(problem)
        cls = size_class_for(
            n, int(row.shape[0]), min_class_n=cfg.min_class_n,
            max_class_n=cfg.max_class_n, max_batch=cfg.max_batch)
        shard = self.router.shard_for(key)
        embedded = embed_instance(problem, cls)
        note = None
        report = _preflight.preflight(embedded)
        if report.fatal:
            if cfg.admission == "reject":
                self.counters["rejected"] += 1
                self._completed.append(Response(
                    request_id=rid, key=key, shard=shard, size_class=cls,
                    ok=False, result=None,
                    error=f"admission rejected: {report.summary()}",
                    served_warm=False, lane="rejected", batch_fill=0,
                    flush_reason="rejected", submitted_at=now,
                    dispatched_at=now, completed_at=now, solve_s=0.0,
                    latency_s=0.0))
                return rid
            embedded, report = _preflight.sanitize(embedded)
            note = f"sanitized at admission: {report.summary()}"
        seed = None
        if cfg.warm_start:
            seed = self.warm_caches[shard].seed_for(key, cls.n)
        req = _Request(request_id=rid, key=key, shard=shard, size_class=cls,
                       n=n, problem=embedded, seed=seed, submitted_at=now,
                       admission_note=note)
        flush = self.batcher.add((shard, cls), req, now, cls.batch)
        if flush is not None:
            self._dispatch(flush)
        self.pump(now)
        return rid

    # ---- dispatch ----

    def pump(self, now: float | None = None) -> None:
        """Dispatch every deadline-expired batch."""
        now = self._clock() if now is None else now
        for flush in self.batcher.due(now):
            self._dispatch(flush)

    def drain(self, now: float | None = None) -> None:
        """Dispatch everything still queued (end of stream/shutdown)."""
        now = self._clock() if now is None else now
        for flush in self.batcher.drain(now):
            self._dispatch(flush)

    def responses(self) -> list[Response]:
        """Pop all completed responses (submission order within a batch)."""
        out, self._completed = self._completed, []
        return out

    def stats(self) -> dict:
        """Operator snapshot: counters + cache stats."""
        out = dict(self.counters)
        out["plan_cache"] = dataclasses.asdict(self.plans.stats)
        out["plan_resident"] = len(self.plans)
        out["warm_cache"] = {
            "served": sum(c.stats.served for c in self.warm_caches),
            "stale": sum(c.stats.stale for c in self.warm_caches),
            "absent": sum(c.stats.absent for c in self.warm_caches),
        }
        if out["flushes"]:
            out["avg_fill"] = out["fill_sum"] / out["flushes"]
        return out

    def _matcher(self, cls: SizeClass):
        spec = _api.ProblemSpec(n=cls.n, cap=cls.cap, batch=cls.batch)
        if self.config.resilient:
            from repro.runtime import resilient as _resilient

            def build():
                return _resilient.ResilientMatcher(
                    spec, self._options, self.config.resilience)
        else:
            def build():
                return _api.plan(spec, self._options)
        return self.plans.get((cls.n, cls.cap, cls.batch), build)

    def _filler(self, cls: SizeClass) -> _api.MatchingProblem:
        """The identity filler instance for ``cls``: unit-weight diagonal,
        trivially solvable, padding warm and cold lanes alike."""
        f = self._fillers.get(cls)
        if f is None:
            eye = np.arange(cls.n, dtype=np.int32)
            f = _api.MatchingProblem.from_graph(_graph.from_coo(
                eye, eye, np.ones(cls.n, np.float32), cls.n,
                capacity=cls.cap))
            self._fillers[cls] = f
        return f

    def _dispatch(self, flush: Flush) -> None:
        shard, cls = flush.key
        self.counters["flushes"] += 1
        self.counters["fill_sum"] += len(flush.items)
        warm_lane = [r for r in flush.items if r.seed is not None]
        cold_lane = [r for r in flush.items if r.seed is None]
        for lane, reqs in (("cold", cold_lane), ("warm", warm_lane)):
            if reqs:
                self._run_lane(lane, reqs, cls, shard, flush)

    def _run_lane(self, lane: str, reqs: list, cls: SizeClass, shard: int,
                  flush: Flush) -> None:
        filler = self._filler(cls)
        pad = cls.batch - len(reqs)
        probs = [r.problem for r in reqs] + [filler] * pad
        batch = _api.MatchingProblem(
            row=np.stack([np.asarray(p.row) for p in probs]),
            col=np.stack([np.asarray(p.col) for p in probs]),
            val=np.stack([np.asarray(p.val) for p in probs]),
            n=cls.n)
        seed = None
        if lane == "warm":
            ident = identity_mates(cls.n)
            seed = (np.stack([r.seed[0] for r in reqs]
                             + [ident[0]] * pad),
                    np.stack([r.seed[1] for r in reqs]
                             + [ident[1]] * pad))
        matcher = self._matcher(cls)
        t0 = time.perf_counter()
        served = matcher(batch) if seed is None \
            else matcher(batch, warm_start=seed)
        resilience = None
        if self.config.resilient:  # ResilientResult: unwrap + keep story
            resilience = served.report.summary()
            result = served.result
        else:
            result = served
        jax.block_until_ready((result.mate_row, result.mate_col))
        solve_s = time.perf_counter() - t0
        completed_at = flush.dispatched_at + solve_s
        mr_all = np.asarray(result.mate_row)
        mc_all = np.asarray(result.mate_col)
        for i, r in enumerate(reqs):
            stripped = strip_instance(result, i, r.n, cls.n)
            if self.config.warm_start:
                self.warm_caches[shard].put(r.key, cls.n, mr_all[i],
                                            mc_all[i])
            self.counters["served"] += 1
            self.counters[f"served_{lane}"] += 1
            if not stripped.perfect:
                self.counters["degraded"] += 1
            error = r.admission_note
            self._completed.append(Response(
                request_id=r.request_id, key=r.key, shard=shard,
                size_class=cls, ok=True, result=stripped, error=error,
                served_warm=lane == "warm", lane=lane,
                batch_fill=len(reqs), flush_reason=flush.reason,
                submitted_at=r.submitted_at,
                dispatched_at=flush.dispatched_at,
                completed_at=completed_at, solve_s=solve_s,
                latency_s=completed_at - r.submitted_at,
                resilience=resilience))
