"""Matching-as-a-service tier (DESIGN.md §11): long-lived serving in
front of the ``core.api`` facade.

The paper's motivating workload — pivot orders for a stream of sparse
factorizations — arrives as many mostly-similar instances per second, not
one-shot calls. This package turns the compile-once/run-many ``Matcher``
into an actual service:

  ``service``     request admission, consistent-hash shard routing,
                  size-class bucketing, batch dispatch (the front door:
                  :class:`MatchingService`).
  ``plan_cache``  LRU of pre-planned ``Matcher``s per size class with
                  hit/miss/eviction counters.
  ``batcher``     deadline batcher: pads requests into [B, cap] batches,
                  dispatching on batch-full or deadline expiry.
  ``warm``        warm-start seed cache + seed-or-cold fallback helper.
  ``loadgen``     open-loop (Poisson-arrival) load generator for the
                  serving benchmark and the ``python -m repro.serving``
                  demo CLI.
"""
from repro.serving.batcher import DeadlineBatcher, Flush
from repro.serving.loadgen import StreamSpec, run_stream
from repro.serving.plan_cache import CacheStats, PlanCache
from repro.serving.service import (
    MatchingService,
    Response,
    ServiceConfig,
    ShardRouter,
    SizeClass,
    embed_instance,
    size_class_for,
    strip_instance,
)
from repro.serving.warm import WarmStartCache, solve_with_seed

__all__ = [
    "CacheStats",
    "DeadlineBatcher",
    "Flush",
    "MatchingService",
    "PlanCache",
    "Response",
    "ServiceConfig",
    "ShardRouter",
    "SizeClass",
    "StreamSpec",
    "WarmStartCache",
    "embed_instance",
    "run_stream",
    "size_class_for",
    "solve_with_seed",
    "strip_instance",
]
