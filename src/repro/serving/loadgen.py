"""Open-loop load generation for the matching service.

An *open-loop* generator submits on a fixed arrival process (Poisson at
``rate_rps``) regardless of how fast the service responds — the honest
way to measure serving latency, since a closed loop (wait for each
response before the next request) lets a slow service throttle its own
offered load and hide queueing delay. The stream models the paper's
motivating workload: a fixed population of users (factorization
pipelines), each re-requesting a matching for a *perturbed repeat* of
its own instance — weights jittered, occasionally an edge dropped — so
warm-start rematching has exactly the structure it exists to exploit.

The stream drives the service on a simulated clock (arrival times), so
throughput/latency numbers reflect the configured arrival process plus
the *measured* solve wall times, deterministically — not the vagaries of
host scheduling between submissions.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import graph as _graph


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Shape of one open-loop run."""

    requests: int = 256
    users: int = 16  # distinct request keys (warm-cache identities)
    n: int = 48  # instance size per user
    avg_degree: float = 5.0
    rate_rps: float = 400.0  # Poisson arrival rate
    weight_jitter: float = 0.02  # relative weight perturbation per repeat
    structure_churn: float = 0.0  # P(drop one random edge) per repeat
    kind: str = "uniform"  # graph.generate family
    seed: int = 0

    def __post_init__(self):
        if self.requests < 1 or self.users < 1:
            raise ValueError("requests and users must be >= 1")
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps!r}")


def perturbed(base: _graph.BipartiteGraph, rng: np.random.Generator,
              weight_jitter: float,
              structure_churn: float) -> _graph.BipartiteGraph:
    """A repeat of ``base``: same structure, jittered weights, and (with
    probability ``structure_churn``) one random edge dropped — the
    "slightly different instance next timestep" the warm path repairs."""
    nnz = base.nnz
    row = base.row[:nnz].copy()
    col = base.col[:nnz].copy()
    val = base.val[:nnz].astype(np.float64)
    if weight_jitter:
        val = np.abs(val * (1.0 + weight_jitter * rng.standard_normal(nnz)))
        val = np.maximum(val, 1e-6)  # keep weights positive
    if structure_churn and nnz > base.n and rng.random() < structure_churn:
        drop = int(rng.integers(0, nnz))
        keep = np.arange(nnz) != drop
        row, col, val = row[keep], col[keep], val[keep]
    return _graph.from_coo(row, col, val.astype(np.float32), base.n)


def _percentiles(latencies_s: np.ndarray) -> dict:
    if latencies_s.size == 0:
        return {"p50_us": 0.0, "p95_us": 0.0, "p99_us": 0.0}
    p50, p95, p99 = np.percentile(latencies_s, [50, 95, 99])
    return {"p50_us": float(p50 * 1e6), "p95_us": float(p95 * 1e6),
            "p99_us": float(p99 * 1e6)}


def run_stream(service, spec: StreamSpec) -> dict:
    """Drive ``service`` with one open-loop stream; return the summary.

    Returns a dict with the raw ``responses`` plus the headline numbers:
    served/rejected counts, warm/cold split, throughput (served requests
    per second of simulated stream time, solve wall included), latency
    percentiles, and mean batch fill.
    """
    rng = np.random.default_rng(spec.seed)
    bases = [_graph.generate(spec.n, spec.avg_degree, kind=spec.kind,
                             seed=spec.seed * 1009 + u)
             for u in range(spec.users)]
    arrivals = np.cumsum(rng.exponential(1.0 / spec.rate_rps,
                                         size=spec.requests))
    for i in range(spec.requests):
        u = i % spec.users
        g = perturbed(bases[u], rng, spec.weight_jitter,
                      spec.structure_churn)
        service.submit(f"user-{u}", g, now=float(arrivals[i]))
    end = float(arrivals[-1]) + service.batcher.deadline_s
    service.drain(now=end)
    responses = service.responses()
    served = [r for r in responses if r.ok]
    lat = np.array([r.latency_s for r in served])
    finish = max((r.completed_at for r in served), default=end)
    span = max(finish - float(arrivals[0]), 1e-9)
    summary = {
        "requests": spec.requests,
        "served": len(served),
        "rejected": len(responses) - len(served),
        "served_warm": sum(r.served_warm for r in served),
        "served_cold": sum(not r.served_warm for r in served),
        "degraded": sum(not r.result.perfect for r in served),
        "throughput_rps": len(served) / span,
        "mean_solve_us": float(np.mean([r.solve_s for r in served]) * 1e6)
        if served else 0.0,
        "mean_fill": float(np.mean([r.batch_fill for r in served]))
        if served else 0.0,
        "responses": responses,
    }
    summary.update(_percentiles(lat))
    return summary
