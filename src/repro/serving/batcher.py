"""Deadline batcher: fill [B, cap] batches until full or a latency
deadline expires, then dispatch once.

Batching amortizes one XLA dispatch over B instances (the batched engine
solves B lanes in one call — ``core.batch``), but a naive "wait for a
full batch" policy would stall a lone request forever. The standard
serving compromise is a *deadline batcher*: the first request into a
class opens that class's batch and starts its deadline clock; the batch
dispatches the moment it is full, or when the deadline expires with
whatever partial fill it has (the dispatcher pads the rest).

Time is injected (callers pass ``now``), never read here — the service
runs against ``time.monotonic`` while tests and the open-loop benchmark
drive a simulated clock deterministically through the same code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Hashable


@dataclasses.dataclass(frozen=True)
class Flush:
    """One dispatched batch: which class, which requests, and why now."""

    key: Hashable  # class key the queue was keyed on
    items: tuple  # queued requests, submission order
    opened_at: float  # when the first item arrived
    dispatched_at: float  # when the batch left the queue
    reason: str  # "full" | "deadline" | "drain"


@dataclasses.dataclass
class _Queue:
    items: list
    opened_at: float


class DeadlineBatcher:
    """Per-class-key queues with a shared deadline.

    ``add`` returns a full :class:`Flush` immediately when the item tops
    the class off at ``max_batch`` (latency floor: a hot class never waits
    on the clock); ``due`` returns every queue whose deadline has expired;
    ``drain`` flushes everything regardless (shutdown / end of stream).
    """

    def __init__(self, deadline_s: float):
        if not deadline_s >= 0:
            raise ValueError(
                f"deadline_s must be >= 0, got {deadline_s!r}")
        self.deadline_s = float(deadline_s)
        self._queues: dict[Hashable, _Queue] = {}

    def add(self, key: Hashable, item: Any, now: float,
            max_batch: int) -> Flush | None:
        """Queue ``item`` under ``key``; return a Flush iff the batch is
        now full (caller dispatches it)."""
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch!r}")
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = _Queue(items=[], opened_at=now)
        q.items.append(item)
        if len(q.items) >= max_batch:
            del self._queues[key]
            return Flush(key=key, items=tuple(q.items), opened_at=q.opened_at,
                         dispatched_at=now, reason="full")
        return None

    def due(self, now: float) -> list[Flush]:
        """Flush every queue whose deadline has expired by ``now``.

        ``dispatched_at`` is the deadline itself, not ``now``: a simulated
        clock may pump late (at the next arrival), and charging the gap to
        the request would invent latency the service never imposed.
        """
        out = []
        for key in list(self._queues):
            q = self._queues[key]
            due_at = q.opened_at + self.deadline_s
            if due_at <= now:
                del self._queues[key]
                out.append(Flush(key=key, items=tuple(q.items),
                                 opened_at=q.opened_at, dispatched_at=due_at,
                                 reason="deadline"))
        return out

    def drain(self, now: float) -> list[Flush]:
        """Flush every queue regardless of deadline (end of stream)."""
        out = []
        for key in list(self._queues):
            q = self._queues.pop(key)
            out.append(Flush(key=key, items=tuple(q.items),
                             opened_at=q.opened_at,
                             dispatched_at=min(q.opened_at + self.deadline_s,
                                               now),
                             reason="drain"))
        return out

    def pending(self) -> int:
        """Total queued (not yet dispatched) items across classes."""
        return sum(len(q.items) for q in self._queues.values())

    def next_deadline(self) -> float | None:
        """Earliest pending deadline, or None when no queue is open —
        what an event loop would sleep until."""
        if not self._queues:
            return None
        return min(q.opened_at for q in self._queues.values()) \
            + self.deadline_s
