"""LRU compile cache of pre-planned matchers.

Planning a ``Matcher`` is cheap; the XLA compile that lands on its first
call is not (tens of ms to seconds per (n, cap, batch) class). A serving
process therefore keeps one planned matcher per size class alive and
reuses it for every batch in that class — this module is that cache, with
LRU eviction so a long tail of rare shapes cannot pin unbounded compiled
executables, and hit/miss/eviction counters so the benchmark and the
operator can see whether the class ladder is actually bucketing traffic
(a hit rate near zero means every request compiles; see
``service.size_class_for``).

The cache is deliberately generic (`get(key, build)`): the service caches
plain ``Matcher``s or ``ResilientMatcher``s with the same instance.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Hashable


@dataclasses.dataclass
class CacheStats:
    """Counters for one cache lifetime."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def summary(self) -> str:
        return (f"{self.hits} hits / {self.misses} misses "
                f"(rate {self.hit_rate:.2f}), {self.evictions} evictions")


class PlanCache:
    """LRU mapping hashable plan keys -> planned matchers.

    ``get`` returns the cached entry (marking it most-recently-used) or
    calls ``build()`` on a miss, inserting the result and evicting the
    least-recently-used entries beyond ``capacity``. An evicted class that
    returns later is re-planned transparently — correctness never depends
    on residency, only latency does.
    """

    def __init__(self, capacity: int = 32):
        if not isinstance(capacity, int) or capacity < 1:
            raise ValueError(
                f"capacity must be a positive int, got {capacity!r}")
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: OrderedDict[Hashable, Any] = OrderedDict()

    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        if key in self._entries:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return self._entries[key]
        self.stats.misses += 1
        entry = build()  # build OUTSIDE the eviction step: a throwing
        # build must leave the cache untouched
        self._entries[key] = entry
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return entry

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        """Resident keys, least- to most-recently used."""
        return list(self._entries.keys())

    def clear(self) -> None:
        self._entries.clear()
