"""2D block partitioning of a sparse matrix over a Pr x Pc process grid.

This mirrors the paper's CombBLAS-style regular 2D distribution: process (a, b)
owns the dense index block rows [a*br, (a+1)*br) x cols [b*bc, (b+1)*bc).
Per-block edge lists are padded to a common capacity so the stacked arrays
[Pr, Pc, cap] shard cleanly under shard_map with PartitionSpec("data","model").

Entries store GLOBAL indices (int32). Padding entries have row = col = n (the
global sentinel) and val = 0; every consumer masks on ``row < n``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Partition2D:
    n: int  # global rows == cols (square, per the paper)
    pr: int
    pc: int
    br: int  # block rows  = ceil(n / pr)
    bc: int  # block cols  = ceil(n / pc)
    cap: int  # per-block edge capacity
    nnz: np.ndarray  # [pr, pc] int32 actual nnz per block
    row: np.ndarray  # [pr, pc, cap] int32 global row ids, lex-sorted per block
    col: np.ndarray  # [pr, pc, cap] int32 global col ids
    val: np.ndarray  # [pr, pc, cap] float32

    def block_of(self, i, j):
        return i // self.br, j // self.bc


@dataclasses.dataclass
class Partition2DBatched:
    """A batch of B instances partitioned over the SAME Pr x Pc grid with a
    shared per-block capacity, stacked [pr, pc, B, cap] so the arrays shard
    under shard_map with PartitionSpec("data", "model", None, None) — each
    device holds its block of every instance and the batched collectives
    amortize across B."""

    n: int
    b: int
    pr: int
    pc: int
    br: int
    bc: int
    cap: int  # shared per-block edge capacity (true max occupancy, padded)
    nnz: np.ndarray  # [pr, pc, B] int32 actual nnz per (block, instance)
    row: np.ndarray  # [pr, pc, B, cap] int32 global rows, lex-sorted per block
    col: np.ndarray  # [pr, pc, B, cap] int32 global cols
    val: np.ndarray  # [pr, pc, B, cap] float32


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def block_occupancy(row, col, n: int, pr: int, pc: int) -> np.ndarray:
    """True per-block nnz counts of a padded COO instance ([cap] arrays,
    padding row == n) or batch ([B, cap]). Returns [pr, pc] (or [B, pr, pc]).
    This is the measurement capacity planning must be based on — the uniform
    m / (pr * pc) estimate undercounts adversarially skewed instances (one
    dense row lands entirely in a single grid row)."""
    row = np.asarray(row)
    col = np.asarray(col)
    if row.ndim == 2:
        return np.stack([
            block_occupancy(r, c, n, pr, pc) for r, c in zip(row, col)
        ])
    br = -(-n // pr)
    bc = -(-n // pc)
    m = row < n
    blk = (row[m] // br) * pc + col[m] // bc
    return np.bincount(blk, minlength=pr * pc).reshape(pr, pc).astype(np.int32)


def plan_block_cap(row, col, n: int, pr: int, pc: int,
                   pad_align: int = 8) -> int:
    """Per-block edge capacity derived from the TRUE max block occupancy
    (never the uniform nnz / (pr * pc) spread). Accepts [cap] or [B, cap]
    padded COO index arrays."""
    occ = int(block_occupancy(row, col, n, pr, pc).max(initial=0))
    return max(_round_up(occ, pad_align), pad_align)


def partition_coo_2d(
    row, col, val, n: int, pr: int, pc: int, cap: int | None = None, pad_align: int = 8
) -> Partition2D:
    row = np.asarray(row, dtype=np.int32)
    col = np.asarray(col, dtype=np.int32)
    val = np.asarray(val, dtype=np.float32)
    br = -(-n // pr)
    bc = -(-n // pc)
    a = row // br
    b = col // bc
    blk = a * pc + b
    order = np.lexsort((col, row, blk))
    row, col, val, blk = row[order], col[order], val[order], blk[order]
    counts = np.bincount(blk, minlength=pr * pc)
    max_nnz = int(counts.max()) if counts.size else 0
    if cap is None:
        cap = max(_round_up(max_nnz, pad_align), pad_align)
    if cap < max_nnz:
        raise ValueError(
            f"cap {cap} < max block nnz {max_nnz}: refusing to truncate "
            f"edges (capacity must come from true block occupancy, see "
            f"plan_block_cap)")
    R = np.full((pr * pc, cap), n, dtype=np.int32)
    C = np.full((pr * pc, cap), n, dtype=np.int32)
    V = np.zeros((pr * pc, cap), dtype=np.float32)
    starts = np.zeros(pr * pc + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for p in range(pr * pc):
        s, e = starts[p], starts[p + 1]
        R[p, : e - s] = row[s:e]
        C[p, : e - s] = col[s:e]
        V[p, : e - s] = val[s:e]
    return Partition2D(
        n=n,
        pr=pr,
        pc=pc,
        br=br,
        bc=bc,
        cap=cap,
        nnz=counts.reshape(pr, pc).astype(np.int32),
        row=R.reshape(pr, pc, cap),
        col=C.reshape(pr, pc, cap),
        val=V.reshape(pr, pc, cap),
    )


def partition_coo_2d_batched(
    row, col, val, n: int, pr: int, pc: int, cap: int | None = None,
    pad_align: int = 8,
) -> Partition2DBatched:
    """Partition a batch of padded [B, cap_in] COO instances (shared n,
    padding entries (n, n, 0)) over one Pr x Pc grid with a SHARED per-block
    capacity.

    ``cap=None`` derives the capacity from the true max block occupancy
    across every (instance, block) pair (``plan_block_cap``). An explicit
    ``cap`` smaller than that occupancy raises — edges are never silently
    overflow-truncated, because a dropped edge would silently degrade the
    matching weight on exactly the adversarial (skewed) instances.
    """
    row = np.asarray(row, dtype=np.int32)
    col = np.asarray(col, dtype=np.int32)
    val = np.asarray(val, dtype=np.float32)
    if row.ndim != 2:
        raise ValueError(f"expected batched [B, cap] arrays, got {row.shape}")
    b = row.shape[0]
    br = -(-n // pr)
    bc = -(-n // pc)
    occ = block_occupancy(row, col, n, pr, pc)  # [B, pr, pc]
    max_occ = int(occ.max(initial=0))
    if cap is None:
        cap = max(_round_up(max_occ, pad_align), pad_align)
    if cap < max_occ:
        raise ValueError(
            f"cap {cap} < max block occupancy {max_occ}: refusing to "
            f"truncate edges (derive capacity with plan_block_cap)")
    R = np.full((pr * pc, b, cap), n, dtype=np.int32)
    C = np.full((pr * pc, b, cap), n, dtype=np.int32)
    V = np.zeros((pr * pc, b, cap), dtype=np.float32)
    for i in range(b):
        m = row[i] < n
        r, c, v = row[i][m], col[i][m], val[i][m]
        blk = (r // br) * pc + c // bc
        order = np.lexsort((c, r, blk))
        r, c, v, blk = r[order], c[order], v[order], blk[order]
        counts = np.bincount(blk, minlength=pr * pc)
        starts = np.zeros(pr * pc + 1, dtype=np.int64)
        np.cumsum(counts, out=starts[1:])
        for p in range(pr * pc):
            s, e = starts[p], starts[p + 1]
            R[p, i, : e - s] = r[s:e]
            C[p, i, : e - s] = c[s:e]
            V[p, i, : e - s] = v[s:e]
    return Partition2DBatched(
        n=n, b=b, pr=pr, pc=pc, br=br, bc=bc, cap=cap,
        nnz=np.transpose(occ, (1, 2, 0)).astype(np.int32),
        row=R.reshape(pr, pc, b, cap),
        col=C.reshape(pr, pc, b, cap),
        val=V.reshape(pr, pc, b, cap),
    )
