"""2D block partitioning of a sparse matrix over a Pr x Pc process grid.

This mirrors the paper's CombBLAS-style regular 2D distribution: process (a, b)
owns the dense index block rows [a*br, (a+1)*br) x cols [b*bc, (b+1)*bc).
Per-block edge lists are padded to a common capacity so the stacked arrays
[Pr, Pc, cap] shard cleanly under shard_map with PartitionSpec("data","model").

Entries store GLOBAL indices (int32). Padding entries have row = col = n (the
global sentinel) and val = 0; every consumer masks on ``row < n``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Partition2D:
    n: int  # global rows == cols (square, per the paper)
    pr: int
    pc: int
    br: int  # block rows  = ceil(n / pr)
    bc: int  # block cols  = ceil(n / pc)
    cap: int  # per-block edge capacity
    nnz: np.ndarray  # [pr, pc] int32 actual nnz per block
    row: np.ndarray  # [pr, pc, cap] int32 global row ids, lex-sorted per block
    col: np.ndarray  # [pr, pc, cap] int32 global col ids
    val: np.ndarray  # [pr, pc, cap] float32

    def block_of(self, i, j):
        return i // self.br, j // self.bc


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def partition_coo_2d(
    row, col, val, n: int, pr: int, pc: int, cap: int | None = None, pad_align: int = 8
) -> Partition2D:
    row = np.asarray(row, dtype=np.int32)
    col = np.asarray(col, dtype=np.int32)
    val = np.asarray(val, dtype=np.float32)
    br = -(-n // pr)
    bc = -(-n // pc)
    a = row // br
    b = col // bc
    blk = a * pc + b
    order = np.lexsort((col, row, blk))
    row, col, val, blk = row[order], col[order], val[order], blk[order]
    counts = np.bincount(blk, minlength=pr * pc)
    max_nnz = int(counts.max()) if counts.size else 0
    if cap is None:
        cap = max(_round_up(max_nnz, pad_align), pad_align)
    if cap < max_nnz:
        raise ValueError(f"cap {cap} < max block nnz {max_nnz}")
    R = np.full((pr * pc, cap), n, dtype=np.int32)
    C = np.full((pr * pc, cap), n, dtype=np.int32)
    V = np.zeros((pr * pc, cap), dtype=np.float32)
    starts = np.zeros(pr * pc + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for p in range(pr * pc):
        s, e = starts[p], starts[p + 1]
        R[p, : e - s] = row[s:e]
        C[p, : e - s] = col[s:e]
        V[p, : e - s] = val[s:e]
    return Partition2D(
        n=n,
        pr=pr,
        pc=pc,
        br=br,
        bc=bc,
        cap=cap,
        nnz=counts.reshape(pr, pc).astype(np.int32),
        row=R.reshape(pr, pc, cap),
        col=C.reshape(pr, pc, cap),
        val=V.reshape(pr, pc, cap),
    )
