"""Sparse substrate: segment ops, padded CSR/COO builders, 2D partitioning.

JAX has no CSR/CSC (BCOO only), no EmbeddingBag, and no native scatter-based
message passing. Per the project brief these are implemented here from
``jnp.take`` + ``jax.ops.segment_sum``-family primitives and are first-class
parts of the system (used by repro.core, repro.models.gnn, repro.models.recsys).
"""
from repro.sparse.csr import (
    PaddedCSR,
    coo_to_padded_csr,
    dedupe_coo_sum,
    max_row_nnz,
    row_ptr_from_sorted,
    sort_coo,
    window_depth,
)
from repro.sparse.ops import (
    coo_sddmm,
    coo_spmm,
    lex_searchsorted,
    searchsorted_in_window,
    segment_argmax,
    segment_max_with_payload,
    segment_softmax,
    x64_available,
)
from repro.sparse.partition import (
    Partition2D,
    Partition2DBatched,
    block_occupancy,
    partition_coo_2d,
    partition_coo_2d_batched,
    plan_block_cap,
)

__all__ = [
    "segment_argmax",
    "segment_max_with_payload",
    "segment_softmax",
    "coo_spmm",
    "coo_sddmm",
    "lex_searchsorted",
    "searchsorted_in_window",
    "x64_available",
    "PaddedCSR",
    "coo_to_padded_csr",
    "dedupe_coo_sum",
    "max_row_nnz",
    "row_ptr_from_sorted",
    "sort_coo",
    "window_depth",
    "Partition2D",
    "Partition2DBatched",
    "block_occupancy",
    "partition_coo_2d",
    "partition_coo_2d_batched",
    "plan_block_cap",
]
