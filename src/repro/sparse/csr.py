"""Padded CSR/COO construction (numpy side — runs in the data pipeline),
plus the device-side ``row_ptr`` builders used by the fused AWAC sweep engine
(DESIGN.md §3) to turn the per-edge completion lookup into a windowed search."""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PaddedCSR:
    """CSR with a fixed nnz capacity. Entries [nnz:] are padding with
    row = n_rows, col = n_cols, val = 0 so that segment ops drop them.

    Also carries the COO row array (sorted) because the matching algorithms are
    edge-centric.
    """

    n_rows: int
    n_cols: int
    nnz: int
    row_ptr: np.ndarray  # [n_rows + 1] int32
    row: np.ndarray  # [cap] int32, sorted
    col: np.ndarray  # [cap] int32, sorted within rows
    val: np.ndarray  # [cap] float32

    @property
    def capacity(self) -> int:
        return int(self.row.shape[0])

    def valid_mask(self) -> np.ndarray:
        return np.arange(self.capacity) < self.nnz


def sort_coo(row, col, val):
    """Sort COO triples lexicographically by (row, col)."""
    order = np.lexsort((col, row))
    return row[order], col[order], val[order]


def dedupe_coo_sum(row, col, val, n_cols=None):
    """Assemble duplicate COO entries by summation (numpy, host-side).

    Returns lex-sorted (row, col, val) with one entry per (row, col) pair,
    duplicate values summed — the Matrix Market assembly convention for
    repeated coordinate entries (and FEM-style element assembly). Unlike
    ``repro.core.graph._dedupe`` (keep-first), no value is dropped.
    """
    row = np.asarray(row)
    col = np.asarray(col)
    val = np.asarray(val)
    if row.size == 0:
        return row, col, val
    if n_cols is None:
        n_cols = int(col.max()) + 1
    key = row.astype(np.int64) * np.int64(n_cols) + col.astype(np.int64)
    order = np.argsort(key, kind="stable")
    key = key[order]
    uniq_mask = np.empty(key.shape, bool)
    uniq_mask[0] = True
    np.not_equal(key[1:], key[:-1], out=uniq_mask[1:])
    seg = np.cumsum(uniq_mask) - 1  # dense segment id per sorted entry
    out_val = np.zeros(int(seg[-1]) + 1, dtype=np.result_type(val, np.float64))
    np.add.at(out_val, seg, val[order])
    first = order[uniq_mask]
    return row[first], col[first], out_val.astype(val.dtype, copy=False)


def coo_to_padded_csr(row, col, val, n_rows, n_cols, capacity=None) -> PaddedCSR:
    row = np.asarray(row, dtype=np.int32)
    col = np.asarray(col, dtype=np.int32)
    val = np.asarray(val, dtype=np.float32)
    nnz = int(row.shape[0])
    if capacity is None:
        capacity = nnz
    if capacity < nnz:
        raise ValueError(f"capacity {capacity} < nnz {nnz}")
    row, col, val = sort_coo(row, col, val)
    counts = np.bincount(row, minlength=n_rows)
    row_ptr = np.zeros(n_rows + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    pad = capacity - nnz
    row = np.concatenate([row, np.full(pad, n_rows, dtype=np.int32)])
    col = np.concatenate([col, np.full(pad, n_cols, dtype=np.int32)])
    val = np.concatenate([val, np.zeros(pad, dtype=np.float32)])
    return PaddedCSR(n_rows, n_cols, nnz, row_ptr, row, col, val)


# --------------------------------------------------------------------------
# Device-side CSR windows over padded lex-sorted COO (fused AWAC sweep)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n",))
def row_ptr_from_sorted(row, n: int):
    """One-time CSR ``row_ptr`` [n + 2] from a padded lex-sorted COO row
    array (padding rows == n). ``row_ptr[i]`` is the first edge index with
    ``row >= i``; ``row_ptr[n]`` is the start of the padding tail and
    ``row_ptr[n + 1]`` the capacity. Built on device so the fused sweep can
    run on graphs that never touch the host."""
    targets = jnp.arange(n + 2, dtype=row.dtype)
    return jnp.searchsorted(row, targets, side="left").astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n",))
def batched_row_ptr_from_sorted(row, n: int):
    """Per-instance CSR ``row_ptr`` [B, n + 2] from a batch of padded
    lex-sorted COO row arrays [B, cap] (padding rows == n). Each instance
    gets the same row_ptr ``row_ptr_from_sorted`` would build for it; the
    batched AWAC engine hoists this out of its while_loop."""
    targets = jnp.arange(n + 2, dtype=row.dtype)
    return jax.vmap(
        lambda r: jnp.searchsorted(r, targets, side="left").astype(jnp.int32)
    )(row)


def window_depth(max_row_nnz: int) -> int:
    """Binary-search rounds needed to resolve a window of ``max_row_nnz``
    entries (one extra round closes half-open intervals)."""
    return max(1, math.ceil(math.log2(max(int(max_row_nnz), 1))) + 1)


def max_row_nnz(row, n: int) -> int:
    """Max nonzeros in any row of a *concrete* (host-available) padded COO
    row array — [cap], or [B, cap] for a batch, in which case the max is
    taken across all instances (each instance's rows are counted separately
    via a per-instance offset). Used to pick the static windowed-search
    depth; callers fall back to a conservative depth when ``row`` is a
    tracer."""
    r = np.asarray(row)
    if r.ndim == 2:
        return max(max_row_nnz(ri, n) for ri in r)
    r = r[r < n]
    if r.size == 0:
        return 1
    return int(np.bincount(r, minlength=1).max())
