"""Segment-op primitives used across the framework.

All ops are shape-static and jit/vmap/shard_map friendly. Padding convention:
invalid entries carry ``segment_id == num_segments`` (one past the end) and are
dropped by passing ``num_segments + 1`` internally and slicing the tail off, or
by masking values to the reduction identity.

Packed-key fast path (fused AWAC sweep engine, DESIGN.md §3): when 64-bit
types are available at trace time (``jax.experimental.enable_x64`` entered
around the jitted call), the two-reduction argmax-with-tie-break ops below
collapse into a single ``segment_max`` over a packed uint64 key
``f32-key-bits ⧺ bitwise-not(payload)``, halving the number of O(m) scatter
passes while staying bit-identical to the two-pass reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG = -jnp.inf

_SIGN32 = np.int32(np.uint32(0x80000000))


def x64_available() -> bool:
    """True when 64-bit dtypes survive canonicalization in the current trace
    context (i.e. we are under ``jax.experimental.enable_x64``)."""
    return jax.dtypes.canonicalize_dtype(np.uint64).itemsize == 8


def _f32_sort_key(values):
    """Monotone int32 key for float32 totally ordered like the floats
    (-inf < ... < +inf; -0.0 and +0.0 compare in bit order — callers only
    feed gains, never signed zeros that must tie)."""
    bits = jax.lax.bitcast_convert_type(values, jnp.int32)
    return jnp.where(bits < 0, ~bits, bits ^ _SIGN32)


def _f32_from_sort_key(key):
    bits = jnp.where(key < 0, key ^ _SIGN32, ~key)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _packed_segment_max(values, payload, segment_ids, num_segments):
    """One-pass (max value, min payload) per segment via a packed uint64 key.

    Requires an x64-enabled trace context. ``payload`` must be >= 0 int32.
    Returns (seg_max f32, seg_payload i32) with (-inf, -1) for empty segments
    and payload -1 wherever seg_max == -inf (matching the two-pass reference).
    """
    key_hi = _f32_sort_key(values)
    # ~payload: smaller payload -> larger low word -> wins uint64 max on ties.
    pair = jnp.stack([~payload, key_hi], axis=-1)  # little-endian: low first
    key = jax.lax.bitcast_convert_type(pair, jnp.uint64)
    out = jax.ops.segment_max(key, segment_ids, num_segments=num_segments)
    pair_out = jax.lax.bitcast_convert_type(out, jnp.uint32).astype(jnp.int32)
    k_hi = pair_out[..., 1]
    seg_payload = ~pair_out[..., 0]
    # uint64 identity (0) only decodes from the impossible (NaN key, payload
    # -1) combination, so it identifies empty segments exactly.
    empty = (k_hi == 0) & (pair_out[..., 0] == 0)
    seg_max = jnp.where(empty, NEG, _f32_from_sort_key(k_hi))
    seg_payload = jnp.where(empty | (seg_max == NEG), -1, seg_payload)
    return seg_max, seg_payload


def segment_max_with_payload(values, payload, segment_ids, num_segments):
    """Per-segment max of ``values`` and the payload of (one of) the argmax rows.

    Ties are broken toward the smallest payload value, which makes the result
    deterministic (the paper's Step C/D pick "one with maximum gain"; we fix the
    tie-break so sequential and distributed implementations agree bit-for-bit).

    Returns (seg_max [num_segments], seg_payload [num_segments int32]).
    Segments with no entries get (-inf, -1).

    Under an x64-enabled trace this is a single packed-key ``segment_max``
    pass; otherwise the two-reduction reference below runs. Both produce
    bit-identical results (see tests/test_fused_sweep.py).
    """
    if x64_available():
        return _packed_segment_max(values, payload, segment_ids, num_segments)
    seg_max = jax.ops.segment_max(
        values, segment_ids, num_segments=num_segments, indices_are_sorted=False
    )
    # Rows achieving their segment's max; among them take min payload.
    hit = values == seg_max[jnp.clip(segment_ids, 0, num_segments - 1)]
    big = jnp.iinfo(jnp.int32).max
    cand = jnp.where(hit, payload, big)
    seg_payload = jax.ops.segment_min(cand, segment_ids, num_segments=num_segments)
    seg_payload = jnp.where(seg_max == NEG, -1, seg_payload)
    seg_payload = jnp.where(seg_payload == big, -1, seg_payload)
    return seg_max, seg_payload


def segment_argmax_tie(values, tie, segment_ids, num_segments):
    """Per-segment argmax with an explicit tie-break key (smallest ``tie``
    wins; a second tie falls back to smallest index). Returns
    (seg_max, seg_idx) where seg_idx indexes into ``values`` (-1 if empty).

    Used by the distributed AWAC Step C so that the distributed winner
    selection matches the single-device rule (max gain, tie -> smallest row)
    even though edges arrive in a different order.

    Under an x64-enabled trace the (max, tie) reduction is one packed-key
    pass + one index-recovery pass instead of three segment reductions."""
    big = jnp.iinfo(jnp.int32).max
    idx = jnp.arange(values.shape[0], dtype=jnp.int32)
    if x64_available():
        seg_max, seg_tie = _packed_segment_max(
            values, tie, segment_ids, num_segments
        )
        hit2 = (values == seg_max[jnp.clip(segment_ids, 0, num_segments - 1)]) & (
            tie == seg_tie[jnp.clip(segment_ids, 0, num_segments - 1)]
        )
        idx_m = jnp.where(hit2, idx, big)
        seg_idx = jax.ops.segment_min(idx_m, segment_ids, num_segments=num_segments)
        seg_idx = jnp.where((seg_max == NEG) | (seg_idx == big), -1, seg_idx)
        return seg_max, seg_idx
    seg_max = jax.ops.segment_max(values, segment_ids, num_segments=num_segments)
    hit = values == seg_max[jnp.clip(segment_ids, 0, num_segments - 1)]
    tie_m = jnp.where(hit, tie, big)
    seg_tie = jax.ops.segment_min(tie_m, segment_ids, num_segments=num_segments)
    hit2 = hit & (tie == seg_tie[jnp.clip(segment_ids, 0, num_segments - 1)])
    idx_m = jnp.where(hit2, idx, big)
    seg_idx = jax.ops.segment_min(idx_m, segment_ids, num_segments=num_segments)
    seg_idx = jnp.where((seg_max == NEG) | (seg_idx == big), -1, seg_idx)
    return seg_max, seg_idx


def batched_segment_max_with_payload(values, payload, segment_ids, num_segments):
    """Batched ``segment_max_with_payload``: values/payload/segment_ids are
    [B, m], segments are per-instance (ids in [0, num_segments]), and the
    reduction runs as ONE flat segment op over B * (num_segments + 1)
    offset segments instead of B dispatches or a vmapped scatter.

    Payloads stay *local* (per-instance edge indices), so the smallest-payload
    tie-break picks the same winner as a per-instance call — the batched
    engine (core/batch.py) relies on this for bit-exactness with core.single.
    Returns (seg_max [B, num_segments], seg_payload [B, num_segments])."""
    b, m = values.shape
    stride = num_segments + 1  # room for the per-instance dump segment
    offs = (jnp.arange(b, dtype=segment_ids.dtype) * stride)[:, None]
    flat_seg = (segment_ids + offs).reshape(-1)
    seg_max, seg_payload = segment_max_with_payload(
        values.reshape(-1), payload.reshape(-1), flat_seg, b * stride
    )
    seg_max = seg_max.reshape(b, stride)[:, :num_segments]
    seg_payload = seg_payload.reshape(b, stride)[:, :num_segments]
    return seg_max, seg_payload


def batched_segment_argmax_tie(values, tie, segment_ids, num_segments):
    """Batched ``segment_argmax_tie``: values/tie/segment_ids are [B, m] with
    per-instance segments, flattened to one offset-segment reduction (same
    layout contract as ``batched_segment_max_with_payload``). Returned
    seg_idx is *local* (an index into instance b's own [m] row; -1 if
    empty) — within an instance the smallest flat index is the smallest
    local index, so the final-level tie-break matches a per-instance call.
    Returns (seg_max [B, num_segments], seg_idx [B, num_segments])."""
    b, m = values.shape
    stride = num_segments + 1
    offs = (jnp.arange(b, dtype=segment_ids.dtype) * stride)[:, None]
    seg_max, seg_idx = segment_argmax_tie(
        values.reshape(-1), tie.reshape(-1), (segment_ids + offs).reshape(-1),
        b * stride,
    )
    seg_max = seg_max.reshape(b, stride)[:, :num_segments]
    seg_idx = seg_idx.reshape(b, stride)[:, :num_segments]
    row_offs = (jnp.arange(b, dtype=seg_idx.dtype) * m)[:, None]
    return seg_max, jnp.where(seg_idx >= 0, seg_idx - row_offs, -1)


def batched_segment_min(values, segment_ids, num_segments):
    """Batched ``jax.ops.segment_min`` over per-instance segments, flattened
    to one offset-segment reduction (same layout contract as
    ``batched_segment_max_with_payload``). Returns [B, num_segments]."""
    b, m = values.shape
    stride = num_segments + 1
    offs = (jnp.arange(b, dtype=segment_ids.dtype) * stride)[:, None]
    out = jax.ops.segment_min(
        values.reshape(-1), (segment_ids + offs).reshape(-1),
        num_segments=b * stride,
    )
    return out.reshape(b, stride)[:, :num_segments]


@functools.partial(jax.jit, static_argnames=("n_steps",))
def batched_searchsorted_in_window(keys, q, lo, hi, n_steps: int):
    """Batched ``searchsorted_in_window``: keys are [B, m]; q/lo/hi are
    [B, k] (k queries per instance, windows in per-instance coordinates).
    Flattens to one search over [B * m] keys by offsetting each instance's
    windows by b * m — windows never cross instance boundaries, so every
    probe reads the same key the per-instance search would. Returns
    (pos [B, k] local, found [B, k])."""
    b, m = keys.shape
    offs = (jnp.arange(b, dtype=lo.dtype) * m)[:, None]
    pos, found = searchsorted_in_window(
        keys.reshape(-1), q.reshape(-1), (lo + offs).reshape(-1),
        (hi + offs).reshape(-1), n_steps=n_steps,
    )
    return pos.reshape(q.shape) - offs, found.reshape(q.shape)


def segment_argmax(values, segment_ids, num_segments):
    """Per-segment argmax (row index into ``values``); -1 for empty segments."""
    idx = jnp.arange(values.shape[0], dtype=jnp.int32)
    _, arg = segment_max_with_payload(values, idx, segment_ids, num_segments)
    return arg


def segment_softmax(logits, segment_ids, num_segments):
    """Numerically-stable softmax within each segment (GAT-style edge softmax)."""
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    seg_max = jnp.where(jnp.isneginf(seg_max), 0.0, seg_max)
    shifted = logits - seg_max[segment_ids]
    ex = jnp.exp(shifted)
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments=num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-30)


def coo_spmm(row, col, val, x, n_rows):
    """y = A @ x for COO A (row, col, val) and dense x [n_cols, d].

    Padding entries must have ``row == n_rows`` (they are accumulated into a
    scratch segment and dropped). This is the GNN message-passing primitive.
    """
    msgs = jnp.take(x, col, axis=0) * val[:, None]
    y = jax.ops.segment_sum(msgs, row, num_segments=n_rows + 1)
    return y[:n_rows]


def coo_sddmm(row, col, a, b):
    """Sampled dense-dense matmul: out[e] = <a[row[e]], b[col[e]]>."""
    return jnp.einsum(
        "ed,ed->e", jnp.take(a, row, axis=0), jnp.take(b, col, axis=0)
    )


@functools.partial(jax.jit, static_argnames=("n_steps",))
def lex_searchsorted(keys_r, keys_c, q_r, q_c, n_steps: int = 32):
    """Vectorized fixed-depth binary search for (q_r, q_c) in the lexicographically
    sorted key pairs (keys_r, keys_c). Returns (pos, found) where ``pos`` is the
    insertion index and ``found`` marks exact hits.

    Avoids int64 key encoding (row*ncols+col overflows int32 for big blocks);
    n_steps=32 covers any int32-sized array.
    """
    m = keys_r.shape[0]
    lo = jnp.zeros_like(q_r)
    hi = jnp.full_like(q_r, m)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        mid_c = jnp.clip(mid, 0, m - 1)
        kr = keys_r[mid_c]
        kc = keys_c[mid_c]
        # key < query (lexicographic)
        lt = (kr < q_r) | ((kr == q_r) & (kc < q_c))
        lo = jnp.where(lt, mid + 1, lo)
        hi = jnp.where(lt, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_steps, body, (lo, hi))
    pos = lo
    pos_c = jnp.clip(pos, 0, m - 1)
    found = (pos < m) & (keys_r[pos_c] == q_r) & (keys_c[pos_c] == q_c)
    return pos, found


@functools.partial(jax.jit, static_argnames=("n_steps",))
def searchsorted_in_window(keys, q, lo, hi, n_steps: int):
    """Per-query binary search for ``q`` inside the sorted window
    ``keys[lo:hi)`` (CSR-windowed completion lookup, DESIGN.md §3).

    ``n_steps`` must cover the widest window (ceil(log2(max_width)) + 1);
    with CSR row windows that is the max row degree — log2(nnz/n)-ish rounds
    instead of the log2(m) a global lex search needs. Returns (pos, found).
    """
    m = keys.shape[0]
    hi0 = hi

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        k = keys[jnp.clip(mid, 0, m - 1)]
        lt = k < q
        lo = jnp.where(lt, mid + 1, lo)
        hi = jnp.where(lt, hi, mid)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, n_steps, body, (lo, hi0))
    pos = lo
    found = (pos < hi0) & (keys[jnp.clip(pos, 0, m - 1)] == q)
    return pos, found
