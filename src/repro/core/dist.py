"""Distributed-memory AWPM via shard_map over a 2D(+pod) device grid.

The paper's √p x √p process grid maps onto the production mesh:
grid row  a  = flattened index over ``row_axes``   (e.g. ("pod", "data")),
grid col  b  = index over ``col_axis``             ("model").

O(m) edge state is strictly 2D-block-sharded ([Pr, Pc, cap] stacked blocks,
global indices, lex-sorted per block). O(n) matching state (mates, u, v,
winners) is replicated and updated identically on every device, so steps C/D
need only all_gathers and the augmentation broadcast of the paper (Alg. 6)
disappears entirely (DESIGN.md §2).

Communication per AWAC round (paper Steps A-D):
  A/B: two bucketed fixed-capacity ``all_to_all``s (first along the column
       axis, then along the row axes) carrying relabeled completion edges
       (i', j') = (mate_row[c], mate_col[r]) — the nonzeros of M Aᵀ M.
  C:   all_gather of per-local-column winners along ``row_axes``.
  D:   all_gather along ``col_axis`` to replicate the winner arrays, then the
       replicated `select_and_augment` from repro.core.single (shared code).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import PartitionSpec as P

from repro.core import batch, single
from repro.core._compat import warn_legacy
from repro.core.single import MIN_GAIN, NEG, MatchState
from repro.sparse.csr import max_row_nnz, window_depth
from repro.sparse.ops import (
    batched_searchsorted_in_window,
    batched_segment_argmax_tie,
    lex_searchsorted,
    searchsorted_in_window,
    segment_argmax_tie,
    segment_max_with_payload,
)
from repro.sparse.partition import partition_coo_2d, partition_coo_2d_batched

try:  # jax >= 0.6 spelling
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
except AttributeError:  # jax 0.4.x: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    _shard_map = functools.partial(_shard_map_exp, check_rep=False)


def make_mesh(shape, axes=("data", "model")):
    """Version-proof ``jax.make_mesh`` — THE mesh builder to pair with
    ``GridSpec`` / ``api.SolveOptions(grid=...)``: explicit Auto axis types
    on jax >= 0.6 (the shard_map engines need Auto axes), plain make_mesh
    on 0.4.x where every axis is Auto already."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    except ImportError:
        return jax.make_mesh(shape, axes)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static description of the process grid embedded in the mesh."""

    mesh: jax.sharding.Mesh
    row_axes: tuple[str, ...] = ("data",)
    col_axis: str = "model"

    @property
    def pr(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.row_axes]))

    @property
    def pc(self) -> int:
        return int(self.mesh.shape[self.col_axis])

    def block_spec(self) -> P:
        ra = self.row_axes[0] if len(self.row_axes) == 1 else self.row_axes
        return P(ra, self.col_axis, None)

    def block_spec_batched(self) -> P:
        """PartitionSpec for [Pr, Pc, B, cap] batched block arrays."""
        ra = self.row_axes[0] if len(self.row_axes) == 1 else self.row_axes
        return P(ra, self.col_axis, None, None)


def _int_fill(n):
    return jnp.int32(n)


def _search_depth(cap: int) -> int:
    """Alias of ``sparse.csr.window_depth`` — ONE formula for "rounds needed
    to binary-search a window of ``cap`` entries", so a plan-time pinned
    depth (api.Matcher) and the run-time measured depth can never drift."""
    return window_depth(cap)


def a2a_bucketed(arrays, fills, dest, valid, n_peers: int, cap_out: int,
                 axis_name, packed: bool = False):
    """Fixed-capacity bucketed all_to_all (the MPI_Alltoallv replacement).

    arrays: list of 1D [L] arrays; fills: per-array padding value.
    dest [L] in [0, n_peers); valid [L] bool. Entries beyond ``cap_out`` per
    destination bucket are dropped (counted in ``dropped`` — the caller
    retries them implicitly on the next AWAC iteration).

    ``packed=True`` (§Perf iteration M1) bitcasts all payloads into ONE
    [n_peers, cap_out, k] int32 all_to_all instead of k+1 separate
    collectives, and derives validity from the first array's fill sentinel —
    the validity exchange disappears entirely.

    Returns (out_arrays list of [n_peers*cap_out], out_valid, dropped).
    """
    L = dest.shape[0]
    d = jnp.where(valid, dest, n_peers)
    order = jnp.argsort(d, stable=True)
    ds = d[order]
    start = jnp.searchsorted(ds, jnp.arange(n_peers, dtype=ds.dtype))
    posin = jnp.arange(L, dtype=jnp.int32) - start[jnp.clip(ds, 0, n_peers - 1)].astype(jnp.int32)
    ok = (ds < n_peers) & (posin < cap_out)
    slot = jnp.where(ok, ds.astype(jnp.int32) * cap_out + posin, n_peers * cap_out)
    # explicit i32: bool sums would widen to i64 under an x64-enabled trace
    dropped = ((ds < n_peers).sum() - ok.sum()).astype(jnp.int32)

    def fill_buf(a, fv):
        buf = jnp.full((n_peers * cap_out + 1,), fv, a.dtype)
        return buf.at[slot].set(a[order])[:-1]

    if packed:
        cols = []
        for a, fv in zip(arrays, fills):
            b = fill_buf(a, fv)
            if b.dtype != jnp.int32:
                b = jax.lax.bitcast_convert_type(b, jnp.int32)
            cols.append(b)
        payload = jnp.stack(cols, axis=-1).reshape(n_peers, cap_out, len(cols))
        recv = jax.lax.all_to_all(payload, axis_name, 0, 0)
        recv = recv.reshape(-1, len(cols))
        outs = []
        for i, (a, fv) in enumerate(zip(arrays, fills)):
            col = recv[:, i]
            if a.dtype != jnp.int32:
                col = jax.lax.bitcast_convert_type(col, a.dtype)
            outs.append(col)
        # validity from the first array's sentinel (mate ids use fill = n)
        vrecv = outs[0] != fills[0]
        return outs, vrecv, dropped

    outs = []
    for a, fv in zip(arrays, fills):
        buf = fill_buf(a, fv).reshape(n_peers, cap_out)
        outs.append(jax.lax.all_to_all(buf, axis_name, 0, 0).reshape(-1))
    vbuf = jnp.zeros((n_peers * cap_out + 1,), jnp.int8).at[slot].set(
        ok.astype(jnp.int8))
    vrecv = jax.lax.all_to_all(vbuf[:-1].reshape(n_peers, cap_out),
                               axis_name, 0, 0)
    return outs, vrecv.reshape(-1).astype(bool), dropped


def _lex_pick(G, TIE, payloads, tie_fill):
    """Pick per-column (max G, tie -> min TIE) across leading device axis.

    G [D, k] float, TIE [D, k] int. Returns (g [k], tie [k], picked payloads).
    Empty columns (all -inf) return (-inf, tie_fill, payload rows from dev 0).
    """
    g0 = G.max(axis=0)
    hit = (G == g0[None, :]) & (g0[None, :] > NEG)
    tie_m = jnp.where(hit, TIE, tie_fill)
    t0 = tie_m.min(axis=0)
    hit2 = hit & (TIE == t0[None, :])
    dev = jnp.argmax(hit2, axis=0)
    out = [jnp.take_along_axis(p, dev[None, :], axis=0)[0] for p in payloads]
    return g0, t0, out


def make_dist_awac(spec: GridSpec, n: int, cap: int, a2a_caps: tuple[int, int],
                   max_iter: int = 1000, min_gain: float = MIN_GAIN,
                   packed: bool = False, backend: str = "fused",
                   window_steps: int | None = None):
    """Build the jitted distributed AWAC. Inputs: blocks [Pr, Pc, cap] (row,
    col, val) + replicated MatchState. Returns (state, iters, dropped).

    backend "fused" (default) runs the sweep engine's CSR-windowed local
    join: each block builds its per-row ``row_ptr`` once, and the Step-A
    completion lookup searches only inside row ``qi``'s short segment
    (``window_steps`` rounds ~ log2(max block-row degree), vs log2(cap) for
    the seed's global per-block lex search). "reference" keeps the seed
    path. Both are bit-identical; callers wrap the run in ``enable_x64`` to
    additionally collapse Step C's reductions into packed-key single passes.
    """
    pr, pc = spec.pr, spec.pc
    br = -(-n // pr)
    bc = -(-n // pc)
    cap1, cap2 = a2a_caps
    row_axes = spec.row_axes if len(spec.row_axes) > 1 else spec.row_axes[0]
    col_axis = spec.col_axis
    all_axes = tuple(spec.row_axes) + (spec.col_axis,)
    if window_steps is None:
        window_steps = _search_depth(cap)

    def block_fn(brow, bcol, bval, mate_row, mate_col, u, v):
        brow = brow.reshape(-1)
        bcol = bcol.reshape(-1)
        bval = bval.reshape(-1)
        b = jax.lax.axis_index(col_axis)
        a = jax.lax.axis_index(row_axes)
        if backend == "fused":
            # One-time per-block CSR row_ptr over the block's global rows
            # [a*br, (a+1)*br); the padding tail (row == n) sits beyond
            # bptr[br]. Loop-invariant, hoisted out of the AWAC rounds.
            bptr = jnp.searchsorted(
                brow, a * br + jnp.arange(br + 1, dtype=brow.dtype),
                side="left",
            ).astype(jnp.int32)

        def round_body(carry):
            state, it, _, drop_acc = carry
            mate_row, mate_col, u, v = state
            # ---- Steps A/B: relabel local nonzeros to completion-edge slots
            i2 = mate_row[bcol]
            j2 = mate_col[brow]
            valid = (brow < n) & (i2 < n) & (j2 < n)
            # stage 1: route to owning grid column (by j2)
            (o_i, o_j, o_w), v1, d1 = a2a_bucketed(
                [i2, j2, bval], [_int_fill(n), _int_fill(n), jnp.float32(0)],
                j2 // bc, valid, pc, cap1, col_axis, packed=packed,
            )
            # stage 2: route to owning grid row (by i2)
            (qi, qj, qw2), qvalid, d2 = a2a_bucketed(
                [o_i, o_j, o_w], [_int_fill(n), _int_fill(n), jnp.float32(0)],
                o_i // br, v1, pr, cap2, row_axes, packed=packed,
            )
            # ---- local join: does candidate edge (qi, qj) exist in my block?
            if backend == "fused":
                li = jnp.clip(qi - a * br, 0, br - 1)
                in_row = qvalid & (qi - a * br == li)
                lo = bptr[li]
                hi = jnp.where(in_row, bptr[li + 1], lo)
                pos, found = searchsorted_in_window(
                    bcol, qj, lo, hi, n_steps=window_steps
                )
            else:
                # (§Perf M2: search depth ceil(log2(cap)) instead of fixed 32)
                pos, found = lex_searchsorted(brow, bcol, qi, qj,
                                              n_steps=_search_depth(cap))
            w1 = bval[jnp.clip(pos, 0, brow.shape[0] - 1)]
            gain = w1 + qw2 - u[qi] - v[qj]
            cand = qvalid & found & (qi > mate_row[qj]) & (gain > min_gain)
            # ---- Step C: per-local-column winner (max gain, tie min row)
            lj = jnp.where(cand, qj - b * bc, bc).astype(jnp.int32)
            gm = jnp.where(cand, gain, NEG)
            Cg, Cidx = segment_argmax_tie(gm, qi, lj, bc + 1)
            selc = jnp.clip(Cidx[:bc], 0)
            has = Cidx[:bc] >= 0
            cg_loc = Cg[:bc]
            ci_loc = jnp.where(has, qi[selc], n).astype(jnp.int32)
            w1_loc = jnp.where(has, w1[selc], 0.0)
            w2_loc = jnp.where(has, qw2[selc], 0.0)
            # combine across grid rows
            G = jax.lax.all_gather(cg_loc, row_axes)
            I = jax.lax.all_gather(ci_loc, row_axes)
            W1 = jax.lax.all_gather(w1_loc, row_axes)
            W2 = jax.lax.all_gather(w2_loc, row_axes)
            g0, i0, (w1_0, w2_0) = _lex_pick(G, I, [W1, W2], jnp.int32(n))
            # ---- replicate per-column winners globally (Step C output)
            Cgain = jax.lax.all_gather(g0, col_axis).reshape(-1)[:n]
            Ci = jax.lax.all_gather(i0, col_axis).reshape(-1)[:n]
            Cw1 = jax.lax.all_gather(w1_0, col_axis).reshape(-1)[:n]
            Cw2 = jax.lax.all_gather(w2_0, col_axis).reshape(-1)[:n]
            Ci = jnp.where(Cgain > NEG, Ci, n).astype(jnp.int32)
            # ---- Step D + augmentation: replicated, shared with single-device
            state, n_surv = single.select_and_augment(
                n, Cgain, Ci, Cw1, Cw2, state, min_gain
            )
            return state, it + 1, n_surv > 0, drop_acc + d1 + d2

        def cond(carry):
            _, it, go, _ = carry
            return go & (it < max_iter)

        state0 = MatchState(mate_row, mate_col, u, v)
        state, iters, _, dropped = jax.lax.while_loop(
            cond, round_body, (state0, jnp.array(0, jnp.int32), jnp.array(True),
                               jnp.array(0, jnp.int32))
        )
        dropped = jax.lax.psum(dropped, all_axes)
        return state.mate_row, state.mate_col, state.u, state.v, iters, dropped

    blk = spec.block_spec()
    fn = _shard_map(
        block_fn,
        mesh=spec.mesh,
        in_specs=(blk, blk, blk, P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P(), P(), P()),
    )

    @jax.jit
    def run(brow, bcol, bval, state: MatchState):
        mr, mc, u, v, iters, dropped = fn(
            brow, bcol, bval, state.mate_row, state.mate_col, state.u, state.v
        )
        return MatchState(mr, mc, u, v), iters, dropped

    return run


def make_dist_greedy_maximal(spec: GridSpec, n: int, cap: int, max_rounds: int = 0):
    """Distributed greedy weighted maximal matching (proposal rounds).
    Bit-identical to repro.core.single.greedy_maximal."""
    pr, pc = spec.pr, spec.pc
    bc = -(-n // pc)
    row_axes = spec.row_axes if len(spec.row_axes) > 1 else spec.row_axes[0]
    col_axis = spec.col_axis
    jvec = jnp.arange(n, dtype=jnp.int32)
    ivec = jnp.arange(n, dtype=jnp.int32)

    def block_fn(brow, bcol, bval, mate_row, mate_col):
        brow = brow.reshape(-1)
        bcol = bcol.reshape(-1)
        bval = bval.reshape(-1)
        b = jax.lax.axis_index(col_axis)

        def round_body(carry):
            mate_row, mate_col, _ = carry
            avail = (brow < n) & (mate_col[brow] == n) & (mate_row[bcol] == n)
            lj = jnp.where(avail, bcol - b * bc, bc).astype(jnp.int32)
            score = jnp.where(avail, bval, NEG)
            Pg, Pidx = segment_argmax_tie(score, brow, lj, bc + 1)
            sel = jnp.clip(Pidx[:bc], 0)
            has = Pidx[:bc] >= 0
            pg_loc = Pg[:bc]
            pi_loc = jnp.where(has, brow[sel], n).astype(jnp.int32)
            G = jax.lax.all_gather(pg_loc, row_axes)
            I = jax.lax.all_gather(pi_loc, row_axes)
            g0, i0, _ = _lex_pick(G, I, [], jnp.int32(n))
            prop_val = jax.lax.all_gather(g0, col_axis).reshape(-1)[:n]
            prop_row = jax.lax.all_gather(i0, col_axis).reshape(-1)[:n]
            prop_row = jnp.where(prop_val > NEG, prop_row, n).astype(jnp.int32)
            # replicated per-row contest (same as single-device round)
            pv = jnp.where(prop_row < n, prop_val, NEG)
            _, rj = segment_max_with_payload(pv, jvec, prop_row, n + 1)
            ok = rj[:n] >= 0
            wcol = jnp.where(ok, rj[:n], n).astype(jnp.int32)
            mate_col = mate_col.at[jnp.where(ok, ivec, n)].set(wcol)
            mate_row = mate_row.at[wcol].set(jnp.where(ok, ivec, n).astype(jnp.int32))
            mate_col = mate_col.at[n].set(n)
            mate_row = mate_row.at[n].set(n)
            return mate_row, mate_col, ok.any()

        mate_row, mate_col, _ = jax.lax.while_loop(
            lambda c: c[2], round_body, (mate_row, mate_col, jnp.array(True))
        )
        return mate_row, mate_col

    blk = spec.block_spec()
    fn = _shard_map(
        block_fn, mesh=spec.mesh,
        in_specs=(blk, blk, blk, P(), P()),
        out_specs=(P(), P()),
    )

    @jax.jit
    def run(brow, bcol, bval):
        n_ = n
        mr0 = jnp.full((n_ + 1,), n_, jnp.int32)
        mc0 = jnp.full((n_ + 1,), n_, jnp.int32)
        return fn(brow, bcol, bval, mr0, mc0)

    return run


def make_dist_mcm(spec: GridSpec, n: int, cap: int):
    """Distributed maximum cardinality matching: layered BFS with per-row
    parent selection across the grid, replicated trace/flip (shared with the
    single-device implementation). Bit-identical to repro.core.single.mcm."""
    pr, pc = spec.pr, spec.pc
    br = -(-n // pr)
    row_axes = spec.row_axes if len(spec.row_axes) > 1 else spec.row_axes[0]
    col_axis = spec.col_axis

    def block_fn(brow, bcol, bval, mate_row, mate_col):
        brow = brow.reshape(-1)
        bcol = bcol.reshape(-1)
        bval = bval.reshape(-1)
        a = jax.lax.axis_index(spec.row_axes if len(spec.row_axes) > 1
                               else spec.row_axes[0])

        def bfs(mate_row, mate_col):
            frontier = jnp.zeros((n + 1,), bool).at[:n].set(mate_row[:n] == n)
            parent_col = jnp.full((n + 1,), n, jnp.int32)
            visited = jnp.zeros((n + 1,), bool)

            def bfs_body(carry):
                frontier, parent_col, visited, found, layers, _ = carry
                elig = (brow < n) & frontier[bcol] & (~visited[brow])
                li = jnp.where(elig, brow - a * br, br).astype(jnp.int32)
                score = jnp.where(elig, bval, NEG)
                Rg, Ridx = segment_argmax_tie(score, bcol, li, br + 1)
                sel = jnp.clip(Ridx[:br], 0)
                has = Ridx[:br] >= 0
                rg_loc = Rg[:br]
                rc_loc = jnp.where(has, bcol[sel], n).astype(jnp.int32)
                # combine across grid columns (a row's edges live in one grid
                # row, spread over all grid columns)
                G = jax.lax.all_gather(rg_loc, col_axis)
                C = jax.lax.all_gather(rc_loc, col_axis)
                g0, c0, _ = _lex_pick(G, C, [], jnp.int32(n))
                # replicate across grid rows -> global per-row parent
                pval = jax.lax.all_gather(g0, row_axes).reshape(-1)[:n]
                pcol = jax.lax.all_gather(c0, row_axes).reshape(-1)[:n]
                new = (pval > NEG) & (~visited[:n])
                pc_new = jnp.where(new, pcol, parent_col[:n]).astype(jnp.int32)
                parent_col = parent_col.at[:n].set(pc_new)
                visited = visited.at[:n].set(visited[:n] | new)
                free_new = new & (mate_col[:n] == n)
                found = free_new.any()
                nf_idx = jnp.where(new & ~free_new, mate_col[:n], n)
                frontier = (jnp.zeros((n + 1,), bool).at[nf_idx].set(True)
                            .at[n].set(False))
                return frontier, parent_col, visited, found, layers + 1, new.any()

            def bfs_cond(carry):
                _, _, _, found, layers, progressed = carry
                return (~found) & progressed & (layers <= n)

            return jax.lax.while_loop(
                bfs_cond, bfs_body,
                (frontier, parent_col, visited, jnp.array(False),
                 jnp.array(0, jnp.int32), jnp.array(True)),
            )

        def phase_body(carry):
            mate_row, mate_col, _ = carry
            frontier, parent_col, visited, found, layers, _ = bfs(mate_row, mate_col)
            mate_row, mate_col = single.trace_and_flip(
                parent_col, visited, found, layers, mate_row, mate_col, n
            )
            return mate_row, mate_col, found

        def phase_cond(carry):
            mate_row, _, go = carry
            return go & (mate_row[:n] == n).any()

        mate_row, mate_col, _ = jax.lax.while_loop(
            phase_cond, phase_body, (mate_row, mate_col, jnp.array(True))
        )
        return mate_row, mate_col

    blk = spec.block_spec()
    fn = _shard_map(
        block_fn, mesh=spec.mesh,
        in_specs=(blk, blk, blk, P(), P()),
        out_specs=(P(), P()),
    )

    @jax.jit
    def run(brow, bcol, bval, mate_row, mate_col):
        return fn(brow, bcol, bval, mate_row, mate_col)

    return run


# --------------------------------------------------------------------------
# Host-level driver
# --------------------------------------------------------------------------


@dataclasses.dataclass
class DistAWPM:
    """Deprecated three-dispatch distributed driver — use
    ``repro.core.api.solve`` / ``plan`` (which route through the
    single-dispatch distributed-batched engine). Partitions the graph,
    builds the three jitted phases, runs them in sequence."""

    spec: GridSpec
    n: int
    cap: int
    a2a_caps: tuple[int, int]
    max_iter: int = 1000
    min_gain: float = MIN_GAIN
    packed: bool = False
    backend: str = "fused"

    def __post_init__(self):
        warn_legacy("repro.core.dist.DistAWPM", "solve()/plan()",
                    stacklevel=4)
        self._greedy = make_dist_greedy_maximal(self.spec, self.n, self.cap)
        self._mcm = make_dist_mcm(self.spec, self.n, self.cap)
        self._awac_cache = {}

    def _get_awac(self, window_steps: int | None):
        key = window_steps
        if key not in self._awac_cache:
            self._awac_cache[key] = make_dist_awac(
                self.spec, self.n, self.cap, self.a2a_caps, self.max_iter,
                self.min_gain, packed=self.packed, backend=self.backend,
                window_steps=window_steps,
            )
        return self._awac_cache[key]

    def partition(self, g):
        """BipartiteGraph -> device-sharded block arrays (plus the static
        windowed-search depth measured from the partition's block rows)."""
        m = np.arange(g.capacity) < g.nnz
        part = partition_coo_2d(
            g.row[m], g.col[m], g.val[m], self.n, self.spec.pr, self.spec.pc,
            cap=self.cap,
        )
        sharding = jax.sharding.NamedSharding(self.spec.mesh, self.spec.block_spec())
        brow = jax.device_put(part.row, sharding)
        bcol = jax.device_put(part.col, sharding)
        bval = jax.device_put(part.val, sharding)
        # max nonzeros any (block, row) pair holds -> windowed search depth
        rows = part.row.reshape(part.row.shape[0] * part.row.shape[1], -1)
        widest = max(max_row_nnz(blk_rows, self.n) for blk_rows in rows)
        return brow, bcol, bval, window_depth(widest)

    def run(self, g, state: MatchState | None = None):
        """Returns (state, awac_iters, dropped)."""
        brow, bcol, bval, ws = self.partition(g)
        if state is None:
            mr, mc = self._greedy(brow, bcol, bval)
            mr, mc = self._mcm(brow, bcol, bval, mr, mc)
            # u, v from mates (cheap replicated lookup on host path)
            row = jnp.asarray(g.row)
            col = jnp.asarray(g.col)
            val = jnp.asarray(g.val)
            state = single.state_from_mates(row, col, val, self.n, mr, mc)
        awac = self._get_awac(ws if self.backend == "fused" else None)
        if self.backend == "fused":
            # Packed-key single-pass Step C reductions (repro.sparse.ops)
            with enable_x64():
                return awac(brow, bcol, bval, state)
        return awac(brow, bcol, bval, state)


def default_caps(n: int, m: int, pr: int, pc: int, slack: float = 2.0):
    """Bucket capacities for the two routing stages: expected load x slack.
    Under the paper's i.i.d. assumption each process receives O(m/p) requests."""
    cap_block = max(int(slack * m / (pr * pc)) + 16, 32)
    cap1 = max(int(slack * cap_block / pc) + 16, 16)
    cap2 = max(int(slack * cap1 * pc / pr) + 16, 16)
    return cap1, cap2


# --------------------------------------------------------------------------
# Distributed-BATCHED engine: B instances, one shard_map dispatch (§5)
# --------------------------------------------------------------------------


class ExchangeIntegrityError(RuntimeError):
    """The two-stage bucketed exchange lost, duplicated, or corrupted
    payloads: the result would not be bit-identical to the local engines.
    Raised by ``api._solve_dist`` on a non-zero dropped counter (undersized
    user a2a_caps) or a failed ``SolveOptions(exchange_check=True)``
    conservation audit."""


# Trace-time exchange hook for the chaos harness (``runtime.chaos``): when
# set, called as ``tap(axis_name, outs, valid) -> (outs, valid)`` on every
# batched exchange's received buffers (axis_name distinguishes the two
# routing stages). None in production — the branch folds away at trace time.
_EXCHANGE_TAP = None


def _tapped(axis_name, outs, valid):
    if _EXCHANGE_TAP is None:
        return outs, valid
    return _EXCHANGE_TAP(axis_name, outs, valid)


def _conserved(arrays, valid):
    """Order-independent conservation signature of an exchange payload:
    (count of valid entries, int32-wraparound checksum of the valid
    payloads' raw bits). The two-stage exchange is a pure routing of
    (i, j, w) triples, so both quantities are conserved end-to-end when
    nothing is dropped — any drop/duplicate changes the count, any
    corruption (including injected NaNs) changes the checksum."""
    cnt = valid.astype(jnp.int32).sum().astype(jnp.int32)
    chk = jnp.zeros((), jnp.int32)
    for a in arrays:
        bits = a if a.dtype == jnp.int32 \
            else jax.lax.bitcast_convert_type(a, jnp.int32)
        chk = chk + jnp.where(valid, bits, 0).sum().astype(jnp.int32)
    return cnt, chk


def a2a_bucketed_batched(arrays, fills, dest, valid, n_peers: int,
                         cap_out: int, axis_name, packed: bool = False):
    """Batched ``a2a_bucketed``: arrays/dest/valid are [B, L] and ONE
    collective per payload (one total when ``packed``) carries every
    instance's buckets as [n_peers, B, cap_out(, k)] — per-message latency
    amortizes across the whole batch instead of paying B exchanges.

    Returns (out arrays list of [B, n_peers * cap_out], out_valid, dropped
    int32 scalar summed over instances)."""
    b, L = dest.shape
    bix = jnp.arange(b, dtype=jnp.int32)[:, None]
    d = jnp.where(valid, dest, n_peers)
    order = jnp.argsort(d, axis=1, stable=True)
    ds = jnp.take_along_axis(d, order, axis=1)
    peers = jnp.arange(n_peers, dtype=ds.dtype)
    start = jax.vmap(lambda s: jnp.searchsorted(s, peers))(ds)
    posin = jnp.arange(L, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        start, jnp.clip(ds, 0, n_peers - 1).astype(jnp.int32), axis=1
    ).astype(jnp.int32)
    ok = (ds < n_peers) & (posin < cap_out)
    slot = jnp.where(ok, ds.astype(jnp.int32) * cap_out + posin,
                     n_peers * cap_out)
    dropped = ((ds < n_peers).sum() - ok.sum()).astype(jnp.int32)

    def fill_buf(a, fv):
        src = jnp.take_along_axis(a, order, axis=1)
        buf = jnp.full((b, n_peers * cap_out + 1), fv, a.dtype)
        return buf.at[bix, slot].set(src)[:, :-1]

    def exchange(x):
        shp = x.shape
        x = x.reshape(b, n_peers, cap_out, *shp[2:])
        x = jnp.moveaxis(x, 1, 0)  # [n_peers, B, cap_out, ...]
        x = jax.lax.all_to_all(x, axis_name, 0, 0)
        return jnp.moveaxis(x, 0, 1).reshape(shp)

    if packed:
        cols = []
        for a, fv in zip(arrays, fills):
            bf = fill_buf(a, fv)
            if bf.dtype != jnp.int32:
                bf = jax.lax.bitcast_convert_type(bf, jnp.int32)
            cols.append(bf)
        recv = exchange(jnp.stack(cols, axis=-1))
        outs = []
        for i, (a, fv) in enumerate(zip(arrays, fills)):
            c = recv[..., i]
            if a.dtype != jnp.int32:
                c = jax.lax.bitcast_convert_type(c, a.dtype)
            outs.append(c)
        # validity from the first array's sentinel (mate ids use fill = n)
        outs, vrecv = _tapped(axis_name, outs, outs[0] != fills[0])
        return outs, vrecv, dropped

    outs = [exchange(fill_buf(a, fv)) for a, fv in zip(arrays, fills)]
    vbuf = jnp.zeros((b, n_peers * cap_out + 1), jnp.int8).at[bix, slot].set(
        ok.astype(jnp.int8))[:, :-1]
    outs, vrecv = _tapped(axis_name, outs, exchange(vbuf).astype(bool))
    return outs, vrecv, dropped


def safe_a2a_caps(cap_blk: int, pr: int, pc: int) -> tuple[int, int]:
    """Bucket capacities making the two-stage exchange provably drop-free:
    stage 1 can at worst route every local edge to one column peer
    (cap1 = cap_blk); stage 2 at worst forwards everything it received to
    one row peer (cap2 = pc * cap1). The bit-identity contract with
    ``core.batch.awpm_batched`` requires that no candidate is ever dropped,
    so these are the driver defaults."""
    return cap_blk, pc * cap_blk


DIST_BATCHED_BACKENDS = ("fused", "reference", "xla", "pallas")


@functools.lru_cache(maxsize=None)
def _make_awpm_dist_batched(spec: GridSpec, n: int, b: int, cap: int,
                            a2a_caps: tuple[int, int], max_iter: int = 1000,
                            min_gain: float = MIN_GAIN, packed: bool = False,
                            backend: str = "fused",
                            window_steps: int | None = None,
                            from_state: bool = False,
                            degrade_infeasible: bool = False,
                            exchange_check: bool = False):
    """Build the single-dispatch distributed-batched AWPM (DESIGN.md §5).

    One shard_map dispatch runs greedy maximal -> MCM -> dual build -> AWAC
    for all B instances: the batched engine's loop skeletons
    (``core.batch.greedy_loop`` / ``mcm_loop`` / ``awac_loop``) carry the
    per-instance convergence masks, and only the per-round winner
    computations are swapped for 2D-block reductions + collectives — so the
    result is bit-identical per instance to ``core.batch.awpm_batched`` by
    construction. Edge state is sharded [Pr, Pc, B, cap]; all O(n) matching
    state is replicated [B, n + 1].

    backend: "fused" (default) joins Step A/B candidates against the local
    block through the batched CSR-windowed search (the fused sweep
    substrate, sparse/ops.py); "reference" keeps the per-block global lex
    search. On the 1x1 grid, "xla"/"pallas" route Steps A+B+C through
    ``core.batch``'s fused batched sweep directly (incl. the batch-grid
    Pallas kernel) — the block is the whole instance, so no exchange is
    needed.

    Returns jitted ``run(brow, bcol, bval) -> (MatchState, iters [B],
    dropped)`` over [Pr, Pc, B, cap] blocks. With ``from_state=True`` the
    runner instead takes a replicated initial MatchState ([B, n + 1]
    fields) and runs the AWAC phase only — ``run(brow, bcol, bval,
    mate_row, mate_col, u, v)`` — the distributed analogue of
    ``core.batch.awac_batched``.
    """
    pr, pc = spec.pr, spec.pc
    if backend not in DIST_BATCHED_BACKENDS:
        raise ValueError(f"unknown dist AWAC backend {backend!r}")
    if backend in ("xla", "pallas") and (pr, pc) != (1, 1):
        raise ValueError(
            f"backend {backend!r} routes through core.batch's local sweep "
            f"and needs the 1x1 grid, got {pr}x{pc}")
    br = -(-n // pr)
    bc = -(-n // pc)
    cap1, cap2 = a2a_caps
    row_axes = spec.row_axes if len(spec.row_axes) > 1 else spec.row_axes[0]
    col_axis = spec.col_axis
    all_axes = tuple(spec.row_axes) + (spec.col_axis,)
    if window_steps is None:
        window_steps = _search_depth(cap)

    def block_fn(brow, bcol, bval, *state_args):
        brow = brow.reshape(b, cap)
        bcol = bcol.reshape(b, cap)
        bval = bval.reshape(b, cap)
        adev = jax.lax.axis_index(row_axes)
        bdev = jax.lax.axis_index(col_axis)
        # Per-instance CSR row_ptr over this device's global rows
        # [adev*br, (adev+1)*br); the padding tail sits beyond bptr[:, br].
        # Loop-invariant, hoisted out of every phase loop.
        targets = adev * br + jnp.arange(br + 1, dtype=brow.dtype)
        bptr = jax.vmap(
            lambda r: jnp.searchsorted(r, targets, side="left"))(brow
        ).astype(jnp.int32)

        def gather_n(x, axis):
            """all_gather [B, k] along ``axis`` -> replicated [B, n]
            (device-major concat, then the padded tail sliced off)."""
            g = jax.lax.all_gather(x, axis)
            return jnp.moveaxis(g, 0, 1).reshape(b, -1)[:, :n]

        # ---- greedy phase: per-column proposals from 2D blocks ----
        def greedy_propose(mate_row, mate_col):
            avail = (brow < n) \
                & (jnp.take_along_axis(mate_col, brow, axis=1) == n) \
                & (jnp.take_along_axis(mate_row, bcol, axis=1) == n)
            lj = jnp.where(avail, bcol - bdev * bc, bc).astype(jnp.int32)
            score = jnp.where(avail, bval, NEG)
            Pg, Pidx = batched_segment_argmax_tie(score, brow, lj, bc + 1)
            sel = jnp.clip(Pidx[:, :bc], 0)
            has = Pidx[:, :bc] >= 0
            pi_loc = jnp.where(
                has, jnp.take_along_axis(brow, sel, axis=1), n
            ).astype(jnp.int32)
            G = jax.lax.all_gather(Pg[:, :bc], row_axes)
            I = jax.lax.all_gather(pi_loc, row_axes)
            g0, i0, _ = _lex_pick(G, I, [], jnp.int32(n))
            pv = gather_n(g0, col_axis)
            prow = gather_n(i0, col_axis)
            return pv, jnp.where(pv > NEG, prow, n).astype(jnp.int32)

        # ---- MCM phase: per-row BFS parents from 2D blocks ----
        def mcm_parents(frontier, visited):
            elig = (brow < n) & jnp.take_along_axis(frontier, bcol, axis=1) \
                & (~jnp.take_along_axis(visited, brow, axis=1))
            li = jnp.where(elig, brow - adev * br, br).astype(jnp.int32)
            score = jnp.where(elig, bval, NEG)
            Rg, Ridx = batched_segment_argmax_tie(score, bcol, li, br + 1)
            sel = jnp.clip(Ridx[:, :br], 0)
            has = Ridx[:, :br] >= 0
            rc_loc = jnp.where(
                has, jnp.take_along_axis(bcol, sel, axis=1), n
            ).astype(jnp.int32)
            # a row's edges live in ONE grid row, spread over grid columns
            G = jax.lax.all_gather(Rg[:, :br], col_axis)
            C = jax.lax.all_gather(rc_loc, col_axis)
            g0, c0, _ = _lex_pick(G, C, [], jnp.int32(n))
            pval = gather_n(g0, row_axes)
            pcol = gather_n(c0, row_axes)
            return pval > NEG, pcol

        # ---- dual build: u, v from the mates (windowed block lookup) ----
        def uv_state(mate_row, mate_col):
            gi = jnp.broadcast_to(
                (adev * br + jnp.arange(br, dtype=jnp.int32))[None, :],
                (b, br))
            gis = jnp.clip(gi, 0, n)
            q = jnp.take_along_axis(mate_col, gis, axis=1)
            pos, found = batched_searchsorted_in_window(
                bcol, q, bptr[:, :br], bptr[:, 1:], n_steps=window_steps)
            w = jnp.where(
                found & (gi < n),
                jnp.take_along_axis(bval, jnp.clip(pos, 0, cap - 1), axis=1),
                0.0)
            bix = jnp.arange(b, dtype=jnp.int32)[:, None]
            # each matched edge (i, mate_col[i]) lives in exactly one block,
            # so the psum replicates the one found weight (plus exact zeros)
            uu = jnp.zeros((b, n + 1), jnp.float32).at[
                bix, jnp.where(gi < n, gis, n)].set(w)
            u = jax.lax.psum(uu, all_axes).at[:, n].set(0.0)
            v = jnp.zeros((b, n + 1), jnp.float32).at[:, :n].set(
                jnp.where(mate_row[:, :n] < n,
                          jnp.take_along_axis(
                              u, jnp.clip(mate_row[:, :n], 0, n), axis=1),
                          0.0))
            return MatchState(mate_row, mate_col, u, v)

        # ---- AWAC Steps A+B+C: batched exchange + windowed local join ----
        def cwinners(state):
            mate_row, mate_col, u, v = state
            i2 = jnp.take_along_axis(mate_row, bcol, axis=1)
            j2 = jnp.take_along_axis(mate_col, brow, axis=1)
            valid = (brow < n) & (i2 < n) & (j2 < n)
            if exchange_check:
                cnt_in, chk_in = _conserved([i2, j2, bval], valid)
            # stage 1: route to owning grid column (by j2)
            (o_i, o_j, o_w), v1, d1 = a2a_bucketed_batched(
                [i2, j2, bval],
                [_int_fill(n), _int_fill(n), jnp.float32(0)],
                j2 // bc, valid, pc, cap1, col_axis, packed=packed,
            )
            # stage 2: route to owning grid row (by o_i)
            (qi, qj, qw2), qvalid, d2 = a2a_bucketed_batched(
                [o_i, o_j, o_w],
                [_int_fill(n), _int_fill(n), jnp.float32(0)],
                o_i // br, v1, pr, cap2, row_axes, packed=packed,
            )
            if exchange_check:
                # end-to-end conservation: the exchange is a pure routing
                # of (i, j, w) triples, so a global count balance (minus
                # capacity drops) and an order-independent checksum (when
                # drop-free) must both hold every round
                cnt_out, chk_out = _conserved([qi, qj, qw2], qvalid)
                tot = jax.lax.psum(
                    jnp.stack([cnt_in, chk_in, cnt_out, chk_out, d1 + d2]),
                    all_axes)
                bad = ((tot[0] - tot[4]) != tot[2]) \
                    | ((tot[4] == 0) & (tot[1] != tot[3]))
                aux = jnp.stack([tot[4], bad.astype(jnp.int32)])
            else:
                aux = d1 + d2
            if backend == "reference":
                pos, found = jax.vmap(functools.partial(
                    lex_searchsorted, n_steps=_search_depth(cap)
                ))(brow, bcol, qi, qj)
            else:  # fused sweep substrate: batched CSR-windowed search
                li = jnp.clip(qi - adev * br, 0, br - 1)
                in_row = qvalid & (qi - adev * br == li)
                lo = jnp.take_along_axis(bptr, li, axis=1)
                hi = jnp.where(
                    in_row, jnp.take_along_axis(bptr, li + 1, axis=1), lo)
                pos, found = batched_searchsorted_in_window(
                    bcol, qj, lo, hi, n_steps=window_steps)
            w1 = jnp.take_along_axis(bval, jnp.clip(pos, 0, cap - 1), axis=1)
            gain = w1 + qw2 \
                - jnp.take_along_axis(u, jnp.clip(qi, 0, n), axis=1) \
                - jnp.take_along_axis(v, jnp.clip(qj, 0, n), axis=1)
            cand = qvalid & found & (gain > min_gain) & (
                qi > jnp.take_along_axis(mate_row, jnp.clip(qj, 0, n), axis=1))
            # Step C: per-local-column winner (max gain, tie min row)
            lj = jnp.where(cand, qj - bdev * bc, bc).astype(jnp.int32)
            gm = jnp.where(cand, gain, NEG)
            Cg, Cidx = batched_segment_argmax_tie(gm, qi, lj, bc + 1)
            sel = jnp.clip(Cidx[:, :bc], 0)
            has = Cidx[:, :bc] >= 0
            ci_loc = jnp.where(
                has, jnp.take_along_axis(qi, sel, axis=1), n
            ).astype(jnp.int32)
            w1_loc = jnp.where(has, jnp.take_along_axis(w1, sel, axis=1), 0.0)
            w2_loc = jnp.where(has, jnp.take_along_axis(qw2, sel, axis=1), 0.0)
            G = jax.lax.all_gather(Cg[:, :bc], row_axes)
            I = jax.lax.all_gather(ci_loc, row_axes)
            W1 = jax.lax.all_gather(w1_loc, row_axes)
            W2 = jax.lax.all_gather(w2_loc, row_axes)
            g0, i0, (w1_0, w2_0) = _lex_pick(G, I, [W1, W2], jnp.int32(n))
            Cgain = gather_n(g0, col_axis)
            Ci = gather_n(i0, col_axis)
            Cw1 = gather_n(w1_0, col_axis)
            Cw2 = gather_n(w2_0, col_axis)
            Ci = jnp.where(Cgain > NEG, Ci, n).astype(jnp.int32)
            return Cgain, Ci, Cw1, Cw2, aux

        if backend in ("xla", "pallas"):
            # 1x1 grid: the block IS the instance — Steps A+B+C run through
            # the batched fused sweep (incl. the batch-grid Pallas kernel).
            rptr = jax.vmap(lambda r: jnp.searchsorted(
                r, jnp.arange(n + 2, dtype=r.dtype), side="left"))(brow
            ).astype(jnp.int32)

            def cwinners(state):  # noqa: F811 — intentional override
                out = batch._cwinners_batched(
                    backend, brow, bcol, bval, rptr, n, state, min_gain,
                    window_steps)
                zero = jnp.zeros((2,), jnp.int32) if exchange_check \
                    else jnp.array(0, jnp.int32)
                return (*out, zero)

        # ---- the pipeline: shared batched loop skeletons, dist winners ----
        if from_state:
            state0 = MatchState(*state_args)
        else:
            mr, mc = batch.greedy_loop(n, b, greedy_propose)
            mr, mc = batch.mcm_loop(n, b, mr, mc, mcm_parents)
            state0 = uv_state(mr, mc)
        state, iters, aux = batch.awac_loop(
            n, state0, max_iter, min_gain, cwinners,
            active0=(batch.is_perfect_batched(state0, n)
                     if degrade_infeasible else None),
            aux0=(jnp.zeros((2,), jnp.int32) if exchange_check else None))
        if not exchange_check:
            # the per-round [dropped, integrity] pair is already psum'd
            # inside cwinners; the plain dropped counter is not
            aux = jax.lax.psum(aux, all_axes)
        return (state.mate_row, state.mate_col, state.u, state.v, iters,
                aux)

    blk = spec.block_spec_batched()
    state_specs = (P(), P(), P(), P()) if from_state else ()
    fn = _shard_map(
        block_fn, mesh=spec.mesh,
        in_specs=(blk, blk, blk) + state_specs,
        out_specs=(P(), P(), P(), P(), P(), P()),
    )

    @jax.jit
    def run(brow, bcol, bval, *state_args):
        mr, mc, u, v, iters, dropped = fn(brow, bcol, bval, *state_args)
        return MatchState(mr, mc, u, v), iters, dropped

    return run


@dataclasses.dataclass
class _DistBatchedAWPM:
    """Host driver for the single-dispatch distributed-batched AWPM: plans
    the per-block capacity from true block occupancy, partitions the padded
    [B, cap] batch over the grid, plans drop-free a2a bucket capacities,
    and dispatches the cached engine. Internal engine behind
    ``repro.core.api.solve``/``plan`` (grid dispatch target) and the
    deprecated ``DistBatchedAWPM`` / ``awpm_dist_batched`` shims."""

    spec: GridSpec
    n: int
    cap: int | None = None  # per-block capacity (None -> true occupancy)
    a2a_caps: tuple[int, int] | None = None  # None -> safe_a2a_caps
    max_iter: int = 1000
    min_gain: float = MIN_GAIN
    packed: bool = False
    backend: str = "fused"
    window_steps: int | None = None  # None -> measured from the partition
    degrade_infeasible: bool = False  # skip AWAC on infeasible instances
    exchange_check: bool = False  # per-round exchange conservation audit

    def partition(self, row, col, val):
        """[B, cap] padded COO -> device-sharded [Pr, Pc, B, cap_blk] blocks
        (plus the partition and the measured windowed-search depth)."""
        part = partition_coo_2d_batched(
            row, col, val, self.n, self.spec.pr, self.spec.pc, cap=self.cap)
        sharding = jax.sharding.NamedSharding(
            self.spec.mesh, self.spec.block_spec_batched())
        brow = jax.device_put(part.row, sharding)
        bcol = jax.device_put(part.col, sharding)
        bval = jax.device_put(part.val, sharding)
        ws = window_depth(max_row_nnz(part.row.reshape(-1, part.cap), self.n))
        return part, brow, bcol, bval, ws

    def run(self, row, col, val, state: MatchState | None = None):
        """row/col/val: padded [B, cap] lex-sorted COO sharing n (see
        ``core.batch.stack_graphs``). Returns (MatchState with [B, n + 1]
        fields, awac_iters [B], dropped) — per instance bit-identical to
        ``core.batch.awpm_batched(row, col, val, n)``. An explicit
        replicated ``state`` skips greedy/MCM and runs the AWAC phase only
        (the distributed ``core.batch.awac_batched``)."""
        part, brow, bcol, bval, ws = self.partition(row, col, val)
        caps = self.a2a_caps or safe_a2a_caps(
            part.cap, self.spec.pr, self.spec.pc)
        if self.window_steps is not None:
            # explicit pin (api.plan): extra search depth never changes a
            # windowed-search result, so any depth >= the measured one is
            # bit-identical — and a pinned depth keys one compiled engine
            # across run() calls with varying data. Clamped UP to the
            # measured need so an undersized pin can never silently miss
            # completion edges.
            ws = max(ws, self.window_steps)
        fn = _make_awpm_dist_batched(
            self.spec, self.n, part.b, part.cap, caps, self.max_iter,
            self.min_gain, packed=self.packed, backend=self.backend,
            window_steps=ws, from_state=state is not None,
            degrade_infeasible=self.degrade_infeasible,
            exchange_check=self.exchange_check)
        # x64 trace context: every winner reduction collapses to the
        # packed-key single pass (repro.sparse.ops), as in core.batch.
        with enable_x64():
            if state is not None:
                return fn(brow, bcol, bval, *state)
            return fn(brow, bcol, bval)


@dataclasses.dataclass
class DistBatchedAWPM(_DistBatchedAWPM):
    """Deprecated host driver — use ``repro.core.api.solve`` (one-shot) or
    ``repro.core.api.plan`` (compile-once/run-many ``Matcher``)."""

    def __post_init__(self):
        warn_legacy("repro.core.dist.DistBatchedAWPM", "plan()",
                    stacklevel=4)


def make_awpm_dist_batched(spec: GridSpec, n: int, b: int, cap: int,
                           a2a_caps: tuple[int, int], max_iter: int = 1000,
                           min_gain: float = MIN_GAIN, packed: bool = False,
                           backend: str = "fused",
                           window_steps: int | None = None,
                           from_state: bool = False):
    """Deprecated factory for the raw block-level engine — use
    ``repro.core.api.plan`` (the ``Matcher`` handle pins capacities and the
    compiled engine at plan time)."""
    warn_legacy("repro.core.dist.make_awpm_dist_batched", "plan()")
    return _make_awpm_dist_batched(
        spec, n, b, cap, a2a_caps, max_iter, min_gain, packed=packed,
        backend=backend, window_steps=window_steps, from_state=from_state)


def _awpm_dist_batched(row, col, val, n: int, spec, *,
                       cap: int | None = None,
                       a2a_caps: tuple[int, int] | None = None,
                       max_iter: int = 1000, min_gain: float = MIN_GAIN,
                       packed: bool = False, backend: str = "fused"):
    """One-shot distributed-batched AWPM on the 2D(+pod) device grid
    (DESIGN.md §5): solves B padded [B, cap] COO instances in a single
    shard_map dispatch with per-instance convergence masks, edge state
    sharded [Pr, Pc, B, cap_blk] and O(n) state replicated. Per instance
    bit-identical to ``core.batch._awpm_batched`` (itself pinned to
    ``core.single._awpm``).

    ``spec`` is a GridSpec or a Mesh (axes ("data", "model")). Returns
    (MatchState with [B, n + 1] fields, awac_iters [B], dropped).

    Internal engine behind ``repro.core.api.solve`` (grid dispatch target)
    and the deprecated ``awpm_dist_batched`` shim."""
    if isinstance(spec, jax.sharding.Mesh):
        spec = GridSpec(spec)
    drv = _DistBatchedAWPM(spec, n, cap=cap, a2a_caps=a2a_caps,
                           max_iter=max_iter, min_gain=min_gain,
                           packed=packed, backend=backend)
    return drv.run(row, col, val)


def awpm_dist_batched(row, col, val, n: int, spec, *, cap: int | None = None,
                      a2a_caps: tuple[int, int] | None = None,
                      max_iter: int = 1000, min_gain: float = MIN_GAIN,
                      packed: bool = False, backend: str = "fused"):
    """Deprecated alias of the distributed-batched pipeline — use
    ``repro.core.api.solve`` with ``SolveOptions(grid=...)``."""
    warn_legacy("repro.core.dist.awpm_dist_batched", "solve()")
    return _awpm_dist_batched(
        row, col, val, n, spec, cap=cap, a2a_caps=a2a_caps,
        max_iter=max_iter, min_gain=min_gain, packed=packed, backend=backend)
