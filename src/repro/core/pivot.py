"""Static pivoting — the paper's motivating application (§6.6).

A perfect matching on the bipartite graph of a sparse matrix gives a row
permutation placing "heavy" entries on the diagonal, so a distributed LU
factorization can proceed without dynamic pivoting (SuperLU_DIST's usage of
MC64). Two objective metrics, as in the paper:

  - "sum":     maximize sum of matched |a_ij|            (MC64 option 4)
  - "product": maximize product of |a_ij| = sum of logs  (MC64 option 5,
               used in Table 6.3)

Includes the LAPACK-style equilibration of §6.6 and an (intentionally)
pivot-free LU solver to measure the solution error the permutation buys.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import BipartiteGraph, from_coo


def log_transformed(g: BipartiteGraph, floor: float = 1e-30) -> BipartiteGraph:
    """Edge weights |a_ij| -> log|a_ij| (product metric). Padding stays 0."""
    m = np.arange(g.capacity) < g.nnz
    val = g.val.copy()
    val[m] = np.log(np.maximum(np.abs(val[m]), floor)).astype(np.float32)
    return BipartiteGraph(n=g.n, nnz=g.nnz, row=g.row, col=g.col, val=val)


def equilibrate(a: np.ndarray):
    """Row/column scaling D_r A D_c with unit row/col max (LAPACK-style simple
    equilibration, one pass each). Returns (scaled, d_r, d_c)."""
    absa = np.abs(a)
    d_r = 1.0 / np.maximum(absa.max(axis=1), 1e-300)
    a1 = a * d_r[:, None]
    d_c = 1.0 / np.maximum(np.abs(a1).max(axis=0), 1e-300)
    return a1 * d_c[None, :], d_r, d_c


def row_permutation(mate_row: np.ndarray, n: int) -> np.ndarray:
    """perm such that (P_r A)[j, j] = A[mate_row[j], j] is the matched entry."""
    perm = np.asarray(mate_row[:n], dtype=np.int64)
    assert (perm < n).all(), "matching must be perfect for static pivoting"
    return perm


def lu_nopivot(a: np.ndarray):
    """Doolittle LU with NO pivoting — emulates the distributed solver's
    static-pivot factorization. Returns (L, U) or raises on zero pivot."""
    n = a.shape[0]
    lu = a.astype(np.float64).copy()
    for k in range(n - 1):
        piv = lu[k, k]
        if piv == 0.0:
            raise ZeroDivisionError(f"zero pivot at {k}")
        lu[k + 1 :, k] /= piv
        lu[k + 1 :, k + 1 :] -= np.outer(lu[k + 1 :, k], lu[k, k + 1 :])
    ell = np.tril(lu, -1) + np.eye(n)
    return ell, np.triu(lu)


def solve_nopivot(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    from scipy.linalg import solve_triangular

    ell, u = lu_nopivot(a)
    y = solve_triangular(ell, b, lower=True, unit_diagonal=True)
    return solve_triangular(u, y)


def static_pivot_solve(a: np.ndarray, b: np.ndarray, mate_row: np.ndarray):
    """Full §6.6 pipeline: equilibrate -> permute rows by the matching ->
    LU without pivoting -> undo scalings. Returns x and the relative error
    helper expects x_true separately."""
    n = a.shape[0]
    a_s, d_r, d_c = equilibrate(a)
    perm = row_permutation(mate_row, n)
    a_p = a_s[perm, :]
    b_p = (b * d_r)[perm]
    y = solve_nopivot(a_p, b_p)
    return d_c * y


def relative_error(x: np.ndarray, x_true: np.ndarray) -> float:
    return float(np.max(np.abs(x - x_true)) / max(np.max(np.abs(x)), 1e-300))


# --------------------------------------------------------------------------
# Multi-matrix batched pivoting (one matching dispatch for a whole batch)
# --------------------------------------------------------------------------


def batched_pivot_permutations(mats, metric: str = "product",
                               backend: str = "auto", mesh=None):
    """AWPM row permutations for a batch of same-size matrices via ONE
    batched matching dispatch — the pivot-serving path: SuperLU/PARDISO-
    style preprocessing pipelines hold many matrices, and the matching
    engine is the shared front-end. One ``api.solve`` call either way:
    ``mesh=None`` runs the local batched engine; a Mesh (or
    ``core.dist.GridSpec``) runs the whole batch across the 2D device grid
    — bit-identical permutations.

    metric: "product" (log-weights, MC64 option-5 analogue, Table 6.3) or
    "sum" (raw |a_ij|). Each matrix is equilibrated first, as in §6.6.
    Returns (perms [B, n] int64, awac_iters [B])."""
    if metric not in ("product", "sum"):
        raise ValueError(f"unknown pivot metric {metric!r}")
    from repro.core.api import MatchingProblem, SolveOptions, solve
    from repro.core.graph import from_coo

    n = mats[0].shape[0]
    gs = []
    for a in mats:
        if a.shape != (n, n):
            raise ValueError("all matrices in a batch must share n")
        a_s, _, _ = equilibrate(np.asarray(a))
        rr, cc = np.nonzero(a_s)
        g = from_coo(rr.astype(np.int32), cc.astype(np.int32),
                     np.abs(a_s[rr, cc]).astype(np.float32), n)
        gs.append(log_transformed(g) if metric == "product" else g)
    res = solve(MatchingProblem.stack(gs),
                SolveOptions(backend=backend, grid=mesh))
    mrs = np.array(res.mate_row[:, :n])
    perms = np.stack([row_permutation(mr, n) for mr in mrs])
    return perms, np.array(res.awac_iters)


def static_pivot_solve_batched(mats, bs, metric: str = "product",
                               backend: str = "auto", mesh=None):
    """Full §6.6 pipeline for B systems: one batched AWPM dispatch (local,
    or across the device grid when ``mesh`` is given) computes all row
    permutations, then each system is equilibrated/permuted/factorized
    (the LU itself stays per-matrix numpy — the matching is the batched hot
    path). Returns (xs [B, n], awac_iters [B])."""
    perms, iters = batched_pivot_permutations(mats, metric=metric,
                                              backend=backend, mesh=mesh)
    xs = [static_pivot_solve(a, b, perm)
          for a, b, perm in zip(mats, bs, perms)]
    return np.stack(xs), iters
