"""Bipartite graph/matrix structures + synthetic matrix suite.

The paper evaluates on UF sparse collection matrices (offline here); the
generators below reproduce the structural families of Table 6.1 (circuit
simulation, FEM/structural banded-symmetric, power-law) while *guaranteeing*
full structural rank by planting a hidden random permutation — matching the
paper's assumption that a perfect matching exists.

Weights are normalized as in §6.1: each row/column max is 1 and all entries
are bounded by 1.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BipartiteGraph:
    """Edge-list (COO) view of a square sparse matrix; padded, shape-static.

    Padding entries carry row = col = n, val = 0.
    """

    n: int
    nnz: int
    row: np.ndarray  # [cap] int32
    col: np.ndarray  # [cap] int32
    val: np.ndarray  # [cap] float32 (weights; paper uses |a_ij| post-normalization)

    @property
    def capacity(self) -> int:
        return int(self.row.shape[0])

    def to_dense(self, fill=0.0) -> np.ndarray:
        a = np.full((self.n, self.n), fill, dtype=np.float64)
        m = np.arange(self.capacity) < self.nnz
        a[self.row[m], self.col[m]] = self.val[m]
        return a

    def structure_dense(self) -> np.ndarray:
        s = np.zeros((self.n, self.n), dtype=bool)
        m = np.arange(self.capacity) < self.nnz
        s[self.row[m], self.col[m]] = True
        return s


def _dedupe(row, col, val):
    key = row.astype(np.int64) * (col.max() + 1 if col.size else 1) + col
    _, idx = np.unique(key, return_index=True)
    return row[idx], col[idx], val[idx]


def from_coo(row, col, val, n, capacity=None, pad_align: int = 8) -> BipartiteGraph:
    row = np.asarray(row, dtype=np.int32)
    col = np.asarray(col, dtype=np.int32)
    val = np.asarray(val, dtype=np.float32)
    order = np.lexsort((col, row))
    row, col, val = row[order], col[order], val[order]
    nnz = int(row.shape[0])
    if capacity is None:
        capacity = max(((nnz + pad_align - 1) // pad_align) * pad_align, pad_align)
    pad = capacity - nnz
    row = np.concatenate([row, np.full(pad, n, np.int32)])
    col = np.concatenate([col, np.full(pad, n, np.int32)])
    val = np.concatenate([val, np.zeros(pad, np.float32)])
    return BipartiteGraph(n=n, nnz=nnz, row=row, col=col, val=val)


def normalize_rowcol_max(row, col, val):
    """Paper §6.1 normalization: max entry of each row/column is 1, entries <= 1."""
    val = np.abs(val).astype(np.float64)
    n = int(max(row.max(), col.max())) + 1 if row.size else 0
    rmax = np.zeros(n)
    np.maximum.at(rmax, row, val)
    val = val / np.maximum(rmax[row], 1e-300)
    cmax = np.zeros(n)
    np.maximum.at(cmax, col, val)
    val = val / np.maximum(cmax[col], 1e-300)
    return val.astype(np.float32)


def generate(
    n: int,
    avg_degree: float = 4.0,
    kind: str = "uniform",
    seed: int = 0,
    normalize: bool = True,
) -> BipartiteGraph:
    """Synthetic square matrix with a planted perfect matching.

    kinds:
      uniform   — iid edges, iid U(0,1] weights (baseline)
      circuit   — planted diagonal heavy (like post-MC64 circuit matrices),
                  plus power-law fan-out columns
      banded    — FEM-like symmetric band (bandwidth ~ 3*avg_degree)
      powerlaw  — skewed degree distribution, adversarial for greedy
      antigreedy — weights arranged so pure greedy maximal matching is ~1/2 weight
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n).astype(np.int32)  # planted perfect matching
    rows = [np.arange(n, dtype=np.int32)]
    cols = [perm]
    m_extra = int(n * max(avg_degree - 1.0, 0.0))

    if kind == "banded":
        band = max(int(3 * avg_degree), 2)
        r = rng.integers(0, n, size=m_extra).astype(np.int32)
        off = rng.integers(-band, band + 1, size=m_extra)
        c = np.clip(r + off, 0, n - 1).astype(np.int32)
    elif kind in ("powerlaw", "circuit", "antigreedy"):
        # zipf-ish column popularity
        popularity = 1.0 / (1.0 + np.arange(n)) ** 0.8
        popularity /= popularity.sum()
        r = rng.integers(0, n, size=m_extra).astype(np.int32)
        c = rng.choice(n, size=m_extra, p=popularity).astype(np.int32)
    else:
        r = rng.integers(0, n, size=m_extra).astype(np.int32)
        c = rng.integers(0, n, size=m_extra).astype(np.int32)
    rows.append(r)
    cols.append(c)
    row = np.concatenate(rows)
    col = np.concatenate(cols)

    if kind == "circuit":
        # heavy planted diagonal, weaker off-diagonals — AWPM should hit ~100%
        val = rng.uniform(0.0, 0.5, size=row.shape[0])
        val[:n] = rng.uniform(0.8, 1.0, size=n)
    elif kind == "antigreedy":
        # off-diagonal slightly heavier than planted edges so greedy locks
        # wrong edges; exercises the augmenting-cycle phase hard.
        val = rng.uniform(0.9, 1.0, size=row.shape[0])
        val[:n] = rng.uniform(0.5, 0.6, size=n)
    else:
        val = rng.uniform(1e-3, 1.0, size=row.shape[0])

    row, col, val = _dedupe(row, col, val.astype(np.float32))
    if normalize:
        val = normalize_rowcol_max(row, col, val)
    return from_coo(row, col, val, n)


SUITE_KINDS = ("uniform", "circuit", "banded", "powerlaw", "antigreedy")


def matrix_suite(n_matrices: int = 100, n: int = 120, seed: int = 0):
    """The >=100-matrix evaluation suite used for the Table 6.2 analogue."""
    out = []
    for i in range(n_matrices):
        kind = SUITE_KINDS[i % len(SUITE_KINDS)]
        deg = 3.0 + (i % 7)
        out.append(
            (
                f"{kind}_n{n}_d{deg:.0f}_s{i}",
                generate(n, avg_degree=deg, kind=kind, seed=seed + i),
            )
        )
    return out
