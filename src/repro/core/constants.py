"""Shared numeric constants of the AWPM algorithm family.

``MIN_GAIN`` is the paper's epsilon: a 4-cycle must improve the matching
weight by more than this to count as an augmenting candidate (guards both
float round-off churn and nontermination on exact ties). The single-device,
batched, distributed, and numpy-reference engines — and the public
``SolveOptions`` default — all import this one definition so they can never
drift apart.
"""
from __future__ import annotations

MIN_GAIN = 1e-6
