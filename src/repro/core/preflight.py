"""Preflight: structural validation of a ``MatchingProblem`` before solve.

The paper's target regime (SuperLU_DIST pre-pivoting at 256 nodes) feeds
AWPM matrices straight off disk or out of a factorization pipeline —
exactly where degenerate inputs appear: ``nan``/``inf`` weights from a
broken transform, duplicate coordinate entries from unassembled triplet
files, empty rows/columns (structurally singular blocks), and instances
with no perfect matching at all. The engines assume none of that: a NaN
weight silently poisons every gain comparison, and an infeasible instance
can never become perfect no matter how long AWAC runs (4-cycle
augmentation preserves cardinality), so every AWAC round spent on one is
pure waste.

This module is the cheap host-side pass that turns those failure modes
into typed, located diagnoses, wired into ``solve()``/``Matcher`` through
``SolveOptions(on_invalid=...)``:

  raise      (default) any fatal data issue or an infeasible instance
             raises ``PreflightError`` / ``InfeasibleProblemError``.
  sanitize   fatal data issues are repaired (non-finite edges dropped,
             duplicate coordinates merged keep-max); infeasibility still
             raises — sanitization fixes data, not structure.
  degrade    repair like ``sanitize``, and return the maximal (imperfect)
             matching with ``perfect=False`` plus the diagnosis attached
             as ``MatchResult.diagnosis`` instead of raising.

Under every policy the solve pipeline short-circuits infeasible instances
after the MCM phase (the cardinality ceiling is known there), so a
deficiency-1 instance costs O(greedy + MCM) work, never ``max_iter`` AWAC
rounds. All checks run on concrete host arrays only; under a jit trace
preflight is skipped and the early exit still applies (the result simply
carries ``perfect=False`` with no diagnosis).

Check catalogue (severities):

  nonfinite_weight   fatal       nan/inf edge weights
  duplicate_edge     fatal       repeated (row, col) coordinates
  negative_weight    warning     legitimate in e.g. the raw log2_scaled
                                 metric — reported, never repaired/raised
  empty_row          structural  a row with no edges (no perfect matching)
  empty_col          structural  a column with no edges
  deficient          structural  max cardinality < n (MCM screen — found
                                 by ``preflight(feasibility=True)`` or by
                                 the solve pipeline's own MCM phase)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = [
    "InfeasibleProblemError",
    "PreflightError",
    "PreflightIssue",
    "PreflightReport",
    "preflight",
    "sanitize",
]

#: issue kind -> severity ("fatal" data corruption, "structural"
#: infeasibility, "warning" reported-but-legal)
SEVERITIES = {
    "nonfinite_weight": "fatal",
    "duplicate_edge": "fatal",
    "negative_weight": "warning",
    "empty_row": "structural",
    "empty_col": "structural",
    "deficient": "structural",
}


@dataclasses.dataclass(frozen=True)
class PreflightIssue:
    """One located finding. ``instance`` is the batch index (None for a
    single-instance problem), ``where`` a small sample of offending
    indices (edge positions for data issues, row/col ids for structural
    ones) — enough to locate the problem without hauling O(m) data."""

    kind: str
    count: int
    detail: str
    instance: int | None = None
    where: tuple[int, ...] = ()

    @property
    def severity(self) -> str:
        return SEVERITIES[self.kind]

    def __str__(self):
        at = "" if self.instance is None else f" [instance {self.instance}]"
        return f"{self.kind}{at}: {self.detail}"


@dataclasses.dataclass(frozen=True)
class PreflightReport:
    """The typed diagnosis: every issue found, queryable by severity."""

    issues: tuple[PreflightIssue, ...]
    checked_feasibility: bool = False

    @property
    def ok(self) -> bool:
        """No issues at all (warnings included)."""
        return not self.issues

    @property
    def fatal(self) -> tuple[PreflightIssue, ...]:
        return tuple(i for i in self.issues if i.severity == "fatal")

    @property
    def structural(self) -> tuple[PreflightIssue, ...]:
        return tuple(i for i in self.issues if i.severity == "structural")

    @property
    def warnings(self) -> tuple[PreflightIssue, ...]:
        return tuple(i for i in self.issues if i.severity == "warning")

    @property
    def solvable(self) -> bool:
        """No fatal data corruption and no structural infeasibility."""
        return not self.fatal and not self.structural

    def summary(self) -> str:
        if not self.issues:
            return "preflight: clean"
        return "; ".join(str(i) for i in self.issues)

    def extend(self, *issues: PreflightIssue) -> "PreflightReport":
        return PreflightReport(self.issues + tuple(issues),
                               self.checked_feasibility)


class PreflightError(ValueError):
    """A fatal or structural preflight finding under ``on_invalid="raise"``.
    Carries the full typed ``report``."""

    def __init__(self, report: PreflightReport, message: str | None = None):
        self.report = report
        super().__init__(message or report.summary())


class InfeasibleProblemError(PreflightError):
    """The instance admits no perfect matching (empty row/column or a
    Hall-violating deficiency found by the MCM screen)."""


def _sample(idx: np.ndarray, k: int = 4) -> tuple[int, ...]:
    return tuple(int(x) for x in idx[:k])


def _scan_instance(row, col, val, n: int, inst: int | None):
    """All cheap checks for one instance's padded COO triple (host numpy)."""
    issues = []
    real = (row < n) & (col < n)
    r, c, v = row[real], col[real], val[real]
    pos = np.flatnonzero(real)

    bad = ~np.isfinite(v)
    if bad.any():
        where = pos[bad]
        issues.append(PreflightIssue(
            "nonfinite_weight", int(bad.sum()),
            f"{int(bad.sum())} non-finite edge weight(s), e.g. edge "
            f"#{int(where[0])} ({int(r[bad][0])}, {int(c[bad][0])}) = "
            f"{v[bad][0]!r}", inst, _sample(where)))

    neg = np.isfinite(v) & (v < 0)
    if neg.any():
        issues.append(PreflightIssue(
            "negative_weight", int(neg.sum()),
            f"{int(neg.sum())} negative edge weight(s) (min "
            f"{float(v[neg].min()):g}) — legal, but consider a "
            f"decision-invariant non-negative lift "
            f"(data.weight_transforms)", inst, _sample(pos[neg])))

    key = r.astype(np.int64) * (n + 1) + c
    skey = np.sort(key)
    dup = skey[1:] == skey[:-1]
    if dup.any():
        k0 = int(skey[1:][dup][0])
        issues.append(PreflightIssue(
            "duplicate_edge", int(dup.sum()),
            f"{int(dup.sum())} duplicate (row, col) coordinate(s), e.g. "
            f"({k0 // (n + 1)}, {k0 % (n + 1)}) — merge duplicates "
            f"(from_coo keeps raw triples as given)", inst,
            _sample(np.unique(skey[1:][dup]))))

    row_deg = np.bincount(r, minlength=n)
    col_deg = np.bincount(c, minlength=n)
    er = np.flatnonzero(row_deg == 0)
    ec = np.flatnonzero(col_deg == 0)
    if er.size:
        issues.append(PreflightIssue(
            "empty_row", int(er.size),
            f"{er.size} row(s) with no edges (e.g. row {int(er[0])}): no "
            f"perfect matching exists", inst, _sample(er)))
    if ec.size:
        issues.append(PreflightIssue(
            "empty_col", int(ec.size),
            f"{ec.size} column(s) with no edges (e.g. column "
            f"{int(ec[0])}): no perfect matching exists", inst,
            _sample(ec)))
    return issues


def preflight(problem, *, feasibility: bool = False) -> PreflightReport:
    """Run the structural pass over ``problem`` (host numpy, O(m log m)).

    ``feasibility=True`` additionally runs the greedy + MCM screen (the
    existing pipeline phases — O(MCM) work, no AWAC) and reports any
    Hall-style deficiency the cheap empty-row/column check cannot see.
    """
    row = np.asarray(problem.row)
    col = np.asarray(problem.col)
    val = np.asarray(problem.val)
    n = int(problem.n)
    issues = []
    if row.ndim == 1:
        issues += _scan_instance(row, col, val, n, None)
    else:
        for b in range(row.shape[0]):
            issues += _scan_instance(row[b], col[b], val[b], n, b)
    if feasibility:
        issues += _mcm_screen(problem)
    return PreflightReport(tuple(issues), checked_feasibility=feasibility)


def _mcm_screen(problem) -> list[PreflightIssue]:
    """Hall-style deficiency screen via the pipeline's own greedy + MCM
    phases (maximum cardinality is exact, so deficiency = n - |MCM|)."""
    import jax.numpy as jnp

    from repro.core import batch as _batch
    from repro.core import single as _single

    n = int(problem.n)
    issues = []
    if np.asarray(problem.row).ndim == 2:
        row = jnp.asarray(problem.row)
        col = jnp.asarray(problem.col)
        val = jnp.asarray(problem.val)
        mr, mc = _batch.greedy_maximal_batched(row, col, val, n)
        mr, mc = _batch.mcm_batched(row, col, val, n, mr, mc)
        card = np.asarray((np.asarray(mr)[:, :n] < n).sum(axis=1))
        for b, k in enumerate(card):
            if int(k) < n:
                issues.append(_deficiency_issue(n, int(k), b))
    else:
        st = _single.greedy_maximal(jnp.asarray(problem.row),
                                    jnp.asarray(problem.col),
                                    jnp.asarray(problem.val), n)
        st = _single.mcm(jnp.asarray(problem.row), jnp.asarray(problem.col),
                         jnp.asarray(problem.val), n,
                         st.mate_row, st.mate_col)
        k = int((np.asarray(st.mate_row)[:n] < n).sum())
        if k < n:
            issues.append(_deficiency_issue(n, k, None))
    return issues


def _deficiency_issue(n: int, cardinality: int,
                      inst: int | None) -> PreflightIssue:
    return PreflightIssue(
        "deficient", n - cardinality,
        f"maximum cardinality {cardinality} < n = {n} "
        f"(deficiency {n - cardinality}): no perfect matching exists",
        inst)


def deficiency_from_mates(mate_row, n: int, report: PreflightReport | None,
                          batched: bool) -> PreflightReport:
    """Fold the deficiency observed on a solved (maximal) matching into a
    report — how the solve pipeline attaches its free MCM screen result."""
    report = report or PreflightReport(())
    mr = np.asarray(mate_row)
    issues = []
    if batched:
        card = (mr[:, :n] < n).sum(axis=1)
        issues = [_deficiency_issue(n, int(k), b)
                  for b, k in enumerate(card) if int(k) < n]
    else:
        k = int((mr[:n] < n).sum())
        if k < n:
            issues = [_deficiency_issue(n, k, None)]
    return report.extend(*issues)


def _sanitize_triple(row, col, val, n: int):
    """Drop non-finite edges, merge duplicate coordinates keep-max.
    Returns (row, col, val) raw (unpadded) real triples."""
    real = (row < n) & (col < n)
    r, c, v = row[real], col[real], val[real]
    keep = np.isfinite(v)
    r, c, v = r[keep], c[keep], v[keep]
    # keep-max merge: within duplicate (row, col) groups the heaviest entry
    # dominates any max-weight matching objective (an edge is picked at
    # most once). Summation semantics belong to assembly (data.mtx).
    order = np.lexsort((-v, c, r))
    r, c, v = r[order], c[order], v[order]
    key = r.astype(np.int64) * (n + 1) + c
    first = np.ones(key.shape, bool)
    first[1:] = key[1:] != key[:-1]
    return r[first], c[first], v[first]


def sanitize(problem) -> tuple[Any, PreflightReport]:
    """Repair fatal data issues (non-finite edges dropped, duplicates
    merged keep-max), preserving the problem's padded capacity so planned
    ``Matcher`` shapes still match. Structural issues are reported, not
    repaired. Returns (sanitized problem, report of what was found)."""
    from repro.core import graph as _graph
    from repro.core.api import MatchingProblem

    report = preflight(problem)
    if not report.fatal:
        return problem, report
    n, cap = int(problem.n), problem.cap
    row = np.asarray(problem.row)
    col = np.asarray(problem.col)
    val = np.asarray(problem.val)
    if row.ndim == 1:
        r, c, v = _sanitize_triple(row, col, val, n)
        g = _graph.from_coo(r, c, v, n, capacity=cap)
        clean = MatchingProblem.from_graph(g)
    else:
        rows, cols, vals = [], [], []
        for b in range(row.shape[0]):
            r, c, v = _sanitize_triple(row[b], col[b], val[b], n)
            g = _graph.from_coo(r, c, v, n, capacity=cap)
            rows.append(g.row)
            cols.append(g.col)
            vals.append(g.val)
        clean = MatchingProblem(row=np.stack(rows), col=np.stack(cols),
                                val=np.stack(vals), n=n)
    return clean, report
