"""Deprecation plumbing for the pre-facade entry points.

The legacy surface (``single.awpm``, ``batch.awpm_batched``,
``dist.awpm_dist_batched`` and the ``DistAWPM`` / ``DistBatchedAWPM`` /
``make_awpm_dist_batched`` factory zoo) stays callable and bit-identical, but
every call funnels through :func:`warn_legacy` so downstream code migrates to
``repro.core.api`` (``solve`` / ``plan``).
"""
from __future__ import annotations

import warnings


def warn_legacy(old: str, new: str, stacklevel: int = 3) -> None:
    """Emit the facade-migration DeprecationWarning for a legacy entry point.

    The default ``stacklevel=3`` points at the *caller* of a deprecated
    function (warn_legacy -> shim -> caller); dataclass shims warning from
    ``__post_init__`` pass 4 (the generated ``__init__`` adds a frame).
    """
    warnings.warn(
        f"{old} is deprecated; use {new} from repro.core.api instead "
        f"(one solve()/plan() facade across single, batched, and "
        f"distributed AWPM)",
        DeprecationWarning,
        stacklevel=stacklevel,
    )
