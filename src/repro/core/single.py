"""Single-device (pure jnp, jit-able) AWPM: greedy maximal -> MCM -> AWAC.

This is both (a) the single-node baseline the paper compares against ("sequential
AWPM", §6.1) and (b) the reference implementation the distributed shard_map
version must agree with: the Step C/D selection + augmentation logic
(`select_and_augment`) is *shared* between the two — the distributed code only
replaces how the per-column Step-C winners are computed (local segment ops +
collectives instead of full-array segment ops).

Conventions (everywhere in repro.core):
  - square matrix, n rows == n cols; edges as padded COO sorted lex by (row, col)
    with padding entries (n, n, 0).
  - ``mate_row`` [n+1]: row matched to column j (sentinel n = unmatched;
    slot n is always n). ``mate_col`` [n+1]: column matched to row i.
  - ``u`` [n+1]: weight of row i's matched edge; ``v`` [n+1]: weight of column
    j's matched edge. Slot n is 0.
  - all weights float32; gains computed as ``w1 + w2 - u - v`` in that order so
    numpy reference and jnp agree exactly.
"""
from __future__ import annotations

import contextlib
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core._compat import warn_legacy
from repro.core.constants import MIN_GAIN
from repro.sparse.csr import max_row_nnz, row_ptr_from_sorted, window_depth
from repro.sparse.ops import lex_searchsorted, searchsorted_in_window, segment_max_with_payload

NEG = -jnp.inf

# Fallback windowed-search depth when the row array is a tracer and the max
# row degree cannot be measured on the host (covers any int32-sized window).
FALLBACK_WINDOW_STEPS = 32


class MatchState(NamedTuple):
    mate_row: jnp.ndarray  # [n+1] int32
    mate_col: jnp.ndarray  # [n+1] int32
    u: jnp.ndarray  # [n+1] float32
    v: jnp.ndarray  # [n+1] float32


def empty_state(n: int) -> MatchState:
    return MatchState(
        jnp.full((n + 1,), n, jnp.int32),
        jnp.full((n + 1,), n, jnp.int32),
        jnp.zeros((n + 1,), jnp.float32),
        jnp.zeros((n + 1,), jnp.float32),
    )


def state_from_mates(row, col, val, n, mate_row, mate_col) -> MatchState:
    """Build MatchState (incl. u, v) from mate arrays (numpy or jnp, len n or n+1)."""
    mate_row = jnp.asarray(mate_row, jnp.int32)
    mate_col = jnp.asarray(mate_col, jnp.int32)
    if mate_row.shape[0] == n:
        mate_row = jnp.concatenate([mate_row, jnp.array([n], jnp.int32)])
        mate_col = jnp.concatenate([mate_col, jnp.array([n], jnp.int32)])
    ivec = jnp.arange(n, dtype=jnp.int32)
    pos, found = lex_searchsorted(row, col, ivec, mate_col[:n])
    uu = jnp.where(found, val[pos], 0.0)
    u = jnp.zeros((n + 1,), jnp.float32).at[:n].set(uu)
    v = jnp.zeros((n + 1,), jnp.float32).at[:n].set(
        jnp.where(mate_row[:n] < n, u[mate_row[:n]], 0.0)
    )
    return MatchState(mate_row, mate_col, u, v)


def matching_weight(state: MatchState, n: int) -> jnp.ndarray:
    return state.u[:n].sum()


def is_perfect(state: MatchState, n: int) -> jnp.ndarray:
    return (state.mate_row[:n] < n).all()


# --------------------------------------------------------------------------
# Phase 1: greedy weighted maximal matching (proposal rounds)
# --------------------------------------------------------------------------


def greedy_round(row, col, val, n: int, mate_row, mate_col):
    """One proposal round of the greedy weighted maximal matching. The
    batched engine (core/batch.py) re-expresses this body on flat
    offset-segment primitives — any change here must be mirrored in
    ``batch._greedy_maximal_batched`` to keep per-instance bit-exactness.
    Returns (mate_row, mate_col, progressed)."""
    cap = row.shape[0]
    eidx = jnp.arange(cap, dtype=jnp.int32)
    jvec = jnp.arange(n, dtype=jnp.int32)
    ivec = jnp.arange(n, dtype=jnp.int32)
    avail = (row < n) & (mate_col[row] == n) & (mate_row[col] == n)
    score = jnp.where(avail, val, NEG)
    seg = jnp.where(avail, col, n)
    pg, pe = segment_max_with_payload(score, eidx, seg, n + 1)
    has = pe[:n] >= 0
    prow = jnp.where(has, row[jnp.clip(pe[:n], 0)], n)
    pv = jnp.where(has, pg[:n], NEG)
    _, rj = segment_max_with_payload(pv, jvec, prow, n + 1)
    ok = rj[:n] >= 0  # per-row winning proposal col
    wcol = jnp.where(ok, rj[:n], n).astype(jnp.int32)
    mate_col = mate_col.at[jnp.where(ok, ivec, n)].set(wcol)
    mate_row = mate_row.at[wcol].set(jnp.where(ok, ivec, n).astype(jnp.int32))
    mate_col = mate_col.at[n].set(n)
    mate_row = mate_row.at[n].set(n)
    return mate_row, mate_col, ok.any()


@functools.partial(jax.jit, static_argnames=("n",))
def greedy_maximal(row, col, val, n: int) -> MatchState:
    def round_body(carry):
        mate_row, mate_col, _ = carry
        return greedy_round(row, col, val, n, mate_row, mate_col)

    def cond(carry):
        return carry[2]

    st0 = empty_state(n)
    mate_row, mate_col, _ = jax.lax.while_loop(
        cond, round_body, (st0.mate_row, st0.mate_col, jnp.array(True))
    )
    return state_from_mates(row, col, val, n, mate_row, mate_col)


# --------------------------------------------------------------------------
# Phase 2: maximum cardinality matching (layered BFS + lockstep trace/flip)
# --------------------------------------------------------------------------


def trace_and_flip(parent_col, visited, found, layers, mate_row, mate_col, n):
    """Lockstep backtrace with per-column claims (winner = smallest endpoint
    row id), then flip the surviving vertex-disjoint augmenting paths.

    All augmenting paths from one layered BFS have the same number of column
    steps (``layers``) and every column belongs to exactly one BFS layer, so
    claim conflicts can only occur between walkers at the same step — one
    claim round per step suffices. Shared verbatim by the distributed MCM.
    """
    widx = jnp.arange(n + 1, dtype=jnp.int32)  # walker ids (= endpoint row ids)
    endpoints = jnp.zeros((n + 1,), bool).at[:n].set(
        visited[:n] & (mate_col[:n] == n)
    ) & found

    def claim_body(carry):
        active, cur, t = carry
        j_w = jnp.where(active, parent_col[cur], n)
        win = jax.ops.segment_min(widx, j_w, num_segments=n + 1)
        active = active & (win[j_w] == widx)
        nxt = mate_row[j_w]
        cur = jnp.where(active & (nxt < n), nxt, cur)
        return active, cur, t + 1

    active, _, _ = jax.lax.while_loop(
        lambda c: c[2] < layers,
        claim_body,
        (endpoints, widx, jnp.array(0, jnp.int32)),
    )

    def flip_body(carry):
        surv, cur, mate_row, mate_col, t = carry
        j = jnp.where(surv, parent_col[cur], n)
        prev = mate_row[j]
        mate_row = mate_row.at[j].set(jnp.where(surv, cur, mate_row[j]).astype(jnp.int32))
        mate_col = mate_col.at[jnp.where(surv, cur, n)].set(j.astype(jnp.int32))
        mate_row = mate_row.at[n].set(n)
        mate_col = mate_col.at[n].set(n)
        surv = surv & (prev < n)
        cur = jnp.where(surv, prev, cur)
        return surv, cur, mate_row, mate_col, t + 1

    _, _, mate_row, mate_col, _ = jax.lax.while_loop(
        lambda c: c[4] < layers,
        flip_body,
        (active, widx, mate_row, mate_col, jnp.array(0, jnp.int32)),
    )
    return mate_row, mate_col


def _mcm_bfs(row, col, val, n: int, mate_row, mate_col):
    """One layered BFS from all free rows with weight-aware parent selection.
    Returns (parent_col, visited, found, layers)."""
    cap = row.shape[0]
    eidx = jnp.arange(cap, dtype=jnp.int32)
    frontier = jnp.zeros((n + 1,), bool).at[:n].set(mate_row[:n] == n)
    parent_col = jnp.full((n + 1,), n, jnp.int32)
    visited = jnp.zeros((n + 1,), bool)

    def bfs_body(carry):
        frontier, parent_col, visited, found, layers, _ = carry
        elig = (row < n) & frontier[col] & (~visited[row])
        score = jnp.where(elig, val, NEG)
        seg = jnp.where(elig, row, n)
        _, re = segment_max_with_payload(score, eidx, seg, n + 1)
        new = re[:n] >= 0
        pc = jnp.where(new, col[jnp.clip(re[:n], 0)], parent_col[:n])
        parent_col = parent_col.at[:n].set(pc.astype(jnp.int32))
        visited = visited.at[:n].set(visited[:n] | new)
        free_new = new & (mate_col[:n] == n)
        found = free_new.any()
        nf_idx = jnp.where(new & ~free_new, mate_col[:n], n)
        frontier = jnp.zeros((n + 1,), bool).at[nf_idx].set(True).at[n].set(False)
        return frontier, parent_col, visited, found, layers + 1, new.any()

    def bfs_cond(carry):
        _, _, _, found, layers, progressed = carry
        return (~found) & progressed & (layers <= n)

    frontier, parent_col, visited, found, layers, _ = jax.lax.while_loop(
        bfs_cond,
        bfs_body,
        (frontier, parent_col, visited, jnp.array(False), jnp.array(0, jnp.int32),
         jnp.array(True)),
    )
    return parent_col, visited, found, layers


def mcm_phase(row, col, val, n: int, mate_row, mate_col):
    """One MCM phase: layered BFS + trace/flip of the augmenting paths it
    found. The batched engine re-expresses this phase on flat
    offset-segment primitives (``batch._mcm_bfs_batched`` /
    ``batch.trace_and_flip_batched``) — changes here must be mirrored there
    to keep per-instance bit-exactness. Returns (mate_row, mate_col,
    found)."""
    parent_col, visited, found, layers = _mcm_bfs(row, col, val, n, mate_row,
                                                 mate_col)
    mate_row, mate_col = trace_and_flip(
        parent_col, visited, found, layers, mate_row, mate_col, n
    )
    return mate_row, mate_col, found


@functools.partial(jax.jit, static_argnames=("n",))
def mcm(row, col, val, n: int, mate_row, mate_col) -> MatchState:
    """Maximum cardinality matching from an initial matching, with the paper's
    weight-aware tie-breaking (heaviest eligible edge chosen as BFS parent)."""

    def phase_body(carry):
        mate_row, mate_col, _ = carry
        return mcm_phase(row, col, val, n, mate_row, mate_col)

    def phase_cond(carry):
        mate_row, _, go = carry
        return go & (mate_row[:n] == n).any()

    if mate_row.shape[0] == n:
        mate_row = jnp.concatenate([jnp.asarray(mate_row, jnp.int32),
                                    jnp.array([n], jnp.int32)])
        mate_col = jnp.concatenate([jnp.asarray(mate_col, jnp.int32),
                                    jnp.array([n], jnp.int32)])
    mate_row, mate_col, _ = jax.lax.while_loop(
        phase_cond, phase_body, (mate_row, mate_col, jnp.array(True))
    )
    return state_from_mates(row, col, val, n, mate_row, mate_col)


# --------------------------------------------------------------------------
# Phase 3: AWAC — approximate-weight augmenting 4-cycles
# --------------------------------------------------------------------------


def select_and_augment(n, Cgain, Ci, Cw1, Cw2, state: MatchState, min_gain):
    """Steps D + survivor selection + augmentation, given global per-column
    Step-C winners. O(n) dense compute, replicated verbatim on every device in
    the distributed version.

    Cgain [n] f32 (-inf if column unrooted), Ci [n] winner row, Cw1/Cw2 [n]
    weights of the (i,j) and (m_j, m_i) edges of the winning cycle.
    Returns (new_state, n_survivors).
    """
    mate_row, mate_col, u, v = state
    jvec = jnp.arange(n, dtype=jnp.int32)
    rooted = Cgain > NEG
    Ci_s = jnp.clip(Ci, 0, n)  # safe gather index
    e2 = jnp.where(rooted, mate_col[Ci_s], n)  # column of row i's matched edge
    dgain = jnp.where(rooted, Cgain, NEG)
    dg, dj = segment_max_with_payload(dgain, jvec, e2, n + 1)
    surv_c2 = (dg[:n] > NEG) & (~rooted)  # e2-columns whose winner survives
    surv_root = jnp.where(surv_c2, dj[:n], n)
    mask_j = jnp.zeros((n + 1,), bool).at[surv_root].set(True)[:n] & rooted
    n_surv = mask_j.sum()

    # deterministic fallback: single globally-best cycle (paper: random augm.)
    best_j = jnp.argmax(jnp.where(rooted, Cgain, NEG))
    use_fb = (n_surv == 0) & rooted.any()
    mask_j = mask_j | ((jvec == best_j) & use_fb)
    n_surv = n_surv + use_fb.astype(n_surv.dtype)

    # ---- augment all surviving cycles (vertex-disjoint by construction)
    i_ = Ci_s
    r2 = mate_row[:n]  # old mate row of each column j
    c2 = mate_col[i_]  # old mate col of each winner row i
    mj = jnp.where(mask_j, jvec, n)
    mi = jnp.where(mask_j, i_, n)
    mr2 = jnp.where(mask_j, r2, n)
    mc2 = jnp.where(mask_j, c2, n)
    mate_row = mate_row.at[mj].set(jnp.where(mask_j, i_, mate_row[mj]).astype(jnp.int32))
    mate_row = mate_row.at[mc2].set(jnp.where(mask_j, r2, mate_row[mc2]).astype(jnp.int32))
    mate_col = mate_col.at[mi].set(jnp.where(mask_j, jvec, mate_col[mi]).astype(jnp.int32))
    mate_col = mate_col.at[mr2].set(jnp.where(mask_j, c2, mate_col[mr2]).astype(jnp.int32))
    u = u.at[mi].set(jnp.where(mask_j, Cw1, u[mi]))
    u = u.at[mr2].set(jnp.where(mask_j, Cw2, u[mr2]))
    v = v.at[mj].set(jnp.where(mask_j, Cw1, v[mj]))
    v = v.at[mc2].set(jnp.where(mask_j, Cw2, v[mc2]))
    mate_row = mate_row.at[n].set(n)
    mate_col = mate_col.at[n].set(n)
    u = u.at[n].set(0.0)
    v = v.at[n].set(0.0)
    return MatchState(mate_row, mate_col, u, v), n_surv


def awac_candidates(row, col, val, n, state: MatchState, min_gain):
    """Steps A+B on the full edge list: per-edge completion lookup + gain.

    Reference path: global log2(m)-round lex search per edge. The fused sweep
    (``awac_cwinners_fused`` / the Pallas ``awac_sweep`` kernel) replaces this
    with a CSR-windowed lookup and never materializes these O(m) arrays."""
    mate_row, mate_col, u, v = state
    qr = mate_row[col]  # m_j for each edge's column
    qc = mate_col[row]  # m_i for each edge's row
    pos, found = lex_searchsorted(row, col, qr, qc)
    w2 = jnp.where(found, val[pos], 0.0)
    gain = val + w2 - u[row] - v[col]
    cand = found & (row < n) & (row > qr) & (gain > min_gain)
    return cand, gain, w2


def awac_cwinners(row, col, val, n, state: MatchState, min_gain):
    """Step C on the full edge list: per-column winner (gain, i, w1, w2).

    Reference (seed) implementation — kept as the bit-exactness oracle for
    the fused backends and still used via ``backend="reference"``."""
    cand, gain, w2 = awac_candidates(row, col, val, n, state, min_gain)
    cap = row.shape[0]
    eidx = jnp.arange(cap, dtype=jnp.int32)
    seg = jnp.where(cand, col, n)
    gm = jnp.where(cand, gain, NEG)
    Cgain_full, Cedge = segment_max_with_payload(gm, eidx, seg, n + 1)
    Cgain, Cedge = Cgain_full[:n], Cedge[:n]
    ce = jnp.clip(Cedge, 0)
    has = Cedge >= 0
    Ci = jnp.where(has, row[ce], n).astype(jnp.int32)
    Cw1 = jnp.where(has, val[ce], 0.0)
    Cw2 = jnp.where(has, w2[ce], 0.0)
    return Cgain, Ci, Cw1, Cw2


def awac_cwinners_fused(row, col, val, row_ptr, n, state: MatchState, min_gain,
                        window_steps: int):
    """Fused Steps A+B+C, XLA path (DESIGN.md §3).

    The completion lookup for (m_j, m_i) is a windowed binary search inside
    row m_j's CSR segment (``window_steps`` rounds ~ log2(max row degree))
    instead of a log2(m)-round global lex search, and Step C's winner
    selection runs as a single packed-key segment reduction when the caller
    traced under x64 (``awac``/``awpm`` do). Bit-identical to
    ``awac_cwinners``."""
    mate_row, mate_col, u, v = state
    cap = row.shape[0]
    qr = mate_row[col]  # m_j for each edge's column
    qc = mate_col[row]  # m_i for each edge's row
    qr_s = jnp.clip(qr, 0, n)
    lo = row_ptr[qr_s]
    # qr == n (unmatched column / padding edge) -> empty window, never found;
    # the reference can "find" the padding entry there but masks it with
    # row < n, so candidate sets agree.
    hi = jnp.where(qr < n, row_ptr[qr_s + 1], lo)
    pos, found = searchsorted_in_window(col, qc, lo, hi, n_steps=window_steps)
    w2 = jnp.where(found, val[jnp.clip(pos, 0, cap - 1)], 0.0)
    gain = val + w2 - u[row] - v[col]
    cand = found & (row < n) & (row > qr) & (gain > min_gain)
    eidx = jnp.arange(cap, dtype=jnp.int32)
    seg = jnp.where(cand, col, n)
    gm = jnp.where(cand, gain, NEG)
    Cgain_full, Cedge = segment_max_with_payload(gm, eidx, seg, n + 1)
    Cgain, Cedge = Cgain_full[:n], Cedge[:n]
    ce = jnp.clip(Cedge, 0)
    has = Cedge >= 0
    Ci = jnp.where(has, row[ce], n).astype(jnp.int32)
    Cw1 = jnp.where(has, val[ce], 0.0)
    Cw2 = jnp.where(has, w2[ce], 0.0)
    return Cgain, Ci, Cw1, Cw2


def _cwinners(backend, row, col, val, row_ptr, n, state, min_gain,
              window_steps):
    if backend == "reference":
        return awac_cwinners(row, col, val, n, state, min_gain)
    if backend == "xla":
        return awac_cwinners_fused(row, col, val, row_ptr, n, state, min_gain,
                                   window_steps)
    if backend == "pallas":
        # Local import: core must stay importable without the kernel package.
        from repro.kernels.cycle_gain.ops import awac_sweep_winners

        return awac_sweep_winners(
            row, col, val, row_ptr, state.mate_row, state.mate_col, state.u,
            state.v, min_gain, n=n, window_steps=window_steps,
        )
    raise ValueError(f"unknown AWAC backend {backend!r}")


def resolve_backend(backend: str, n: int | None = None,
                    batch: int | None = None) -> str:
    """Resolve ``"auto"`` to a concrete local AWAC backend.

    Consults the measured dispatch table (``BENCH_dispatch.json``, written
    by the kernels bench job — see ``repro.kernels.dispatch``) for the
    winner on this platform and shape class. Only when no measurement
    exists for the platform does the old structural heuristic apply
    (compiled Pallas lowering on TPU, fused XLA elsewhere) — a guess, and
    labeled as one in the dispatch module docs, never a claim.
    """
    if backend != "auto":
        return backend
    try:
        from repro.kernels.dispatch import choose_backend

        winner = choose_backend(n=n, batch=batch)
    except ImportError:  # core stays usable without the kernel package
        winner = None
    if winner is not None:
        return winner
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _x64_scope(row):
    """The packed-key single-pass reductions (repro.sparse.ops) need an
    x64-enabled TRACE context — but entering ``enable_x64`` in the middle of
    an outer trace promotes fresh loop carries to int64 while existing
    values stay int32 (while_loop carry type mismatch). Inside an outer jit
    the scope is skipped and the two-pass fallback runs instead
    (bit-identical by the sparse.ops contract)."""
    if isinstance(row, jax.core.Tracer):
        return contextlib.nullcontext()
    return enable_x64()


def _resolve_window_steps(row, n, window_steps):
    cap = int(row.shape[-1])
    if window_steps is not None:
        ws = int(window_steps)
        # a row holds at most min(cap, n) entries, so a depth covering that
        # bound provably resolves every window — no need to measure
        if ws >= window_depth(min(cap, n)):
            return ws
        # an undersized override is clamped UP: extra depth never changes a
        # windowed-search result, but under-depth would silently miss
        # completion edges — the override may add depth, never break
        # correctness. Under a trace the need cannot be measured, so the
        # provable bound stands in for it.
        if isinstance(row, jax.core.Tracer):
            return window_depth(min(cap, n))
        return max(ws, window_depth(max_row_nnz(row, n)))
    if isinstance(row, jax.core.Tracer):
        return FALLBACK_WINDOW_STEPS
    return window_depth(max_row_nnz(row, n))


@functools.partial(
    jax.jit, static_argnames=("n", "max_iter", "backend", "window_steps",
                              "degrade_infeasible")
)
def _awac_loop(row, col, val, row_ptr, n: int, state: MatchState,
               max_iter: int, min_gain, backend: str, window_steps: int,
               degrade_infeasible: bool = False):
    def body(carry):
        state, it, _ = carry
        Cgain, Ci, Cw1, Cw2 = _cwinners(
            backend, row, col, val, row_ptr, n, state, min_gain, window_steps
        )
        state, n_surv = select_and_augment(n, Cgain, Ci, Cw1, Cw2, state, min_gain)
        return state, it + 1, n_surv > 0

    def cond(carry):
        _, it, go = carry
        return go & (it < max_iter)

    # AWAC rotates 4-cycles — cardinality never changes — so on an
    # imperfect (infeasible-instance) matching every round is pure waste:
    # skip the loop outright when asked to degrade
    go0 = is_perfect(state, n) if degrade_infeasible else jnp.array(True)
    state, iters, _ = jax.lax.while_loop(
        cond, body, (state, jnp.array(0, jnp.int32), go0)
    )
    return state, iters


def awac(row, col, val, n: int, state: MatchState, max_iter: int = 1000,
         min_gain: float = MIN_GAIN, backend: str = "auto",
         row_ptr=None, window_steps: int | None = None,
         degrade_infeasible: bool = False):
    """Full AWAC loop. Returns (state, iters).

    backend: "auto" (measured dispatch-table winner, see
    ``resolve_backend``) | "xla" (fused sweep) | "pallas" (fused
    ``awac_sweep`` kernel, one launch per iteration) | "pallas_persistent"
    (whole loop in one persistent kernel) | "reference" (seed jnp path, the
    bit-exactness oracle). All backends produce identical results and
    iteration counts.
    """
    backend = resolve_backend(backend, n=n)
    window_steps = _resolve_window_steps(row, n, window_steps)
    if row_ptr is None:
        row_ptr = row_ptr_from_sorted(row, n)
    if backend == "pallas_persistent":
        # Local import: core must stay importable without the kernel package.
        from repro.kernels.cycle_gain.ops import awac_persistent_loop

        go0 = is_perfect(state, n) if degrade_infeasible else jnp.array(True)
        mr, mc, u, v, iters = awac_persistent_loop(
            row, col, val, row_ptr, state.mate_row, state.mate_col, state.u,
            state.v, min_gain, go0, n=n, window_steps=window_steps,
            max_iter=max_iter)
        return MatchState(mr, mc, u, v), iters
    if backend == "xla":
        # x64-enabled trace context lets Step C run as ONE packed-key uint64
        # segment_max (see repro.sparse.ops); inputs/outputs stay f32/i32.
        # Under an outer jit the scope is a no-op (see _x64_scope).
        with _x64_scope(row):
            return _awac_loop(row, col, val, row_ptr, n, state, max_iter,
                              min_gain, backend, window_steps,
                              degrade_infeasible)
    return _awac_loop(row, col, val, row_ptr, n, state, max_iter, min_gain,
                      backend, window_steps, degrade_infeasible)


def _awpm(row, col, val, n: int, max_iter: int = 1000,
          min_gain: float = MIN_GAIN, backend: str = "auto",
          window_steps: int | None = None,
          degrade_infeasible: bool = False):
    """Full pipeline: greedy maximal -> MCM -> AWAC. Returns (state, awac_iters).

    Internal engine behind ``repro.core.api.solve`` (the single-instance
    dispatch target) and the deprecated ``awpm`` shim.
    """
    st = greedy_maximal(row, col, val, n)
    st = mcm(row, col, val, n, st.mate_row, st.mate_col)
    return awac(row, col, val, n, st, max_iter=max_iter, min_gain=min_gain,
                backend=backend, window_steps=window_steps,
                degrade_infeasible=degrade_infeasible)


def awpm(row, col, val, n: int, max_iter: int = 1000, min_gain: float = MIN_GAIN,
         backend: str = "auto"):
    """Deprecated alias of the full pipeline — use ``repro.core.api.solve``."""
    warn_legacy("repro.core.single.awpm", "solve()")
    return _awpm(row, col, val, n, max_iter=max_iter, min_gain=min_gain,
                 backend=backend)
