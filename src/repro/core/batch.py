"""Batched AWPM engine: one-shot matching of B instances in one dispatch.

The paper's motivating workloads (static pivoting for sparse direct solvers,
per-group MoE routing) need *many* heavy-weight perfect matchings at once.
This module solves a whole batch of padded [B, cap] COO instances (shared n,
per-instance edge lists; padding entries (n, n, 0)) with per-instance
convergence masks inside single ``lax.while_loop``s — no python loop over
instances, no per-instance jit dispatch (DESIGN.md §4).

Bit-exactness contract: for every instance b and every backend,
``awpm_batched(row, col, val, n)`` produces exactly the arrays
``core.single.awpm(row[b], col[b], val[b], n)`` would. The greedy/MCM round
bodies here are ``single.greedy_round`` / ``single.mcm_phase`` re-expressed
on the flat batched segment primitives
(``sparse.ops.batched_segment_max_with_payload`` etc.) — kept in sync with
single.py by the differential suite — while ``single.select_and_augment``
and the "reference" Step C are vmapped verbatim. A converged instance's
state is frozen by the mask while the rest of the batch keeps iterating. Extra windowed-search depth (the batch measures one
``window_steps`` across all instances) never changes a search result, so the
shared depth preserves per-instance bit-identity.

Backends mirror ``core.single.awac``:
  reference — vmapped seed oracle (global lex search per instance)
  xla       — flat batched fused sweep: one offset-segment reduction and one
              offset-window search over all B * cap edges (production CPU)
  pallas    — batch-grid ``awac_sweep`` kernel, batch as leading grid axis
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import single
from repro.core._compat import warn_legacy
from repro.core.single import MIN_GAIN, NEG, MatchState
from repro.sparse.csr import batched_row_ptr_from_sorted
from repro.sparse.ops import (
    batched_searchsorted_in_window,
    batched_segment_max_with_payload,
    batched_segment_min,
)


def stack_graphs(graphs):
    """Pad a list of BipartiteGraphs (shared n, arbitrary per-instance nnz)
    into batched [B, cap] (row, col, val) jnp arrays with a common capacity.
    Extra slots are padding edges (n, n, 0), which every phase drops."""
    n = graphs[0].n
    if any(g.n != n for g in graphs):
        raise ValueError("all instances in a batch must share n")
    cap = max(g.capacity for g in graphs)
    b = len(graphs)
    row = np.full((b, cap), n, np.int32)
    col = np.full((b, cap), n, np.int32)
    val = np.zeros((b, cap), np.float32)
    for i, g in enumerate(graphs):
        row[i, : g.capacity] = g.row
        col[i, : g.capacity] = g.col
        val[i, : g.capacity] = g.val
    return jnp.asarray(row), jnp.asarray(col), jnp.asarray(val)


def empty_mates(b: int, n: int):
    full = jnp.full((b, n + 1), n, jnp.int32)
    return full, full


def matching_weight_batched(state: MatchState, n: int) -> jnp.ndarray:
    """Per-instance matching weight [B]."""
    return state.u[:, :n].sum(axis=1)


def is_perfect_batched(state: MatchState, n: int) -> jnp.ndarray:
    """Per-instance perfect-matching flag [B]."""
    return (state.mate_row[:, :n] < n).all(axis=1)


def state_from_mates_batched(row, col, val, n: int, mate_row,
                             mate_col) -> MatchState:
    """Batched ``single.state_from_mates``: fields are [B, n + 1]."""
    return jax.vmap(
        lambda r, c, v, mr, mc: single.state_from_mates(r, c, v, n, mr, mc)
    )(row, col, val, mate_row, mate_col)


@functools.partial(jax.jit, static_argnames=("n", "window_steps"))
def _state_from_mates_windowed(row, col, val, row_ptr, n: int, mate_row,
                               mate_col, window_steps: int) -> MatchState:
    """``state_from_mates_batched`` with the matched-edge weight lookup as a
    CSR-windowed search inside each row's own segment (log2(max degree)
    rounds) instead of the 32-round global lex search. Identical output:
    (row i, mate_col[i]) is a unique key, so a found position — and the
    not-found zero — agree with the lex path."""
    b, cap = row.shape
    mate_row = mate_row.astype(jnp.int32)
    mate_col = mate_col.astype(jnp.int32)
    pos, found = batched_searchsorted_in_window(
        col, mate_col[:, :n], row_ptr[:, :n], row_ptr[:, 1 : n + 1],
        n_steps=window_steps,
    )
    uu = jnp.where(
        found, jnp.take_along_axis(val, jnp.clip(pos, 0, cap - 1), axis=1),
        0.0)
    u = jnp.zeros((b, n + 1), jnp.float32).at[:, :n].set(uu)
    v = jnp.zeros((b, n + 1), jnp.float32).at[:, :n].set(
        jnp.where(mate_row[:, :n] < n,
                  jnp.take_along_axis(u, jnp.clip(mate_row[:, :n], 0, n),
                                      axis=1), 0.0)
    )
    return MatchState(mate_row, mate_col, u, v)


# --------------------------------------------------------------------------
# Phase 1: batched greedy weighted maximal matching
# --------------------------------------------------------------------------


def greedy_propose_full(row, col, val, n: int, mate_row, mate_col):
    """Per-column best available proposal from the full batched edge list:
    (pv [B, n] score with NEG where none, prow [B, n] proposing row with
    sentinel n). The distributed-batched engine (core/dist.py) computes the
    same two arrays from 2D blocks + collectives and feeds them to the same
    ``greedy_commit`` — that split is what keeps the two engines
    bit-identical by construction."""
    b, cap = row.shape
    eidx = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), (b, cap))
    avail = (row < n) & (jnp.take_along_axis(mate_col, row, axis=1) == n) \
        & (jnp.take_along_axis(mate_row, col, axis=1) == n)
    score = jnp.where(avail, val, NEG)
    seg = jnp.where(avail, col, n)
    pg, pe = batched_segment_max_with_payload(score, eidx, seg, n + 1)
    has = pe[:, :n] >= 0
    prow = jnp.where(
        has, jnp.take_along_axis(row, jnp.clip(pe[:, :n], 0), axis=1), n)
    pv = jnp.where(has, pg[:, :n], NEG)
    return pv, prow


def greedy_commit(pv, prow, n: int, mate_row, mate_col, active):
    """Replicated per-row contest + mate scatter of one greedy proposal
    round (shared verbatim with the distributed-batched engine). Frozen
    instances accept nothing. Returns (mate_row, mate_col, active)."""
    b = pv.shape[0]
    jvec = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    ivec = jnp.arange(n, dtype=jnp.int32)
    bidx = jnp.arange(b)[:, None]
    _, rj = batched_segment_max_with_payload(pv, jvec, prow, n + 1)
    ok = (rj[:, :n] >= 0) & active[:, None]
    wcol = jnp.where(ok, rj[:, :n], n).astype(jnp.int32)
    mate_col = mate_col.at[bidx, jnp.where(ok, ivec[None, :], n)].set(wcol)
    mate_row = mate_row.at[bidx, wcol].set(
        jnp.where(ok, ivec[None, :], n).astype(jnp.int32))
    mate_col = mate_col.at[:, n].set(n)
    mate_row = mate_row.at[:, n].set(n)
    return mate_row, mate_col, active & ok.any(axis=1)


def greedy_loop(n: int, b: int, propose_fn):
    """Greedy proposal rounds for B instances in one while_loop with
    per-instance convergence masks. ``propose_fn(mate_row, mate_col) ->
    (pv, prow)`` supplies each round's per-column proposals — the full edge
    list here, blocks + collectives in the distributed engine. Returns
    (mate_row, mate_col), each [B, n + 1]."""

    def round_body(carry):
        mate_row, mate_col, active = carry
        pv, prow = propose_fn(mate_row, mate_col)
        return greedy_commit(pv, prow, n, mate_row, mate_col, active)

    def cond(carry):
        return carry[2].any()

    mr0, mc0 = empty_mates(b, n)
    mate_row, mate_col, _ = jax.lax.while_loop(
        cond, round_body, (mr0, mc0, jnp.ones((b,), bool))
    )
    return mate_row, mate_col


def greedy_maximal_batched(row, col, val, n: int):
    """``single.greedy_maximal``'s proposal rounds for all instances in one
    while_loop: each round is ``single.greedy_round`` re-expressed on the
    flat offset-segment reductions, and instances whose round proposes
    nothing go inactive (their mates freeze). Returns (mate_row, mate_col),
    each [B, n + 1].

    Traced under x64 so both per-round reductions run as single packed-key
    passes (bit-identical to the two-pass reference — sparse.ops); under an
    outer jit the scope is a no-op and the two-pass fallback runs (see
    ``single._x64_scope``)."""
    with single._x64_scope(row):
        return _greedy_maximal_batched(row, col, val, n)


@functools.partial(jax.jit, static_argnames=("n",))
def _greedy_maximal_batched(row, col, val, n: int):
    b = row.shape[0]
    return greedy_loop(
        n, b, functools.partial(greedy_propose_full, row, col, val, n))


# --------------------------------------------------------------------------
# Phase 2: batched maximum cardinality matching
# --------------------------------------------------------------------------


def bfs_parents_full(row, col, val, n: int, frontier, visited):
    """Per-row BFS parent proposals (new [B, n] mask, pcol [B, n] — valid
    only where ``new``) from the full batched edge list. The distributed
    engine computes the same arrays from 2D blocks + collectives and feeds
    the same ``bfs_commit``."""
    b, cap = row.shape
    eidx = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), (b, cap))
    elig = (row < n) & jnp.take_along_axis(frontier, col, axis=1) \
        & (~jnp.take_along_axis(visited, row, axis=1))
    score = jnp.where(elig, val, NEG)
    seg = jnp.where(elig, row, n)
    _, re = batched_segment_max_with_payload(score, eidx, seg, n + 1)
    new = re[:, :n] >= 0
    pcol = jnp.take_along_axis(col, jnp.clip(re[:, :n], 0), axis=1)
    return new, pcol


def bfs_commit(new, pcol, n: int, mate_col, parent_col, visited):
    """One BFS layer's replicated state update (shared verbatim with the
    distributed-batched engine). Returns (parent_col, visited, frontier,
    found)."""
    b = new.shape[0]
    bidx = jnp.arange(b)[:, None]
    pc = jnp.where(new, pcol, parent_col[:, :n])
    parent_col = parent_col.at[:, :n].set(pc.astype(jnp.int32))
    visited = visited.at[:, :n].set(visited[:, :n] | new)
    free_new = new & (mate_col[:, :n] == n)
    found = free_new.any(axis=1)
    nf_idx = jnp.where(new & ~free_new, mate_col[:, :n], n)
    frontier = jnp.zeros((b, n + 1), bool).at[bidx, nf_idx].set(True) \
        .at[:, n].set(False)
    return parent_col, visited, frontier, found


def mcm_bfs_loop(n: int, b: int, mate_row, mate_col, parents_fn):
    """Layered BFS for all instances in one while_loop: per-instance layer
    counts, found flags, and progress masks. ``parents_fn(frontier,
    visited) -> (new, pcol)`` supplies each layer's per-row parent winners
    (full edge list here; blocks + collectives in core.dist). An instance
    whose own BFS terminated (found / stalled / layer bound) freezes while
    deeper searches continue. Returns (parent_col, visited, found, layers),
    leading dim B."""
    frontier0 = jnp.zeros((b, n + 1), bool).at[:, :n].set(
        mate_row[:, :n] == n)
    parent_col0 = jnp.full((b, n + 1), n, jnp.int32)
    visited0 = jnp.zeros((b, n + 1), bool)

    def act_of(found, layers, progressed):
        return (~found) & progressed & (layers <= n)

    def bfs_body(carry):
        frontier, parent_col, visited, found, layers, progressed = carry
        act = act_of(found, layers, progressed)
        new, pcol = parents_fn(frontier, visited)
        parent_col2, visited2, frontier2, found2 = bfs_commit(
            new, pcol, n, mate_col, parent_col, visited)
        keep = act[:, None]
        return (jnp.where(keep, frontier2, frontier),
                jnp.where(keep, parent_col2, parent_col),
                jnp.where(keep, visited2, visited),
                jnp.where(act, found2, found),
                layers + act.astype(jnp.int32),
                jnp.where(act, new.any(axis=1), progressed))

    def bfs_cond(carry):
        _, _, _, found, layers, progressed = carry
        return act_of(found, layers, progressed).any()

    frontier, parent_col, visited, found, layers, _ = jax.lax.while_loop(
        bfs_cond, bfs_body,
        (frontier0, parent_col0, visited0, jnp.zeros((b,), bool),
         jnp.zeros((b,), jnp.int32), jnp.ones((b,), bool)),
    )
    return parent_col, visited, found, layers


def _mcm_bfs_batched(row, col, val, n: int, mate_row, mate_col):
    """``single._mcm_bfs`` for all instances in one while_loop (see
    ``mcm_bfs_loop``)."""
    b = row.shape[0]
    return mcm_bfs_loop(
        n, b, mate_row, mate_col,
        functools.partial(bfs_parents_full, row, col, val, n))


def trace_and_flip_batched(parent_col, visited, found, layers, mate_row,
                           mate_col, n: int):
    """Batched ``single.trace_and_flip``: lockstep backtrace with per-column
    claims then flips, each loop running to every instance's own ``layers``
    bound with per-instance masks (flat offset segment_min for the claims)."""
    b = parent_col.shape[0]
    widx = jnp.broadcast_to(jnp.arange(n + 1, dtype=jnp.int32), (b, n + 1))
    bidx = jnp.arange(b)[:, None]
    endpoints = (jnp.zeros((b, n + 1), bool).at[:, :n].set(
        visited[:, :n] & (mate_col[:, :n] == n))) & found[:, None]

    def claim_body(carry):
        active, cur, t = carry
        run = t < layers
        j_w = jnp.where(active, jnp.take_along_axis(parent_col, cur, axis=1),
                        n)
        win = batched_segment_min(widx, j_w, n + 1)
        active2 = active & (jnp.take_along_axis(win, j_w, axis=1) == widx)
        nxt = jnp.take_along_axis(mate_row, j_w, axis=1)
        cur2 = jnp.where(active2 & (nxt < n), nxt, cur)
        keep = run[:, None]
        return (jnp.where(keep, active2, active),
                jnp.where(keep, cur2, cur), t + run.astype(jnp.int32))

    active, _, _ = jax.lax.while_loop(
        lambda c: (c[2] < layers).any(), claim_body,
        (endpoints, widx, jnp.zeros((b,), jnp.int32)),
    )

    def flip_body(carry):
        surv, cur, mate_row, mate_col, t = carry
        run = t < layers
        j = jnp.where(surv, jnp.take_along_axis(parent_col, cur, axis=1), n)
        prev = jnp.take_along_axis(mate_row, j, axis=1)
        mr2 = mate_row.at[bidx, j].set(
            jnp.where(surv, cur, prev).astype(jnp.int32))
        mc2 = mate_col.at[bidx, jnp.where(surv, cur, n)].set(
            j.astype(jnp.int32))
        mr2 = mr2.at[:, n].set(n)
        mc2 = mc2.at[:, n].set(n)
        surv2 = surv & (prev < n)
        cur2 = jnp.where(surv2, prev, cur)
        keep = run[:, None]
        return (jnp.where(keep, surv2, surv), jnp.where(keep, cur2, cur),
                jnp.where(keep, mr2, mate_row),
                jnp.where(keep, mc2, mate_col), t + run.astype(jnp.int32))

    _, _, mate_row, mate_col, _ = jax.lax.while_loop(
        lambda c: (c[4] < layers).any(), flip_body,
        (active, widx, mate_row, mate_col, jnp.zeros((b,), jnp.int32)),
    )
    return mate_row, mate_col


def mcm_batched(row, col, val, n: int, mate_row, mate_col):
    """Batched MCM: one masked phase loop over the flat-batched
    BFS + trace/flip bodies (``single.mcm_phase`` re-expressed on the
    offset-segment primitives). Returns (mate_row, mate_col).

    Traced under x64 so each BFS layer's winner reduction runs as a single
    packed-key pass (bit-identical to the two-pass reference); no-op under
    an outer jit (see ``single._x64_scope``)."""
    with single._x64_scope(row):
        return _mcm_batched(row, col, val, n, mate_row, mate_col)


def mcm_loop(n: int, b: int, mate_row, mate_col, parents_fn):
    """Masked MCM phase loop over the batched BFS + trace/flip bodies,
    parameterized by the per-layer parent selection (``parents_fn``, see
    ``mcm_bfs_loop``) so the distributed-batched engine shares every mask
    and commit verbatim. Returns (mate_row, mate_col)."""

    def body(carry):
        mr, mc, active = carry
        parent_col, visited, found, layers = mcm_bfs_loop(
            n, b, mr, mc, parents_fn)
        # frozen instances trace nothing: zero their layer counts + found
        found = found & active
        layers = jnp.where(active, layers, 0)
        mr2, mc2 = trace_and_flip_batched(parent_col, visited, found, layers,
                                          mr, mc, n)
        keep = active[:, None]
        mr = jnp.where(keep, mr2, mr)
        mc = jnp.where(keep, mc2, mc)
        active = active & found & (mr[:, :n] == n).any(axis=1)
        return mr, mc, active

    def cond(carry):
        return carry[2].any()

    active0 = (mate_row[:, :n] == n).any(axis=1)
    mate_row, mate_col, _ = jax.lax.while_loop(
        cond, body, (mate_row, mate_col, active0)
    )
    return mate_row, mate_col


@functools.partial(jax.jit, static_argnames=("n",))
def _mcm_batched(row, col, val, n: int, mate_row, mate_col):
    b = row.shape[0]
    return mcm_loop(n, b, mate_row, mate_col,
                    functools.partial(bfs_parents_full, row, col, val, n))


# --------------------------------------------------------------------------
# Phase 3: batched AWAC
# --------------------------------------------------------------------------


def awac_cwinners_fused_batched(row, col, val, row_ptr, n: int,
                                state: MatchState, min_gain,
                                window_steps: int):
    """Flat batched fused Steps A+B+C: the [B, cap] edge streams are treated
    as one B * cap edge list with per-instance offset windows
    (``batched_searchsorted_in_window``) and offset segments
    (``batched_segment_max_with_payload``) — one reduction pass for the whole
    batch, bit-identical per instance to ``single.awac_cwinners_fused``."""
    mate_row, mate_col, u, v = state
    b, cap = row.shape
    qr = jnp.take_along_axis(mate_row, col, axis=1)  # m_j for each edge
    qc = jnp.take_along_axis(mate_col, row, axis=1)  # m_i for each edge
    qr_s = jnp.clip(qr, 0, n)
    lo = jnp.take_along_axis(row_ptr, qr_s, axis=1)
    hi = jnp.where(qr < n, jnp.take_along_axis(row_ptr, qr_s + 1, axis=1), lo)
    pos, found = batched_searchsorted_in_window(col, qc, lo, hi,
                                                n_steps=window_steps)
    w2 = jnp.where(
        found,
        jnp.take_along_axis(val, jnp.clip(pos, 0, cap - 1), axis=1), 0.0)
    gain = val + w2 - jnp.take_along_axis(u, row, axis=1) \
        - jnp.take_along_axis(v, col, axis=1)
    cand = found & (row < n) & (row > qr) & (gain > min_gain)
    eidx = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), (b, cap))
    seg = jnp.where(cand, col, n)
    gm = jnp.where(cand, gain, NEG)
    Cgain_full, Cedge = batched_segment_max_with_payload(gm, eidx, seg, n + 1)
    Cgain, Cedge = Cgain_full[:, :n], Cedge[:, :n]
    ce = jnp.clip(Cedge, 0)
    has = Cedge >= 0
    Ci = jnp.where(has, jnp.take_along_axis(row, ce, axis=1), n) \
        .astype(jnp.int32)
    Cw1 = jnp.where(has, jnp.take_along_axis(val, ce, axis=1), 0.0)
    Cw2 = jnp.where(has, jnp.take_along_axis(w2, ce, axis=1), 0.0)
    return Cgain, Ci, Cw1, Cw2


def _cwinners_batched(backend, row, col, val, row_ptr, n, state, min_gain,
                      window_steps):
    if backend == "reference":
        return jax.vmap(
            lambda r, c, v, mr, mc, u, vv: single.awac_cwinners(
                r, c, v, n, MatchState(mr, mc, u, vv), min_gain)
        )(row, col, val, *state)
    if backend == "xla":
        return awac_cwinners_fused_batched(row, col, val, row_ptr, n, state,
                                           min_gain, window_steps)
    if backend == "pallas":
        # Local import: core must stay importable without the kernel package.
        from repro.kernels.cycle_gain.ops import awac_sweep_winners_batched

        return awac_sweep_winners_batched(
            row, col, val, row_ptr, state.mate_row, state.mate_col, state.u,
            state.v, min_gain, n=n, window_steps=window_steps,
        )
    raise ValueError(f"unknown AWAC backend {backend!r}")


# Trace-time convergence-mask hook for the chaos harness
# (``runtime.chaos``): when set, called as ``tap(active, iters) -> active``
# after each round's convergence update. None in production — the branch
# below folds away entirely at trace time.
_CONVERGENCE_TAP = None


def awac_loop(n: int, state: MatchState, max_iter: int, min_gain,
              cwinners_fn, active0=None, aux0=None):
    """Masked batched AWAC loop. ``cwinners_fn(state) -> (Cgain, Ci, Cw1,
    Cw2, aux)`` supplies each round's Step A+B+C winners plus an int32
    value accumulated across rounds (scalar 0 for the local backends; the
    dropped-candidate count — or the [dropped, integrity] pair under
    exchange checking — for the distributed engine's bucketed exchanges).
    Step D + augmentation is the vmapped ``single.select_and_augment`` —
    shared verbatim with every other engine.

    ``active0`` ([B] bool) masks instances out of the loop from round 0
    (the infeasible-instance short-circuit: an imperfect matching can never
    become perfect through 4-cycle rotations). ``aux0`` overrides the aux
    accumulator's initial value/shape. Returns (state, iters [B], aux)."""
    b = state.mate_row.shape[0]
    select = jax.vmap(
        lambda Cg, Ci, Cw1, Cw2, mr, mc, u, v: single.select_and_augment(
            n, Cg, Ci, Cw1, Cw2, MatchState(mr, mc, u, v), min_gain)
    )

    def body(carry):
        state, iters, active, aux = carry
        Cgain, Ci, Cw1, Cw2, a = cwinners_fn(state)
        new_state, n_surv = select(Cgain, Ci, Cw1, Cw2, *state)
        keep = active[:, None]
        state = MatchState(
            *(jnp.where(keep, ns, s) for ns, s in zip(new_state, state)))
        iters = iters + active.astype(jnp.int32)
        active = active & (n_surv > 0) & (iters < max_iter)
        if _CONVERGENCE_TAP is not None:
            active = _CONVERGENCE_TAP(active, iters)
        return state, iters, active, aux + a

    def cond(carry):
        return carry[2].any()

    # max_iter <= 0 admits no iterations, matching single._awac_loop
    go0 = jnp.full((b,), max_iter > 0)
    if active0 is not None:
        go0 = go0 & active0
    state, iters, _, aux = jax.lax.while_loop(
        cond, body,
        (state, jnp.zeros((b,), jnp.int32), go0,
         jnp.array(0, jnp.int32) if aux0 is None else aux0),
    )
    return state, iters, aux


@functools.partial(
    jax.jit, static_argnames=("n", "max_iter", "backend", "window_steps",
                              "degrade_infeasible")
)
def _awac_loop_batched(row, col, val, row_ptr, n: int, state: MatchState,
                       max_iter: int, min_gain, backend: str,
                       window_steps: int, degrade_infeasible: bool = False):
    def cwinners(st):
        out = _cwinners_batched(backend, row, col, val, row_ptr, n, st,
                                min_gain, window_steps)
        return (*out, jnp.array(0, jnp.int32))

    active0 = is_perfect_batched(state, n) if degrade_infeasible else None
    state, iters, _ = awac_loop(n, state, max_iter, min_gain, cwinners,
                                active0=active0)
    return state, iters


def _resolve_window_steps_batched(row, n, window_steps):
    # csr.max_row_nnz measures [B, cap] rows across the whole batch (one
    # shared static depth; extra rounds beyond an instance's own need never
    # change its search results), so the single-instance resolver applies.
    return single._resolve_window_steps(row, n, window_steps)


def awac_batched(row, col, val, n: int, state: MatchState,
                 max_iter: int = 1000, min_gain: float = MIN_GAIN,
                 backend: str = "auto", row_ptr=None,
                 window_steps: int | None = None,
                 degrade_infeasible: bool = False):
    """Batched AWAC loop over [B, cap] instances. Returns (state, iters [B]).

    Same backend contract as ``single.awac``; every instance's result and
    iteration count are bit-identical to its own single-instance run."""
    backend = single.resolve_backend(backend, n=n, batch=row.shape[0])
    window_steps = _resolve_window_steps_batched(row, n, window_steps)
    if row_ptr is None:
        row_ptr = batched_row_ptr_from_sorted(row, n)
    if backend == "pallas_persistent":
        # Local import: core must stay importable without the kernel package.
        from repro.kernels.cycle_gain.ops import awac_persistent_loop_batched

        b = row.shape[0]
        go0 = is_perfect_batched(state, n) if degrade_infeasible \
            else jnp.ones((b,), bool)
        mr, mc, u, v, iters = awac_persistent_loop_batched(
            row, col, val, row_ptr, state.mate_row, state.mate_col, state.u,
            state.v, min_gain, go0, n=n, window_steps=window_steps,
            max_iter=max_iter)
        return MatchState(mr, mc, u, v), iters
    if backend == "xla":
        # Same x64 trace context as single.awac: Step C runs as one
        # packed-key uint64 segment_max over the whole batch (no-op under
        # an outer jit, see single._x64_scope).
        with single._x64_scope(row):
            return _awac_loop_batched(row, col, val, row_ptr, n, state,
                                      max_iter, min_gain, backend,
                                      window_steps, degrade_infeasible)
    return _awac_loop_batched(row, col, val, row_ptr, n, state, max_iter,
                              min_gain, backend, window_steps,
                              degrade_infeasible)


# --------------------------------------------------------------------------
# Warm-start rematching: seed the pipeline from previous mate arrays
# --------------------------------------------------------------------------


def _normalize_mates_batched(mate_row, mate_col, b: int, n: int):
    """Accept seed mates of shape [B, n] or [B, n + 1] (numpy or jnp, any
    int dtype) and return int32 [B, n + 1] arrays with the sentinel slot
    pinned. Shape mismatches raise ValueError — the caller decides whether
    that means \"fall back to cold\" (serving) or \"user error\" (api)."""
    mate_row = jnp.asarray(mate_row, jnp.int32)
    mate_col = jnp.asarray(mate_col, jnp.int32)
    if mate_row.shape != mate_col.shape:
        raise ValueError(
            f"warm-start mate arrays disagree: mate_row {mate_row.shape} vs "
            f"mate_col {mate_col.shape}")
    if mate_row.shape == (b, n):
        pad = jnp.full((b, 1), n, jnp.int32)
        mate_row = jnp.concatenate([mate_row, pad], axis=1)
        mate_col = jnp.concatenate([mate_col, pad], axis=1)
    elif mate_row.shape != (b, n + 1):
        raise ValueError(
            f"warm-start mate arrays must be [B, n] or [B, n + 1] = "
            f"[{b}, {n + 1}], got {mate_row.shape}")
    return (mate_row.at[:, n].set(n), mate_col.at[:, n].set(n))


@functools.partial(jax.jit, static_argnames=("n", "window_steps"))
def repair_mates_batched(row, col, val, row_ptr, n: int, mate_row, mate_col,
                         window_steps: int):
    """Repair seed mates against the CURRENT edge lists: a claimed pair
    (i, j) survives only if it is mutual (``mate_col[i] == j``) and the
    edge still exists in the instance (CSR-windowed membership probe). Any
    out-of-range, one-sided, or structurally-stale entry is unmatched on
    both sides, so the output is always a partial matching on existing
    edges — whatever garbage the seed carried. Returns (mate_row,
    mate_col), int32 [B, n + 1]."""
    b = row.shape[0]
    jvec = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    mr = mate_row[:, :n]
    valid = (mr >= 0) & (mr < n)
    i_s = jnp.clip(mr, 0, n)
    lo = jnp.take_along_axis(row_ptr, i_s, axis=1)
    hi = jnp.where(valid, jnp.take_along_axis(row_ptr, i_s + 1, axis=1), lo)
    _, found = batched_searchsorted_in_window(col, jvec, lo, hi,
                                              n_steps=window_steps)
    mutual = valid & (jnp.take_along_axis(mate_col, i_s, axis=1) == jvec)
    keep = mutual & found
    bidx = jnp.arange(b)[:, None]
    new_mr = jnp.full((b, n + 1), n, jnp.int32).at[:, :n].set(
        jnp.where(keep, mr, n))
    new_mc = jnp.full((b, n + 1), n, jnp.int32).at[
        bidx, jnp.where(keep, i_s, n)].set(jnp.where(keep, jvec, n))
    return new_mr.at[:, n].set(n), new_mc.at[:, n].set(n)


def warm_mates_batched(row, col, val, row_ptr, n: int, mate_row, mate_col,
                       window_steps: int):
    """Repaired seed + bounded MCM top-up: the warm-start replacement for
    the greedy + MCM cold phases. The top-up is the pipeline's own batched
    MCM, whose phase loop is bounded by the seed deficiency (each phase
    either matches a free row or stops) — an intact seed runs ZERO phases,
    which is where warm-start rematching earns its keep on mostly-stable
    streams. Returns (mate_row, mate_col)."""
    mate_row, mate_col = repair_mates_batched(
        row, col, val, row_ptr, n, mate_row, mate_col, window_steps)
    return mcm_batched(row, col, val, n, mate_row, mate_col)


def _awpm_batched_from_state(row, col, val, n: int, mate_row, mate_col,
                             max_iter: int = 1000,
                             min_gain: float = MIN_GAIN, backend: str = "auto",
                             row_ptr=None, window_steps: int | None = None,
                             degrade_infeasible: bool = False):
    """Warm-start batched pipeline: repair the seed mates -> MCM top-up ->
    AWAC, replacing greedy + MCM-from-scratch (DESIGN.md §11). Returns
    (MatchState, awac_iters [B]), same contract as ``_awpm_batched``.

    When the seed IS an AWAC fixed point of the same instance (the
    previous result of an unchanged problem), repair keeps every pair, the
    top-up runs zero phases, and AWAC converges on its first round —
    returning the seed matching (mates, duals, weight) bit-identically."""
    window_steps = _resolve_window_steps_batched(row, n, window_steps)
    if row_ptr is None:
        row_ptr = batched_row_ptr_from_sorted(row, n)
    mate_row, mate_col = _normalize_mates_batched(
        mate_row, mate_col, row.shape[0], n)
    mate_row, mate_col = warm_mates_batched(
        row, col, val, row_ptr, n, mate_row, mate_col, window_steps)
    state = _state_from_mates_windowed(row, col, val, row_ptr, n, mate_row,
                                       mate_col, window_steps)
    return awac_batched(row, col, val, n, state, max_iter=max_iter,
                        min_gain=min_gain, backend=backend, row_ptr=row_ptr,
                        window_steps=window_steps,
                        degrade_infeasible=degrade_infeasible)


def awpm_batched(row, col, val, n: int, max_iter: int = 1000,
                 min_gain: float = MIN_GAIN, backend: str = "auto",
                 row_ptr=None, window_steps: int | None = None):
    """Deprecated alias of the batched pipeline — use ``repro.core.api.solve``
    with a batched ``MatchingProblem``."""
    warn_legacy("repro.core.batch.awpm_batched", "solve()")
    return _awpm_batched(row, col, val, n, max_iter=max_iter,
                         min_gain=min_gain, backend=backend, row_ptr=row_ptr,
                         window_steps=window_steps)


def _awpm_batched(row, col, val, n: int, max_iter: int = 1000,
                  min_gain: float = MIN_GAIN, backend: str = "auto",
                  row_ptr=None, window_steps: int | None = None,
                  degrade_infeasible: bool = False):
    """Full batched pipeline: greedy maximal -> MCM -> AWAC for B instances
    in three dispatches total. row/col/val are [B, cap] padded lex-sorted COO
    sharing n (see ``stack_graphs``). Returns (MatchState with [B, n + 1]
    fields, awac_iters [B]) — per instance bit-identical to
    ``single._awpm(row[b], col[b], val[b], n)`` on the same backend.

    Internal engine behind ``repro.core.api.solve`` (the batched dispatch
    target) and the deprecated ``awpm_batched`` shim."""
    window_steps = _resolve_window_steps_batched(row, n, window_steps)
    if row_ptr is None:
        row_ptr = batched_row_ptr_from_sorted(row, n)
    mate_row, mate_col = greedy_maximal_batched(row, col, val, n)
    mate_row, mate_col = mcm_batched(row, col, val, n, mate_row, mate_col)
    state = _state_from_mates_windowed(row, col, val, row_ptr, n, mate_row,
                                       mate_col, window_steps)
    return awac_batched(row, col, val, n, state, max_iter=max_iter,
                        min_gain=min_gain, backend=backend, row_ptr=row_ptr,
                        window_steps=window_steps,
                        degrade_infeasible=degrade_infeasible)
