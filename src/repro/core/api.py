"""Unified AWPM facade: one problem/options/result API across single,
batched, and distributed solving.

The paper presents AWPM as ONE algorithm (greedy maximal -> MCM -> AWAC
4-cycle refinement) with one set of knobs; this module is the one public
entry point that matches that framing — the analogue of how Azad et al.
expose AWPM to SuperLU_DIST behind a single call. Three PRs of growth left
three divergent entry-point families (``single.awpm``,
``batch.awpm_batched``, ``dist.awpm_dist_batched`` plus the ``DistAWPM`` /
``DistBatchedAWPM`` / ``make_awpm_dist_batched`` factory zoo), each
threading loose COO triples and a different kwarg subset; those all remain
as deprecation shims, bit-identical, while every consumer routes through:

  - :class:`MatchingProblem` — a pytree holding the padded lex-sorted COO
    edge list ([cap] for one instance, [B, cap] for a batch) plus the
    static ``n``; constructors ``from_coo`` / ``from_graph`` / ``stack``.
  - :class:`SolveOptions` — a frozen, eagerly-validated dataclass carrying
    every knob (``max_iter``, ``min_gain``, ``backend``, ``window_steps``,
    ``grid``, ``cap``, ``a2a_caps``, ``packed``).
  - :func:`solve` — dispatches single -> batched -> distributed from the
    problem shape and grid presence, returning a :class:`MatchResult`.
  - :func:`plan` -> :class:`Matcher` — the compile-once/run-many handle:
    capacity planning (``sparse.partition.plan_block_cap``), a2a bucket
    sizing, windowed-search depth pinning, and the distributed engine
    construction all happen at plan time; the XLA compile itself lands on
    the first call (standard jit) and every later call reuses that one
    executable.

Dispatch rules (DESIGN.md §7):

  ===========  =========  =============================================
  problem      grid       engine
  ===========  =========  =============================================
  [cap]        None       ``single._awpm``        (one instance)
  [B, cap]     None       ``batch._awpm_batched`` (one dispatch, B lanes)
  [cap]        GridSpec   distributed-batched engine, lifted to B=1
  [B, cap]     GridSpec   ``dist._DistBatchedAWPM`` (one shard_map dispatch)
  ===========  =========  =============================================

Every route is bit-identical per instance to every other (the engines are
pinned to each other by the differential suites), so dispatch is purely a
performance decision.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch as _batch
from repro.core import graph as _graph
from repro.core import preflight as _preflight
from repro.core import single as _single
from repro.core.constants import MIN_GAIN
from repro.core.single import MatchState
from repro.sparse.csr import window_depth

#: every backend ``SolveOptions`` accepts. "auto" resolves locally via the
#: MEASURED dispatch table (``repro.kernels.dispatch``, refreshed by the
#: kernels bench job) — the winner for this platform and shape class, not a
#: hard-coded platform rule; on a grid it resolves to the "fused"
#: exchange+windowed-join engine. "reference" is the seed bit-exactness
#: oracle. "pallas_persistent" runs the whole AWAC loop in one persistent
#: kernel and is local-only; "fused" is distributed-only; "xla"/"pallas"
#: with a grid require the 1x1 grid (the block is the whole instance).
BACKENDS = ("auto", "reference", "xla", "pallas", "pallas_persistent",
            "fused")

#: ``SolveOptions.on_invalid`` policies (see ``core.preflight``).
ON_INVALID = ("raise", "sanitize", "degrade")

__all__ = [
    "BACKENDS",
    "MIN_GAIN",
    "ON_INVALID",
    "MatchResult",
    "Matcher",
    "MatchingProblem",
    "ProblemSpec",
    "SolveOptions",
    "plan",
    "solve",
]


# --------------------------------------------------------------------------
# problem
# --------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: array fields —
# identity semantics keep == and hash() usable (pytree-dataclass convention)
class MatchingProblem:
    """One (or a batch of) heavy-weight perfect-matching instance(s).

    ``row``/``col``/``val`` follow the repo-wide padded COO convention:
    lex-sorted by (row, col) per instance, padding entries (n, n, 0),
    square n x n. Shapes are [cap] (single instance) or [B, cap] (a batch
    sharing ``n``). Direct construction assumes that convention; use
    ``from_coo`` to sort/pad raw triples, ``from_graph`` for a
    ``BipartiteGraph``, and ``stack`` to batch instances of mixed nnz.

    Registered as a jax pytree (leaves row/col/val, static ``n``) so a
    problem can cross jit boundaries whole.
    """

    row: Any  # [cap] or [B, cap] int32
    col: Any  # same shape as row
    val: Any  # same shape, float32
    n: int

    def __post_init__(self):
        shp = np.shape(self.row)
        if np.shape(self.col) != shp or np.shape(self.val) != shp:
            raise ValueError(
                f"row/col/val shapes differ: {shp}, {np.shape(self.col)}, "
                f"{np.shape(self.val)}")
        if len(shp) not in (1, 2):
            raise ValueError(
                f"expected [cap] or [B, cap] edge arrays, got shape {shp}")

    # ---- pytree protocol ----
    def tree_flatten(self):
        return (self.row, self.col, self.val), self.n

    @classmethod
    def tree_unflatten(cls, n, leaves):
        # bypass __post_init__: transforms may rebuild with placeholder
        # leaves that have no shape
        obj = object.__new__(cls)
        object.__setattr__(obj, "row", leaves[0])
        object.__setattr__(obj, "col", leaves[1])
        object.__setattr__(obj, "val", leaves[2])
        object.__setattr__(obj, "n", n)
        return obj

    # ---- shape queries ----
    @property
    def is_batched(self) -> bool:
        return len(np.shape(self.row)) == 2

    @property
    def batch_size(self) -> int | None:
        """B for a batched problem, None for a single instance."""
        shp = np.shape(self.row)
        return int(shp[0]) if len(shp) == 2 else None

    @property
    def cap(self) -> int:
        """Padded edge capacity per instance."""
        return int(np.shape(self.row)[-1])

    @property
    def spec(self) -> "ProblemSpec":
        return ProblemSpec(n=int(self.n), cap=self.cap,
                           batch=self.batch_size)

    # ---- constructors ----
    @classmethod
    def from_coo(cls, row, col, val, n: int,
                 capacity: int | None = None) -> "MatchingProblem":
        """Sort raw COO triples lexicographically and pad to ``capacity``
        (rounded up to the repo-wide alignment when None)."""
        g = _graph.from_coo(row, col, val, n, capacity=capacity)
        return cls.from_graph(g)

    @classmethod
    def from_graph(cls, g: _graph.BipartiteGraph) -> "MatchingProblem":
        return cls(row=g.row, col=g.col, val=g.val, n=g.n)

    @classmethod
    def stack(cls, items: Sequence[Any]) -> "MatchingProblem":
        """Pad instances (``BipartiteGraph``s or single-instance problems)
        of arbitrary per-instance nnz — but shared ``n`` — into one batched
        [B, cap] problem. Subsumes ``core.batch.stack_graphs``."""
        if not items:
            raise ValueError("stack() needs at least one instance")
        gs = []
        for it in items:
            if isinstance(it, _graph.BipartiteGraph):
                gs.append(it)
            elif isinstance(it, MatchingProblem):
                if it.is_batched:
                    raise ValueError(
                        "stack() takes single instances; got a batched "
                        f"problem of B={it.batch_size}")
                r = np.asarray(it.row, np.int32)
                gs.append(_graph.BipartiteGraph(
                    n=it.n, nnz=int((r < it.n).sum()), row=r,
                    col=np.asarray(it.col, np.int32),
                    val=np.asarray(it.val, np.float32)))
            else:
                raise TypeError(
                    f"stack() takes BipartiteGraphs or MatchingProblems, "
                    f"got {type(it).__name__}")
        row, col, val = _batch.stack_graphs(gs)
        return cls(row=row, col=col, val=val, n=gs[0].n)


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """Static shape signature of a :class:`MatchingProblem` — what
    :func:`plan` specializes a :class:`Matcher` to."""

    n: int
    cap: int
    batch: int | None = None

    def __post_init__(self):
        # accept (and normalize away) numpy integers — n/cap/batch routinely
        # come off array shapes
        for name in ("n", "cap"):
            object.__setattr__(
                self, name,
                _as_int(f"{name} must be a positive int", getattr(self, name)))
        if self.batch is not None:
            object.__setattr__(
                self, "batch",
                _as_int("batch must be None or a positive int", self.batch))


# --------------------------------------------------------------------------
# options
# --------------------------------------------------------------------------


def _as_int(message: str, v, minimum: int = 1) -> int:
    """Validate an integral knob (python or numpy int, bool excluded,
    >= minimum) and normalize it to a plain int."""
    if isinstance(v, bool) or not isinstance(v, (int, np.integer)) \
            or v < minimum:
        raise ValueError(f"{message}, got {v!r}")
    return int(v)


def _as_grid_spec(grid):
    """Normalize Mesh | GridSpec -> validated GridSpec (clear errors)."""
    from repro.core.dist import GridSpec  # local: core stays light to import

    if isinstance(grid, GridSpec):
        spec = grid
    elif isinstance(grid, jax.sharding.Mesh):
        spec = GridSpec(grid)
    else:
        raise ValueError(
            f"grid must be a jax.sharding.Mesh or repro.core.dist.GridSpec, "
            f"got {type(grid).__name__}")
    have = tuple(spec.mesh.axis_names)
    missing = [a for a in (*spec.row_axes, spec.col_axis) if a not in have]
    if missing:
        raise ValueError(
            f"bad grid shape: mesh axes {have} are missing the process-grid "
            f"axes {tuple(missing)} (row_axes={spec.row_axes}, "
            f"col_axis={spec.col_axis!r})")
    return spec


@dataclasses.dataclass(frozen=True)
class SolveOptions:
    """Every AWPM knob, validated eagerly at construction.

    max_iter      AWAC round budget (>= 0; 0 skips refinement entirely).
    min_gain      minimum 4-cycle gain to count as augmenting (paper eps).
    backend       one of :data:`BACKENDS`; "auto" picks per dispatch target.
    window_steps  windowed-search depth override (None = measured/derived;
                  extra depth never changes results, and an undersized
                  override is clamped up to the measured need).
    grid          None (local) or a Mesh / ``core.dist.GridSpec`` — presence
                  selects the distributed engine.
    cap           distributed per-block edge capacity override (None = true
                  block occupancy via ``sparse.partition.plan_block_cap``;
                  too small raises "refusing to truncate" at partition
                  time — edges are never dropped silently).
    a2a_caps      distributed bucket capacities for the two exchange stages
                  (None = provably drop-free ``safe_a2a_caps``).
    packed        pack the distributed exchanges into one collective each.
    on_invalid    policy for degenerate input (``core.preflight``):
                  "raise" rejects fatal issues (non-finite weights,
                  duplicate edges) and infeasible instances with a typed
                  error; "sanitize" repairs the data (drop non-finite
                  edges, merge duplicates keep-max) but still raises on
                  infeasibility; "degrade" additionally returns the maximal
                  imperfect matching (``perfect=False``) with the diagnosis
                  attached instead of raising. All three short-circuit AWAC
                  on infeasible instances (a 4-cycle rotation can never
                  raise cardinality, so the budget would be pure waste).
    exchange_check  distributed-only: conserve-count + checksum accounting
                  across the two-stage exchange each AWAC round; any
                  drop/duplicate/corruption raises
                  ``core.dist.ExchangeIntegrityError``.
    """

    max_iter: int = 1000
    min_gain: float = MIN_GAIN
    backend: str = "auto"
    window_steps: int | None = None
    grid: Any = None
    cap: int | None = None
    a2a_caps: tuple[int, int] | None = None
    packed: bool = False
    on_invalid: str = "raise"
    exchange_check: bool = False

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}: expected one of "
                f"{BACKENDS}")
        if self.on_invalid not in ON_INVALID:
            raise ValueError(
                f"unknown on_invalid policy {self.on_invalid!r}: expected "
                f"one of {ON_INVALID}")
        object.__setattr__(
            self, "max_iter",
            _as_int("max_iter must be a non-negative int", self.max_iter,
                    minimum=0))
        if not math.isfinite(float(self.min_gain)) or float(self.min_gain) < 0:
            # negative values would admit zero/negative-gain 4-cycles and
            # let AWAC churn tie swaps for the whole max_iter budget
            raise ValueError(
                f"min_gain must be finite and >= 0, got {self.min_gain!r}")
        if self.window_steps is not None:
            object.__setattr__(
                self, "window_steps",
                _as_int("window_steps must be None or a positive int",
                        self.window_steps))
        if self.cap is not None:
            object.__setattr__(
                self, "cap",
                _as_int("cap must be None or a positive per-block edge "
                        "capacity", self.cap))
        if self.a2a_caps is not None:
            caps = tuple(self.a2a_caps)
            if len(caps) != 2:
                raise ValueError(
                    f"a2a_caps must be two positive ints (stage-1, stage-2 "
                    f"bucket capacities), got {self.a2a_caps!r}")
            caps = tuple(
                _as_int("a2a_caps must be two positive ints", c)
                for c in caps)
            object.__setattr__(self, "a2a_caps", caps)
        if self.grid is not None:
            spec = _as_grid_spec(self.grid)
            object.__setattr__(self, "grid", spec)
            if self.backend == "pallas_persistent":
                raise ValueError(
                    "backend 'pallas_persistent' runs the whole AWAC loop "
                    "inside one local kernel and cannot participate in the "
                    "distributed exchange — drop SolveOptions.grid")
            if self.backend in ("xla", "pallas") and \
                    (spec.pr, spec.pc) != (1, 1):
                raise ValueError(
                    f"backend {self.backend!r} routes through the local "
                    f"fused sweep and needs the 1x1 grid, got "
                    f"{spec.pr}x{spec.pc}")
        else:
            if self.backend == "fused":
                raise ValueError(
                    "backend 'fused' is the distributed exchange engine and "
                    "requires SolveOptions.grid")
            for name in ("cap", "a2a_caps"):
                if getattr(self, name) is not None:
                    raise ValueError(
                        f"{name} is a distributed capacity knob and "
                        f"requires SolveOptions.grid")
            if self.packed:
                raise ValueError(
                    "packed is a distributed exchange knob and requires "
                    "SolveOptions.grid")
            if self.exchange_check:
                raise ValueError(
                    "exchange_check audits the distributed two-stage "
                    "exchange and requires SolveOptions.grid")

    def _dist_backend(self) -> str:
        return "fused" if self.backend == "auto" else self.backend


# --------------------------------------------------------------------------
# result
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExecutionInfo:
    """How a solve actually executed — the honest dispatch record.

    ``backend``: the concrete engine that ran (never "auto").
    ``source``: how it was chosen — "explicit" (user-pinned), "table" (the
    measured dispatch table, ``repro.kernels.dispatch``), "heuristic"
    (platform fallback when the table has no measurements for this
    platform), or "grid-default" (the distributed route's fused engine).
    ``ran_interpreted``: for Pallas backends, whether the kernel executes
    in the Pallas interpreter (True on platforms without a compiled
    lowering) — None for non-Pallas backends. Interpreter execution is
    correctness-grade, never performance-grade.
    """

    backend: str
    source: str
    ran_interpreted: bool | None = None
    #: True when the solve was seeded from previous mates (warm-start
    #: rematching) instead of running greedy + MCM from scratch.
    warm_started: bool = False


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: see MatchingProblem
class MatchResult:
    """Matching produced by :func:`solve` / a :class:`Matcher`.

    Single instance: ``mate_row``/``mate_col`` are [n + 1] (sentinel slot n;
    ``mate_row[j]`` = row matched to column j), ``weight``/``awac_iters``/
    ``perfect`` scalars. Batched: leading B on everything.

    ``diagnosis`` is a ``core.preflight.PreflightReport`` (or None) when
    preflight found issues worth surfacing — always present on a degraded
    (``perfect=False``) result, never on a clean solve. ``execution`` is an
    :class:`ExecutionInfo` recording the engine that actually ran (resolved
    backend, dispatch source, interpreter flag). Both ride as pytree
    aux_data (static).
    """

    mate_row: Any  # [n+1] or [B, n+1] int32; sentinel n = unmatched
    mate_col: Any  # [n+1] or [B, n+1] int32
    weight: Any  # matched-edge weight sum, f32
    awac_iters: Any  # AWAC rounds until convergence, i32
    perfect: Any  # bool: every column matched
    diagnosis: Any = None  # PreflightReport | None (static, host-side only)
    execution: Any = None  # ExecutionInfo | None (static)

    def tree_flatten(self):
        return (self.mate_row, self.mate_col, self.weight, self.awac_iters,
                self.perfect), (self.diagnosis, self.execution)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        diagnosis, execution = aux
        return cls(*leaves, diagnosis=diagnosis, execution=execution)


def _result(state: MatchState, iters, n: int, batched: bool) -> MatchResult:
    if batched:
        weight = _batch.matching_weight_batched(state, n)
        perfect = _batch.is_perfect_batched(state, n)
    else:
        weight = _single.matching_weight(state, n)
        perfect = _single.is_perfect(state, n)
    return MatchResult(mate_row=state.mate_row, mate_col=state.mate_col,
                       weight=weight, awac_iters=iters, perfect=perfect)


# --------------------------------------------------------------------------
# solve
# --------------------------------------------------------------------------


def _check_types(problem, options):
    if not isinstance(problem, MatchingProblem):
        raise TypeError(
            f"solve() takes a MatchingProblem (see from_coo/from_graph/"
            f"stack), got {type(problem).__name__}")
    if not isinstance(options, SolveOptions):
        raise TypeError(
            f"options must be SolveOptions, got {type(options).__name__}")


def _is_traced(problem: MatchingProblem) -> bool:
    return any(isinstance(x, jax.core.Tracer)
               for x in (problem.row, problem.col, problem.val))


def _apply_preflight(problem: MatchingProblem, options: SolveOptions):
    """Host-side input screening per ``options.on_invalid``. Returns the
    (possibly sanitized) problem and the report to carry into
    :func:`_finish` — or (problem, None) under a trace, where host
    inspection is impossible (the in-engine AWAC short-circuit still
    protects infeasible instances from burning the round budget)."""
    if _is_traced(problem):
        return problem, None
    report = _preflight.preflight(problem)
    if report.fatal:
        if options.on_invalid == "raise":
            raise _preflight.PreflightError(
                report,
                f"preflight rejected the problem: {report.summary()}. Pass "
                f"SolveOptions(on_invalid='sanitize') to repair, or "
                f"'degrade' to also accept infeasible instances.")
        problem, report = _preflight.sanitize(problem)
    if report.structural and options.on_invalid == "raise":
        # empty rows/columns make a perfect matching impossible — under the
        # strict policy that is an error, and it is known before solving
        raise _preflight.InfeasibleProblemError(
            report,
            f"problem has no perfect matching: {report.summary()}. Pass "
            f"SolveOptions(on_invalid='degrade') for the maximal matching.")
    return problem, report


def _finish(problem: MatchingProblem, result: MatchResult,
            options: SolveOptions, report) -> MatchResult:
    """Post-solve policy: attach the preflight diagnosis, and on an
    imperfect result either raise (raise/sanitize policies) or return the
    degraded matching with the deficiency folded into the diagnosis."""
    if isinstance(result.perfect, jax.core.Tracer):
        return result
    if bool(np.asarray(result.perfect).all()):
        if report is not None and report.issues:
            return dataclasses.replace(result, diagnosis=report)
        return result
    report = _preflight.deficiency_from_mates(
        result.mate_row, problem.n, report, batched=problem.is_batched)
    if options.on_invalid != "degrade":
        raise _preflight.InfeasibleProblemError(
            report,
            f"problem has no perfect matching: {report.summary()}. Pass "
            f"SolveOptions(on_invalid='degrade') for the maximal matching.")
    return dataclasses.replace(result, diagnosis=report)


def _execution_info(problem: MatchingProblem,
                    options: SolveOptions) -> ExecutionInfo:
    """Resolve what will actually run, for ``MatchResult.execution``.

    Mirrors the engines' own resolution (``core.single.resolve_backend`` /
    the kernel wrappers' ``interpret=None`` auto-detection) so the record
    matches the dispatch decision made inside the solve."""
    if options.grid is not None:
        return ExecutionInfo(
            backend=options._dist_backend(),
            source="explicit" if options.backend != "auto"
            else "grid-default")
    batch = problem.batch_size
    if options.backend != "auto":
        backend, source = options.backend, "explicit"
    else:
        try:
            from repro.kernels.dispatch import choose_backend

            winner = choose_backend(n=problem.n, batch=batch)
        except ImportError:
            winner = None
        backend = winner if winner is not None else \
            _single.resolve_backend("auto", n=problem.n, batch=batch)
        source = "table" if winner is not None else "heuristic"
    interpreted = None
    if backend.startswith("pallas"):
        try:
            from repro.kernels.backend import resolve_execution

            interpreted = resolve_execution(None).interpret
        except ImportError:
            pass
    return ExecutionInfo(backend=backend, source=source,
                         ran_interpreted=interpreted)


def _warm_mates(problem: MatchingProblem, warm_start):
    """Normalize a warm-start seed to (mate_row, mate_col) arrays matching
    the problem's batchedness ([n]/[n + 1] for a single instance, leading B
    for a batch). Accepts a previous :class:`MatchResult` or a
    (mate_row, mate_col) pair. A seed whose shape cannot belong to this
    problem raises ValueError — the serving tier catches that and falls
    back to the cold path; entry *values* are never validated here (the
    engine-side repair unmatches every stale/garbage pair)."""
    if isinstance(warm_start, MatchResult):
        mr, mc = warm_start.mate_row, warm_start.mate_col
    elif isinstance(warm_start, (tuple, list)) and len(warm_start) == 2:
        mr, mc = warm_start
    else:
        raise TypeError(
            f"warm_start must be a MatchResult or a (mate_row, mate_col) "
            f"pair, got {type(warm_start).__name__}")
    n = problem.n
    shp = np.shape(mr)
    if np.shape(mc) != shp:
        raise ValueError(
            f"warm_start mate arrays disagree: {shp} vs {np.shape(mc)}")
    if problem.is_batched:
        want = [(problem.batch_size, n), (problem.batch_size, n + 1)]
    else:
        want = [(n,), (n + 1,)]
    if shp not in want:
        raise ValueError(
            f"warm_start shape {shp} does not fit the problem (expected "
            f"one of {want}; stale seeds from a different n/batch must be "
            f"discarded, not repaired)")
    return mr, mc


def solve(problem: MatchingProblem,
          options: SolveOptions | None = None, *,
          warm_start=None) -> MatchResult:
    """Run the full AWPM pipeline (greedy maximal -> MCM -> AWAC) on
    ``problem``, dispatching on its shape and ``options.grid`` (see the
    module docstring table). Returns a :class:`MatchResult`; bit-identical
    per instance on every route and backend.

    ``warm_start`` (a previous :class:`MatchResult` or a (mate_row,
    mate_col) pair) seeds the pipeline from an earlier matching instead of
    greedy + MCM from scratch: stale pairs are repaired against the current
    edge list, a bounded MCM top-up closes any seed deficiency, and AWAC
    runs from there (DESIGN.md §11). Seeding never changes the contract —
    the result is a perfect matching of THIS problem — and a seed that is
    already an AWAC fixed point of the same problem is returned
    bit-identically."""
    options = SolveOptions() if options is None else options
    _check_types(problem, options)
    warm = None if warm_start is None else _warm_mates(problem, warm_start)
    problem, report = _apply_preflight(problem, options)
    if options.grid is not None:
        result = _solve_dist(problem, options, warm=warm)
    elif problem.is_batched:
        if warm is None:
            state, iters = _batch._awpm_batched(
                problem.row, problem.col, problem.val, problem.n,
                max_iter=options.max_iter, min_gain=options.min_gain,
                backend=options.backend, window_steps=options.window_steps,
                degrade_infeasible=True)
        else:
            state, iters = _batch._awpm_batched_from_state(
                problem.row, problem.col, problem.val, problem.n,
                warm[0], warm[1], max_iter=options.max_iter,
                min_gain=options.min_gain, backend=options.backend,
                window_steps=options.window_steps, degrade_infeasible=True)
        result = _result(state, iters, problem.n, batched=True)
    else:
        if warm is None:
            state, iters = _single._awpm(
                problem.row, problem.col, problem.val, problem.n,
                max_iter=options.max_iter, min_gain=options.min_gain,
                backend=options.backend, window_steps=options.window_steps,
                degrade_infeasible=True)
        else:
            # lift to B=1: the batched engine is pinned bit-identical per
            # instance to the single-instance one, so the lift is purely
            # a code-path economy (one warm engine, not two)
            wmr, wmc = (jnp.asarray(x)[None] for x in warm)
            bstate, biters = _batch._awpm_batched_from_state(
                problem.row[None], problem.col[None], problem.val[None],
                problem.n, wmr, wmc, max_iter=options.max_iter,
                min_gain=options.min_gain, backend=options.backend,
                window_steps=options.window_steps, degrade_infeasible=True)
            state = MatchState(*(x[0] for x in bstate))
            iters = biters[0]
        result = _result(state, iters, problem.n, batched=False)
    result = dataclasses.replace(
        result, execution=dataclasses.replace(
            _execution_info(problem, options), warm_started=warm is not None))
    return _finish(problem, result, options, report)


def _solve_dist(problem: MatchingProblem, options: SolveOptions,
                driver=None, warm=None) -> MatchResult:
    """Grid dispatch: one distributed-batched shard_map dispatch (a single
    instance is lifted to B=1 — still bit-identical, the batched engine is
    pinned per instance to the single-instance one)."""
    from repro.core import dist as _dist

    if any(isinstance(x, jax.core.Tracer)
           for x in (problem.row, problem.col, problem.val)):
        raise TypeError(
            "the distributed route partitions the edge list on the host and "
            "cannot run under jit — call solve()/Matcher with grid= outside "
            "jit (the local routes trace fine)")
    row = np.asarray(problem.row)
    col = np.asarray(problem.col)
    val = np.asarray(problem.val)
    batched = problem.is_batched
    if not batched:
        row, col, val = row[None], col[None], val[None]
    state0 = None
    if warm is not None:
        # warm start on a grid: the cheap host-side phases (seed repair +
        # MCM top-up + dual build) run on the local batched engine, then
        # ONE distributed dispatch runs the AWAC phase from that state
        # (the driver's from_state entry, DESIGN.md §5)
        from repro.sparse.csr import batched_row_ptr_from_sorted

        wmr, wmc = warm
        if not batched:
            wmr, wmc = jnp.asarray(wmr)[None], jnp.asarray(wmc)[None]
        jrow, jcol, jval = jnp.asarray(row), jnp.asarray(col), \
            jnp.asarray(val)
        ws = _batch._resolve_window_steps_batched(
            jrow, problem.n, options.window_steps)
        row_ptr = batched_row_ptr_from_sorted(jrow, problem.n)
        wmr, wmc = _batch._normalize_mates_batched(
            wmr, wmc, row.shape[0], problem.n)
        wmr, wmc = _batch.warm_mates_batched(
            jrow, jcol, jval, row_ptr, problem.n, wmr, wmc, ws)
        state0 = _batch._state_from_mates_windowed(
            jrow, jcol, jval, row_ptr, problem.n, wmr, wmc, ws)
    if driver is None:
        driver = _dist._DistBatchedAWPM(
            options.grid, problem.n, cap=options.cap,
            a2a_caps=options.a2a_caps, max_iter=options.max_iter,
            min_gain=options.min_gain, packed=options.packed,
            backend=options._dist_backend(),
            window_steps=options.window_steps,
            degrade_infeasible=True,
            exchange_check=options.exchange_check)
    state, iters, aux = driver.run(row, col, val, state=state0)
    aux = np.asarray(aux)
    # with exchange_check the engine psums a [dropped, integrity] pair per
    # run; otherwise aux is the plain global dropped counter
    dropped = int(aux[0]) if aux.ndim else int(aux)
    integrity = int(aux[1]) if aux.ndim else 0
    if integrity != 0:
        raise _dist.ExchangeIntegrityError(
            f"exchange integrity check failed on {integrity} AWAC round(s): "
            f"payloads received across the two-stage all_to_all do not "
            f"match what was sent (count or checksum mismatch). The "
            f"exchange lost, duplicated, or corrupted data; the result "
            f"cannot be trusted.")
    # only user-overridden a2a_caps can drop (the safe_a2a_caps default is
    # provably drop-free); a drop breaks the bit-identity contract, so it
    # is an error here, never a silent degradation
    if dropped != 0:
        raise _dist.ExchangeIntegrityError(
            f"{dropped} exchange requests were dropped by the "
            f"user-supplied a2a_caps={options.a2a_caps}: the result would "
            f"not be bit-identical to the local engines. Raise the bucket "
            f"capacities or leave a2a_caps=None for the drop-free default.")
    if not batched:
        state = MatchState(*(x[0] for x in state))
        iters = iters[0]
    return _result(state, iters, problem.n, batched)


# --------------------------------------------------------------------------
# plan: the compile-once/run-many Matcher
# --------------------------------------------------------------------------


class Matcher:
    """Solve handle specialized to one :class:`ProblemSpec` + options.

    Replaces the ``DistAWPM`` / ``DistBatchedAWPM`` /
    ``make_awpm_dist_batched`` factory zoo: all per-spec planning happens
    ONCE here — distributed per-block capacity (true occupancy via
    ``plan_block_cap`` when a prototype problem is given, the provable
    worst-case bound otherwise), drop-free a2a bucket capacities, the
    pinned windowed-search depth, and the block-level engine construction.
    The XLA compile lands on the first ``matcher(problem)`` call (standard
    jit) and every later call reuses that one executable. Construct via
    :func:`plan`.
    """

    def __init__(self, problem_spec: ProblemSpec, options: SolveOptions,
                 prototype: MatchingProblem | None = None):
        self.problem_spec = problem_spec
        self.options = options
        grid = options.grid
        self._driver = None
        if grid is None:
            # pinned local search depth: covers any row (<= min(cap, n)
            # entries), and extra depth never changes a search result. A
            # user override below that bound is lifted to it, so the pin
            # stays >= any measured need and every call keys one compiled
            # executable.
            bound = window_depth(min(problem_spec.cap, problem_spec.n))
            self._window_steps = max(options.window_steps or 0, bound)
            self.block_cap = None
            self.a2a_caps = None
            return

        from repro.core import dist as _dist
        from repro.sparse.partition import plan_block_cap

        n, pr, pc = problem_spec.n, grid.pr, grid.pc
        if options.cap is not None:
            self.block_cap = options.cap
        elif prototype is not None:
            self.block_cap = plan_block_cap(
                np.asarray(prototype.row), np.asarray(prototype.col),
                n, pr, pc)
        else:
            # worst-case occupancy: a block never holds more than its dense
            # extent nor more than the instance's whole edge list
            br, bc = -(-n // pr), -(-n // pc)
            self.block_cap = max(8, min(problem_spec.cap, br * bc))
        self.a2a_caps = options.a2a_caps or _dist.safe_a2a_caps(
            self.block_cap, pr, pc)
        # one depth formula (csr.window_depth) for plan-time pin and
        # run-time measurement, and the pin is lifted to the block bound:
        # pin >= measured always, so run() keeps the pin and the first
        # serving call hits the plan-time engine cache entry
        self._window_steps = max(options.window_steps or 0,
                                 window_depth(self.block_cap))
        self._driver = _dist._DistBatchedAWPM(
            grid, n, cap=self.block_cap, a2a_caps=self.a2a_caps,
            max_iter=options.max_iter, min_gain=options.min_gain,
            packed=options.packed, backend=options._dist_backend(),
            window_steps=self._window_steps,
            degrade_infeasible=True, exchange_check=options.exchange_check)
        # materialize the block-level engine now (plan-time, not per call;
        # the XLA compile itself still lands on the first call); the call
        # form mirrors _DistBatchedAWPM.run exactly so the lru_cache key
        # matches and the first serving call is a cache hit
        _dist._make_awpm_dist_batched(
            grid, n, problem_spec.batch or 1, self.block_cap, self.a2a_caps,
            options.max_iter, options.min_gain, packed=options.packed,
            backend=options._dist_backend(), window_steps=self._window_steps,
            from_state=False, degrade_infeasible=True,
            exchange_check=options.exchange_check)

    def _check(self, problem: MatchingProblem):
        spec = self.problem_spec
        if not isinstance(problem, MatchingProblem):
            raise TypeError(
                f"Matcher takes a MatchingProblem, got "
                f"{type(problem).__name__}")
        if problem.n != spec.n or problem.batch_size != spec.batch:
            raise ValueError(
                f"problem (n={problem.n}, batch={problem.batch_size}) does "
                f"not match the planned spec (n={spec.n}, "
                f"batch={spec.batch})")
        if problem.cap != spec.cap:
            raise ValueError(
                f"problem cap {problem.cap} != planned cap {spec.cap} "
                f"(the plan is shape-specialized; re-plan() or pad to the "
                f"planned capacity)")

    def __call__(self, problem: MatchingProblem,
                 warm_start=None) -> MatchResult:
        self._check(problem)
        opts = self.options
        if self._driver is not None:
            warm = None if warm_start is None \
                else _warm_mates(problem, warm_start)
            problem, report = _apply_preflight(problem, opts)
            try:
                result = _solve_dist(problem, opts, driver=self._driver,
                                     warm=warm)
                if result.execution is not None:
                    result = dataclasses.replace(
                        result, execution=dataclasses.replace(
                            result.execution, warm_started=warm is not None))
                return _finish(problem, result, opts, report)
            except ValueError as e:
                if "refusing to truncate" not in str(e):
                    raise
                # a prototype-planned capacity is the prototype's TRUE
                # occupancy (zero headroom) — denser same-spec data needs a
                # bigger plan, not the partition-internal advice
                raise ValueError(
                    f"problem exceeds the planned per-block capacity "
                    f"(block_cap={self.block_cap}): {e}. plan() again with "
                    f"a denser prototype, or pass SolveOptions(cap=...) "
                    f"with headroom for the serving workload.") from e
        pinned = dataclasses.replace(opts, window_steps=self._window_steps)
        return solve(problem, pinned, warm_start=warm_start)

    def __repr__(self):
        mode = "local" if self._driver is None else (
            f"grid {self.options.grid.pr}x{self.options.grid.pc}, "
            f"block_cap={self.block_cap}, a2a_caps={self.a2a_caps}")
        return (f"Matcher(n={self.problem_spec.n}, cap={self.problem_spec.cap}, "
                f"batch={self.problem_spec.batch}, "
                f"backend={self.options.backend!r}, {mode}, "
                f"window_steps={self._window_steps})")


def plan(problem_spec: ProblemSpec | MatchingProblem,
         options: SolveOptions | None = None) -> Matcher:
    """Build a :class:`Matcher` for ``problem_spec`` (a :class:`ProblemSpec`
    or a prototype :class:`MatchingProblem` — the latter lets distributed
    capacity planning measure TRUE block occupancy instead of the
    worst-case bound). Plan-time work: capacity + bucket planning, search
    depth pinning, engine construction. Call-time work: partition + one
    dispatch (the XLA compile lands on the first call and is reused by
    every later one)."""
    options = SolveOptions() if options is None else options
    if not isinstance(options, SolveOptions):
        raise TypeError(
            f"options must be SolveOptions, got {type(options).__name__}")
    prototype = None
    if isinstance(problem_spec, MatchingProblem):
        prototype = problem_spec
        problem_spec = problem_spec.spec
    elif not isinstance(problem_spec, ProblemSpec):
        raise TypeError(
            f"plan() takes a ProblemSpec or a prototype MatchingProblem, "
            f"got {type(problem_spec).__name__}")
    return Matcher(problem_spec, options, prototype=prototype)
