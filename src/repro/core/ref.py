"""Sequential reference implementations (numpy) — the oracles.

- ``exact_mwpm``: optimum MWPM via scipy's Jonker-Volgenant (the MC64-option-4
  surrogate; identical optimum).
- ``greedy_maximal``: sequential greedy maximal matching by weight.
- ``mcm_kuhn``: maximum cardinality matching (Kuhn augmenting DFS), weight-aware
  tie-breaking as in the paper's modified MCM init.
- ``sequential_awac``: the deterministic Pettie-Sanders-style Algorithm 1
  (max-gain 4-cycle per column + true greedy vertex-disjoint selection).
- ``awac_round_select``: ONE round of the *parallel* selection rule (Steps A-D,
  incl. the "rooted edge wins" discard) in plain numpy. The distributed and the
  single-device jnp implementations must match this bit-for-bit; it is the
  ground truth for tests.

Conventions: square matrix, n rows == n cols. ``mate_row[j]`` = row matched to
column j; ``mate_col[i]`` = column matched to row i; sentinel ``n`` = unmatched.
"""
from __future__ import annotations

import numpy as np

from repro.core.constants import MIN_GAIN

try:  # exact oracle
    from scipy.optimize import linear_sum_assignment

    HAVE_SCIPY = True
except Exception:  # pragma: no cover
    HAVE_SCIPY = False


def matching_weight(dense_val, mate_row):
    n = dense_val.shape[0]
    j = np.arange(n)
    m = mate_row < n
    return float(dense_val[mate_row[m], j[m]].sum())


def is_perfect(mate_row, n):
    return bool((np.asarray(mate_row[:n]) < n).all())


def check_matching(struct, mate_row):
    """Validity: matched edges exist, no row used twice."""
    n = struct.shape[0]
    used = mate_row[mate_row < n]
    assert len(np.unique(used)) == len(used), "row matched twice"
    for j in range(n):
        if mate_row[j] < n:
            assert struct[mate_row[j], j], f"matched edge ({mate_row[j]},{j}) missing"


def exact_mwpm(dense_val, struct):
    """Optimum-weight perfect matching on structural nonzeros. Returns
    (mate_row [n], weight). Raises if no perfect matching exists."""
    assert HAVE_SCIPY
    n = dense_val.shape[0]
    BIG = 1e9
    cost = np.where(struct, -dense_val, BIG)
    r, c = linear_sum_assignment(cost)
    if not struct[r, c].all():
        raise ValueError("no perfect matching exists")
    mate_row = np.full(n, n, dtype=np.int64)
    mate_row[c] = r
    return mate_row, float(dense_val[r, c].sum())


def greedy_maximal(dense_val, struct):
    """Sequential greedy: repeatedly take the heaviest available edge."""
    n = dense_val.shape[0]
    rr, cc = np.nonzero(struct)
    order = np.argsort(-dense_val[rr, cc], kind="stable")
    rr, cc = rr[order], cc[order]
    mate_row = np.full(n, n, dtype=np.int64)
    mate_col = np.full(n, n, dtype=np.int64)
    for i, j in zip(rr, cc):
        if mate_col[i] == n and mate_row[j] == n:
            mate_col[i] = j
            mate_row[j] = i
    return mate_row, mate_col


def mcm_kuhn(dense_val, struct, mate_row=None, mate_col=None):
    """Maximum cardinality matching via Kuhn's augmenting DFS, visiting
    neighbors heaviest-first (the paper's weight-aware tie-break)."""
    n = dense_val.shape[0]
    if mate_row is None:
        mate_row, mate_col = greedy_maximal(dense_val, struct)
    mate_row = mate_row.copy()
    mate_col = mate_col.copy()
    # adjacency: for each column, rows sorted by weight desc
    adj = []
    for j in range(n):
        rows = np.nonzero(struct[:, j])[0]
        adj.append(rows[np.argsort(-dense_val[rows, j], kind="stable")])

    def try_augment(j, vis_cols):
        for i in adj[j]:
            if vis_rows[i]:
                continue
            vis_rows[i] = True
            if mate_col[i] == n or try_augment(mate_col[i], vis_cols):
                mate_col[i] = j
                mate_row[j] = i
                return True
        return False

    import sys

    old = sys.getrecursionlimit()
    sys.setrecursionlimit(max(10000, 4 * n + 100))
    try:
        for j in range(n):
            if mate_row[j] == n:
                vis_rows = np.zeros(n, dtype=bool)
                try_augment(j, None)
    finally:
        sys.setrecursionlimit(old)
    return mate_row, mate_col


def _cycle_gain(dense_val, mate_row, mate_col, i, j):
    r2 = mate_row[j]
    c2 = mate_col[i]
    return dense_val[i, j] + dense_val[r2, c2] - dense_val[i, c2] - dense_val[r2, j]


def sequential_awac(dense_val, struct, mate_row, mate_col, max_iter=1000):
    """Algorithm 1: per-column max-gain 4-cycle + greedy vertex-disjoint apply."""
    n = dense_val.shape[0]
    mate_row = mate_row.copy()
    mate_col = mate_col.copy()
    iters = 0
    for _ in range(max_iter):
        iters += 1
        S = []
        for j in range(n):
            r2 = mate_row[j]
            best = (MIN_GAIN, -1)
            for i in np.nonzero(struct[:, j])[0]:
                if i == r2:
                    continue
                c2 = mate_col[i]
                if not struct[r2, c2]:
                    continue
                g = dense_val[i, j] + dense_val[r2, c2] - dense_val[i, c2] - dense_val[r2, j]
                if g > best[0]:
                    best = (g, i)
            if best[1] >= 0:
                S.append((best[0], best[1], j))
        if not S:
            break
        S.sort(key=lambda t: (-t[0], t[2]))
        used_rows = np.zeros(n, dtype=bool)
        used_cols = np.zeros(n, dtype=bool)
        applied = 0
        for g, i, j in S:
            r2 = mate_row[j]
            c2 = mate_col[i]
            if used_rows[i] or used_rows[r2] or used_cols[j] or used_cols[c2]:
                continue
            used_rows[i] = used_rows[r2] = True
            used_cols[j] = used_cols[c2] = True
            mate_row[j] = i
            mate_col[i] = j
            mate_row[c2] = r2
            mate_col[r2] = c2
            applied += 1
        if applied == 0:
            break
    return mate_row, mate_col, iters


def find_augmenting_4cycle(dense_val, struct, mate_row, mate_col, min_gain=MIN_GAIN):
    """Any positive-gain 4-cycle, or None. Used by the 2/3-optimality property
    test (a PM with no augmenting 4-cycle is 2/3-optimal)."""
    n = dense_val.shape[0]
    for j in range(n):
        r2 = mate_row[j]
        for i in np.nonzero(struct[:, j])[0]:
            if i == r2:
                continue
            c2 = mate_col[i]
            if not struct[r2, c2]:
                continue
            g = dense_val[i, j] + dense_val[r2, c2] - dense_val[i, c2] - dense_val[r2, j]
            if g > min_gain:
                return (float(g), int(i), int(j))
    return None


def awac_round_select(dense_val, struct, mate_row, mate_col, min_gain=MIN_GAIN):
    """ONE bulk-synchronous round of the parallel selection rule.

    Returns (survivor root cols list[(i, j)], n_candidates). Mirrors Steps A-D:
      A/B: candidates = edges (i,j), i > mate_row[j], completion edge exists,
           gain > min_gain
      C:   per root column j keep max gain (tie: smallest i)
      D:   per e2-column mate_col[i] keep max gain (tie: smallest j);
           discard winners whose e2-column is itself rooted
      fallback: if all discarded but candidates exist, apply the single global
           best candidate (the paper suggests random augmentations; we use the
           deterministic best-single-cycle fallback — recorded in DESIGN.md §2)
    """
    n = dense_val.shape[0]
    jj = np.arange(n)
    ii = np.arange(n)
    v = dense_val[mate_row[jj], jj]  # weight of column j's matched edge
    u = dense_val[ii, mate_col[ii]]  # weight of row i's matched edge

    # Step A/B: all candidates
    cands = []  # (gain, i, j)
    rr, cc = np.nonzero(struct)
    r2 = mate_row[cc]
    c2 = mate_col[rr]
    exists = struct[r2, c2]
    gain = dense_val[rr, cc] + dense_val[r2, c2] - u[rr] - v[cc]
    ok = exists & (rr > r2) & (gain > min_gain)
    cands = list(zip(gain[ok], rr[ok], cc[ok]))
    if not cands:
        return [], 0

    # Step C: per-column winner (max gain, tie smallest i)
    cwin = {}
    for g, i, j in cands:
        cur = cwin.get(j)
        if cur is None or (g > cur[0]) or (g == cur[0] and i < cur[1]):
            cwin[j] = (g, i)
    rooted = set(cwin.keys())

    # Step D: group by e2col = mate_col[i]
    dwin = {}
    for j, (g, i) in cwin.items():
        e2 = int(mate_col[i])
        cur = dwin.get(e2)
        if cur is None or (g > cur[0]) or (g == cur[0] and j < cur[2]):
            dwin[e2] = (g, i, j)
    survivors = [(i, j) for e2, (g, i, j) in dwin.items() if e2 not in rooted]
    if not survivors:
        # deterministic fallback: best single cycle (tie smallest j)
        g, i, j = max(((g, i, j) for j, (g, i) in cwin.items()),
                      key=lambda t: (t[0], -t[2]))
        survivors = [(i, j)]
    survivors.sort(key=lambda t: t[1])
    return survivors, len(cands)


def apply_cycles(mate_row, mate_col, survivors):
    mate_row = mate_row.copy()
    mate_col = mate_col.copy()
    for i, j in survivors:
        r2 = mate_row[j]
        c2 = mate_col[i]
        mate_row[j] = i
        mate_col[i] = j
        mate_row[c2] = r2
        mate_col[r2] = c2
    return mate_row, mate_col


def awac_parallel_rule(dense_val, struct, mate_row, mate_col, max_iter=10000,
                       min_gain=MIN_GAIN):
    """Iterate ``awac_round_select`` to fixpoint — the numpy model of the
    full parallel algorithm. Oracle for the jnp/distributed versions."""
    mate_row = mate_row.copy()
    mate_col = mate_col.copy()
    iters = 0
    for _ in range(max_iter):
        survivors, n_cand = awac_round_select(
            dense_val, struct, mate_row, mate_col, min_gain
        )
        if not survivors:
            break
        iters += 1
        mate_row, mate_col = apply_cycles(mate_row, mate_col, survivors)
    return mate_row, mate_col, iters


def awpm_reference(dense_val, struct, max_iter=10000):
    """Full sequential AWPM: greedy -> MCM -> parallel-rule AWAC."""
    mate_row, mate_col = greedy_maximal(dense_val, struct)
    mate_row, mate_col = mcm_kuhn(dense_val, struct, mate_row, mate_col)
    if not is_perfect(mate_row, dense_val.shape[0]):
        raise ValueError("input has no perfect matching")
    mate_row, mate_col, iters = awac_parallel_rule(
        dense_val, struct, mate_row, mate_col, max_iter
    )
    return mate_row, mate_col, iters
