"""repro.core — the paper's contribution: distributed-memory approximate-weight
perfect bipartite matching (AWPM = greedy maximal -> MCM -> AWAC 4-cycles).

Public surface (DESIGN.md §7): build a :class:`MatchingProblem`, tune
:class:`SolveOptions`, call :func:`solve` (or :func:`plan` for a
compile-once/run-many :class:`Matcher`). The pre-facade entry points
(``single.awpm`` / ``batch.awpm_batched`` / ``dist.awpm_dist_batched`` and
the ``Dist*`` driver zoo) remain as bit-identical deprecation shims.
"""
from repro.core import api, batch, dual, graph, pivot, preflight, ref, single
from repro.core.api import (
    BACKENDS,
    ON_INVALID,
    Matcher,
    MatchingProblem,
    MatchResult,
    ProblemSpec,
    SolveOptions,
    plan,
    solve,
)
from repro.core.constants import MIN_GAIN
from repro.core.dual import DualCertificate, certify, dual_certificate
from repro.core.graph import BipartiteGraph, from_coo, generate, matrix_suite
from repro.core.preflight import InfeasibleProblemError, PreflightError, PreflightReport

__all__ = [
    "api",
    "batch",
    "dual",
    "graph",
    "pivot",
    "preflight",
    "ref",
    "single",
    "BACKENDS",
    "MIN_GAIN",
    "ON_INVALID",
    "DualCertificate",
    "InfeasibleProblemError",
    "Matcher",
    "MatchingProblem",
    "MatchResult",
    "PreflightError",
    "PreflightReport",
    "ProblemSpec",
    "SolveOptions",
    "certify",
    "dual_certificate",
    "plan",
    "solve",
    "BipartiteGraph",
    "from_coo",
    "generate",
    "matrix_suite",
]
