"""repro.core — the paper's contribution: distributed-memory approximate-weight
perfect bipartite matching (AWPM = greedy maximal -> MCM -> AWAC 4-cycles)."""
from repro.core import batch, graph, pivot, ref, single
from repro.core.graph import BipartiteGraph, from_coo, generate, matrix_suite

__all__ = [
    "batch",
    "graph",
    "pivot",
    "ref",
    "single",
    "BipartiteGraph",
    "from_coo",
    "generate",
    "matrix_suite",
]
