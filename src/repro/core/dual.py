"""LP-dual certificates for heavy-weight perfect matchings (DESIGN.md §8).

The assignment LP's dual says: any potentials (u_i, v_j) with
``u_i + v_j >= w_ij`` on every edge certify ``sum(u) + sum(v) >= OPT``
(weak duality; the perfect-matching constraints are equalities, so the
duals are free-sign). That upper bound lower-bounds the approximation
ratio ``weight / bound`` WITHOUT the O(n^3) exact oracle
(``core.ref.exact_mwpm``) — the only way to audit the paper's
"very close to the optimum" claim on instances too large to solve exactly.

Construction (host numpy, O(max_rounds * m)): seed from the matching and
solve the difference-constraint system that complementary slackness
demands. Writing m_j for the matched row of column j and pinning
``u_{m_j} + v_j = w(m_j, j)`` (tight matched edges) turns feasibility on
edge (i, j) into ``u_{m_j} <= u_i + (w(m_j, j) - w_ij)`` — a shortest-path
problem over rows, solved by Bellman-Ford. It converges within n rounds
iff the constraint graph has no negative cycle, which holds exactly when
the matching admits no weight-increasing alternating cycle — i.e. when the
matching is OPTIMAL. Then ``sum(u) + sum(v) == weight`` and the
certificate is tight (ratio bound 1). For a suboptimal matching the
descent is cut off at ``max_rounds`` and feasibility is restored by
lifting each v_j by its column's worst violation — the bound stays sound,
exceeding the matching weight by the accumulated slack. The final lift
also absorbs float round-off, so soundness never rests on exact
arithmetic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DualCertificate", "certify", "dual_certificate"]


@dataclasses.dataclass(frozen=True)
class DualCertificate:
    """Feasible dual potentials + the bound they certify.

    ``upper_bound >= OPT >= weight`` always; ``tight`` means the
    Bellman-Ford descent converged (no weight-increasing alternating
    cycle), in which case ``upper_bound == weight`` up to float round-off
    and the matching is certified optimal.
    """

    u: np.ndarray  # [n] float64 row potentials
    v: np.ndarray  # [n] float64 column potentials
    weight: float  # matched-edge weight sum (float64 recompute)
    upper_bound: float  # sum(u) + sum(v) >= optimum
    tight: bool  # descent converged -> matching certified optimal
    rounds: int  # Bellman-Ford rounds used

    @property
    def bound_valid(self) -> bool:
        """Whether ``weight / upper_bound`` is a meaningful ratio bound.
        False only for a non-converged certificate with a non-positive
        upper bound (possible in the raw log2_scaled metric, where all
        weights <= 0): there a quotient of negatives inverts the
        inequality and certifies nothing."""
        return self.tight or self.upper_bound > 0.0

    @property
    def ratio_bound(self) -> float:
        """Certified lower bound on weight / OPT (1.0 when tight).
        Raises ``ValueError`` when ``bound_valid`` is False — a silent NaN
        here used to flow into BENCH comparisons; callers that can accept
        an absent bound should use :meth:`ratio_bound_or`."""
        if self.tight:
            return 1.0
        if not self.bound_valid:
            raise ValueError(
                f"no valid ratio bound: upper_bound={self.upper_bound:.6g} "
                f"<= 0 without convergence (raw log2_scaled-style metric?). "
                f"Check bound_valid or use ratio_bound_or(); the absolute "
                f"slack ({self.slack:.6g}) is still meaningful.")
        return self.weight / self.upper_bound

    def ratio_bound_or(self, default=None):
        """``ratio_bound`` when valid, else ``default`` — the NaN-free
        accessor for reporting pipelines."""
        return self.ratio_bound if self.bound_valid else default

    @property
    def slack(self) -> float:
        """upper_bound - weight: how far from certified-optimal."""
        return self.upper_bound - self.weight

    def potentials(self) -> tuple[np.ndarray, np.ndarray]:
        """The feasible dual vectors ``(u, v)`` — row potentials first —
        as float64 copies (mutating the return never corrupts the
        certificate). This is the public accessor downstream consumers
        use (``repro.solver.pivoting`` recovers the MC64-style row/column
        scalings from these; ``experiments`` reads them for reporting):
        every potential pair satisfies ``u_i + v_j >= w_ij`` on every
        edge, with equality on matched edges when ``tight``.
        """
        return np.array(self.u, np.float64, copy=True), \
            np.array(self.v, np.float64, copy=True)


def dual_certificate(row, col, val, n: int, mate_row, *,
                     max_rounds: int | None = None,
                     refine_sweeps: int = 8,
                     tol: float = 1e-9) -> DualCertificate:
    """Certify the perfect matching ``mate_row`` on the COO instance.

    Accepts padded or raw triples (entries with row or col >= n are
    dropped) and ``mate_row`` of length n or n+1 (sentinel slot ignored);
    everything is host numpy, float64. Raises if the matching is not
    perfect or uses an edge absent from the edge list. ``max_rounds``
    caps the Bellman-Ford descent (default n — the provable convergence
    bound when the matching is optimal); ``refine_sweeps`` tightens a
    non-converged bound by dual coordinate descent (each sweep stays
    feasible and only lowers the bound); ``tol`` is the relative
    convergence/tightness threshold.
    """
    row = np.asarray(row).reshape(-1).astype(np.int64)
    col = np.asarray(col).reshape(-1).astype(np.int64)
    val = np.asarray(val).reshape(-1).astype(np.float64)
    keep = (row < n) & (col < n) & (row >= 0) & (col >= 0)
    row, col, val = row[keep], col[keep], val[keep]
    mate_row = np.asarray(mate_row).reshape(-1).astype(np.int64)[:n]
    if mate_row.shape[0] != n or (mate_row >= n).any() or (mate_row < 0).any():
        raise ValueError(
            "dual_certificate needs a PERFECT matching (every column "
            "matched); certify the output of solve() only when "
            "result.perfect is True")
    if len(np.unique(mate_row)) != n:
        raise ValueError("mate_row matches a row twice — not a matching")

    # matched-edge weights w_col[j] = w(mate_row[j], j), via one sorted
    # key lookup over the (deduped-or-not) edge list
    key = row * np.int64(n) + col
    order = np.argsort(key, kind="stable")
    skey, sval = key[order], val[order]
    jvec = np.arange(n, dtype=np.int64)
    mkey = mate_row * np.int64(n) + jvec
    pos = np.searchsorted(skey, mkey)
    pos_c = np.clip(pos, 0, max(skey.shape[0] - 1, 0))
    found = (pos < skey.shape[0]) & (skey[pos_c] == mkey)
    if not found.all():
        j_bad = int(jvec[~found][0])
        raise ValueError(
            f"matched edge ({int(mate_row[j_bad])}, {j_bad}) is not in the "
            f"edge list — matching and instance disagree")
    w_col = sval[pos_c]
    weight = float(w_col.sum())
    scale = max(1.0, float(np.abs(val).max()) if val.size else 0.0)

    # Bellman-Ford over rows on the difference constraints
    #   u[m_j] <= u[i] + (w_col[j] - w_ij)   for every edge (i, j), i != m_j
    m_j = mate_row[col]  # matched row of each edge's column
    off = row != m_j  # matched edges give the trivial u_i <= u_i
    src, tgt = row[off], m_j[off]
    delta = w_col[col[off]] - val[off]
    if max_rounds is None:
        max_rounds = n
    u = np.zeros(n, np.float64)
    rounds = 0
    converged = src.size == 0
    for rounds in range(1, max_rounds + 1):
        new_u = u.copy()
        np.minimum.at(new_u, tgt, u[src] + delta)
        improved = float((u - new_u).max()) if n else 0.0
        u = new_u
        if improved <= tol * scale:
            converged = True
            break

    # tight matched edges: v_j = w_col[j] - u[m_j]; then restore exact
    # feasibility by lifting v per column (absorbs non-convergence AND
    # float slop — soundness never depends on the loop above)
    v = w_col - u[mate_row]
    lift = np.zeros(n, np.float64)
    np.maximum.at(lift, col, val - u[row] - v[col])
    lift = np.maximum(lift, 0.0)
    v = v + lift
    tight = bool(converged and float(lift.sum()) <= tol * scale * max(n, 1))
    if not tight:
        # dual coordinate descent: u_i := max_j (w_ij - v_j) is the least
        # row potential feasible against the current v (bound can only
        # drop), then v_j := max_i (w_ij - u_i) restores feasibility
        # column-wise. Every sweep ends feasible, so soundness holds no
        # matter where we stop. Skipped when already tight: the bound is
        # the matching weight, the floor weak duality allows.
        for _ in range(max(refine_sweeps, 0)):
            u = np.full(n, -np.inf)
            np.maximum.at(u, row, val - v[col])
            u[np.isinf(u)] = 0.0  # unreachable for perfect matchings
            v = np.full(n, -np.inf)
            np.maximum.at(v, col, val - u[row])
    upper = float(u.sum() + v.sum())
    return DualCertificate(u=u, v=v, weight=weight, upper_bound=upper,
                           tight=tight, rounds=rounds)


def certify(problem, result, **kwargs):
    """Certify a ``solve()`` result against its ``MatchingProblem``.

    Single instance -> one :class:`DualCertificate`; batched problem ->
    a list with one certificate per instance. Host-side (numpy) — call it
    on concrete results, outside jit.
    """
    row = np.asarray(problem.row)
    col = np.asarray(problem.col)
    val = np.asarray(problem.val)
    mate_row = np.asarray(result.mate_row)
    if problem.is_batched:
        return [
            dual_certificate(row[b], col[b], val[b], problem.n, mate_row[b],
                             **kwargs)
            for b in range(row.shape[0])
        ]
    return dual_certificate(row, col, val, problem.n, mate_row, **kwargs)
