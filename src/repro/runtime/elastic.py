"""Elastic scaling: rebuild the mesh after node failures and re-shard state.

At 1000+ node scale, node loss is routine. The recovery protocol here:
  1. the coordinator detects dead hosts (heartbeat timeouts — simulated),
  2. ``surviving_mesh`` folds the device grid down to the largest full
     (data', model) rectangle the survivors can form (dropping data-parallel
     rows keeps every TP group intact, so model shards stay complete),
  3. state is restored from the latest checkpoint with the NEW shardings
     (CheckpointManager.restore re-places host arrays), and the data pipeline
     skips ahead deterministically (TokenPipeline is keyed on (seed, step)).

The dry-run environment has fake devices, so failures are injected by
masking device ids; the logic is identical on real fleets.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

try:  # jax >= 0.6
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: Mesh has no axis_types argument
    AxisType = None


@dataclasses.dataclass
class FleetState:
    devices: np.ndarray  # current device grid [data, model] (or pod,...)
    alive: np.ndarray  # bool mask over devices.reshape(-1)

    @property
    def n_alive(self) -> int:
        return int(self.alive.sum())


def initial_fleet(mesh) -> FleetState:
    devs = np.asarray(mesh.devices)
    return FleetState(devs, np.ones(devs.size, bool))


def fail_hosts(fleet: FleetState, dead_device_ids) -> FleetState:
    alive = fleet.alive.copy()
    flat = fleet.devices.reshape(-1)
    for i, d in enumerate(flat):
        if d.id in set(dead_device_ids):
            alive[i] = False
    return FleetState(fleet.devices, alive)


def surviving_mesh(fleet: FleetState, axis_names=("data", "model")):
    """Largest full-rectangle mesh from surviving devices: keep every
    data-parallel row whose devices are ALL alive (a dead device kills its
    whole TP row — its model-parallel peers hold unusable shard fractions)."""
    devs = fleet.devices
    if devs.ndim == 3:  # fold pod axis into data for recovery
        devs = devs.reshape(-1, devs.shape[-1])
        axis_names = ("data", "model")
    alive = fleet.alive.reshape(devs.shape)
    rows_ok = alive.all(axis=1)
    kept = devs[rows_ok]
    if kept.shape[0] == 0:
        raise RuntimeError("no complete data-parallel row survived")
    if AxisType is None:
        return jax.sharding.Mesh(kept, axis_names)
    return jax.sharding.Mesh(
        kept, axis_names,
        axis_types=(AxisType.Auto,) * len(axis_names),
    )


def reshard_state(state, old_specs, new_mesh):
    """Re-place a (host or device) pytree onto the shrunk mesh with the same
    PartitionSpecs — batch dims divide the smaller data axis as long as the
    global batch is a multiple of the new data size."""
    return jax.tree.map(
        lambda x, s: jax.device_put(
            np.asarray(jax.device_get(x)),
            jax.sharding.NamedSharding(new_mesh, s),
        ),
        state, old_specs,
    )
