"""Chaos harness: deterministic fault injection for the AWPM pipeline.

The acceptance bar for the robustness layer (DESIGN.md §9): every injected
fault is provably either **detected** — the pipeline raises a typed error —
or **survived** — the served result is bit-identical to the reference
backend (via fallback). Zero silent corruptions.

Fault classes and their hooks:

  exchange payload faults   drop / duplicate / corrupt_index /
                            corrupt_weight / nan_weight applied to the
                            received buffers of either stage of
                            ``core.dist.a2a_bucketed_batched`` (the
                            ``dist._EXCHANGE_TAP`` trace-time hook).
                            Detection: ``SolveOptions(exchange_check=True)``
                            conservation accounting (count + order-
                            independent checksum) -> ``ExchangeIntegrityError``.
                            Survival: ``resilient_solve`` degrades to the
                            local chain, which never touches the exchange.
  flip_converged            forces the batched AWAC convergence mask off
                            after ``count`` rounds (the
                            ``batch._CONVERGENCE_TAP`` hook) — the classic
                            "looks converged, is not" failure. Detection:
                            ``ResilientOptions(verify_convergence=True)``
                            audit (a converged result must admit no
                            augmenting 4-cycle). Survival: a single-instance
                            problem degrades to ``single._awac_loop``,
                            which the tap cannot reach.
  backend failure           ``failing_backend`` / ``failing_grid`` patch the
                            engine entry points to raise (transiently or
                            persistently). Survival: retry + degradation.
  device loss               ``runtime.elastic.fail_hosts`` masking; survival
                            via ``surviving_mesh`` replanning or the local
                            chain.
  nan input                 non-finite weights in the problem itself.
                            Detection: ``core.preflight`` (the default
                            ``on_invalid="raise"``); survival:
                            ``on_invalid="sanitize"``.

All injection is seed-deterministic (positions are chosen by rank among
the valid entries, rotated by ``seed``) and trace-time: ``inject`` swaps a
module-level tap and clears the jit caches so the faulty collective is
actually compiled in, then restores and clears again on exit.

``run_chaos_matrix`` executes the whole detect-vs-survive matrix and
returns one record per case; the chaos CI job fails if any record is not
ok. Works on any (pr, pc) grid incl. 1x1 (exchange faults need pc > 1 or
pr > 1 to have a real collective but the taps fire regardless).
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as _api
from repro.core.dist import ExchangeIntegrityError
from repro.core.preflight import PreflightError
from repro.runtime.resilient import (
    ResilientOptions,
    TransientFault,
    VerificationError,
    resilient_solve,
    verify_result,
)

__all__ = [
    "EXCHANGE_FAULTS",
    "FaultSpec",
    "failing_backend",
    "failing_grid",
    "inject",
    "run_chaos_matrix",
]

#: payload fault kinds the exchange tap implements
EXCHANGE_FAULTS = ("drop", "duplicate", "corrupt_index", "corrupt_weight",
                   "nan_weight")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault. ``stage`` selects which exchange stage the
    payload faults hit (1 = column routing, 2 = row routing, None = both);
    ``seed`` rotates which valid entries are chosen; ``count`` is how many
    entries per instance (payload faults) or how many AWAC rounds to allow
    before forcing convergence (flip_converged)."""

    kind: str
    stage: int | None = None
    seed: int = 0
    count: int = 1

    def __post_init__(self):
        if self.kind not in EXCHANGE_FAULTS + ("flip_converged",):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.stage not in (None, 1, 2):
            raise ValueError(f"stage must be None, 1, or 2, got {self.stage!r}")


def _selected(valid, seed: int, count: int):
    """[B, L] bool: deterministically pick ``min(count, n_valid)`` valid
    entries per instance — by rank among valid entries, rotated by seed."""
    idx = jnp.cumsum(valid.astype(jnp.int32), axis=1) - 1
    nv = valid.sum(axis=1, keepdims=True)
    return valid & (((idx - seed) % jnp.maximum(nv, 1)) < count)


def _exchange_tap(fault: FaultSpec):
    def tap(axis_name, outs, valid):
        names = axis_name if isinstance(axis_name, (tuple, list)) \
            else (axis_name,)
        stage = 1 if "model" in names else 2
        if fault.stage is not None and stage != fault.stage:
            return outs, valid
        sel = _selected(valid, fault.seed, fault.count)
        if fault.kind == "drop":
            return outs, valid & ~sel
        if fault.kind == "duplicate":
            b, L = valid.shape
            bix = jnp.arange(b)
            src = jnp.argmax(sel, axis=1)
            dst = jnp.argmax(~valid, axis=1)
            do = sel.any(axis=1) & (~valid).any(axis=1)
            onehot = do[:, None] & (
                jnp.arange(L)[None, :] == dst[:, None])
            outs = [jnp.where(onehot, a[bix, src][:, None], a) for a in outs]
            return outs, valid | onehot
        if fault.kind == "corrupt_index":
            outs = [jnp.where(sel, outs[0] + 1, outs[0])] + list(outs[1:])
            return outs, valid
        w = outs[-1]
        if fault.kind == "corrupt_weight":
            w = jnp.where(sel, w * jnp.float32(1.0009765625) + 1.0, w)
        else:  # nan_weight
            w = jnp.where(sel, jnp.float32(jnp.nan), w)
        return list(outs[:-1]) + [w], valid

    return tap


def _convergence_tap(fault: FaultSpec):
    def tap(active, iters):
        # force "converged" once ``count`` rounds have run
        return active & (iters < fault.count)

    return tap


@contextlib.contextmanager
def inject(fault: FaultSpec):
    """Install ``fault``'s trace-time tap for the duration of the block.
    Clears the jit caches on entry and exit so the tap is compiled in (and
    back out) — cached executables would otherwise keep serving the clean
    (or faulty) collective."""
    from repro.core import batch as _batch
    from repro.core import dist as _dist

    if fault.kind == "flip_converged":
        prev = _batch._CONVERGENCE_TAP
        _batch._CONVERGENCE_TAP = _convergence_tap(fault)
    else:
        prev = _dist._EXCHANGE_TAP
        _dist._EXCHANGE_TAP = _exchange_tap(fault)
    jax.clear_caches()
    try:
        yield
    finally:
        if fault.kind == "flip_converged":
            _batch._CONVERGENCE_TAP = prev
        else:
            _dist._EXCHANGE_TAP = prev
        jax.clear_caches()


@contextlib.contextmanager
def failing_backend(*backends, exc_type=TransientFault,
                    fail_times: int | None = None):
    """Patch the local engine entry points so any solve resolving to one of
    ``backends`` raises ``exc_type`` — persistently, or only for the first
    ``fail_times`` offending calls (a transient fault). Yields a dict whose
    ``n`` counts injected failures."""
    from repro.core import batch as _batch
    from repro.core import single as _single

    state = {"n": 0}

    def wrap(orig):
        def inner(*args, backend="auto", **kw):
            if _single.resolve_backend(backend) in backends:
                if fail_times is None or state["n"] < fail_times:
                    state["n"] += 1
                    raise exc_type(
                        f"injected {backends} backend failure "
                        f"#{state['n']}")
            return orig(*args, backend=backend, **kw)

        return inner

    orig_s, orig_b = _single._awpm, _batch._awpm_batched
    _single._awpm = wrap(orig_s)
    _batch._awpm_batched = wrap(orig_b)
    try:
        yield state
    finally:
        _single._awpm = orig_s
        _batch._awpm_batched = orig_b


@contextlib.contextmanager
def failing_grid(exc_type=TransientFault, fail_times: int | None = None):
    """Patch the distributed driver so grid dispatches raise ``exc_type``
    (persistently or for the first ``fail_times`` calls)."""
    from repro.core import dist as _dist

    state = {"n": 0}
    orig = _dist._DistBatchedAWPM.run

    def run(self, *args, **kwargs):
        if fail_times is None or state["n"] < fail_times:
            state["n"] += 1
            raise exc_type(f"injected grid engine failure #{state['n']}")
        return orig(self, *args, **kwargs)

    _dist._DistBatchedAWPM.run = run
    try:
        yield state
    finally:
        _dist._DistBatchedAWPM.run = orig


# --------------------------------------------------------------------------
# the detect-vs-survive matrix
# --------------------------------------------------------------------------


def _bit_identical(result: _api.MatchResult, ref: _api.MatchResult) -> bool:
    return (np.array_equal(np.asarray(result.mate_row),
                           np.asarray(ref.mate_row))
            and np.array_equal(np.asarray(result.mate_col),
                               np.asarray(ref.mate_col))
            and np.array_equal(np.asarray(result.weight),
                               np.asarray(ref.weight)))


def _pick_instance(n: int, avg_degree: float, min_awac_iters: int):
    """Deterministic seed scan for an instance whose reference solve needs
    at least ``min_awac_iters`` AWAC rounds (so a prematurely-flipped
    convergence mask provably leaves an augmenting 4-cycle behind). A fixed
    shared capacity keeps every candidate on one compiled executable."""
    from repro.core import graph as _graph

    cap = None
    for seed in range(200):
        for kind in ("antigreedy", "uniform"):
            g = _graph.generate(n, avg_degree=avg_degree, kind=kind,
                                seed=seed)
            real = np.asarray(g.row) < n
            if cap is None:
                cap = max(int(real.sum()) * 2, 64)
            if int(real.sum()) > cap:
                continue
            p = _api.MatchingProblem.from_coo(
                np.asarray(g.row)[real], np.asarray(g.col)[real],
                np.asarray(g.val)[real], n, capacity=cap)
            r = _api.solve(p, _api.SolveOptions(backend="reference"))
            if bool(r.perfect) and int(r.awac_iters) >= min_awac_iters:
                return p, r
    raise RuntimeError(
        f"no planted instance with >= {min_awac_iters} AWAC rounds found")


def run_chaos_matrix(pr: int = 2, pc: int = 4, n: int = 48,
                     avg_degree: float = 6.0, log=print):
    """Execute the full fault-injection matrix on a (pr, pc) fake-device
    grid. Returns a list of records ``{"fault", "mode", "ok", "detail"}`` —
    one per (fault class, detect/survive) case; the chaos CI job asserts
    every record is ok. Needs pr * pc local devices."""
    from repro.runtime import elastic

    mesh = jax.make_mesh((pr, pc), ("data", "model"))
    gopts = _api.SolveOptions(grid=mesh, exchange_check=True)
    records = []

    def record(fault, mode, ok, detail):
        records.append({"fault": fault, "mode": mode, "ok": bool(ok),
                        "detail": detail})
        log(f"[chaos] {'ok ' if ok else 'FAIL'} {fault:<24} {mode:<8} "
            f"{detail}")

    # a planted instance whose reference solve needs >= 3 AWAC rounds:
    # stopping after round 1 provably leaves an augmenting 4-cycle
    p, ref = _pick_instance(n, avg_degree, min_awac_iters=3)

    # ---- exchange payload faults: detect via conservation accounting,
    # ---- survive via degradation to the local chain ----
    for kind in EXCHANGE_FAULTS:
        for stage in (1, 2):
            fault = FaultSpec(kind, stage=stage, seed=7)
            name = f"{kind}@stage{stage}"
            with inject(fault):
                try:
                    _api.solve(p, gopts)
                    record(name, "detect", False,
                           "no ExchangeIntegrityError raised")
                except ExchangeIntegrityError:
                    record(name, "detect", True, "ExchangeIntegrityError")
            with inject(fault):
                rr = resilient_solve(p, gopts)
                ok = _bit_identical(rr.result, ref) and rr.report.degraded
                record(name, "survive", ok, rr.report.summary())

    # ---- flip_converged: detected on a batched problem (every rung shares
    # ---- the tainted batched loop), survived by a single instance (the
    # ---- single-instance loop is out of the tap's reach) ----
    fault = FaultSpec("flip_converged", count=1)
    pb = _api.MatchingProblem.stack([p, p])
    ropts = ResilientOptions(verify_convergence=True)
    with inject(fault):
        try:
            resilient_solve(pb, _api.SolveOptions(grid=mesh),
                            resilience=ropts)
            record("flip_converged", "detect", False,
                   "premature convergence not flagged")
        except VerificationError as e:
            record("flip_converged", "detect", True,
                   f"VerificationError after {len(e.report.attempts)} "
                   f"attempt(s)")
    with inject(fault):
        rr = resilient_solve(p, _api.SolveOptions(grid=mesh),
                             resilience=ropts)
        ok = _bit_identical(rr.result, ref) and rr.report.degraded
        record("flip_converged", "survive", ok, rr.report.summary())

    # ---- backend failures: transient (retry, same rung) and persistent
    # ---- (degrade down the chain), plus a dying grid engine ----
    with failing_backend("xla", "pallas", fail_times=1):
        rr = resilient_solve(p)
        record("backend_transient", "survive",
               _bit_identical(rr.result, ref) and not rr.report.degraded,
               rr.report.summary())
    with failing_backend("xla", "pallas"):
        rr = resilient_solve(p)
        ok = _bit_identical(rr.result, ref) \
            and rr.report.backend_used == "local reference"
        record("backend_persistent", "survive", ok, rr.report.summary())
    with failing_grid():
        rr = resilient_solve(p, _api.SolveOptions(grid=mesh))
        ok = _bit_identical(rr.result, ref) and rr.report.degraded
        record("grid_engine_down", "survive", ok, rr.report.summary())

    # ---- device loss: shrink to the surviving rows, or go local ----
    fleet = elastic.initial_fleet(mesh)
    if pr > 1:
        dead = elastic.fail_hosts(
            fleet, [np.asarray(mesh.devices)[-1, 0].id])
        rr = resilient_solve(p, _api.SolveOptions(grid=mesh), fleet=dead)
        ok = _bit_identical(rr.result, ref) \
            and "shrunk" in (rr.report.backend_used or "")
        record("device_loss_partial", "survive", ok, rr.report.summary())
    dead_all = elastic.fail_hosts(
        fleet, [r[0].id for r in np.asarray(mesh.devices).reshape(
            -1, np.asarray(mesh.devices).shape[-1])])
    rr = resilient_solve(p, _api.SolveOptions(grid=mesh), fleet=dead_all)
    ok = _bit_identical(rr.result, ref) \
        and (rr.report.backend_used or "").startswith("local")
    record("device_loss_total", "survive", ok, rr.report.summary())

    # ---- nan input: rejected by preflight, or sanitized and re-verified.
    # The NaN edge goes into a padding slot, so sanitization restores
    # exactly ``p`` and the served result must be bit-identical to ref ----
    row = np.asarray(p.row).copy()
    col = np.asarray(p.col).copy()
    val = np.asarray(p.val).copy()
    pad = np.flatnonzero(row >= n)
    row[pad[-1]], col[pad[-1]], val[pad[-1]] = 0, 0, np.nan
    p_nan = _api.MatchingProblem(row=row, col=col, val=val, n=n)
    try:
        _api.solve(p_nan, _api.SolveOptions(grid=mesh))
        record("nan_input", "detect", False, "no PreflightError raised")
    except PreflightError:
        record("nan_input", "detect", True, "PreflightError")
    rr = resilient_solve(
        p_nan, _api.SolveOptions(grid=mesh, exchange_check=True,
                                 on_invalid="sanitize"))
    ok = _bit_identical(rr.result, ref) \
        and not verify_result(p, rr.result)
    record("nan_input", "survive", ok, rr.report.summary())
    return records


def assert_all_ok(records):
    bad = [r for r in records if not r["ok"]]
    if bad:
        lines = "\n".join(
            f"  {r['fault']} [{r['mode']}]: {r['detail']}" for r in bad)
        raise AssertionError(
            f"{len(bad)} chaos case(s) neither detected nor survived:\n"
            f"{lines}")
    return records


def main(argv=None):
    """CLI entry for the CI chaos job: run the full matrix on a pr x pc
    mesh (the fake device count must be set via XLA_FLAGS before jax
    initializes) and exit non-zero on any silent corruption."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pr", type=int, default=2)
    ap.add_argument("--pc", type=int, default=4)
    ap.add_argument("--n", type=int, default=48)
    args = ap.parse_args(argv)
    records = run_chaos_matrix(pr=args.pr, pc=args.pc, n=args.n)
    assert_all_ok(records)
    print(f"ALL {len(records)} CASES OK", flush=True)


if __name__ == "__main__":
    main()
