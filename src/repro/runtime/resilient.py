"""Guarded AWPM execution: deadlines, bounded retry, backend degradation,
and post-solve verification over ``repro.core.api``.

The serving tier the ROADMAP targets cannot call ``solve()`` naked: a
Pallas kernel can miscompile on a new toolchain, a device can drop out
mid-exchange, a transient XLA runtime error can kill an otherwise healthy
request, and a silently wrong matching poisons the downstream
factorization it exists to stabilize. ``resilient_solve`` wraps the facade
with the standard serving guards:

  - **wall-clock deadline** — the request fails fast with
    ``DeadlineExceededError`` instead of hanging a caller;
  - **bounded retry with exponential backoff** for transient failures
    (``TransientFault``, XLA runtime errors) on the same rung;
  - **backend degradation chain** — the requested engine first, then each
    strictly-more-conservative rung: a grid engine falls back to the local
    engines, ``pallas -> xla -> reference``; the rung that finally served
    the request is recorded, never hidden;
  - **device-loss recovery** — with a ``runtime.elastic.FleetState``, a
    dead device folds the grid down to ``surviving_mesh`` before the grid
    rung runs (and to the local chain when no full row survived);
  - **post-solve verification** — structural invariants (mate bijectivity,
    matched edges exist in the instance, recomputed weight, perfect-flag
    consistency) and optionally a convergence audit (one reference
    winner-search pass: a converged result must admit no augmenting
    4-cycle) and a ``core.dual`` optimality certificate.

Every attempt, fallback, verification outcome, and the serving rung land
on the returned ``ResilienceReport`` — surfaced, never swallowed. Errors
that reflect the *request* rather than the *execution* (bad types/options,
``PreflightError``, ``InfeasibleProblemError``) propagate immediately:
no amount of retrying fixes an infeasible instance.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core import api as _api
from repro.core import single as _single
from repro.core.dist import ExchangeIntegrityError
from repro.core.preflight import PreflightError

__all__ = [
    "DeadlineExceededError",
    "ResilienceReport",
    "ResilientMatcher",
    "ResilientOptions",
    "ResilientResult",
    "TransientFault",
    "VerificationError",
    "resilient_solve",
    "verify_result",
]


class TransientFault(RuntimeError):
    """A failure worth retrying on the same rung (injected by the chaos
    harness; real analogues: preempted device, flaky interconnect)."""


class DeadlineExceededError(RuntimeError):
    """The wall-clock deadline expired before any rung produced a verified
    result. Carries the partial ``report``."""

    def __init__(self, message: str, report: "ResilienceReport"):
        self.report = report
        super().__init__(message)


class VerificationError(RuntimeError):
    """Every rung either failed or produced a result that flunked
    post-solve verification. Carries the full ``report`` — the verifier
    failures per rung are in its attempts."""

    def __init__(self, message: str, report: "ResilienceReport"):
        self.report = report
        super().__init__(message)


@dataclasses.dataclass(frozen=True)
class ResilientOptions:
    """Guard knobs, orthogonal to ``SolveOptions`` (which keeps owning the
    algorithm).

    deadline_s        wall-clock budget across ALL rungs/retries (None =
                      unbounded).
    max_retries       same-rung retries for transient failures.
    backoff_s         first retry delay; grows by ``backoff_factor``.
    verify            run the structural post-solve verifier on every
                      candidate result (a failure moves to the next rung).
    verify_convergence  additionally audit convergence with one reference
                      winner-search pass (catches a prematurely-converged
                      loop — e.g. a flipped convergence mask).
    certify           attach a ``core.dual`` certificate to perfect
                      results (skipped silently for imperfect ones).
    """

    deadline_s: float | None = None
    max_retries: int = 2
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    verify: bool = True
    verify_convergence: bool = False
    certify: bool = False

    def __post_init__(self):
        if self.deadline_s is not None and not self.deadline_s > 0:
            raise ValueError(
                f"deadline_s must be positive or None, got {self.deadline_s!r}")
        if not isinstance(self.max_retries, int) or self.max_retries < 0:
            raise ValueError(
                f"max_retries must be a non-negative int, got "
                f"{self.max_retries!r}")


@dataclasses.dataclass(frozen=True)
class Attempt:
    """One execution attempt: which rung, what happened."""

    rung: str  # e.g. "grid 2x4 (fused)", "local xla"
    outcome: str  # "ok" | "transient" | "integrity" | "verify_failed"
    #               | "error"
    detail: str = ""
    wall_s: float = 0.0
    retry: int = 0  # 0 = first try on this rung


@dataclasses.dataclass(frozen=True)
class ResilienceReport:
    """Everything that happened while serving one request."""

    attempts: tuple[Attempt, ...]
    backend_used: str | None = None  # rung label that served the request
    degraded: bool = False  # served by a rung below the requested one
    verification: tuple[str, ...] = ()  # failures of the SERVED result ( () = clean)
    certificate: Any = None  # core.dual certificate(s) when requested

    def summary(self) -> str:
        served = self.backend_used or "unserved"
        flag = " (degraded)" if self.degraded else ""
        return (f"served by {served}{flag} after {len(self.attempts)} "
                f"attempt(s)")


@dataclasses.dataclass(frozen=True)
class ResilientResult:
    """A ``MatchResult`` plus the serving story."""

    result: _api.MatchResult
    report: ResilienceReport


# --------------------------------------------------------------------------
# post-solve verification
# --------------------------------------------------------------------------


def _verify_instance(row, col, val, n, mate_row, mate_col, weight, perfect,
                     iters, max_iter, min_gain, check_convergence, label):
    """Invariant checks for one instance (host numpy). Returns failures."""
    fails = []
    mr = np.asarray(mate_row)
    mc = np.asarray(mate_col)
    if mr.shape != (n + 1,) or mc.shape != (n + 1,):
        return [f"{label}mate arrays have wrong shape {mr.shape}/{mc.shape}"]
    if mr[n] != n or mc[n] != n:
        fails.append(f"{label}sentinel slot corrupted: mate_row[n]={mr[n]}, "
                     f"mate_col[n]={mc[n]}")
    if ((mr < 0) | (mr > n)).any() or ((mc < 0) | (mc > n)).any():
        fails.append(f"{label}mate entries outside [0, n]")
        return fails
    # partial bijection: matched columns map to distinct rows and the two
    # mate arrays are mutual inverses on the matched set
    cols = np.flatnonzero(mr[:n] < n)
    rows = mr[cols]
    if np.unique(rows).size != rows.size:
        fails.append(f"{label}mate_row maps two columns to one row")
    elif not (mc[rows] == cols).all():
        fails.append(f"{label}mate_row/mate_col are not mutual inverses")
    rows2 = np.flatnonzero(mc[:n] < n)
    if rows2.size != cols.size:
        fails.append(f"{label}matched-row count {rows2.size} != "
                     f"matched-column count {cols.size}")
    # matched edges must exist in the instance; recompute the weight
    real = np.asarray(row) < n
    key = np.asarray(row)[real].astype(np.int64) * (n + 1) \
        + np.asarray(col)[real]
    order = np.argsort(key, kind="stable")
    skey = key[order]
    sval = np.asarray(val)[real][order]
    qkey = rows.astype(np.int64) * (n + 1) + cols
    pos = np.searchsorted(skey, qkey)
    found = (pos < skey.size) & (skey[np.clip(pos, 0, skey.size - 1)] == qkey)
    if not found.all():
        miss = np.flatnonzero(~found)[0]
        fails.append(f"{label}matched edge ({int(rows[miss])}, "
                     f"{int(cols[miss])}) is not in the edge list")
    else:
        w = float(sval[pos].sum()) if qkey.size else 0.0
        if not np.isclose(w, float(weight), rtol=1e-4, atol=1e-4):
            fails.append(f"{label}recomputed weight {w:.6g} != reported "
                         f"{float(weight):.6g}")
    if bool(perfect) != (cols.size == n):
        fails.append(f"{label}perfect flag {bool(perfect)} inconsistent "
                     f"with {cols.size}/{n} matched columns")
    if check_convergence and bool(perfect) and int(iters) < int(max_iter) \
            and not fails:
        # a converged result must admit no augmenting 4-cycle: one
        # reference winner-search pass over the final state
        import jax.numpy as jnp

        state = _single.state_from_mates(
            jnp.asarray(row), jnp.asarray(col), jnp.asarray(val), n,
            jnp.asarray(mr), jnp.asarray(mc))
        Cgain, _, _, _ = _single.awac_cwinners(
            jnp.asarray(row), jnp.asarray(col), jnp.asarray(val), n, state,
            min_gain)
        if bool((np.asarray(Cgain) > min_gain).any()):
            fails.append(
                f"{label}result reported converged after {int(iters)} "
                f"round(s) but still admits an augmenting 4-cycle "
                f"(convergence mask was wrong)")
    return fails


def verify_result(problem: _api.MatchingProblem, result: _api.MatchResult,
                  options: _api.SolveOptions | None = None,
                  check_convergence: bool = False) -> tuple[str, ...]:
    """Re-check the permutation invariant and the reported weight of
    ``result`` against ``problem`` from scratch (host-side, independent of
    every engine). Returns a tuple of human-readable failures — empty means
    verified."""
    options = options or _api.SolveOptions()
    n = int(problem.n)
    if problem.is_batched:
        fails = []
        for bi in range(problem.batch_size):
            fails += _verify_instance(
                np.asarray(problem.row)[bi], np.asarray(problem.col)[bi],
                np.asarray(problem.val)[bi], n,
                np.asarray(result.mate_row)[bi],
                np.asarray(result.mate_col)[bi],
                np.asarray(result.weight)[bi],
                np.asarray(result.perfect)[bi],
                np.asarray(result.awac_iters)[bi], options.max_iter,
                options.min_gain, check_convergence, f"[instance {bi}] ")
        return tuple(fails)
    return tuple(_verify_instance(
        problem.row, problem.col, problem.val, n, result.mate_row,
        result.mate_col, result.weight, result.perfect, result.awac_iters,
        options.max_iter, options.min_gain, check_convergence, ""))


# --------------------------------------------------------------------------
# degradation chain
# --------------------------------------------------------------------------


#: most-aggressive to most-conservative: the persistent whole-loop kernel
#: degrades to the per-sweep kernel, then the fused XLA sweep, then the seed
#: reference path
_LOCAL_CHAIN = ("pallas_persistent", "pallas", "xla", "reference")


def _local_options(options: _api.SolveOptions,
                   backend: str) -> _api.SolveOptions:
    """Strip the distributed-only knobs so a grid request can degrade to a
    local rung."""
    return dataclasses.replace(
        options, grid=None, cap=None, a2a_caps=None, packed=False,
        exchange_check=False, backend=backend)


def _build_rungs(options: _api.SolveOptions, fleet=None):
    """The degradation chain as (label, SolveOptions) pairs: the requested
    engine first, then every strictly-more-conservative rung."""
    rungs = []
    if options.grid is not None:
        grid = options.grid
        if fleet is not None and not fleet.alive.all():
            from repro.runtime import elastic

            try:
                mesh = elastic.surviving_mesh(fleet)
                grid = dataclasses.replace(
                    options, grid=mesh).grid  # re-validate via SolveOptions
                rungs.append((
                    f"grid {grid.pr}x{grid.pc} ({options._dist_backend()}, "
                    f"shrunk)", dataclasses.replace(options, grid=mesh)))
            except RuntimeError:
                pass  # no usable grid survived: straight to the local chain
        else:
            rungs.append((
                f"grid {grid.pr}x{grid.pc} ({options._dist_backend()})",
                options))
    start = _single.resolve_backend(options.backend) \
        if options.backend == "auto" else options.backend
    if start not in _LOCAL_CHAIN:  # "fused" is grid-only: full local chain
        start = _LOCAL_CHAIN[0]
    for b in _LOCAL_CHAIN[_LOCAL_CHAIN.index(start):]:
        rungs.append((f"local {b}", _local_options(options, b)))
    return rungs


def _classify(exc: BaseException) -> str:
    """fatal: the request is wrong — propagate. integrity: this rung's
    result can't be trusted — next rung, no retry. transient: same rung is
    worth retrying."""
    if isinstance(exc, PreflightError):
        return "fatal"
    if isinstance(exc, (TypeError, ValueError)):
        return "fatal"
    if isinstance(exc, ExchangeIntegrityError):
        return "integrity"
    return "transient"  # TransientFault, XlaRuntimeError, other RuntimeErrors


# --------------------------------------------------------------------------
# the guarded loop
# --------------------------------------------------------------------------


def _serve(problem, rungs, requested_label, options, resilience, run_rung):
    start_t = time.monotonic()
    attempts: list[Attempt] = []

    def remaining():
        if resilience.deadline_s is None:
            return None
        return resilience.deadline_s - (time.monotonic() - start_t)

    def fail(exc_cls, msg):
        report = ResilienceReport(attempts=tuple(attempts))
        raise exc_cls(msg + f" [{report.summary()}]", report)

    for label, opts in rungs:
        retry = 0
        while True:
            left = remaining()
            if left is not None and left <= 0:
                fail(DeadlineExceededError,
                     f"deadline {resilience.deadline_s}s expired before any "
                     f"rung produced a verified result")
            t0 = time.monotonic()
            try:
                result = run_rung(label, opts)
            except Exception as e:
                kind = _classify(e)
                if kind == "fatal":
                    raise
                attempts.append(Attempt(
                    rung=label,
                    outcome="integrity" if kind == "integrity" else
                    "transient", detail=f"{type(e).__name__}: {e}",
                    wall_s=time.monotonic() - t0, retry=retry))
                if kind == "integrity" or retry >= resilience.max_retries:
                    break  # next rung
                delay = resilience.backoff_s * \
                    resilience.backoff_factor ** retry
                if (left := remaining()) is not None:
                    delay = min(delay, max(left, 0.0))
                time.sleep(delay)
                retry += 1
                continue
            wall = time.monotonic() - t0
            fails = ()
            if resilience.verify:
                fails = verify_result(
                    problem, result, opts,
                    check_convergence=resilience.verify_convergence)
            if fails:
                attempts.append(Attempt(
                    rung=label, outcome="verify_failed",
                    detail="; ".join(fails), wall_s=wall, retry=retry))
                break  # a wrong result is not retryable on the same rung
            attempts.append(Attempt(rung=label, outcome="ok", wall_s=wall,
                                    retry=retry))
            cert = None
            if resilience.certify and bool(
                    np.asarray(result.perfect).all()):
                from repro.core import dual as _dual

                cert = _dual.certify(problem, result)
            report = ResilienceReport(
                attempts=tuple(attempts), backend_used=label,
                degraded=label != requested_label, verification=fails,
                certificate=cert)
            return ResilientResult(result=result, report=report)
    fail(VerificationError,
         "every rung failed or produced a result that flunked verification")


def resilient_solve(problem: _api.MatchingProblem,
                    options: _api.SolveOptions | None = None,
                    resilience: ResilientOptions | None = None,
                    fleet=None, warm_start=None) -> ResilientResult:
    """``core.api.solve`` behind the full guard stack (module docstring).
    ``fleet`` is an optional ``runtime.elastic.FleetState`` consulted
    before the grid rung. ``warm_start`` threads straight through to
    ``solve`` on every rung (warm-start rematching, DESIGN.md §11) — a
    seed the facade rejects as stale raises immediately (fatal: the
    *request* is wrong, no rung can fix it; the serving tier's
    ``serving.warm.solve_with_seed`` owns the cold fallback). Returns a
    :class:`ResilientResult`; raises ``DeadlineExceededError`` /
    ``VerificationError`` (each carrying the report) when no rung can
    serve, and propagates request errors (``PreflightError`` etc.)
    untouched."""
    options = _api.SolveOptions() if options is None else options
    resilience = ResilientOptions() if resilience is None else resilience
    rungs = _build_rungs(options, fleet=fleet)
    return _serve(problem, rungs, rungs[0][0], options, resilience,
                  lambda label, opts: _api.solve(
                      problem, opts, warm_start=warm_start))


class ResilientMatcher:
    """The compile-once/run-many analogue of :func:`resilient_solve`: one
    planned ``Matcher`` per rung (built lazily on first use, cached), the
    same guarded serving loop per call."""

    def __init__(self, problem_spec, options: _api.SolveOptions | None = None,
                 resilience: ResilientOptions | None = None, fleet=None):
        self.options = _api.SolveOptions() if options is None else options
        self.resilience = ResilientOptions() if resilience is None \
            else resilience
        self.fleet = fleet
        self._spec = problem_spec
        self._rungs = _build_rungs(self.options, fleet=fleet)
        self._matchers: dict[str, _api.Matcher] = {}

    def _matcher(self, label, opts) -> _api.Matcher:
        m = self._matchers.get(label)
        if m is None:
            m = _api.plan(self._spec, opts)
            self._matchers[label] = m
        return m

    def __call__(self, problem: _api.MatchingProblem,
                 warm_start=None) -> ResilientResult:
        return _serve(
            problem, self._rungs, self._rungs[0][0], self.options,
            self.resilience,
            lambda label, opts: self._matcher(label, opts)(
                problem, warm_start=warm_start))

    def __repr__(self):
        return (f"ResilientMatcher(rungs={[r for r, _ in self._rungs]}, "
                f"resilience={self.resilience})")
