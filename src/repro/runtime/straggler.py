"""Straggler detection: EWMA step-time monitor with z-score flagging.

On a real fleet each host reports its step wall-time; ranks whose EWMA
exceeds ``threshold`` x the fleet median are flagged for (a) input resharding
away from them, (b) eviction + elastic re-mesh (runtime.elastic). Here the
monitor also serves the single-host training loop as a slow-step alarm."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 2.0
    warmup: int = 5

    def __post_init__(self):
        self.ewma: dict[int, float] = {}
        self.n: dict[int, int] = {}
        self.flagged: set[int] = set()
        self.history: list[tuple[int, float]] = []

    def record(self, step: int, dt: float, rank: int = 0):
        prev = self.ewma.get(rank)
        self.ewma[rank] = dt if prev is None else \
            self.alpha * dt + (1 - self.alpha) * prev
        self.n[rank] = self.n.get(rank, 0) + 1
        self.history.append((step, rank, dt))
        self._evaluate()

    def _evaluate(self):
        ready = {r: t for r, t in self.ewma.items() if self.n[r] >= self.warmup}
        if len(ready) < 2:
            return
        med = float(np.median(list(ready.values())))
        self.flagged = {r for r, t in ready.items() if t > self.threshold * med}

    def slow_ranks(self):
        return sorted(self.flagged)

    def slow_steps(self, rank: int = 0):
        """Per-step alarm for a SINGLE rank (cross-rank z-scoring needs >= 2
        ranks; a lone serving loop still wants to know which dispatches
        stalled): steps whose wall time exceeded ``threshold`` x the rank's
        median, once ``warmup`` samples exist."""
        dts = [(s, t) for s, r, t in self.history if r == rank]
        if len(dts) < max(self.warmup, 1):
            return []
        med = float(np.median([t for _, t in dts]))
        return sorted(s for s, t in dts if t > self.threshold * med)
