"""Render results/dryrun/*.json into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.roofline.report [--mesh single]
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def load(mesh="single"):
    recs = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        recs.append(r)
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x * 1e6:.0f}us"
    if x < 1.0:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(recs):
    lines = [
        "| arch | shape | mode | compute | memory | collective | dominant |"
        " peak/dev | MF ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | FAIL: "
                         f"{r.get('error', '?')[:60]} | | | | | |")
            continue
        rl = r["roofline"]
        mfr = r.get("model_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mode')} "
            f"| {fmt_s(rl['compute_s'])} | {fmt_s(rl['memory_s'])} "
            f"| {fmt_s(rl['collective_s'])} | **{rl['dominant']}** "
            f"| {r['peak_memory_per_device'] / 2**20:.0f}+"
            f"{r.get('temp_bytes', 0) / 2**30:.1f}G "
            f"| {mfr:.2f} |" if mfr else
            f"| {r['arch']} | {r['shape']} | {r.get('mode')} "
            f"| {fmt_s(rl['compute_s'])} | {fmt_s(rl['memory_s'])} "
            f"| {fmt_s(rl['collective_s'])} | **{rl['dominant']}** "
            f"| {r['peak_memory_per_device'] / 2**20:.0f}MB | - |"
        )
    return "\n".join(lines)


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | chips | compile | HLO flops/dev | "
        "HLO bytes/dev | coll bytes/dev (ag/ar/a2a/rs/cp) | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r.get('chips', '-')} | - | - | - | - | FAIL |")
            continue
        cb = r["collectives"]["bytes"]
        coll = "/".join(f"{cb[k] / 2**20:.0f}M" for k in
                        ("all-gather", "all-reduce", "all-to-all",
                         "reduce-scatter", "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
            f"| {r.get('compile_s', '-')}s | {r['flops_per_device']:.2e} "
            f"| {r['bytes_per_device']:.2e} | {coll} | OK |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun", "summary"])
    args = ap.parse_args()
    recs = load(args.mesh)
    if args.table == "roofline":
        print(roofline_table(recs))
    elif args.table == "dryrun":
        print(dryrun_table(recs))
    else:
        ok = sum(1 for r in recs if r.get("ok"))
        print(f"{args.mesh}: {ok}/{len(recs)} cells OK")
        for r in recs:
            if not r.get("ok"):
                print(f"  FAIL {r['arch']} {r['shape']}: {r.get('error')}")


if __name__ == "__main__":
    main()
