"""Roofline analysis from compiled dry-run artifacts (TPU v5e model).

Terms (per the brief):
  compute term    = HLO_FLOPs_global / (chips * peak_FLOP/s)
  memory term     = HLO_bytes_global / (chips * HBM_bw)
  collective term = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` reports the PER-DEVICE SPMD program, so the
global quantities are per-device x chips and the chips factor cancels:
compute term = flops_per_device / peak. Collective bytes are parsed from the
compiled HLO text (not in cost_analysis): we sum the RESULT-shape bytes of
every all-gather / all-reduce / all-to-all / reduce-scatter /
collective-permute instruction in the per-device module.
"""
from __future__ import annotations

import dataclasses
import re

V5E = {
    "peak_flops": 197e12,  # bf16 per chip
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link
    "vmem_bytes": 16 * 2**20,  # per-core VMEM (Pallas working-set budget)
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-gather|all-reduce|all-to-all|reduce-scatter|collective-permute)"
    r"(-start)?\("
)

_SHAPE_RE = re.compile(
    r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|c64|c128)"
    r"\[([0-9,]*)\]"
)


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_tuple_elements(shape_str: str) -> list[str]:
    """Top-level elements of an HLO tuple shape string, or [] when the
    string is not a parenthesized tuple. Layout braces ``{1,0}`` and nested
    tuples are kept intact (commas inside either never split)."""
    s = shape_str.strip()
    if not (s.startswith("(") and s.endswith(")")):
        return []
    parts, depth, buf = [], 0, []
    for ch in s[1:-1]:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        parts.append("".join(buf))
    return [p.strip() for p in parts]


def _is_context_scalar(element: str) -> bool:
    """Async collectives append u32[]/s32[] context scalars to the -start
    tuple; they carry no payload and must not count as collective bytes."""
    return re.fullmatch(r"[su]32\[\]\S*", element) is not None


def _start_result_bytes(shape_str: str) -> int:
    """Payload bytes of an async ``-start`` instruction.

    The -start shape is a tuple ``(operand(s), result(s), context...)`` —
    counting the whole tuple double-counts the payload (operand aliases) and
    adds the u32[] contexts. Only the result portion (second non-context
    top-level element; itself possibly a tuple, e.g. all-to-all-start)
    carries the bytes the link actually moves.
    """
    elements = [e for e in _split_tuple_elements(shape_str)
                if not _is_context_scalar(e)]
    if not elements:  # not a tuple: count the shape as-is
        return shape_bytes(shape_str)
    result = elements[1] if len(elements) >= 2 else elements[0]
    return shape_bytes(result)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind, from the compiled SPMD module.

    Line-based (one HLO instruction per line). Sync collectives count their
    full result shape (a tuple result, e.g. decomposed all-to-all, sums all
    elements). Async ``-start`` halves count only the result portion of the
    start tuple — the operand aliases and u32[] context scalars in
    ``(operand, result, context...)`` are bookkeeping, not payload — and the
    ``-done`` halves are excluded entirely so starts aren't double-counted.
    """
    out = {"all-gather": 0, "all-reduce": 0, "all-to-all": 0,
           "reduce-scatter": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, kind, is_start = m.group(1), m.group(2), bool(m.group(3))
        out[kind] += _start_result_bytes(shapes) if is_start \
            else shape_bytes(shapes)
        counts[kind] += 1
    return {"bytes": out, "counts": counts, "total": sum(out.values())}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float, hw=V5E) -> Roofline:
    ct = flops_per_device / hw["peak_flops"]
    mt = bytes_per_device / hw["hbm_bw"]
    lt = coll_bytes_per_device / hw["ici_bw"]
    terms = {"compute": ct, "memory": mt, "collective": lt}
    dom = max(terms, key=terms.get)
    return Roofline(ct, mt, lt, dom, flops_per_device, bytes_per_device,
                    coll_bytes_per_device)


_LANE = 128  # TPU lane width: every Pallas last-dim tile is a multiple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class EdgeTilePlan:
    """VMEM-budgeted edge tiling for the AWAC sweep kernels.

    ``te``: edge-tile width (multiple of the 128 lane width).
    ``cap_padded``: edge capacity after padding (a multiple of ``te``, so
    the kernel grid / in-kernel tile loop divides evenly).
    ``resident_bytes``: the per-instance VMEM-resident working set (full
    col/val copies + O(n) state + winner blocks).
    ``stream_bytes``: the double-buffered per-tile edge streams.
    ``fits``: resident + stream within the budget (False only for
    instances too large for single-core VMEM residency — the kernel still
    runs, but spills; callers may prefer the XLA backend then).
    """

    te: int
    cap_padded: int
    resident_bytes: int
    stream_bytes: int
    fits: bool


def plan_edge_tile(cap: int, n: int, *, target_te: int = 512,
                   vmem_limit: int | None = None) -> EdgeTilePlan:
    """Pick the AWAC sweep edge-tile width from the VMEM roofline.

    Mirrors PR 4's clamp-up policy for ``window_steps``: undersized inputs
    are padded UP to a legal tile (``cap < 128`` becomes one 128-lane tile)
    rather than rejected, and the tile shrinks below ``target_te`` only when
    the double-buffered streams would not fit next to the resident working
    set (resident col/val dominates, so this matters only near the VMEM
    roof). All returned sizes satisfy the kernels' divisibility contract:
    ``te % 128 == 0`` and ``cap_padded % te == 0``.
    """
    if cap < 1 or n < 1:
        raise ValueError(
            f"roofline.plan_edge_tile: need cap >= 1 and n >= 1, got "
            f"cap={cap}, n={n}")
    budget = int(V5E["vmem_bytes"] if vmem_limit is None else vmem_limit)
    nv = _round_up(n + 2, _LANE)
    np_ = _round_up(n + 1, _LANE)
    # full col/val copies (i32 + f32) + ptr/mate_row/mate_col (i32) +
    # u/v (f32) + the four winner blocks
    cap_lane = max(_round_up(cap, _LANE), _LANE)
    resident = cap_lane * 8 + 5 * nv * 4 + 4 * np_ * 4
    te = max(min(_round_up(target_te, _LANE), cap_lane), _LANE)
    while te > _LANE and resident + 2 * 3 * te * 4 > budget:
        te -= _LANE
    cap_padded = max(_round_up(cap, te), te)
    stream = 2 * 3 * te * 4
    return EdgeTilePlan(te=te, cap_padded=cap_padded,
                        resident_bytes=resident, stream_bytes=stream,
                        fits=resident + stream <= budget)


def useful_flops(arch: str, shape_name: str, mode: str, cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for LM train (N params w/o embeddings, D tokens);
    2*N_active*D for decode/prefill-token; family-appropriate analogues
    elsewhere (documented in EXPERIMENTS.md)."""
    if cfg.family == "lm":
        d, L = cfg.d_model, cfg.n_layers
        hd = cfg.hd
        attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + cfg.n_heads * hd * d
        if cfg.moe is not None:
            mo = cfg.moe
            ffn_active = 3 * d * (mo.d_ff_expert * mo.top_k
                                  + (mo.d_ff_shared or 0))
            n_dense = mo.first_dense
            n_active = (L - n_dense) * (attn + ffn_active) \
                + n_dense * (attn + 3 * d * (mo.d_ff_dense or cfg.d_ff))
        else:
            n_active = L * (attn + 3 * d * cfg.d_ff)
        n_active += d * cfg.vocab  # lm head
        tokens = shape.d("global_batch") * (shape.d("seq_len") if mode != "decode"
                                            else 1)
        if mode == "train":
            return 6.0 * n_active * tokens
        if mode == "prefill":
            return 2.0 * n_active * tokens
        # decode also reads the KV cache: attention scores 2*B*S*H*hd*2
        kv = 4.0 * shape.d("global_batch") * shape.d("seq_len") \
            * cfg.n_heads * hd * L
        return 2.0 * n_active * tokens + kv
    if cfg.family == "recsys":
        d = cfg.embed_dim
        b = shape.d("batch")
        s = cfg.seq_len
        per_tok = cfg.n_blocks * (4 * d * d + 2 * cfg.d_ff_mult * d * d
                                  + 2 * s * d)
        flops = 2.0 * b * s * per_tok
        if mode == "train":
            flops *= 3
            flops += 6.0 * b * s * d * (cfg.n_items + 2) * 0  # masked subset
            flops += 6.0 * b * s * d  # embedding
            flops += 6.0 * b * s * (cfg.n_items + 2) * d * 0.2  # masked lm head
        elif mode == "retrieval":
            flops += 2.0 * shape.d("n_candidates") * d
        else:
            flops += 2.0 * b * d * (cfg.n_items + 2)
        return flops
    if cfg.family == "gnn":
        n, e = shape.d("n_nodes", 1), shape.d("n_edges", 1)
        if shape.name == "minibatch_lg":
            from repro.data.graphs import sampled_sizes

            n, e = sampled_sizes(shape.d("batch_nodes"),
                                 (shape.d("fanout1"), shape.d("fanout2")))
        if shape.name == "molecule":
            n, e = n * shape.d("batch"), e * shape.d("batch")
        d = cfg.d_hidden
        L = cfg.n_layers
        train_mult = 3.0  # fwd + bwd
        if cfg.kind == "graphsage":
            per_layer = 2 * e * d + 4 * n * d * d
        elif cfg.kind == "dimenet":
            from repro.data.graphs import TRIPLET_FACTOR

            p_tri = TRIPLET_FACTOR * e
            nb = cfg.opt("n_bilinear", 8)
            per_layer = 2 * p_tri * nb * d * d / 8 + 8 * e * d * d
        elif cfg.kind == "equiformer_v2":
            k_comp = (cfg.opt("l_max", 6) + 1) ** 2
            per_layer = 2 * e * k_comp * d * d + 4 * e * d * d
        else:  # graphcast: processor on the MESH edges
            from repro.data.graphs import graphcast_sizes

            sz = graphcast_sizes(n)
            per_layer = 2 * sz["e_mesh"] * 8 * d * d
        return train_mult * L * per_layer
    if cfg.family == "matching":
        # per AWAC round: relabel+join O(m log m) + O(n) selection
        import math

        n = shape.d("n")
        m = n * shape.d("avg_degree")
        return (m * (2 + math.log2(max(m, 2))) + 8 * n) * 8
    return 0.0
