"""Pure-jnp oracle for flash attention (GQA, causal/full)."""
import jax.numpy as jnp

NEG = float("-inf")


def attention_ref(q, k, v, causal=True):
    """q [B, H, S, D]; k, v [B, Hkv, Sk, D]. fp32 softmax, output q.dtype."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / (d ** 0.5)
    s_ = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(k.shape[2])[None, :]
        s_ = jnp.where(qpos >= kpos, s_, NEG)
    m = jnp.max(s_, axis=-1, keepdims=True)
    m = jnp.where(m > NEG, m, 0.0)
    p = jnp.exp(s_ - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return (o / jnp.maximum(l, 1e-30)).astype(q.dtype)
