"""jit'd wrapper: model-layout attention entry point with kernel dispatch.

Model layout is [B, S, H, D] (sequence-major, as produced by the QKV
projections); the kernel wants [B, H, S, D]. A recompute-based custom_vjp
makes the kernel usable in training forward passes: backward re-runs the jnp
reference (IO-optimal backward kernels are a recorded perf-TODO in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "use_kernel", "interpret"))
def attention(q, k, v, *, causal: bool = True, use_kernel: bool = False,
              interpret: bool | None = None):
    """q [B, S, H, D]; k, v [B, Sk, Hkv, D] -> [B, S, H, D]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if use_kernel:
        o = _attention_vjp(qt, kt, vt, causal, interpret)
    else:
        o = attention_ref(qt, kt, vt, causal=causal)
    return jnp.swapaxes(o, 1, 2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attention_vjp(q, k, v, causal, interpret):
    return flash_attention(q, k, v, causal=causal, interpret=interpret)


def _fwd(q, k, v, causal, interpret):
    return flash_attention(q, k, v, causal=causal, interpret=interpret), (q, k, v)


def _bwd(causal, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: attention_ref(q, k, v, causal=causal),
                     q, k, v)
    return vjp(g)


_attention_vjp.defvjp(_fwd, _bwd)
