"""Pallas TPU flash attention (forward), GQA-aware, causal/full.

Blockwise online-softmax attention with explicit VMEM tiling:
grid (B, H, num_q_tiles, num_kv_tiles), kv innermost; running (m, l, acc)
live in VMEM scratch that persists across the kv grid dimension (the output
block index is constant along it), written back on the last kv step.

GQA: the k/v BlockSpec index maps query head h to kv head h // (H // Hkv),
so kv tiles are fetched once per group without materializing repeats.

Used for LM prefill/training forward; the decode path (1 query token against
a sharded KV cache) uses the two-pass sharded softmax in
repro.models.attention instead (flash-decoding style), which XLA handles
well without a custom kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, scale: float, tq: int, tk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [tq, tk]
    if causal:
        qpos = iq * tq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, NEG)

    m_prev = m_ref[...]
    row_max = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, row_max)
    m_safe = jnp.where(m_new > NEG, m_new, 0.0)
    p = jnp.exp(s - m_safe)  # exp(-inf)=0 keeps fully-masked rows at 0
    alpha = jnp.exp(m_prev - m_safe)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("causal", "tq", "tk", "interpret")
)
def flash_attention(q, k, v, *, causal: bool = True, tq: int = 128,
                    tk: int = 128, interpret: bool | None = None):
    """q: [B, H, S, D]; k, v: [B, Hkv, S, D] with H % Hkv == 0.
    S must be a multiple of max(tq, tk). Returns [B, H, S, D] in q.dtype."""
    b, h, s, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0 and s % tq == 0 and sk % tk == 0, (q.shape, k.shape)
    group = h // hkv
    nq, nk = s // tq, sk // tk
    scale = 1.0 / (d ** 0.5)
    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=scale, tq=tq, tk=tk,
                          nk=nk),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, tq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, tk, d),
                         lambda b, h, iq, ik, group=group: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, tk, d),
                         lambda b, h, iq, ik, group=group: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, d), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
    return out
