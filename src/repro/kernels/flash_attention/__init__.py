from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["attention", "attention_ref", "flash_attention"]
