"""Kernel execution-mode selection shared by all Pallas kernel wrappers.

``interpret=None`` everywhere means "auto": run the compiled kernel wherever
a Pallas lowering exists for the current platform (Mosaic on TPU, Triton on
GPU), and fall back to the Pallas interpreter only where none does (CPU CI,
unit tests).

The seed version of this module conflated "not TPU" with "run the
interpreter", which silently labeled GPU runs — where a compiled lowering
exists — as interpreter runs, and (worse) let interpreter timings land in
BENCH_kernels.json indistinguishable from kernel timings. Every resolution
now returns/records an :class:`ExecutionMode` carrying the explicit
``interpret`` flag, and the kernel wrappers thread it into bench rows and
``MatchResult.execution`` so an interpreter timing can never masquerade as a
compiled-kernel timing again.
"""
from __future__ import annotations

import dataclasses

import jax

#: Platforms with a compiled Pallas lowering in the pinned jax floor
#: (0.4.37): Mosaic on TPU, Triton on CUDA/ROCm ("gpu" is the platform name
#: older jax reports for both).
COMPILED_PLATFORMS = frozenset({"tpu", "gpu", "cuda", "rocm"})


@dataclasses.dataclass(frozen=True)
class ExecutionMode:
    """How a Pallas kernel actually executes.

    ``interpret``: True = Pallas interpreter (emulation; correctness-grade
    only — never a kernel timing). ``platform``: the jax default backend the
    resolution was made for. ``forced``: True when the caller pinned
    ``interpret`` explicitly rather than letting auto-detection decide.
    """

    interpret: bool
    platform: str
    forced: bool = False

    @property
    def ran_interpreted(self) -> bool:
        return self.interpret

    def describe(self) -> str:
        """Bench-row annotation, e.g. ``interpret=True``."""
        return f"interpret={self.interpret}"


#: Last mode any kernel wrapper resolved (trace-time side effect; host-side
#: diagnostics only — never read inside a traced computation).
_LAST_MODE: ExecutionMode | None = None


def resolve_execution(interpret: bool | None) -> ExecutionMode:
    """Resolve ``interpret`` to an explicit :class:`ExecutionMode`.

    Explicit True/False wins; ``None`` auto-detects: compiled wherever the
    platform has a Pallas lowering (see ``COMPILED_PLATFORMS``), interpreter
    elsewhere. Records the resolution for :func:`last_execution`.
    """
    global _LAST_MODE
    platform = jax.default_backend()
    if interpret is None:
        mode = ExecutionMode(interpret=platform not in COMPILED_PLATFORMS,
                             platform=platform)
    else:
        mode = ExecutionMode(interpret=bool(interpret), platform=platform,
                             forced=True)
    _LAST_MODE = mode
    return mode


def resolve_interpret(interpret: bool | None) -> bool:
    """Back-compat boolean view of :func:`resolve_execution` — every kernel
    wrapper funnels through here, so the resolved mode is always recorded."""
    return resolve_execution(interpret).interpret


def last_execution() -> ExecutionMode | None:
    """The most recently resolved mode (None before any kernel wrapper ran).

    Trace-time accurate: wrappers resolve the mode while tracing, so after a
    kernel call this reflects the mode that kernel was staged with.
    """
    return _LAST_MODE
