"""Kernel execution-mode selection shared by all Pallas kernel wrappers.

``interpret=None`` everywhere means "auto": run the compiled Mosaic kernel on
TPU, fall back to the Pallas interpreter elsewhere (CPU CI, unit tests). The
old hard-coded ``interpret=True`` default meant a TPU run silently executed
the interpreter; flipping to auto-detection makes the compiled path the
default where it exists while keeping every other environment working.
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """Explicit True/False wins; None auto-detects from the default backend."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
