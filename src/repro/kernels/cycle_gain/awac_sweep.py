"""Pallas kernel for the fused sparse AWAC sweep: Steps A+B+C in one pass.

Per CSR-tiled edge block (DESIGN.md §3) the kernel fuses
  A: completion lookup of (m_j, m_i) — a *windowed* binary search inside row
     m_j's CSR segment of the lex-sorted edge list (row_ptr windows),
  B: cycle gain  w1 + w2 - u[i] - v[j]  and the candidate mask
     ``found & i < n & i > m_j & gain > min_gain``,
  C: the per-column winner accumulation (gain, row, w1, w2) with
     smallest-row tie-break,
entirely on-chip: the per-edge ``gain``/``w2``/``cand`` arrays live only in
VMEM registers for the current tile and are never written to HBM. The winner
arrays are VMEM-resident outputs revisited by every grid step (same
accumulate-in-place pattern as the dense ``cycle_gain`` kernel).

Sizing: the full ``col``/``val`` arrays plus the O(n) matching state stay
resident in VMEM (cap * 8 B + ~6n * 4 B — e.g. 160 KB for n = 2048 at
8 nnz/row), while the per-edge streams are pipelined in (1, te) tiles.
Column ``n`` doubles as the scatter dump slot for masked-out lanes, mirroring
the XLA path's segment-id padding convention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = float("-inf")
BIG = jnp.iinfo(jnp.int32).max


def _kernel(row_ref, col_ref, val_ref, colf_ref, valf_ref, ptr_ref, mr_ref,
            mc_ref, u_ref, v_ref, mg_ref, gain_ref, rowo_ref, w1_ref, w2_ref,
            *, n: int, cap: int, window_steps: int):
    # grid = (B, tiles): axis 0 walks instances, axis 1 streams edge tiles
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        gain_ref[...] = jnp.full_like(gain_ref, NEG)
        rowo_ref[...] = jnp.full_like(rowo_ref, BIG)
        w1_ref[...] = jnp.zeros_like(w1_ref)
        w2_ref[...] = jnp.zeros_like(w2_ref)

    r = row_ref[0]
    c = col_ref[0]
    w1 = val_ref[0]
    colf = colf_ref[0]
    valf = valf_ref[0]
    ptr = ptr_ref[0]
    mg = mg_ref[0, 0]

    # ---- Step A: windowed completion lookup (m_j, m_i) in row m_j's segment
    qr = jnp.take(mr_ref[0], jnp.clip(c, 0, n))
    qc = jnp.take(mc_ref[0], jnp.clip(r, 0, n))
    qr_s = jnp.clip(qr, 0, n)
    lo = jnp.take(ptr, qr_s)
    hi0 = jnp.where(qr < n, jnp.take(ptr, qr_s + 1), lo)
    hi = hi0
    for _ in range(window_steps):
        mid = (lo + hi) // 2
        k = jnp.take(colf, jnp.clip(mid, 0, cap - 1))
        lt = k < qc
        lo = jnp.where(lt, mid + 1, lo)
        hi = jnp.where(lt, hi, mid)
    found = (lo < hi0) & (jnp.take(colf, jnp.clip(lo, 0, cap - 1)) == qc)
    w2 = jnp.where(found, jnp.take(valf, jnp.clip(lo, 0, cap - 1)), 0.0)

    # ---- Step B: gain + candidate mask (same op order as the jnp reference)
    gain = w1 + w2 - jnp.take(u_ref[0], jnp.clip(r, 0, n)) - jnp.take(
        v_ref[0], jnp.clip(c, 0, n))
    cand = found & (r < n) & (r > qr) & (gain > mg)

    # ---- Step C: per-column winner accumulation (masked lanes -> slot n)
    cj = jnp.where(cand, c, n)
    g_cur = gain_ref[0]
    g2 = g_cur.at[cj].max(jnp.where(cand, gain, NEG))
    hit = cand & (gain == jnp.take(g2, cj))
    rc = jnp.full_like(rowo_ref[0], BIG).at[cj].min(jnp.where(hit, r, BIG))
    r_cur = rowo_ref[0]
    # Columns this tile improves take the tile's min hitting row outright;
    # gain ties resolve toward the smaller row (a tile can never tie both
    # gain and row of the incumbent — (row, col) pairs are unique).
    r2 = jnp.where(g2 > g_cur, rc, jnp.minimum(r_cur, rc))
    sel = hit & (r == jnp.take(r2, cj))
    cjs = jnp.where(sel, cj, n)
    w1_2 = w1_ref[0].at[cjs].set(jnp.where(sel, w1, 0.0))
    w2_2 = w2_ref[0].at[cjs].set(jnp.where(sel, w2, 0.0))
    gain_ref[0] = g2
    rowo_ref[0] = r2
    w1_ref[0] = w1_2
    w2_ref[0] = w2_2


@functools.partial(
    jax.jit, static_argnames=("n", "te", "window_steps", "interpret")
)
def awac_sweep(row, col, val, row_ptr, mate_row, mate_col, u, v, min_gain, *,
               n: int, te: int, window_steps: int, interpret: bool):
    """Single-instance sweep: row/col/val [cap] padded lex-sorted COO
    (cap % te == 0, padding rows == n); row_ptr [n + 2]; mate/u/v [n + 1];
    min_gain f32 scalar. A B=1 slice of ``awac_sweep_batched`` (one grid,
    one kernel body — nothing to keep in sync).

    Returns per-column winners over slots [n + 1 padded to lanes]:
    (Cgain f32 (-inf if none), Crow i32 (INT32_MAX if none), Cw1, Cw2).
    Callers slice [:n] and map the sentinels (see ops.awac_sweep_winners).
    """
    out = awac_sweep_batched(
        row[None], col[None], val[None], row_ptr[None], mate_row[None],
        mate_col[None], u[None], v[None], min_gain,
        n=n, te=te, window_steps=window_steps, interpret=interpret,
    )
    return out[0][0], out[1][0], out[2][0], out[3][0]


@functools.partial(
    jax.jit, static_argnames=("n", "te", "window_steps", "interpret")
)
def awac_sweep_batched(row, col, val, row_ptr, mate_row, mate_col, u, v,
                       min_gain, *, n: int, te: int, window_steps: int,
                       interpret: bool):
    """Batch-grid sweep: all inputs carry a leading batch axis (row/col/val
    [B, cap], row_ptr [B, n + 2], state [B, n + 1]) and the grid is
    (B, cap // te) — batch as the leading (slow) axis, so each instance's
    winner blocks stay VMEM-resident while its edge tiles stream through,
    then write back once as the grid moves to the next instance.

    Returns per-instance winner blocks (Cgain, Crow, Cw1, Cw2), each
    [B, n + 1 padded to lanes]; callers slice [:, :n] and map sentinels.
    """
    b, cap = row.shape
    if te % 128 != 0 or te < 128 or cap % te != 0:
        # a bare assert here was stripped under ``python -O`` and made the
        # kernel unusable for cap < 128; the wrappers in ops.py auto-select
        # a legal (te, padded cap) via roofline.plan_edge_tile instead
        raise ValueError(
            f"awac_sweep_batched: edge tile te={te} must be a positive "
            f"multiple of 128 that divides cap={cap} (pad cap or pass "
            f"te=None to the ops wrappers for automatic tile selection)")
    np_ = pl.cdiv(n + 1, 128) * 128
    nv = pl.cdiv(n + 2, 128) * 128
    grid = (b, cap // te)

    def lane_pad(x, width, fill):
        return jnp.full((b, width), fill, x.dtype).at[:, : x.shape[1]].set(x)

    tiled = pl.BlockSpec((1, te), lambda i, t: (i, t))

    def full(width):
        return pl.BlockSpec((1, width), lambda i, t: (i, 0))

    out_spec = pl.BlockSpec((1, np_), lambda i, t: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, n=n, cap=cap, window_steps=window_steps),
        grid=grid,
        in_specs=[
            tiled, tiled, tiled,                  # row, col, val (streamed)
            full(cap), full(cap),                 # instance col, val (resident)
            full(nv),                             # row_ptr
            full(nv), full(nv),                   # mate_row, mate_col
            full(nv), full(nv),                   # u, v
            pl.BlockSpec((1, 1), lambda i, t: (0, 0)),  # min_gain (shared)
        ],
        out_specs=[out_spec] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((b, np_), jnp.float32),
            jax.ShapeDtypeStruct((b, np_), jnp.int32),
            jax.ShapeDtypeStruct((b, np_), jnp.float32),
            jax.ShapeDtypeStruct((b, np_), jnp.float32),
        ],
        interpret=interpret,
    )(
        row, col, val, col, val,
        lane_pad(row_ptr, nv, cap),
        lane_pad(mate_row, nv, n), lane_pad(mate_col, nv, n),
        lane_pad(u, nv, 0), lane_pad(v, nv, 0),
        jnp.asarray(min_gain, jnp.float32).reshape(1, 1),
    )
    return out
