from repro.kernels.cycle_gain.awac_sweep import awac_sweep, awac_sweep_batched
from repro.kernels.cycle_gain.cycle_gain import cycle_gain
from repro.kernels.cycle_gain.ops import (
    awac_sweep_winners,
    awac_sweep_winners_batched,
    cycle_gain_padded,
)
from repro.kernels.cycle_gain.ref import cycle_gain_ref

__all__ = [
    "awac_sweep",
    "awac_sweep_batched",
    "awac_sweep_winners",
    "awac_sweep_winners_batched",
    "cycle_gain",
    "cycle_gain_padded",
    "cycle_gain_ref",
]
