"""Pallas TPU kernel for the paper's hot spot: fused 4-cycle gain + Step-C
per-column max/argmax over dense tiles.

Given a block tile of the matrix A and the matching-permuted tile
A2[i, j] = A[m_j, m_i] (both with structural zeros encoded as exact 0.0),
and the matched-edge weights u (rows) / v (cols), computes

    W[i, j] = A[i, j] + A2[i, j] - u[i] - v[j]          (gain of the 4-cycle)
    best_gain[j] = max_i W[i, j],  best_row[j] = argmax_i W[i, j]

masked to entries where BOTH A and A2 are structurally present. Ties break
toward the smallest row index, matching repro.core's selection rule.

TPU adaptation (DESIGN.md §2): the CPU algorithm walks CSR adjacency per
vertex; on TPU we densify per VMEM tile — the MXU/VPU prefer dense 8x128
lanes, and per-column max is a lane-wise reduction. The same kernel computes
the swap-gain matrix of the AWPM MoE router (token x expert-slot
assignment), where tiles are naturally dense.

Grid: (n_tiles, m_tiles) — m (row) tiles iterate fastest; the output column
tile is revisited across row tiles and accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

NEG = float("-inf")


def _kernel(a_ref, a2_ref, u_ref, v_ref, gain_ref, row_ref, *, tm: int):
    im = pl.program_id(1)

    @pl.when(im == 0)
    def _init():
        gain_ref[...] = jnp.full_like(gain_ref, NEG)
        row_ref[...] = jnp.full_like(row_ref, -1)

    a = a_ref[...]
    a2 = a2_ref[...]
    mask = (a != 0.0) & (a2 != 0.0)
    w = a + a2 - u_ref[...] - v_ref[...]  # u: [TM,1] broadcasts, v: [1,TN]
    w = jnp.where(mask, w, NEG)
    g = jnp.max(w, axis=0, keepdims=True)  # [1, TN]
    # argmax with smallest-row tie-break: first hit along rows
    rows = jax.lax.broadcasted_iota(jnp.int32, w.shape, 0)
    hit = (w == g) & (g > NEG)
    r = jnp.min(jnp.where(hit, rows, jnp.iinfo(jnp.int32).max), axis=0,
                keepdims=True)
    r = jnp.where(g > NEG, r + im * tm, -1)
    better = g > gain_ref[...]
    row_ref[...] = jnp.where(better, r.astype(jnp.int32), row_ref[...])
    gain_ref[...] = jnp.where(better, g, gain_ref[...])


@functools.partial(
    jax.jit, static_argnames=("tm", "tn", "interpret")
)
def cycle_gain(a, a2, u, v, *, tm: int = 256, tn: int = 256,
               interpret: bool | None = None):
    """a, a2: [M, N] f32 (0.0 = structurally absent); u: [M] f32; v: [N] f32.
    Returns (best_gain [N] f32, best_row [N] i32, -1 where no candidate).

    M, N must be multiples of (tm, tn); use ops.cycle_gain_padded otherwise.
    """
    m, n = a.shape
    assert m % tm == 0 and n % tn == 0, (m, n, tm, tn)
    grid = (n // tn, m // tm)
    out = pl.pallas_call(
        functools.partial(_kernel, tm=tm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (j, i)),
            pl.BlockSpec((tm, tn), lambda i, j: (j, i)),
            pl.BlockSpec((tm, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tn), lambda i, j: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, tn), lambda i, j: (0, i)),
            pl.BlockSpec((1, tn), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(a, a2, u[:, None], v[None, :])
    return out[0][0], out[1][0]
