"""jit'd public wrappers for the cycle_gain kernel package (padding +
dispatch): the dense tile kernel and the fused sparse AWAC sweep."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret
from repro.kernels.cycle_gain.awac_sweep import awac_sweep_batched
from repro.kernels.cycle_gain.cycle_gain import cycle_gain
from repro.kernels.cycle_gain.persistent import awac_persistent_batched
from repro.kernels.cycle_gain.ref import cycle_gain_ref

NEG = float("-inf")


def _round_up(x, m):
    return (x + m - 1) // m * m


def _edge_tile(cap: int, n: int, te: int | None) -> tuple[int, int]:
    """(te, padded cap) for the sweep kernels: an explicit ``te`` keeps the
    seed padding rule; ``te=None`` asks the VMEM roofline planner
    (``roofline.analysis.plan_edge_tile``), which clamps undersized
    instances UP to one legal 128-lane tile instead of failing the kernels'
    divisibility check."""
    if te is not None:
        return te, max(_round_up(cap, te), te)
    from repro.roofline.analysis import plan_edge_tile

    plan = plan_edge_tile(cap, n)
    return plan.te, plan.cap_padded


def _pad_edges(row, col, val, n, capp):
    b, cap = row.shape
    if capp == cap:
        return row, col, val
    pad = capp - cap
    row = jnp.concatenate([row, jnp.full((b, pad), n, row.dtype)], axis=1)
    col = jnp.concatenate([col, jnp.full((b, pad), n, col.dtype)], axis=1)
    val = jnp.concatenate([val, jnp.zeros((b, pad), val.dtype)], axis=1)
    return row, col, val


@functools.partial(jax.jit, static_argnames=("tm", "tn", "use_kernel", "interpret"))
def cycle_gain_padded(a, a2, u, v, *, tm: int = 256, tn: int = 256,
                      use_kernel: bool = True, interpret: bool | None = None):
    """Pads (M, N) up to tile multiples and dispatches to the Pallas kernel
    (or the jnp reference when ``use_kernel=False`` — used by XLA-only
    paths). ``interpret=None`` auto-detects: compiled on TPU, interpreter
    elsewhere."""
    m, n = a.shape
    if not use_kernel:
        return cycle_gain_ref(a, a2, u, v)
    tm = min(tm, _round_up(m, 8))
    tn = min(tn, _round_up(n, 128))
    mp, np_ = _round_up(m, tm), _round_up(n, tn)
    a_p = jnp.zeros((mp, np_), a.dtype).at[:m, :n].set(a)
    a2_p = jnp.zeros((mp, np_), a2.dtype).at[:m, :n].set(a2)
    u_p = jnp.zeros((mp,), u.dtype).at[:m].set(u)
    v_p = jnp.zeros((np_,), v.dtype).at[:n].set(v)
    g, r = cycle_gain(a_p, a2_p, u_p, v_p, tm=tm, tn=tn,
                      interpret=resolve_interpret(interpret))
    return g[:n], r[:n]


@functools.partial(
    jax.jit, static_argnames=("n", "te", "window_steps", "interpret")
)
def awac_sweep_winners(row, col, val, row_ptr, mate_row, mate_col, u, v,
                       min_gain, *, n: int, window_steps: int,
                       te: int | None = None,
                       interpret: bool | None = None):
    """Fused Steps A+B+C via the ``awac_sweep`` Pallas kernel.

    Same contract as ``repro.core.single.awac_cwinners``: returns
    (Cgain [n], Ci [n] (sentinel n if no candidate), Cw1 [n], Cw2 [n]),
    bit-identical to the jnp reference. A B=1 slice of
    ``awac_sweep_winners_batched`` (one padding/sentinel path to maintain).
    """
    Cgain, Ci, Cw1, Cw2 = awac_sweep_winners_batched(
        row[None], col[None], val[None], row_ptr[None], mate_row[None],
        mate_col[None], u[None], v[None], min_gain,
        n=n, window_steps=window_steps, te=te, interpret=interpret,
    )
    return Cgain[0], Ci[0], Cw1[0], Cw2[0]


@functools.partial(
    jax.jit, static_argnames=("n", "te", "window_steps", "interpret")
)
def awac_sweep_winners_batched(row, col, val, row_ptr, mate_row, mate_col, u,
                               v, min_gain, *, n: int, window_steps: int,
                               te: int | None = None,
                               interpret: bool | None = None):
    """Batched fused Steps A+B+C via the batch-grid ``awac_sweep_batched``
    kernel. All operands carry a leading batch axis; returns per-instance
    (Cgain [B, n], Ci [B, n] (sentinel n if no candidate), Cw1, Cw2),
    bit-identical to running ``awac_sweep_winners`` per instance.
    ``te=None`` sizes the edge tile from the VMEM roofline (small instances
    clamp up to one 128-lane tile instead of failing)."""
    b, cap = row.shape
    te, capp = _edge_tile(cap, n, te)
    row, col, val = _pad_edges(row, col, val, n, capp)
    Cgain, Crow, Cw1, Cw2 = awac_sweep_batched(
        row, col, val, row_ptr, mate_row, mate_col, u, v, min_gain,
        n=n, te=te, window_steps=window_steps,
        interpret=resolve_interpret(interpret),
    )
    Cgain, Crow, Cw1, Cw2 = (Cgain[:, :n], Crow[:, :n], Cw1[:, :n],
                             Cw2[:, :n])
    has = Cgain > NEG
    Ci = jnp.where(has, Crow, n).astype(jnp.int32)
    return Cgain, Ci, jnp.where(has, Cw1, 0.0), jnp.where(has, Cw2, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("n", "te", "window_steps", "max_iter", "interpret"))
def awac_persistent_loop(row, col, val, row_ptr, mate_row, mate_col, u, v,
                         min_gain, go0, *, n: int, window_steps: int,
                         max_iter: int, te: int | None = None,
                         interpret: bool | None = None):
    """Whole AWAC loop (sweeps + select/augment + convergence) in one
    persistent ``pallas_call`` — the ``backend="pallas_persistent"`` engine
    behind ``core.single.awac``.

    Same state contract as ``core.single._awac_loop``: returns
    (mate_row, mate_col, u, v, iters) with state over [n + 1] and the
    scalar iteration count, bit-identical to driving per-sweep kernels from
    the host while_loop. ``go0`` is the round-0 gate (False = skip the loop,
    the degrade-infeasible short-circuit)."""
    mr, mc, uu, vv, it = awac_persistent_loop_batched(
        row[None], col[None], val[None], row_ptr[None], mate_row[None],
        mate_col[None], u[None], v[None], min_gain,
        jnp.asarray(go0).reshape(1), n=n, window_steps=window_steps,
        max_iter=max_iter, te=te, interpret=interpret)
    return mr[0], mc[0], uu[0], vv[0], it[0]


@functools.partial(
    jax.jit,
    static_argnames=("n", "te", "window_steps", "max_iter", "interpret"))
def awac_persistent_loop_batched(row, col, val, row_ptr, mate_row, mate_col,
                                 u, v, min_gain, go0, *, n: int,
                                 window_steps: int, max_iter: int,
                                 te: int | None = None,
                                 interpret: bool | None = None):
    """Batched persistent AWAC loop: one kernel launch runs every
    instance's full iteration loop (grid step b = instance b's loop; each
    converges independently via its own in-kernel while condition).

    Returns (mate_row, mate_col, u, v [B, n + 1], iters [B]); per instance
    bit-identical — state and iteration counts — to the host-driven
    while_loop over ``awac_sweep_winners_batched``. ``te=None`` sizes the
    edge tile from the VMEM roofline."""
    b, cap = row.shape
    te, capp = _edge_tile(cap, n, te)
    row, col, val = _pad_edges(row, col, val, n, capp)
    mr, mc, uu, vv, it = awac_persistent_batched(
        row, col, val, row_ptr, mate_row, mate_col, u, v, min_gain, go0,
        n=n, te=te, window_steps=window_steps, max_iter=max_iter,
        interpret=resolve_interpret(interpret))
    return (mr[:, : n + 1], mc[:, : n + 1], uu[:, : n + 1], vv[:, : n + 1],
            it)


def swap_gains(affinity, assign_expert, tok_affinity, *, use_kernel=True,
               interpret=None):
    """AWPM-router building block: gains of swapping token i's expert with the
    expert owning slot j.

    affinity [T, E]: token->expert affinity (dense).
    assign_expert [T] int: current expert of each token.
    tok_affinity [T]: affinity of each token's current assignment.

    Returns gain [T, T] is too big; instead this evaluates the bipartite
    token x token swap through the cycle_gain contract: A[i, j] =
    affinity[i, expert[j]] (i moving to j's expert), A2[i, j] =
    affinity[j, expert[i]], u[i] = v[i] = tok_affinity[i]. The per-column
    winner is each token j's best swap partner. Computed tile-wise by the
    kernel without materializing [T, T] in HBM when T is tiled by the caller.
    """
    a = jnp.take(affinity, assign_expert, axis=1)  # [T, T]: aff[i, e_j]
    a2 = a.T
    return cycle_gain_padded(a, a2, tok_affinity, tok_affinity,
                             use_kernel=use_kernel, interpret=interpret)
