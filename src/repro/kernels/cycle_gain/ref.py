"""Pure-jnp oracle for the cycle_gain kernel."""
import jax.numpy as jnp

NEG = float("-inf")


def cycle_gain_ref(a, a2, u, v):
    """Same contract as kernels.cycle_gain.cycle_gain (no tiling constraint)."""
    mask = (a != 0.0) & (a2 != 0.0)
    w = a + a2 - u[:, None] - v[None, :]
    w = jnp.where(mask, w, NEG)
    g = jnp.max(w, axis=0)
    rows = jnp.arange(a.shape[0], dtype=jnp.int32)[:, None]
    hit = (w == g[None, :]) & (g[None, :] > NEG)
    r = jnp.min(jnp.where(hit, rows, jnp.iinfo(jnp.int32).max), axis=0)
    r = jnp.where(g > NEG, r, -1)
    return g, r.astype(jnp.int32)
