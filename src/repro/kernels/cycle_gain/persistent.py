"""Persistent whole-iteration Pallas AWAC kernel: the full loop on-chip.

The streamed ``awac_sweep`` kernel fuses Steps A+B+C of ONE sweep, but the
driver (``core.single._awac_loop`` / ``core.batch.awac_loop``) still runs
Step D + the convergence check between sweeps on the host side of the
``pallas_call`` boundary — one kernel launch (and one HBM round-trip of the
full matching state) per AWAC iteration. This kernel makes the iteration
loop itself the kernel body: grid ``(B,)``, one grid step per instance, and
inside it

  - an ``lax.while_loop`` over AWAC iterations whose carry is the matching
    state (``mate_row``/``mate_col``/``u``/``v``, each a [nv] lane vector),
    the iteration counter, and the convergence flag — VMEM-resident across
    the whole loop, never written back until convergence;
  - per iteration, an ``lax.fori_loop`` over ``cap // te`` edge tiles
    running the same fused Step A+B+C body as ``awac_sweep`` (windowed
    binary search, gain, per-column winner accumulation with smallest-row
    tie-break), with the winner blocks as loop carries;
  - Steps D + augmentation (``core.single.select_and_augment``) re-expressed
    on lane vectors: the ``segment_max_with_payload`` over e2-columns
    becomes a scatter-max + tie-resolving scatter-min (identical max/min-
    payload semantics), the deterministic single-best-cycle fallback becomes
    max + first-index-of-max, and the eight augmentation scatters run in the
    reference's exact order;
  - the convergence check ``n_surv > 0`` feeding the while condition.

Bit-identity contract: for every instance, (mate_row, mate_col, u, v) after
the loop AND the iteration count equal ``core.single._awac_loop`` on any
backend. Gains are computed in the reference's operation order
(``w1 + w2 - u[i] - v[j]``); every winner/augmentation reduction is an
order-free max/min or writes duplicate-identical values, so scatter order
cannot perturb results.

Edge tiles are sized by ``roofline.analysis.plan_edge_tile`` (VMEM budget:
resident edge copies + state + winner blocks + double-buffered streams);
see the wrappers in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = float("-inf")
BIG = jnp.iinfo(jnp.int32).max


def _kernel(row_ref, col_ref, val_ref, ptr_ref, mr_ref, mc_ref, u_ref, v_ref,
            mg_ref, go_ref, mro_ref, mco_ref, uo_ref, vo_ref, it_ref, *,
            n: int, cap: int, te: int, window_steps: int, max_iter: int):
    r_all = row_ref[0]
    c_all = col_ref[0]
    w_all = val_ref[0]
    ptr = ptr_ref[0]
    mg = mg_ref[0, 0]
    nv = mr_ref.shape[-1]
    n_tiles = cap // te
    # 1D iota is unsupported on TPU; broadcast on the lane axis and strip
    jlane = jax.lax.broadcasted_iota(jnp.int32, (1, nv), 1)[0]
    lane_n = jlane < n

    def sweep(mr, mc, u, v):
        """Steps A+B+C over all edge tiles — the ``awac_sweep`` kernel body
        with the winner blocks as fori carries instead of output refs."""

        def tile_body(t, acc):
            g_cur, r_cur, w1_cur, w2_cur = acc
            r = jax.lax.dynamic_slice(r_all, (t * te,), (te,))
            c = jax.lax.dynamic_slice(c_all, (t * te,), (te,))
            w1 = jax.lax.dynamic_slice(w_all, (t * te,), (te,))
            # Step A: windowed completion lookup in row m_j's CSR segment
            qr = jnp.take(mr, jnp.clip(c, 0, n))
            qc = jnp.take(mc, jnp.clip(r, 0, n))
            qr_s = jnp.clip(qr, 0, n)
            lo = jnp.take(ptr, qr_s)
            hi0 = jnp.where(qr < n, jnp.take(ptr, qr_s + 1), lo)
            hi = hi0
            for _ in range(window_steps):
                mid = (lo + hi) // 2
                k = jnp.take(c_all, jnp.clip(mid, 0, cap - 1))
                lt = k < qc
                lo = jnp.where(lt, mid + 1, lo)
                hi = jnp.where(lt, hi, mid)
            found = (lo < hi0) & (
                jnp.take(c_all, jnp.clip(lo, 0, cap - 1)) == qc)
            w2 = jnp.where(
                found, jnp.take(w_all, jnp.clip(lo, 0, cap - 1)), 0.0)
            # Step B: gain + candidate mask (reference op order)
            gain = w1 + w2 - jnp.take(u, jnp.clip(r, 0, n)) - jnp.take(
                v, jnp.clip(c, 0, n))
            cand = found & (r < n) & (r > qr) & (gain > mg)
            # Step C: per-column winner accumulation (masked lanes -> slot n)
            cj = jnp.where(cand, c, n)
            g2 = g_cur.at[cj].max(jnp.where(cand, gain, NEG))
            hit = cand & (gain == jnp.take(g2, cj))
            rc = jnp.full_like(r_cur, BIG).at[cj].min(jnp.where(hit, r, BIG))
            r2 = jnp.where(g2 > g_cur, rc, jnp.minimum(r_cur, rc))
            sel = hit & (r == jnp.take(r2, cj))
            cjs = jnp.where(sel, cj, n)
            w1_2 = w1_cur.at[cjs].set(jnp.where(sel, w1, 0.0))
            w2_2 = w2_cur.at[cjs].set(jnp.where(sel, w2, 0.0))
            return g2, r2, w1_2, w2_2

        init = (jnp.full((nv,), NEG, jnp.float32),
                jnp.full((nv,), BIG, jnp.int32),
                jnp.zeros((nv,), jnp.float32),
                jnp.zeros((nv,), jnp.float32))
        return jax.lax.fori_loop(0, n_tiles, tile_body, init)

    def select_augment(mr, mc, u, v, Cgain, Crow, Cw1, Cw2):
        """``core.single.select_and_augment`` on [nv] lane vectors."""
        rooted = (Cgain > NEG) & lane_n
        Ci = jnp.where(rooted, Crow, n).astype(jnp.int32)
        Cw1 = jnp.where(rooted, Cw1, 0.0)
        Cw2 = jnp.where(rooted, Cw2, 0.0)
        Ci_s = jnp.clip(Ci, 0, n)
        # Step D: per-e2-column winner via scatter-max + min-payload
        # (identical semantics to segment_max_with_payload: max gain wins,
        # gain ties resolve to the smallest column index)
        e2 = jnp.where(rooted, jnp.take(mc, Ci_s), n)
        dgain = jnp.where(rooted, Cgain, NEG)
        dmax = jnp.full((nv,), NEG, jnp.float32).at[e2].max(dgain)
        hitd = rooted & (dgain == jnp.take(dmax, e2))
        dj = jnp.full((nv,), BIG, jnp.int32).at[e2].min(
            jnp.where(hitd, jlane, BIG))
        surv_c2 = (dmax > NEG) & (~rooted) & lane_n
        surv_root = jnp.where(surv_c2, dj, n)
        ms = jnp.zeros((nv,), jnp.int32).at[surv_root].set(
            jnp.where(surv_c2, 1, 0))
        mask_j = (ms > 0) & rooted
        n_surv = jnp.sum(mask_j.astype(jnp.int32))

        # deterministic fallback: single globally-best cycle. argmax's
        # first-occurrence rule = smallest lane index attaining the max.
        bg = jnp.max(jnp.where(rooted, Cgain, NEG))
        best_j = jnp.min(jnp.where(rooted & (Cgain == bg), jlane, BIG))
        use_fb = (n_surv == 0) & rooted.any()
        mask_j = mask_j | ((jlane == best_j) & use_fb)
        n_surv = n_surv + use_fb.astype(jnp.int32)

        # augmentation: the reference's exact scatter sequence (surviving
        # cycles are vertex-disjoint, so all real writes are unique; masked
        # lanes dump duplicate-identical values into slot n)
        i_ = Ci_s
        r2v = mr
        c2v = jnp.take(mc, i_)
        mj = jnp.where(mask_j, jlane, n)
        mi = jnp.where(mask_j, i_, n)
        mr2 = jnp.where(mask_j, r2v, n)
        mc2 = jnp.where(mask_j, c2v, n)
        mr_n = mr.at[mj].set(
            jnp.where(mask_j, i_, jnp.take(mr, mj)).astype(jnp.int32))
        mr_n = mr_n.at[mc2].set(
            jnp.where(mask_j, r2v, jnp.take(mr_n, mc2)).astype(jnp.int32))
        mc_n = mc.at[mi].set(
            jnp.where(mask_j, jlane, jnp.take(mc, mi)).astype(jnp.int32))
        mc_n = mc_n.at[mr2].set(
            jnp.where(mask_j, c2v, jnp.take(mc_n, mr2)).astype(jnp.int32))
        u_n = u.at[mi].set(jnp.where(mask_j, Cw1, jnp.take(u, mi)))
        u_n = u_n.at[mr2].set(jnp.where(mask_j, Cw2, jnp.take(u_n, mr2)))
        v_n = v.at[mj].set(jnp.where(mask_j, Cw1, jnp.take(v, mj)))
        v_n = v_n.at[mc2].set(jnp.where(mask_j, Cw2, jnp.take(v_n, mc2)))
        mr_n = mr_n.at[n].set(n)
        mc_n = mc_n.at[n].set(n)
        u_n = u_n.at[n].set(0.0)
        v_n = v_n.at[n].set(0.0)
        return mr_n, mc_n, u_n, v_n, n_surv

    def body(carry):
        mr, mc, u, v, it, _ = carry
        Cg, Cr, Cw1, Cw2 = sweep(mr, mc, u, v)
        mr, mc, u, v, n_surv = select_augment(mr, mc, u, v, Cg, Cr, Cw1, Cw2)
        return mr, mc, u, v, it + 1, n_surv > 0

    def cond(carry):
        return carry[5] & (carry[4] < max_iter)

    mr, mc, u, v, it, _ = jax.lax.while_loop(
        cond, body,
        (mr_ref[0], mc_ref[0], u_ref[0], v_ref[0], jnp.int32(0),
         go_ref[0, 0] > 0))
    mro_ref[0] = mr
    mco_ref[0] = mc
    uo_ref[0] = u
    vo_ref[0] = v
    it_ref[0, 0] = it


@functools.partial(
    jax.jit,
    static_argnames=("n", "te", "window_steps", "max_iter", "interpret"))
def awac_persistent(row, col, val, row_ptr, mate_row, mate_col, u, v,
                    min_gain, go0, *, n: int, te: int, window_steps: int,
                    max_iter: int, interpret: bool):
    """Single-instance persistent loop — a B=1 slice of
    ``awac_persistent_batched`` (one grid, one kernel body)."""
    mr, mc, uu, vv, it = awac_persistent_batched(
        row[None], col[None], val[None], row_ptr[None], mate_row[None],
        mate_col[None], u[None], v[None], min_gain, go0[None],
        n=n, te=te, window_steps=window_steps, max_iter=max_iter,
        interpret=interpret)
    return mr[0], mc[0], uu[0], vv[0], it[0]


@functools.partial(
    jax.jit,
    static_argnames=("n", "te", "window_steps", "max_iter", "interpret"))
def awac_persistent_batched(row, col, val, row_ptr, mate_row, mate_col, u, v,
                            min_gain, go0, *, n: int, te: int,
                            window_steps: int, max_iter: int,
                            interpret: bool):
    """Whole AWAC loop for B instances in ONE ``pallas_call``.

    row/col/val [B, cap] padded lex-sorted COO (cap % te == 0, padding rows
    == n); row_ptr [B, n + 2]; mate/u/v [B, n + 1]; min_gain f32 scalar;
    go0 [B] bool — the per-instance round-0 gate (False short-circuits the
    loop: the infeasible-instance degrade path, matching
    ``core.batch.awac_loop``'s ``active0``).

    Returns (mate_row, mate_col, u, v, iters): state over [B, n + 1 padded
    to lanes] plus per-instance iteration counts [B]; callers slice
    [:, :n + 1]. Bit-identical (state AND counts) to driving
    ``awac_sweep_batched`` from the host ``while_loop``.
    """
    b, cap = row.shape
    if te % 128 != 0 or te < 128 or cap % te != 0:
        raise ValueError(
            f"awac_persistent_batched: edge tile te={te} must be a positive "
            f"multiple of 128 that divides cap={cap} (pad cap or pass "
            f"te=None to the ops wrappers for automatic tile selection)")
    nv = pl.cdiv(n + 2, 128) * 128
    grid = (b,)

    def lane_pad(x, width, fill):
        return jnp.full((b, width), fill, x.dtype).at[:, : x.shape[1]].set(x)

    full = lambda width: pl.BlockSpec((1, width), lambda i: (i, 0))  # noqa: E731
    out = pl.pallas_call(
        functools.partial(_kernel, n=n, cap=cap, te=te,
                          window_steps=window_steps, max_iter=max_iter),
        grid=grid,
        in_specs=[
            full(cap), full(cap), full(cap),      # row, col, val (resident)
            full(nv),                             # row_ptr
            full(nv), full(nv),                   # mate_row, mate_col
            full(nv), full(nv),                   # u, v
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # min_gain (shared)
            pl.BlockSpec((1, 1), lambda i: (i, 0)),  # go0 (per instance)
        ],
        out_specs=[full(nv)] * 4 + [pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((b, nv), jnp.int32),
            jax.ShapeDtypeStruct((b, nv), jnp.int32),
            jax.ShapeDtypeStruct((b, nv), jnp.float32),
            jax.ShapeDtypeStruct((b, nv), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        row, col, val,
        lane_pad(row_ptr, nv, cap),
        lane_pad(mate_row, nv, n), lane_pad(mate_col, nv, n),
        lane_pad(u, nv, 0), lane_pad(v, nv, 0),
        jnp.asarray(min_gain, jnp.float32).reshape(1, 1),
        go0.astype(jnp.int32).reshape(b, 1),
    )
    return out[0], out[1], out[2], out[3], out[4][:, 0]
