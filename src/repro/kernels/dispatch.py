"""Measured backend dispatch table for ``backend="auto"``.

The seed resolved ``"auto"`` with a hard-coded platform rule ("pallas on
TPU, xla elsewhere") — an asserted claim, not a measured one, and on CPU it
was measurably wrong once the Pallas interpreter numbers were labeled
honestly. This module replaces the rule with a small measured table,
persisted as ``BENCH_dispatch.json`` next to ``BENCH_kernels.json`` at the
repo root and refreshed by the kernels bench job (``benchmarks/
bench_kernels.py``), which times every local AWAC backend per shape class
and records the winner.

Table schema (one entry per ``<platform>/<shape class>``)::

    {"entries": {"cpu/single_large": {
         "winner": "xla",
         "us_per_iter": {"reference": 5276.2, "xla": 2525.4, ...},
         "interpret": {"pallas": true, "pallas_persistent": true}},
      ...},
     "metadata": {...}}

Shape classes are deliberately coarse — ``{single|batched}_{small|large}``
with the small/large split at ``n <= SMALL_N`` — because the bench job must
be able to measure every class on every CI run. Lookup falls back
class -> same-kind class -> any class for the platform -> None; a None
answer means "unmeasured here", and the caller (``core.single.
resolve_backend``) falls back to the platform heuristic, clearly labeled as
such.

``check_regression.py --dispatch`` gates the committed table against fresh
measurements so a stale winner (losing by more than the routing factor)
fails CI instead of silently mis-routing ``auto``.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Any

#: committed location: repo root, next to BENCH_kernels.json
DEFAULT_TABLE_PATH = pathlib.Path(__file__).resolve().parents[3] \
    / "BENCH_dispatch.json"

#: env override for tests / alternate deployments
TABLE_ENV_VAR = "REPRO_DISPATCH_TABLE"

#: boundary of the {small, large} shape-class split (inclusive small side)
SMALL_N = 256

#: backends the bench job measures per class (order = bench order)
MEASURED_BACKENDS = ("reference", "xla", "pallas", "pallas_persistent")

_CACHE: dict[str, dict | None] = {}


def table_path() -> pathlib.Path:
    return pathlib.Path(os.environ.get(TABLE_ENV_VAR, DEFAULT_TABLE_PATH))


def shape_class(n: int | None, batch: int | None = None) -> str:
    """Coarse shape class: ``{single|batched}_{small|large}``.

    ``n=None`` (shape unknown at resolve time, e.g. the resilient runtime
    resolving a backend name without a problem in hand) conservatively maps
    to the large single-instance class — the class whose winner is the
    safest default for arbitrary work.
    """
    kind = "batched" if batch is not None and batch > 1 else "single"
    size = "large" if n is None or n > SMALL_N else "small"
    return f"{kind}_{size}"


def load_table(path: str | os.PathLike | None = None) -> dict | None:
    """Load (and cache) the dispatch table; None when absent/unreadable."""
    p = str(path if path is not None else table_path())
    if p in _CACHE:
        return _CACHE[p]
    try:
        with open(p) as f:
            table = json.load(f)
        if not isinstance(table.get("entries"), dict):
            table = None
    except (OSError, ValueError):
        table = None
    _CACHE[p] = table
    return table


def clear_cache() -> None:
    _CACHE.clear()


def _entry(table: dict, platform: str, klass: str) -> dict | None:
    entries = table["entries"]
    hit = entries.get(f"{platform}/{klass}")
    if hit is not None:
        return hit
    # same kind (single/batched), other size
    kind = klass.split("_")[0]
    for key, e in sorted(entries.items()):
        plat, _, kl = key.partition("/")
        if plat == platform and kl.startswith(kind):
            return e
    # any class measured on this platform
    for key, e in sorted(entries.items()):
        if key.partition("/")[0] == platform:
            return e
    return None


def choose_backend(n: int | None = None, batch: int | None = None,
                   platform: str | None = None,
                   path: str | os.PathLike | None = None) -> str | None:
    """Measured winner for (platform, shape class), or None if unmeasured.

    None tells the caller to fall back to its heuristic — the table never
    guesses about platforms it has no measurements for.
    """
    table = load_table(path)
    if table is None:
        return None
    if platform is None:
        import jax

        platform = jax.default_backend()
    entry = _entry(table, platform, shape_class(n, batch))
    if entry is None:
        return None
    winner = entry.get("winner")
    return winner if isinstance(winner, str) and winner else None


def save_table(entries: dict[str, Any], metadata: dict[str, Any],
               path: str | os.PathLike | None = None) -> pathlib.Path:
    """Persist a freshly measured table (bench job) and drop the cache."""
    p = pathlib.Path(path if path is not None else table_path())
    with open(p, "w") as f:
        json.dump({"entries": entries, "metadata": metadata}, f, indent=1)
        f.write("\n")
    clear_cache()
    return p
