"""jit'd wrapper for router_swap: pads T to tile multiples and E to lanes."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.router_swap.ref import router_swap_ref
from repro.kernels.router_swap.router_swap import router_swap

NEG = float("-inf")


def _round_up(x, m):
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("ti", "tj", "use_kernel",
                                             "interpret"))
def router_swap_padded(affinity, assign, cur, *, ti: int = 256, tj: int = 256,
                       use_kernel: bool = True, interpret: bool | None = None):
    if not use_kernel:
        return router_swap_ref(affinity, assign, cur)
    t, e = affinity.shape
    ti = min(ti, _round_up(t, 8))
    tj = min(tj, _round_up(t, 128))
    tp = _round_up(t, max(ti, tj))
    ep = _round_up(e, 128)
    # pad affinity with ZEROS (never -inf: -inf + -inf = NaN would poison the
    # column max); padded tokens get expert id e (distinct from real ids) and
    # cur=+inf, which drives every gain involving them to exactly -inf
    aff = jnp.zeros((tp, ep), jnp.float32).at[:t, :e].set(affinity)
    as_p = jnp.full((tp,), e, jnp.int32).at[:t].set(assign)
    cur_p = jnp.full((tp,), jnp.inf, jnp.float32).at[:t].set(cur)
    g, r = router_swap(aff, as_p, cur_p, ti=ti, tj=tj, interpret=interpret)
    return g[:t], r[:t]
