"""Pallas TPU kernel: tiled swap-gain search for the AWPM MoE router.

The router's 4-cycle (AWAC) phase needs, for every token j, its best swap
partner i: gain W[i,j] = aff[i, e_j] + aff[j, e_i] - cur[i] - cur[j]. The XLA
fallback materializes the [T, T] gain matrix; this kernel never does — per
(TI, TJ) tile it reconstructs A[i,j] = aff[i, e_j] on the MXU as
``aff_tile @ onehot(assign_tile)^T`` (the canonical TPU gather-as-matmul) and
accumulates the per-column max/argmax across row tiles, exactly like the
cycle_gain kernel accumulates Step C winners.

VMEM per step: 2 aff tiles [T_tile, E] + the [TI, TJ] gain tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

NEG = float("-inf")


def _kernel(aff_i_ref, aff_j_ref, as_i_ref, as_j_ref, cur_i_ref, cur_j_ref,
            gain_ref, part_ref, *, ti: int, e: int):
    ij = pl.program_id(0)
    ii = pl.program_id(1)

    @pl.when(ii == 0)
    def _init():
        gain_ref[...] = jnp.full_like(gain_ref, NEG)
        part_ref[...] = jnp.full_like(part_ref, -1)

    aff_i = aff_i_ref[...]  # [TI, E]
    aff_j = aff_j_ref[...]  # [TJ, E]
    as_i = as_i_ref[...]  # [TI, 1] int32
    as_j = as_j_ref[...]  # [TJ, 1]
    lanes_i = jax.lax.broadcasted_iota(jnp.int32, (as_i.shape[0], e), 1)
    lanes_j = jax.lax.broadcasted_iota(jnp.int32, (as_j.shape[0], e), 1)
    onehot_i = (as_i == lanes_i).astype(aff_i.dtype)  # [TI, E]
    onehot_j = (as_j == lanes_j).astype(aff_j.dtype)  # [TJ, E]
    # A[i, j] = aff[i, e_j];  A2[i, j] = aff[j, e_i]
    a = jax.lax.dot_general(aff_i, onehot_j, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    a2 = jax.lax.dot_general(onehot_i, aff_j, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w = a + a2 - cur_i_ref[...] - cur_j_ref[...]  # [TI,1] + [1,TJ] broadcast
    gi = ii * ti + jax.lax.broadcasted_iota(jnp.int32, w.shape, 0)
    gj = ij * gain_ref.shape[-1] + jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    same_tok = gi == gj
    same_exp = as_i == jnp.transpose(as_j)  # [TI, TJ] via broadcast
    w = jnp.where(same_tok | same_exp, NEG, w)

    g = jnp.max(w, axis=0, keepdims=True)  # [1, TJ]
    rows = jax.lax.broadcasted_iota(jnp.int32, w.shape, 0)
    hit = (w == g) & (g > NEG)
    r = jnp.min(jnp.where(hit, rows, jnp.iinfo(jnp.int32).max), axis=0,
                keepdims=True)
    r = jnp.where(g > NEG, r + ii * ti, -1)
    better = g > gain_ref[...]
    part_ref[...] = jnp.where(better, r.astype(jnp.int32), part_ref[...])
    gain_ref[...] = jnp.where(better, g, gain_ref[...])


@functools.partial(jax.jit, static_argnames=("ti", "tj", "interpret"))
def router_swap(affinity, assign, cur, *, ti: int = 256, tj: int = 256,
                interpret: bool | None = None):
    """affinity [T, E] f32; assign [T] int32; cur [T] f32 (current affinity).
    Returns (best_gain [T], best_partner [T] int32, -1 if none).
    T % ti == 0, T % tj == 0 required (ops.py pads)."""
    t, e = affinity.shape
    assert t % ti == 0 and t % tj == 0, (t, ti, tj)
    grid = (t // tj, t // ti)
    out = pl.pallas_call(
        functools.partial(_kernel, ti=ti, e=e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, e), lambda j, i: (i, 0)),
            pl.BlockSpec((tj, e), lambda j, i: (j, 0)),
            pl.BlockSpec((ti, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((tj, 1), lambda j, i: (j, 0)),
            pl.BlockSpec((ti, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((1, tj), lambda j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tj), lambda j, i: (0, j)),
            pl.BlockSpec((1, tj), lambda j, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, t), jnp.float32),
            jax.ShapeDtypeStruct((1, t), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(affinity, affinity, assign[:, None], assign[:, None], cur[:, None],
      cur[None, :])
    return out[0][0], out[1][0]
