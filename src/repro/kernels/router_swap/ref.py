"""Pure-jnp oracle for router_swap (materializes the [T, T] gain matrix)."""
import jax.numpy as jnp

NEG = float("-inf")


def router_swap_ref(affinity, assign, cur):
    t = affinity.shape[0]
    a = jnp.take(affinity, assign, axis=1)  # [T, T]: aff[i, e_j]
    w = a + a.T - cur[:, None] - cur[None, :]
    tok = jnp.arange(t)
    same_tok = tok[:, None] == tok[None, :]
    same_exp = assign[:, None] == assign[None, :]
    w = jnp.where(same_tok | same_exp, NEG, w)
    g = jnp.max(w, axis=0)
    rows = tok[:, None]
    hit = (w == g[None, :]) & (g[None, :] > NEG)
    r = jnp.min(jnp.where(hit, rows, jnp.iinfo(jnp.int32).max), axis=0)
    return g, jnp.where(g > NEG, r, -1).astype(jnp.int32)
