from repro.kernels.router_swap.ops import router_swap_padded
from repro.kernels.router_swap.ref import router_swap_ref
from repro.kernels.router_swap.router_swap import router_swap

__all__ = ["router_swap", "router_swap_padded", "router_swap_ref"]
