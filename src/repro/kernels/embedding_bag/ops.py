"""jit'd wrapper for embedding_bag: padding + dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _round_up(x, m):
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("tb", "tv", "use_kernel", "interpret"))
def embedding_bag_padded(idx, w, table, *, tb: int = 8, tv: int = 512,
                         use_kernel: bool = True, interpret: bool | None = None):
    if not use_kernel:
        return embedding_bag_ref(idx, w, table)
    b, l = idx.shape
    v, d = table.shape
    tb = min(tb, _round_up(b, 8))
    tv = min(tv, _round_up(v, 128))
    bp, vp = _round_up(b, tb), _round_up(v, tv)
    idx_p = jnp.full((bp, l), -1, idx.dtype).at[:b].set(idx)
    w_p = jnp.zeros((bp, l), w.dtype).at[:b].set(w)
    tbl_p = jnp.zeros((vp, d), table.dtype).at[:v].set(table)
    out = embedding_bag(idx_p, w_p, tbl_p, tb=tb, tv=tv, interpret=interpret)
    return out[:b]
