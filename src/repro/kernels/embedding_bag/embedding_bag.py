"""Pallas TPU EmbeddingBag kernel (weighted sum over multi-hot bags).

JAX has no native EmbeddingBag; the framework implements it (per the brief)
as take+segment_sum in repro.models.recsys.embedding. This kernel is the
TPU-native hot-path version for the RecSys serve/bulk shapes.

TPU adaptation: random-row gather from HBM is DMA-bound and irregular; the
MXU-native formulation processes the table in VMEM-resident vocab tiles and
accumulates ``multi_hot(bag, tile) @ tile`` — a dense [TB, TV] x [TV, D]
matmul per (bag-tile, vocab-tile), turning the gather into systolic compute.
The weighted multi-hot matrix is built on the VPU from index compares.
This is the standard small/medium-vocab embedding strategy on TPU; huge
tables are row-sharded across the mesh first (models/recsys/embedding.py),
making each shard's slice exactly this kernel's regime.

Grid: (num_bag_tiles, num_vocab_tiles), vocab innermost; output revisited.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _kernel(idx_ref, w_ref, tbl_ref, o_ref, *, tv: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]  # [TB, L] int32, -1 padding
    w = w_ref[...]  # [TB, L] f32
    tbl = tbl_ref[...]  # [TV, D]
    local = idx - iv * tv
    lanes = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], idx.shape[1], tv), 2)
    match = (local[:, :, None] == lanes) & (idx[:, :, None] >= 0)
    multi_hot = jnp.sum(jnp.where(match, w[:, :, None], 0.0), axis=1)  # [TB, TV]
    o_ref[...] += jax.lax.dot_general(
        multi_hot, tbl, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tb", "tv", "interpret"))
def embedding_bag(idx, w, table, *, tb: int = 8, tv: int = 512,
                  interpret: bool | None = None):
    """idx [B, L] int32 (-1 = padding); w [B, L] f32; table [V, D] f32.
    Returns [B, D] f32 with out[b] = sum_l w[b,l] * table[idx[b,l]].
    B % tb == 0 and V % tv == 0 required (ops.py pads)."""
    b, l = idx.shape
    v, d = table.shape
    assert b % tb == 0 and v % tv == 0, (idx.shape, table.shape, tb, tv)
    grid = (b // tb, v // tv)
    return pl.pallas_call(
        functools.partial(_kernel, tv=tv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, l), lambda ib, iv: (ib, 0)),
            pl.BlockSpec((tb, l), lambda ib, iv: (ib, 0)),
            pl.BlockSpec((tv, d), lambda ib, iv: (iv, 0)),
        ],
        out_specs=pl.BlockSpec((tb, d), lambda ib, iv: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(idx, w, table)
