from repro.kernels.embedding_bag.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ops import embedding_bag_padded
from repro.kernels.embedding_bag.ref import embedding_bag_ref

__all__ = ["embedding_bag", "embedding_bag_padded", "embedding_bag_ref"]
