"""Pure-jnp oracle for embedding_bag (gather + weighted sum)."""
import jax.numpy as jnp


def embedding_bag_ref(idx, w, table):
    """idx [B, L] int32 (-1 padding); w [B, L]; table [V, D] -> [B, D] f32."""
    safe = jnp.clip(idx, 0, table.shape[0] - 1)
    rows = jnp.take(table, safe, axis=0).astype(jnp.float32)  # [B, L, D]
    wm = jnp.where(idx >= 0, w, 0.0).astype(jnp.float32)
    return jnp.sum(rows * wm[:, :, None], axis=1)
