"""Sharded embedding lookup + EmbeddingBag (JAX has neither natively; built
from take + segment_sum per the brief; the Pallas kernel in
repro.kernels.embedding_bag is the TPU hot-path version)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.ops import embedding_bag_padded
from repro.models.param import ParamDef, embed_init


def table_def(n_rows: int, dim: int, name_axis: str = "vocab"):
    return ParamDef((n_rows, dim), embed_init(0.02), (name_axis, "embed"))


def lookup(table, idx):
    """Plain row gather; with a row-sharded table XLA lowers this to a
    one-hot-free dynamic-gather + collective (all-to-all style)."""
    return jnp.take(table, idx, axis=0)


def embedding_bag(table, idx, weights, use_kernel: bool = False,
                  interpret: bool = True):
    """out[b] = sum_l weights[b, l] * table[idx[b, l]]; idx -1 = padding."""
    if use_kernel:
        return embedding_bag_padded(idx, weights, table, interpret=interpret)
    safe = jnp.clip(idx, 0, table.shape[0] - 1)
    rows = jnp.take(table, safe, axis=0)
    w = jnp.where(idx >= 0, weights, 0.0).astype(rows.dtype)
    return jnp.sum(rows * w[..., None], axis=-2)
