"""BERT4Rec (arXiv:1904.06690): bidirectional transformer over item
interaction sequences, trained with masked-item prediction (Cloze). Scoring
head is the tied item-embedding matmul.

Shapes (the assigned cells): train_batch 65536 masked-LM; serve_p99/bulk
score the next item for each sequence; retrieval_cand scores 1 user against
1M candidate items (tied-embedding dot products, sharded over candidates).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (
    dense,
    dense_def,
    gelu_mlp,
    gelu_mlp_def,
    layernorm,
    layernorm_def,
    softmax_xent,
)
from repro.models.param import ParamDef, embed_init
from repro.models.recsys.embedding import table_def


def bert4rec_def(cfg):
    d = cfg.embed_dim
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append({
            "ln1": layernorm_def(d),
            "q": dense_def(d, d, ("embed", "heads"), bias=True, bias_axis="heads"),
            "k": dense_def(d, d, ("embed", "heads"), bias=True, bias_axis="heads"),
            "v": dense_def(d, d, ("embed", "heads"), bias=True, bias_axis="heads"),
            "o": dense_def(d, d, ("heads", "embed"), bias=True, bias_axis="embed"),
            "ln2": layernorm_def(d),
            "ffn": gelu_mlp_def(d, cfg.d_ff_mult * d),
        })
    return {
        "items": table_def(cfg.padded_items, d),  # +mask +pad +shard padding
        "pos": ParamDef((cfg.seq_len, d), embed_init(0.02), (None, "embed")),
        "blocks": blocks,
        "final_ln": layernorm_def(d),
        "out_bias": ParamDef((cfg.padded_items,), lambda k, s, dt: jnp.zeros(s, dt),
                             ("vocab",)),
    }


def _bidir_attention(bp, x, cfg):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    q = dense(bp["q"], x).reshape(b, s, h, hd)
    k = dense(bp["k"], x).reshape(b, s, h, hd)
    v = dense(bp["v"], x).reshape(b, s, h, hd)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
    p = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, d)
    return dense(bp["o"], o)


def encode(params, item_seq, cfg):
    """item_seq [B, S] int32 -> hidden [B, S, d]."""
    from repro.models.act_sharding import constrain

    x = constrain(jnp.take(params["items"], item_seq, axis=0), "rec_act")
    x = x + params["pos"][None, : x.shape[1]]
    for bp in params["blocks"]:
        x = constrain(x + _bidir_attention(bp, layernorm(bp["ln1"], x), cfg),
                      "rec_act")
        x = constrain(x + gelu_mlp(bp["ffn"], layernorm(bp["ln2"], x)),
                      "rec_act")
    return layernorm(params["final_ln"], x)


def logits_all_items(params, hidden):
    """Tied-embedding scores over the full item vocabulary."""
    return (hidden.astype(jnp.float32) @ params["items"].T.astype(jnp.float32)
            + params["out_bias"])


def loss_fn(params, batch, cfg):
    """Masked-item (Cloze) objective. batch: item_seq [B,S], labels [B,S],
    mask [B,S] (1 at masked positions)."""
    h = encode(params, batch["item_seq"], cfg)
    logits = logits_all_items(params, h)
    loss = softmax_xent(logits, batch["labels"], batch["mask"])
    return loss, {"xent": loss}


def serve_scores(params, item_seq, cfg):
    """Next-item scores from the last position. [B, n_items+2]."""
    h = encode(params, item_seq, cfg)
    return logits_all_items(params, h[:, -1:])[:, 0]


def retrieval_scores(params, item_seq, candidates, cfg):
    """Score ONE user sequence against a candidate set [Nc] (batched dot,
    never a loop): returns [B, Nc]."""
    h = encode(params, item_seq, cfg)[:, -1]  # [B, d]
    cand_emb = jnp.take(params["items"], candidates, axis=0)  # [Nc, d]
    return (h.astype(jnp.float32) @ cand_emb.T.astype(jnp.float32)
            + params["out_bias"][candidates])
