"""Parameter definition + logical-axis sharding system.

Modules declare parameter trees of ``ParamDef`` (shape, init, logical axis
names). ``init_params`` materializes them; ``partition_specs`` maps logical
axes to mesh axes through a rules dict (MaxText-style), so the same model
definition serves 1-device smoke tests and the 512-chip dry-run.

Logical axis vocabulary (see launch/mesh.py for the production rules):
  "batch"     data-parallel dimension         -> ("pod", "data")
  "embed"     model/residual width            -> "model" (TP) or None
  "mlp"       FFN hidden                      -> "model"
  "heads"     attention heads                 -> "model"
  "kv_heads"  KV heads                        -> "model" when divisible
  "vocab"     vocabulary / item tables        -> "model"
  "experts"   MoE expert dimension            -> "pod" (EP) when divisible
  "fsdp"      parameter sharding dimension    -> "data" (FSDP)
  "nodes"/"edges"  graph entities             -> ("pod", "data")
  None        replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    init: Callable  # (key, shape, dtype) -> array
    axes: tuple[str | None, ...]  # logical axis names, len == len(shape)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.axes) == len(self.shape), (self.shape, self.axes)


def dense_init(fan_in: float | None = None, scale: float = 1.0):
    def f(key, shape, dtype):
        fi = fan_in if fan_in is not None else shape[0]
        return jax.random.normal(key, shape, dtype) * (scale / np.sqrt(max(fi, 1)))

    return f


def zeros_init():
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init():
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


def embed_init(scale: float = 1.0):
    return lambda key, shape, dtype: jax.random.normal(key, shape, dtype) * scale


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key):
    """Materialize a tree of ParamDef into arrays (unique key per leaf)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [d.init(k, d.shape, d.dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs):
    """ShapeDtypeStruct tree (for AOT lowering — no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def partition_specs(defs, rules: dict[str | None, Any]):
    """Map logical axes -> PartitionSpec through `rules`. Unknown names are an
    error (catches typos); None maps to replicated."""

    def spec(d: ParamDef):
        entries = []
        for name in d.axes:
            if name is None:
                entries.append(None)
            else:
                if name not in rules:
                    raise KeyError(f"no sharding rule for logical axis {name!r}")
                entries.append(rules[name])
        return P(*entries)

    return jax.tree.map(spec, defs, is_leaf=is_def)


def sharded_init(defs, key, mesh, rules):
    """init_params + device placement according to the rules (used by the
    real trainer; the dry-run uses abstract_params instead)."""
    specs = partition_specs(defs, rules)
    params = init_params(defs, key)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(mesh, s)),
        params,
        specs,
    )


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))
