"""Activation-sharding constraint policy.

Model code calls ``constrain(x, key)`` at well-known points; the launcher
installs a per-family policy (key -> PartitionSpec) during tracing. Without a
policy (smoke tests, single device) it's a no-op. This is what keeps XLA's
sharding propagation honest — without the constraints GSPMD can (and did, see
EXPERIMENTS.md §Perf iteration 1) replicate the batch dimension of attention
scores across the mesh.

Keys:
  lm_act        [B, S, d]       transformer residual stream
  lm_qkv        [B, S, H, D]    per-head projections
  lm_logits     [B, S, V] / [B, V]
  mlp_hidden    [..., ff]       FFN hidden
  moe_buf       [E, C, d]       expert dispatch buffers
  nodes         [N, ...]        GNN node states
  edges         [E, ...]        GNN edge messages
  rec_act       [B, S, d]       bert4rec stream
"""
from __future__ import annotations

import contextlib
import threading

import jax

_tls = threading.local()


@contextlib.contextmanager
def policy(mesh, mapping: dict):
    prev = getattr(_tls, "policy", None)
    _tls.policy = (mesh, mapping)
    try:
        yield
    finally:
        _tls.policy = prev


def with_policy(mesh, mapping: dict):
    """Wrap fn so the policy is active while it is traced."""

    def deco(fn):
        def wrapped(*a, **k):
            with policy(mesh, mapping):
                return fn(*a, **k)

        return wrapped

    return deco


def constrain(x, key: str):
    pol = getattr(_tls, "policy", None)
    if pol is None:
        return x
    mesh, mapping = pol
    spec = mapping.get(key)
    if isinstance(spec, dict):  # rank-dispatched specs (e.g. mlp_hidden 2D/3D)
        spec = spec.get(x.ndim)
    if spec is None:
        return x
    ns = jax.sharding.NamedSharding(mesh, spec)
    return jax.lax.with_sharding_constraint(x, ns)
