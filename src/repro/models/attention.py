"""GQA attention with RoPE, QKV bias (Qwen-style), KV cache, and a decode path
designed for sharded caches (sequence parallelism at 32k-500k KV lengths).

Train/prefill attention dispatches to the flash Pallas kernel or the jnp
reference (cfg.attention_impl); decode is pure jnp — a 1-token query against
a [B, S, Hkv, D] cache lowers to a reduction XLA distributes over the
sequence-sharded cache (flash-decoding-style two-pass softmax comes out of
the sharded logsumexp automatically).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention as flash_or_ref
from repro.models.act_sharding import constrain
from repro.models.layers import dense, dense_def, rope


def attention_def(cfg):
    d, hd = cfg.d_model, cfg.hd
    return {
        "q": dense_def(d, cfg.n_heads * hd, ("embed", "heads"), bias=cfg.qkv_bias,
                       bias_axis="heads"),
        "k": dense_def(d, cfg.n_kv_heads * hd, ("embed", "kv_heads"),
                       bias=cfg.qkv_bias, bias_axis="kv_heads"),
        "v": dense_def(d, cfg.n_kv_heads * hd, ("embed", "kv_heads"),
                       bias=cfg.qkv_bias, bias_axis="kv_heads"),
        "o": dense_def(cfg.n_heads * hd, d, ("heads", "embed")),
    }


def _qkv(p, x, positions, cfg):
    b, s, _ = x.shape
    hd = cfg.hd
    q = constrain(dense(p["q"], x).reshape(b, s, cfg.n_heads, hd), "lm_qkv")
    k = constrain(dense(p["k"], x).reshape(b, s, cfg.n_kv_heads, hd), "lm_kv")
    v = constrain(dense(p["v"], x).reshape(b, s, cfg.n_kv_heads, hd), "lm_kv")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def self_attention(p, x, positions, cfg):
    """Causal self-attention for train/prefill. x [B, S, d]."""
    q, k, v = _qkv(p, x, positions, cfg)
    o = flash_or_ref(q, k, v, causal=True,
                     use_kernel=(cfg.attention_impl == "pallas"))
    b, s, _ = x.shape
    o = constrain(o, "lm_qkv")
    return dense(p["o"], o.reshape(b, s, cfg.n_heads * cfg.hd)), (k, v)


def decode_attention(p, x1, k_cache, v_cache, pos, cfg):
    """One decode step. x1 [B, 1, d]; caches [B, S_max, Hkv, D]; pos scalar
    (current length). Returns (out [B, 1, d], k_new, v_new) where k/v_new are
    the single-position entries to insert at ``pos``."""
    b = x1.shape[0]
    hd = cfg.hd
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k1, v1 = _qkv(p, x1, positions, cfg)
    group = cfg.n_heads // cfg.n_kv_heads
    # fold new kv into the score against the cache by treating it as cache[pos]
    kc = jax.lax.dynamic_update_slice(k_cache, k1.astype(k_cache.dtype),
                                      (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(v_cache, v1.astype(v_cache.dtype),
                                      (0, pos, 0, 0))
    qh = q.reshape(b, cfg.n_kv_heads, group, hd)  # [B, Hkv, G, D] (S=1 folded)
    scores = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                        kc.astype(jnp.float32)) / (hd ** 0.5)
    valid = (jnp.arange(kc.shape[1]) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)
    pexp = jnp.exp(scores - m)
    l = jnp.sum(pexp, axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", pexp, vc.astype(jnp.float32)) / jnp.maximum(
        l, 1e-30
    )
    o = o.reshape(b, 1, cfg.n_heads * hd).astype(x1.dtype)
    return dense(p["o"], o), kc, vc
