"""Mixture-of-Experts layer with two routers:

- ``topk``: the literature-faithful baseline (softmax gate, top-k, capacity
  dropping, load-balancing aux loss) — what qwen2-moe / deepseek-moe ship.
- ``awpm``: the paper's technique applied to routing (DESIGN.md §4). Token ->
  expert-slot assignment is a maximum-weight perfect matching on the
  (token x slot) bipartite graph; we approximate it exactly the way the paper
  approximates MWPM: a greedy balanced assignment (the maximal-matching
  phase) followed by weight-augmenting 4-cycle rounds (the AWAC phase), where
  a 4-cycle = a pair of tokens swapping experts with positive total affinity
  gain, applied as a vertex-disjoint (mutual-best) set per round. This gives
  a perfectly load-balanced, drop-free routing with near-max affinity and no
  aux loss — the BASE-layers objective solved with the paper's machinery
  instead of an auction (which §1 argues scales poorly).

Dispatch is sort-based (argsort by expert, rank-within-expert slots), never
materializing [T, E, C] one-hots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_def, mlp, mlp_def
from repro.models.param import ParamDef, dense_init

NEG = float("-inf")


def _unpad_idx(nb, tb, tbp):
    """Indices selecting the first tb rows of each tbp-sized block."""
    return (jnp.arange(nb * tb, dtype=jnp.int32) // tb * tbp
            + jnp.arange(nb * tb, dtype=jnp.int32) % tb)


def moe_def(cfg, moe):
    d = cfg.d_model
    e, ff = moe.n_experts, moe.d_ff_expert
    p = {
        "router": {"w": ParamDef((d, e), dense_init(d), ("embed", None))},
        "experts": {
            "gate": ParamDef((e, d, ff), dense_init(d),
                             ("experts", "embed", "expert_mlp")),
            "up": ParamDef((e, d, ff), dense_init(d),
                           ("experts", "embed", "expert_mlp")),
            "down": ParamDef((e, ff, d), dense_init(ff),
                             ("experts", "expert_mlp", "embed")),
        },
    }
    if moe.n_shared:
        p["shared"] = mlp_def(d, moe.d_ff_shared or moe.n_shared * ff)
        if moe.shared_gate:
            p["shared_gate"] = dense_def(d, 1, ("embed", None))
    return p


# --------------------------- routers ---------------------------------------


def topk_route(logits, k, capacity):
    """Faithful baseline. Returns (expert [T,k], slot [T,k], weight [T,k],
    keep [T,k], aux_loss). Slot is rank-within-expert; tokens beyond
    ``capacity`` are dropped."""
    t, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, k)  # [T, k]
    w = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # rank within expert over flattened (token-major) choices
    flat_e = topi.reshape(-1)  # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0].reshape(t, k)
    keep = slot < capacity
    # aux load-balance loss (Switch-style)
    frac_tokens = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return topi, slot, w.astype(logits.dtype), keep, aux


def balanced_assign_batched(aff, capacity, max_iters=None):
    """Greedy balanced assignment (the 'maximal matching' phase) for a batch
    of G independent groups in one while_loop with per-group convergence
    masks: proposal rounds with per-expert top-capacity acceptance, then a
    deterministic round-robin cleanup so every token is assigned and every
    expert holds exactly ``capacity`` tokens. aff [G, T, E] (-inf =
    forbidden). Returns assigned [G, T]."""
    g, t, e = aff.shape
    assert t == e * capacity, (t, e, capacity)
    max_iters = max_iters or (e + 8)
    gidx = jnp.arange(g)[:, None]

    def body(carry):
        assigned, cap, it, active = carry
        open_e = cap > 0
        aff_m = jnp.where(
            (assigned[..., None] >= 0) | ~open_e[:, None, :], NEG, aff)
        best_v = aff_m.max(axis=2)
        best_e = jnp.argmax(aff_m, axis=2)
        has = best_v > NEG
        score_te = jnp.where(
            has[:, None, :]
            & (best_e[:, None, :] == jnp.arange(e)[None, :, None]),
            jnp.swapaxes(aff, 1, 2), NEG,
        )  # [G, E, T]
        vals, idxs = jax.lax.top_k(score_te, capacity)  # [G, E, C]
        ok = (vals > NEG) & (jnp.arange(capacity)[None, None, :]
                             < cap[:, :, None])
        ok = ok & active[:, None, None]  # frozen groups accept nothing
        tok = jnp.where(ok, idxs, t).reshape(g, -1)
        exp = jnp.where(ok, jnp.arange(e, dtype=jnp.int32)[None, :, None],
                        0).reshape(g, -1)
        assigned = jnp.concatenate(
            [assigned, jnp.full((g, 1), -1, jnp.int32)], axis=1)
        assigned = assigned.at[gidx, tok].set(exp.astype(jnp.int32))[:, :t]
        cap = cap - ok.sum(axis=2)
        return assigned, cap, it + 1, active & (assigned < 0).any(axis=1)

    def cond(carry):
        _, _, it, active = carry
        return active.any() & (it < max_iters)

    assigned0 = jnp.full((g, t), -1, jnp.int32)
    cap0 = jnp.full((g, e), capacity, jnp.int32)
    assigned, cap, _, _ = jax.lax.while_loop(
        cond, body,
        (assigned0, cap0, jnp.array(0, jnp.int32), jnp.ones((g,), bool)))
    # cleanup: r-th remaining token -> expert owning the r-th free slot
    rem = assigned < 0
    rank = jnp.cumsum(rem.astype(jnp.int32), axis=1) - 1
    free_cum = jnp.cumsum(cap, axis=1)
    slot_expert = jax.vmap(
        lambda fc, rk: jnp.searchsorted(fc, rk, side="right")
    )(free_cum, rank).astype(jnp.int32)
    return jnp.where(rem, slot_expert, assigned)


def balanced_assign(aff, capacity, max_iters=None):
    """Single-group wrapper over ``balanced_assign_batched``. aff [T, E]."""
    return balanced_assign_batched(aff[None], capacity, max_iters)[0]


def swap_improve_batched(aff, assign, rounds: int, min_gain=1e-6):
    """AWAC on the router for G groups at once: mutual-best positive-gain
    token swaps, applied as a vertex-disjoint set per round (the swap-gain
    matrix is [G, T, T], block-diagonal — tokens never swap across groups).
    Preserves perfect balance exactly. aff [G, T, E], assign [G, T]."""
    g, t = assign.shape
    tvec = jnp.arange(t, dtype=jnp.int32)
    gidx = jnp.arange(g)[:, None]

    def body(_, assign):
        cur = jnp.take_along_axis(aff, assign[..., None], axis=2)[..., 0]
        a = jax.vmap(lambda af, asn: jnp.take(af, asn, axis=1))(aff, assign)
        w = a + jnp.swapaxes(a, 1, 2) - cur[:, :, None] - cur[:, None, :]
        same = assign[:, :, None] == assign[:, None, :]
        w = jnp.where(same, NEG, w)  # same-expert swap is a no-op
        gg = w.max(axis=1)
        bp = jnp.argmax(w, axis=1).astype(jnp.int32)  # best partner per col
        mutual = (jnp.take_along_axis(bp, bp, axis=1) == tvec) \
            & (gg > min_gain) & (tvec < bp)
        swap_with = jnp.where(mutual, bp, tvec)
        swap_with = jnp.concatenate(
            [swap_with, jnp.full((g, 1), t, jnp.int32)], axis=1)
        swap_with = swap_with.at[gidx, jnp.where(mutual, bp, t)].set(
            jnp.where(mutual, tvec, t).astype(jnp.int32)
        )[:, :t]
        swap_with = jnp.where(swap_with == t, tvec[None, :], swap_with)
        return jnp.take_along_axis(assign, swap_with, axis=1)

    return jax.lax.fori_loop(0, rounds, body, assign)


def swap_improve(aff, assign, rounds: int, min_gain=1e-6):
    """Single-group wrapper over ``swap_improve_batched``."""
    return swap_improve_batched(aff[None], assign[None], rounds, min_gain)[0]


def awpm_route_batched(logits, k, capacity_per_round, swap_rounds):
    """Batched AWPM routing (DESIGN.md §4): k rounds of balanced assignment
    + 4-cycle improvement for all G groups in one dispatch; round r penalizes
    experts already used by the token (soft constraint, finite penalty: a
    duplicate expert wastes a slot but stays well-defined — like the paper's
    dropped cycles, rare cases are tolerated rather than paying for an exact
    resolution). logits [G, T, E]. Returns (expert [G,T,k], slot [G,T,k],
    weight [G,T,k], keep(all True), aux(0))."""
    g, t, e = logits.shape
    aff = logits.astype(jnp.float32)
    used = jnp.zeros((g, t, e), bool)
    experts = []
    for _ in range(k):
        a_r = jnp.where(used, aff - 1e6, aff)
        assign = balanced_assign_batched(a_r, capacity_per_round)
        assign = swap_improve_batched(a_r, assign, swap_rounds)
        used = used | jax.nn.one_hot(assign, e, dtype=bool)
        experts.append(assign)
    topi = jnp.stack(experts, axis=2)  # [G, T, k]
    # slots: round r occupies [r*C, (r+1)*C); rank within (expert, round)
    slots = []
    for r in range(k):
        onehot = jax.nn.one_hot(experts[r], e, dtype=jnp.int32)
        ranks = jnp.cumsum(onehot, axis=1) - onehot
        srank = jnp.take_along_axis(ranks, experts[r][..., None],
                                    axis=2)[..., 0]
        slots.append(srank + r * capacity_per_round)
    slot = jnp.stack(slots, axis=2)
    sel_aff = jnp.take_along_axis(aff, topi, axis=2)
    w = jax.nn.softmax(sel_aff, axis=-1).astype(logits.dtype)
    keep = jnp.ones((g, t, k), bool)
    return topi, slot, w, keep, jnp.float32(0.0)


def awpm_route(logits, k, capacity_per_round, swap_rounds):
    """Single-group wrapper over ``awpm_route_batched``. logits [T, E]."""
    topi, slot, w, keep, aux = awpm_route_batched(
        logits[None], k, capacity_per_round, swap_rounds)
    return topi[0], slot[0], w[0], keep[0], aux


def matching_route_batched(logits, k, capacity_per_round, dist_spec=None,
                           max_iter: int = 1000):
    """Exact BASE-layers routing through the core matching engine: each
    round, token -> expert-slot assignment is a heavy-weight perfect
    matching on the dense (token x slot) bipartite graph (slot s belongs to
    expert s // capacity_per_round), solved for ALL G groups in one batched
    ``api.solve`` dispatch — or in one distributed-batched shard_map
    dispatch across the 2D device grid when ``dist_spec`` (a
    ``core.dist.GridSpec`` or Mesh) is present. The distributed path runs
    eagerly (it partitions on the host), so call it outside jit.

    Same contract as ``awpm_route_batched`` (round r penalizes experts the
    token already used; slots of round r occupy [r*C, (r+1)*C)): returns
    (expert [G,T,k], slot [G,T,k], weight [G,T,k], keep(all True), aux(0)).
    Unlike the swap-based router this is the engine's full
    greedy -> MCM -> AWAC pipeline, so per-round assignments admit no
    augmenting 4-cycle at all."""
    from repro.core.api import MatchingProblem, SolveOptions, solve

    g, t, e = logits.shape
    if t != e * capacity_per_round:
        raise ValueError(f"tokens {t} != slots {e * capacity_per_round}")
    aff = logits.astype(jnp.float32)
    used = jnp.zeros((g, t, e), bool)
    tvec = jnp.arange(t, dtype=jnp.int32)
    # dense (token x slot) COO, row-major == lex-sorted by (row, col)
    row = jnp.broadcast_to(jnp.repeat(tvec, t)[None, :], (g, t * t))
    col = jnp.broadcast_to(jnp.tile(tvec, t)[None, :], (g, t * t))
    opts = SolveOptions(max_iter=max_iter, grid=dist_spec)
    rounds = []
    for r in range(k):
        a_r = jnp.where(used, aff - 1e6, aff)
        # val[g, i*t + s] = a_r[g, i, s // C]
        val = jnp.repeat(a_r, capacity_per_round, axis=2).reshape(g, t * t)
        res = solve(MatchingProblem(row=row, col=col, val=val, n=t), opts)
        slot_of = res.mate_col[:, :t].astype(jnp.int32)  # token -> slot
        assign = slot_of // capacity_per_round
        used = used | jax.nn.one_hot(assign, e, dtype=bool)
        # slot uniqueness within (expert, round) comes from the matching
        rounds.append((assign, slot_of % capacity_per_round))
    topi = jnp.stack([a for a, _ in rounds], axis=2)
    slot = jnp.stack(
        [s + r * capacity_per_round for r, (_, s) in enumerate(rounds)],
        axis=2)
    sel_aff = jnp.take_along_axis(aff, topi, axis=2)
    w = jax.nn.softmax(sel_aff, axis=-1).astype(logits.dtype)
    return topi, slot, w, jnp.ones((g, t, k), bool), jnp.float32(0.0)


# --------------------------- dispatch + layer --------------------------------


def _expert_ffn(pe, xe):
    """xe [E, C, d] -> [E, C, d] through per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", xe, pe["gate"].astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, pe["up"].astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      pe["down"].astype(xe.dtype))


def _expert_ffn_grouped(pe, xe):
    """xe [G, E, C, d] -> [G, E, C, d] through per-expert SwiGLU."""
    from repro.models.act_sharding import constrain

    wg = constrain(pe["gate"].astype(xe.dtype), "w_expert")
    wu = constrain(pe["up"].astype(xe.dtype), "w_expert")
    wd = constrain(pe["down"].astype(xe.dtype), "w_expert")
    g = jnp.einsum("gecd,edf->gecf", xe, wg)
    u = jnp.einsum("gecd,edf->gecf", xe, wu)
    return jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, wd)


def moe_apply(p, x, cfg, moe, dist_spec=None):
    """x [B, S, d] -> (y [B, S, d], aux_loss).

    Dispatch is GROUPED: tokens are split into G groups (router_block for the
    AWPM router; dispatch_groups for top-k; G=1 reproduces global dispatch),
    each group routed and scattered into its own [E, C_g, d] buffer. Groups
    shard over the data axes, so dispatch scatters stay shard-local and the
    only cross-device traffic is the expert einsum itself (token <-> expert
    all_to_all under expert parallelism) — §Perf iteration E1.

    With ``dist_spec`` (a ``core.dist.GridSpec``) and the AWPM router, all
    groups route through the distributed-batched matching engine in one
    shard_map dispatch (``matching_route_batched``) — exact BASE-layers
    assignments instead of the swap-improvement approximation. Host path
    only (the distributed engine partitions on the host): call outside
    jit."""
    from repro.models.act_sharding import constrain

    b, s, d = x.shape
    t = b * s
    e, k = moe.n_experts, moe.top_k
    xt = x.reshape(t, d)
    logits = dense(p["router"], xt)

    if moe.router == "awpm":
        gb_sz = min(moe.router_block or t, t)
    else:
        gb_sz = t // max(moe.dispatch_groups, 1) if moe.dispatch_groups else t
    n_g = -(-t // gb_sz)
    tpad = n_g * gb_sz
    logits_g = jnp.zeros((tpad, e), logits.dtype).at[:t].set(logits) \
        .reshape(n_g, gb_sz, e)
    x_g = jnp.zeros((tpad, d), xt.dtype).at[:t].set(xt).reshape(n_g, gb_sz, d)

    if moe.router == "awpm":
        # Block-local AWPM routing (DESIGN.md §4): the swap-gain matrix is
        # [gb, gb] per group, never [T, T]; per-group balance => global.
        # All groups route through ONE batched call — the per-group
        # while_loops run jointly with per-group convergence masks instead
        # of G vmapped dispatch lanes.
        tbp = -(-gb_sz // e) * e
        cap_round = tbp // e
        capacity = k * cap_round

        lgp = jnp.zeros((n_g, tbp, e), logits_g.dtype) \
            .at[:, :gb_sz].set(logits_g)
        if dist_spec is not None:
            ti, sl, ww, _, _ = matching_route_batched(lgp, k, cap_round,
                                                      dist_spec=dist_spec)
        else:
            ti, sl, ww, _, _ = awpm_route_batched(lgp, k, cap_round,
                                                  moe.router_swap_rounds)
        topi, slot, w = (ti[:, :gb_sz], sl[:, :gb_sz],
                         ww[:, :gb_sz])  # [G, gb, k]
        keep = jnp.ones((n_g, gb_sz, k), bool)
        aux = jnp.float32(0.0)
    else:
        capacity = int(moe.capacity_factor * k * gb_sz / e) + 1
        topi, slot, w, keep, aux = jax.vmap(
            lambda l: topk_route(l, k, capacity))(logits_g)
        aux = aux.mean()
    aux = aux * moe.aux_loss_coef

    c = capacity
    flat_idx = jnp.where(keep, topi * c + slot, e * c).reshape(n_g, gb_sz * k)
    src = jnp.repeat(x_g, k, axis=1)  # [G, gb*k, d]

    def disp(fi, xg):
        return jnp.zeros((e * c + 1, d), xt.dtype).at[fi].set(xg)[:-1]

    buf = jax.vmap(disp)(flat_idx, src).reshape(n_g, e, c, d)
    buf = constrain(buf, "moe_buf4")
    ye = constrain(_expert_ffn_grouped(p["experts"], buf), "moe_buf4")
    ye = ye.reshape(n_g, e * c, d)
    gathered = jax.vmap(lambda y, fi: jnp.take(y, jnp.clip(fi, 0, e * c - 1),
                                               axis=0))(ye, flat_idx)
    gathered = jnp.where((flat_idx < e * c)[..., None], gathered, 0.0)
    yt = (gathered.reshape(n_g, gb_sz, k, d)
          * w[..., None].astype(xt.dtype)).sum(axis=2).reshape(tpad, d)[:t]

    if "shared" in p:
        sh = mlp(p["shared"], xt)
        if "shared_gate" in p:
            sh = sh * jax.nn.sigmoid(dense(p["shared_gate"], xt).astype(jnp.float32)
                                     ).astype(xt.dtype)
        yt = yt + sh
    return yt.reshape(b, s, d), aux


def router_stats(logits, topi, n_experts):
    """Diagnostics: per-expert load fractions + mean selected affinity."""
    load = jnp.bincount(topi.reshape(-1), length=n_experts)
    sel = jnp.take_along_axis(logits, topi, axis=1)
    return {"load": load, "mean_affinity": sel.mean(),
            "load_cv": jnp.std(load.astype(jnp.float32))
                       / jnp.maximum(load.mean(), 1e-9)}
