"""GraphSAGE (arXiv:1706.02216): mean aggregator, 2 layers, L2-normalized
hidden states. Works on full graphs and on sampled blocks (minibatch_lg)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.act_sharding import constrain
from repro.models.gnn.common import GraphBatch, aggregate, node_or_graph_loss
from repro.models.layers import dense, dense_def, softmax_xent


def graphsage_def(cfg, d_in: int, n_classes: int):
    d = cfg.d_hidden
    layers = []
    din = d_in
    for _ in range(cfg.n_layers):
        layers.append({
            "self": dense_def(din, d, ("embed", "mlp"), bias=True, bias_axis="mlp"),
            "neigh": dense_def(din, d, ("embed", "mlp"), bias=False),
        })
        din = d
    return {"layers": layers,
            "out": dense_def(d, n_classes, ("mlp", None), bias=True,
                             bias_axis=None)}


def apply(params, gb: GraphBatch, cfg):
    n = gb.node_feat.shape[0]
    h = gb.node_feat
    for lp in params["layers"]:
        neigh = aggregate(jnp.take(h, jnp.clip(gb.edge_src, 0, n - 1), axis=0)
                          * (gb.edge_src < n)[:, None].astype(h.dtype),
                          gb.edge_dst, n, op="mean")
        h = jax.nn.relu(dense(lp["self"], h) + dense(lp["neigh"], neigh))
        h = constrain(
            h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6),
            "nodes")
    return dense(params["out"], h)  # [N, n_classes]


def loss_fn(params, gb: GraphBatch, cfg, mask=None):
    logits = apply(params, gb, cfg)
    if mask is not None and jnp.issubdtype(gb.labels.dtype, jnp.integer):
        return softmax_xent(logits, gb.labels, mask), logits
    return node_or_graph_loss(logits, gb)
