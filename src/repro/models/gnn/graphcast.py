"""GraphCast (arXiv:2212.12794): encoder-processor-decoder mesh GNN.

Grid nodes (n_vars=227 features) -> encoder over grid2mesh edges -> 16
processor message-passing layers on the (refined icosahedral, here: coarse
synthetic) mesh -> decoder over mesh2grid edges -> per-grid-node delta of all
variables. Node/edge MLPs with residuals and sum aggregation, as in the
paper. Mesh topology is supplied by the data pipeline (sizes derive from
mesh_refinement; see data/graphs.py)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.act_sharding import constrain
from repro.models.gnn.common import aggregate, mlp2, mlp2_def


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphCastBatch:
    grid_feat: jnp.ndarray  # [Ng, n_vars]
    g2m_src: jnp.ndarray  # [E1] grid idx
    g2m_dst: jnp.ndarray  # [E1] mesh idx
    mesh_src: jnp.ndarray  # [Em]
    mesh_dst: jnp.ndarray  # [Em]
    m2g_src: jnp.ndarray  # [E2] mesh idx
    m2g_dst: jnp.ndarray  # [E2] grid idx
    target: jnp.ndarray  # [Ng, n_vars]
    n_mesh: int = dataclasses.field(default=4, metadata=dict(static=True))


def graphcast_def(cfg, n_vars: int):
    d = cfg.d_hidden
    proc = [{"edge": mlp2_def(3 * d, d, d), "node": mlp2_def(2 * d, d, d)}
            for _ in range(cfg.n_layers)]
    return {
        "grid_embed": mlp2_def(n_vars, d, d),
        "g2m_edge": mlp2_def(d, d, d),
        "mesh_node_enc": mlp2_def(d, d, d),
        "proc": proc,
        "m2g_edge": mlp2_def(d, d, d),
        "grid_dec": mlp2_def(2 * d, d, n_vars),
    }


def apply(params, gb: GraphCastBatch, cfg):
    ng = gb.grid_feat.shape[0]
    nm = gb.n_mesh
    hg = mlp2(params["grid_embed"], gb.grid_feat)  # [Ng, d]

    # ---- encoder: grid -> mesh
    e1s = jnp.clip(gb.g2m_src, 0, ng - 1)
    msg = mlp2(params["g2m_edge"], jnp.take(hg, e1s, 0))
    msg = msg * (gb.g2m_src < ng)[:, None].astype(msg.dtype)
    hm = aggregate(msg, jnp.where(gb.g2m_src < ng, gb.g2m_dst, nm), nm, "sum")
    hm = mlp2(params["mesh_node_enc"], hm)

    # ---- processor: message passing on the mesh (residual)
    for lp in params["proc"]:
        es = jnp.clip(gb.mesh_src, 0, nm - 1)
        ed = jnp.clip(gb.mesh_dst, 0, nm - 1)
        em = mlp2(lp["edge"], jnp.concatenate(
            [jnp.take(hm, es, 0), jnp.take(hm, ed, 0),
             jnp.take(hm, es, 0) - jnp.take(hm, ed, 0)], axis=-1))
        em = em * (gb.mesh_src < nm)[:, None].astype(em.dtype)
        agg = aggregate(em, jnp.where(gb.mesh_src < nm, gb.mesh_dst, nm), nm,
                        "sum")
        hm = constrain(
            hm + mlp2(lp["node"], jnp.concatenate([hm, agg], axis=-1)),
            "nodes")

    # ---- decoder: mesh -> grid
    e2s = jnp.clip(gb.m2g_src, 0, nm - 1)
    dm = mlp2(params["m2g_edge"], jnp.take(hm, e2s, 0))
    dm = dm * (gb.m2g_src < nm)[:, None].astype(dm.dtype)
    hg2 = aggregate(dm, jnp.where(gb.m2g_src < nm, gb.m2g_dst, ng), ng, "sum")
    delta = mlp2(params["grid_dec"], jnp.concatenate([hg, hg2], axis=-1))
    return gb.grid_feat + delta  # next-state prediction


def loss_fn(params, gb: GraphCastBatch, cfg):
    pred = apply(params, gb, cfg)
    return jnp.mean((pred - gb.target) ** 2), pred
