"""Real neighbor sampler for minibatch training (GraphSAGE-style fixed
fanout). Numpy-side (data pipeline); outputs padded, shape-static blocks."""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class SampledBlocks(NamedTuple):
    """Layered blocks, leaf-to-root. nodes[0] are the deepest sampled nodes;
    nodes[-1] are the seeds. edge lists are (src=child, dst=parent) in LOCAL
    node numbering of the concatenated node list."""

    node_ids: np.ndarray  # [N_total] global ids (with repeats; pad = -1)
    edge_src: np.ndarray  # [E] local idx into node_ids (pad = N_total)
    edge_dst: np.ndarray  # [E]
    seed_offset: int  # seeds live at node_ids[seed_offset:seed_offset+B]
    n_seeds: int


def build_csr(n_nodes: int, src: np.ndarray, dst: np.ndarray):
    """Adjacency (incoming-neighbor) CSR: for each node, its neighbors."""
    order = np.argsort(dst, kind="stable")
    s, d = src[order], dst[order]
    counts = np.bincount(d, minlength=n_nodes)
    ptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    return ptr, s


def sample_blocks(ptr, nbrs, seeds: np.ndarray, fanouts, rng) -> SampledBlocks:
    """Uniform with-replacement fanout sampling, layered (root -> leaves),
    returned leaf-to-root. Nodes with no neighbors self-loop."""
    layers = [np.asarray(seeds, np.int64)]
    for f in fanouts:
        parents = layers[-1]
        deg = ptr[parents + 1] - ptr[parents]
        # with-replacement uniform sample; degree-0 nodes self-loop
        r = rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(parents), f))
        child = nbrs[ptr[parents][:, None] + r]
        child = np.where(deg[:, None] > 0, child, parents[:, None])
        layers.append(child.reshape(-1))
    # local numbering: concatenate leaf-to-root
    layers = layers[::-1]
    node_ids = np.concatenate(layers)
    offsets = np.cumsum([0] + [len(x) for x in layers])
    es, ed = [], []
    # layer L (children) -> layer L+1 (parents); children of parent p are the
    # contiguous f-block at p*f in the child layer
    for li in range(len(layers) - 1):
        child_off, parent_off = offsets[li], offsets[li + 1]
        n_par = len(layers[li + 1])
        f = len(layers[li]) // n_par
        src = child_off + np.arange(n_par * f)
        dst = parent_off + np.repeat(np.arange(n_par), f)
        es.append(src)
        ed.append(dst)
    edge_src = np.concatenate(es).astype(np.int32)
    edge_dst = np.concatenate(ed).astype(np.int32)
    return SampledBlocks(node_ids.astype(np.int64), edge_src, edge_dst,
                         seed_offset=int(offsets[-2]), n_seeds=len(seeds))
