"""GNN substrate: GraphBatch, message passing (segment ops — JAX has no
sparse message passing; built here per the brief), radial/spherical bases.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense, dense_def


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphBatch:
    """Generic padded graph. Edge padding: src = dst = n_nodes (sentinel row
    dropped by segment ops). ``graph_id`` batches small graphs (molecule
    shape); None for single graphs. ``n_graphs`` is static metadata."""

    node_feat: jnp.ndarray  # [N, F]
    edge_src: jnp.ndarray  # [E] int32
    edge_dst: jnp.ndarray  # [E] int32
    labels: jnp.ndarray  # [N] int32 (node class) or [G, n_out] f32
    coords: jnp.ndarray | None = None  # [N, 3]
    graph_id: jnp.ndarray | None = None  # [N] int32 graph membership
    triplets: tuple | None = None  # (edge_kj [P], edge_ji [P]) int32
    n_graphs: int = dataclasses.field(default=1, metadata=dict(static=True))


def aggregate(messages, dst, n_nodes, op="sum"):
    """Scatter-aggregate messages [E, F] to nodes by dst (sentinel = n_nodes)."""
    if op == "sum":
        out = jax.ops.segment_sum(messages, dst, num_segments=n_nodes + 1)
    elif op == "mean":
        s = jax.ops.segment_sum(messages, dst, num_segments=n_nodes + 1)
        cnt = jax.ops.segment_sum(jnp.ones((messages.shape[0], 1), messages.dtype),
                                  dst, num_segments=n_nodes + 1)
        out = s / jnp.maximum(cnt, 1.0)
    elif op == "max":
        out = jax.ops.segment_max(messages, dst, num_segments=n_nodes + 1)
        out = jnp.where(jnp.isneginf(out), 0.0, out)
    else:
        raise ValueError(op)
    return out[:n_nodes]


def mlp2_def(d_in, d_hidden, d_out, axes=("embed", "mlp")):
    return {
        "l1": dense_def(d_in, d_hidden, axes, bias=True, bias_axis="mlp"),
        "l2": dense_def(d_hidden, d_out, (axes[1], axes[0]), bias=True,
                        bias_axis="embed"),
    }


def mlp2(p, x, act=jax.nn.silu):
    return dense(p["l2"], act(dense(p["l1"], x)))


def radial_basis(dist, n_radial: int, cutoff: float = 5.0):
    """DimeNet-style sine radial basis: sin(n pi d / c) / d."""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(dist[..., None], 1e-6)
    return jnp.sin(n * jnp.pi * d / cutoff) / d * jnp.sqrt(2.0 / cutoff)


def _legendre_all(ct, l_max: int):
    """Associated Legendre P_l^m(ct) for 0<=m<=l<=l_max via stable recurrences.
    Returns list P[l][m] of arrays shaped like ct."""
    st = jnp.sqrt(jnp.maximum(1.0 - ct * ct, 0.0))
    P = [[None] * (l_max + 1) for _ in range(l_max + 1)]
    P[0][0] = jnp.ones_like(ct)
    for m in range(1, l_max + 1):
        P[m][m] = -(2 * m - 1) * st * P[m - 1][m - 1]
    for m in range(l_max):
        P[m + 1][m] = (2 * m + 1) * ct * P[m][m]
    for m in range(l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[l][m] = ((2 * l - 1) * ct * P[l - 1][m]
                       - (l + m - 1) * P[l - 2][m]) / (l - m)
    return P


def real_spherical_harmonics(vec, l_max: int):
    """Real SH Y_lm of unit-normalized vec [..., 3] up to l_max.
    Returns [..., (l_max+1)^2] ordered (l, m) with m in [-l..l]."""
    import math

    v = vec / jnp.maximum(jnp.linalg.norm(vec, axis=-1, keepdims=True), 1e-9)
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    ct = z
    phi = jnp.arctan2(y, x)
    P = _legendre_all(ct, l_max)
    out = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            norm = math.sqrt(
                (2 * l + 1) / (4 * math.pi)
                * math.factorial(l - am) / math.factorial(l + am)
            )
            if m == 0:
                out.append(norm * P[l][0])
            elif m > 0:
                out.append(math.sqrt(2.0) * norm * P[l][am] * jnp.cos(am * phi))
            else:
                out.append(math.sqrt(2.0) * norm * P[l][am] * jnp.sin(am * phi))
    return jnp.stack(out, axis=-1)


def node_or_graph_loss(out, gb: GraphBatch):
    """Shared head: int labels -> per-node classification; float labels ->
    per-graph pooled regression (molecule shape)."""
    from repro.models.layers import softmax_xent

    if jnp.issubdtype(gb.labels.dtype, jnp.integer):
        return softmax_xent(out, gb.labels), out
    gid = gb.graph_id if gb.graph_id is not None else jnp.zeros(
        (out.shape[0],), jnp.int32)
    pred = jax.ops.segment_sum(out, gid, num_segments=gb.n_graphs)
    tgt = gb.labels.astype(jnp.float32).reshape(pred.shape)
    return jnp.mean((pred - tgt) ** 2), pred


def sh_degree_index(l_max: int):
    """Per-component degree l and order m arrays of length (l_max+1)^2."""
    ls, ms = [], []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            ls.append(l)
            ms.append(m)
    return np.array(ls, np.int32), np.array(ms, np.int32)
