"""EquiformerV2 (arXiv:2306.12059) — eSCN-style equivariant graph attention.

Node states are irrep features X [N, (l_max+1)^2, C] (l_max=6 -> 49
components, C channels). Per layer (structure follows the paper; the
full Wigner rotation into per-edge frames is simplified to global-frame
SO(2)-restricted mixing — a deliberate fidelity trade recorded here):

  1. edge invariants: radial basis of |r_ij| + per-degree norms of X_i
  2. multi-head attention weights from invariants (n_heads scalar heads)
  3. messages: per-degree channel mix of X_i, modulated per (l, channel) by a
     radial MLP, PLUS spherical-harmonic injection Y_lm(r_ij) ⊗ (channel map
     of the scalar part) — only components with |m| <= m_max participate in
     the mixing (the eSCN O(L^6)->O(L^3) restriction)
  4. segment-sum aggregation, equivariant RMS norm per degree, gated
     nonlinearity (scalar gate per channel from the l=0 part)

Output head: invariant (l=0) features -> node logits / graph energy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.act_sharding import constrain
from repro.models.gnn.common import (
    GraphBatch,
    mlp2,
    mlp2_def,
    radial_basis,
    real_spherical_harmonics,
    sh_degree_index,
)
from repro.models.layers import dense, dense_def
from repro.models.param import ParamDef, dense_init, ones_init

N_RAD = 8


def equiformer_def(cfg, d_in: int, n_out: int):
    c = cfg.d_hidden
    l_max = cfg.opt("l_max", 6)
    n_heads = cfg.opt("n_heads", 8)
    n_l = l_max + 1
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "inv_mlp": mlp2_def(n_l * c + N_RAD, c, c),
            "attn": dense_def(c, n_heads, ("mlp", None), bias=True,
                              bias_axis=None),
            "mix": ParamDef((n_l, c, c), dense_init(c), (None, "embed", "mlp")),
            "rad_scale": dense_def(c, n_l * c, ("mlp", None)),
            "sh_inject": dense_def(c, c, ("embed", "mlp")),
            "gate": dense_def(c, c, ("embed", "mlp"), bias=True, bias_axis=None),
            "norm_scale": ParamDef((n_l, c), ones_init(), (None, None)),
        })
    return {
        "embed": dense_def(d_in, c, ("embed", "mlp"), bias=True, bias_axis="mlp"),
        "layers": layers,
        "head": mlp2_def(c, c, n_out),
    }


def _degree_norm(x, ls_arr, n_l):
    """Per-degree L2 norms: x [N, K, C] -> [N, n_l, C]."""
    sq = jax.ops.segment_sum(
        jnp.moveaxis(x * x, 1, 0), jnp.asarray(ls_arr), num_segments=n_l
    )
    return jnp.sqrt(jnp.moveaxis(sq, 0, 1) + 1e-12)


def apply(params, gb: GraphBatch, cfg):
    n = gb.node_feat.shape[0]
    c = cfg.d_hidden
    l_max = cfg.opt("l_max", 6)
    m_max = cfg.opt("m_max", 2)
    n_l = l_max + 1
    k = n_l * n_l
    ls_arr, ms_arr = sh_degree_index(l_max)

    dt = gb.node_feat.dtype  # compute dtype (bf16 under the gnn_bf16 variant)
    src = jnp.clip(gb.edge_src, 0, n - 1)
    dst = jnp.clip(gb.edge_dst, 0, n - 1)
    evalid = (gb.edge_src < n).astype(dt)
    vec = (jnp.take(gb.coords, dst, axis=0)
           - jnp.take(gb.coords, src, axis=0)).astype(jnp.float32)
    dist = jnp.linalg.norm(vec, axis=-1)
    rbf = radial_basis(dist, N_RAD).astype(dt) * evalid[:, None]
    sh = (real_spherical_harmonics(vec, l_max).astype(dt)
          * evalid[:, None])  # [E, K]

    if cfg.opt("escn_subspace", False):
        # §Perf iteration Q1: carry ONLY the |m| <= m_max components — the
        # eSCN restriction applied to the state itself (the dropped
        # components never interact under the global-frame simplification
        # noted in the module docstring), shrinking every edge
        # gather/message by K/K_sub.
        sel = np.nonzero(np.abs(ms_arr) <= m_max)[0]
        ls_arr, ms_arr = ls_arr[sel], ms_arr[sel]
        k = len(sel)
        sh = sh[:, jnp.asarray(sel)]
    m_ok = jnp.asarray((np.abs(ms_arr) <= m_max)).astype(dt)

    # init: scalar (l=0) part from input features, higher degrees zero
    x = jnp.zeros((n, k, c), gb.node_feat.dtype)
    x = x.at[:, 0, :].set(jax.nn.silu(dense(params["embed"], gb.node_feat)))

    ls_j = jnp.asarray(ls_arr)
    for lp in params["layers"]:
        xi = jnp.take(x, src, axis=0)  # [E, K, C]
        # 1. invariants
        norms = _degree_norm(xi, ls_arr, n_l).reshape(xi.shape[0], -1)
        inv = mlp2(lp["inv_mlp"], jnp.concatenate([norms.astype(dt), rbf],
                                                  axis=-1))
        # 2. attention (per scalar head -> broadcast over channels/heads)
        att = jax.nn.sigmoid(dense(lp["attn"], inv))  # [E, H]
        att = jnp.repeat(att, c // att.shape[-1], axis=-1).astype(dt)  # [E, C]
        # 3. messages: per-degree channel mix, radial modulation, eSCN m-mask
        mixed = jnp.einsum("ekc,kcd->ekd", xi,
                           jnp.take(lp["mix"], ls_j, axis=0).astype(dt))
        scale = dense(lp["rad_scale"], inv).reshape(-1, n_l, c)
        msg = mixed * jnp.take(scale, ls_j, axis=1)
        msg = msg * m_ok[None, :, None]
        # SH injection from the scalar channel map
        inj = dense(lp["sh_inject"], xi[:, 0, :])  # [E, C]
        msg = msg + sh[:, :, None] * inj[:, None, :]
        msg = constrain(msg * att[:, None, :] * evalid[:, None, None],
                        "edges3")
        # 4. aggregate + equivariant norm + gated nonlinearity
        agg = jax.ops.segment_sum(msg, jnp.where(gb.edge_src < n, dst, n),
                                  num_segments=n + 1)[:n]
        x = x + agg
        dn = _degree_norm(x, ls_arr, n_l).astype(dt)  # [N, n_l, C]
        x = x / jnp.take(dn, ls_j, axis=1) * jnp.take(
            lp["norm_scale"], ls_j, axis=0)[None].astype(dt)
        gate = jax.nn.sigmoid(dense(lp["gate"], x[:, 0, :])).astype(dt)
        x = constrain(x * gate[:, None, :], "nodes3")

    return dense(params["head"]["l2"],
                 jax.nn.silu(dense(params["head"]["l1"], x[:, 0, :])))


def loss_fn(params, gb: GraphBatch, cfg):
    from repro.models.gnn.common import node_or_graph_loss

    out = apply(params, gb, cfg)  # [N, n_out]
    return node_or_graph_loss(out, gb)
