"""DimeNet (arXiv:2003.03123): directional message passing with angular
(triplet) features. Structure: RBF edge embedding -> n_blocks interaction
blocks (triplet gather + spherical-radial bilinear layer) -> per-block output
heads summed -> per-graph energy.

Triplets (k->j, j->i) index into the EDGE list (precomputed by the data
pipeline with a fixed capacity; padding index = n_edges)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.act_sharding import constrain
from repro.models.gnn.common import GraphBatch, mlp2, mlp2_def, radial_basis
from repro.models.layers import dense, dense_def
from repro.models.param import ParamDef, dense_init


def dimenet_def(cfg, d_in: int, n_out: int = 1):
    d = cfg.d_hidden
    n_rad = cfg.opt("n_radial", 6)
    n_sph = cfg.opt("n_spherical", 7)
    n_bil = cfg.opt("n_bilinear", 8)
    blocks = []
    for _ in range(cfg.n_layers):
        blocks.append({
            "msg": mlp2_def(d, d, d),
            "rbf_proj": dense_def(n_rad, d, (None, "mlp")),
            "sbf_proj": dense_def(n_sph * n_rad, n_bil, (None, None)),
            "bilinear": ParamDef((n_bil, d, d), dense_init(d), (None, "embed", "mlp")),
            "update": mlp2_def(d, d, d),
            "out": mlp2_def(d, d, n_out),
        })
    return {
        "embed_node": dense_def(d_in, d, ("embed", "mlp"), bias=True,
                                bias_axis="mlp"),
        "embed_edge": dense_def(2 * d + cfg.opt("n_radial", 6), d,
                                (None, "mlp"), bias=True, bias_axis="mlp"),
        "blocks": blocks,
    }


def _angles(gb: GraphBatch):
    """cos(angle) at triplets (k->j, j->i) + distances."""
    n = gb.node_feat.shape[0]
    e = gb.edge_src.shape[0]
    src = jnp.clip(gb.edge_src, 0, n - 1)
    dst = jnp.clip(gb.edge_dst, 0, n - 1)
    vec = jnp.take(gb.coords, dst, axis=0) - jnp.take(gb.coords, src, axis=0)
    dist = jnp.linalg.norm(vec, axis=-1)
    t_kj, t_ji = gb.triplets
    tk = jnp.clip(t_kj, 0, e - 1)
    tj = jnp.clip(t_ji, 0, e - 1)
    v1 = -jnp.take(vec, tk, axis=0)  # j -> k
    v2 = jnp.take(vec, tj, axis=0)  # j -> i
    cos = jnp.sum(v1 * v2, -1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-6
    )
    return dist, cos


def _sbf(cos, dist_kj, n_sph, n_rad, cutoff=5.0):
    """Spherical-radial basis: Chebyshev-in-angle x sine-in-distance."""
    ang = jnp.arccos(jnp.clip(cos, -1.0, 1.0))
    sph = jnp.cos(ang[:, None] * jnp.arange(n_sph, dtype=jnp.float32))
    rad = radial_basis(dist_kj, n_rad, cutoff)
    return (sph[:, :, None] * rad[:, None, :]).reshape(cos.shape[0], -1)


def apply(params, gb: GraphBatch, cfg):
    """Returns per-graph predictions [n_graphs, n_out]."""
    n = gb.node_feat.shape[0]
    e = gb.edge_src.shape[0]
    n_rad = cfg.opt("n_radial", 6)
    n_sph = cfg.opt("n_spherical", 7)
    src = jnp.clip(gb.edge_src, 0, n - 1)
    dst = jnp.clip(gb.edge_dst, 0, n - 1)
    edge_valid = (gb.edge_src < n)[:, None].astype(gb.node_feat.dtype)
    dist, cos = _angles(gb)
    rbf = radial_basis(dist, n_rad)
    h = jax.nn.silu(dense(params["embed_node"], gb.node_feat))
    m = jax.nn.silu(dense(params["embed_edge"], jnp.concatenate(
        [jnp.take(h, src, 0), jnp.take(h, dst, 0), rbf], axis=-1))) * edge_valid

    t_kj, t_ji = gb.triplets
    t_valid = (t_kj < e) & (t_ji < e)
    tk = jnp.clip(t_kj, 0, e - 1)
    tj = jnp.clip(t_ji, 0, e - 1)
    sbf = _sbf(cos, jnp.take(dist, tk), n_sph, n_rad)
    sbf = jnp.where(t_valid[:, None], sbf, 0.0)

    out_sum = None
    for bp in params["blocks"]:
        # triplet messages: m_kj gathered to each (kj, ji) pair
        m_kj = jnp.take(mlp2(bp["msg"], m), tk, axis=0)
        w = dense(bp["sbf_proj"], sbf)  # [P, n_bilinear]
        tri = jnp.einsum("pb,bdf,pd->pf", w, bp["bilinear"], m_kj)
        agg = jax.ops.segment_sum(
            jnp.where(t_valid[:, None], tri, 0.0),
            jnp.where(t_valid, tj, e), num_segments=e + 1)[:e]
        m = constrain(m + jax.nn.silu(
            mlp2(bp["update"], m + agg) + dense(bp["rbf_proj"], rbf))
            * edge_valid, "edges")
        # per-block output: edges -> nodes -> graph
        node_out = jax.ops.segment_sum(mlp2(bp["out"], m), jnp.where(
            gb.edge_src < n, dst, n), num_segments=n + 1)[:n]
        out_sum = node_out if out_sum is None else out_sum + node_out

    return out_sum  # [N, n_out] per-node outputs


def loss_fn(params, gb: GraphBatch, cfg):
    from repro.models.gnn.common import node_or_graph_loss

    out = apply(params, gb, cfg)
    return node_or_graph_loss(out, gb)
