"""Model facade: config -> (param defs, loss fn) for every architecture
family. Used by smoke tests, the trainer, and the dry-run launcher."""
from __future__ import annotations

import importlib

from repro.configs.base import ShapeSpec

# per-shape output dims for GNN node classification (dataset conventions:
# cora=7, reddit=41, ogbn-products=47, molecule=regression)
GNN_OUT = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47,
           "molecule": 1}


def gnn_module(kind: str):
    return importlib.import_module(f"repro.models.gnn.{kind}")


def gnn_out_dim(shape_name: str) -> int:
    return GNN_OUT.get(shape_name, 7)


def build_defs(cfg, shape: ShapeSpec | None = None):
    """Parameter definitions for (cfg, shape). LM/recsys defs are
    shape-independent; GNN defs need the input feature dim + output size."""
    fam = cfg.family
    if fam == "lm":
        from repro.models import transformer

        return transformer.lm_def(cfg)
    if fam == "recsys":
        from repro.models.recsys import bert4rec

        return bert4rec.bert4rec_def(cfg)
    if fam == "gnn":
        assert shape is not None, "GNN defs depend on the shape cell"
        d_feat = shape.d("d_feat", 16)
        if cfg.kind == "graphcast":
            from repro.models.gnn import graphcast

            return graphcast.graphcast_def(cfg, cfg.opt("n_vars", 227))
        mod = gnn_module(cfg.kind)
        n_out = gnn_out_dim(shape.name)
        if cfg.kind == "graphsage":
            return mod.graphsage_def(cfg, d_feat, n_out)
        if cfg.kind == "dimenet":
            return mod.dimenet_def(cfg, d_feat, n_out)
        if cfg.kind == "equiformer_v2":
            return mod.equiformer_def(cfg, d_feat, n_out)
    raise ValueError(f"unknown family {fam}")


def build_loss(cfg):
    """(params, batch) -> (loss, aux). Batch type is family-specific."""
    fam = cfg.family
    if fam == "lm":
        from repro.models import transformer

        return lambda p, b: transformer.loss_fn(p, b, cfg)
    if fam == "recsys":
        from repro.models.recsys import bert4rec

        return lambda p, b: bert4rec.loss_fn(p, b, cfg)
    if fam == "gnn":
        mod = gnn_module(cfg.kind)
        return lambda p, b: mod.loss_fn(p, b, cfg)
    raise ValueError(fam)
