"""Shared neural layers (pure functions over ParamDef trees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef, dense_init, ones_init, zeros_init


# ----------------------------- norms ---------------------------------------


def rmsnorm_def(d, axes=("embed",)):
    return {"scale": ParamDef((d,), ones_init(), axes)}


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def layernorm_def(d, axes=("embed",)):
    return {"scale": ParamDef((d,), ones_init(), axes),
            "bias": ParamDef((d,), zeros_init(), axes)}


def layernorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ----------------------------- dense ----------------------------------------


def dense_def(d_in, d_out, axes, bias=False, bias_axis=None):
    d = {"w": ParamDef((d_in, d_out), dense_init(d_in), axes)}
    if bias:
        d["b"] = ParamDef((d_out,), zeros_init(), (bias_axis,))
    return d


def dense(p, x):
    from repro.models.act_sharding import constrain

    # "w_fsdp" (policy-gated): all-gather the bf16-cast weight over the FSDP
    # axis at use, instead of letting GSPMD all-reduce activations when
    # contracting over the sharded dim (§Perf iteration L1).
    w = constrain(p["w"].astype(x.dtype), "w_fsdp")
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def mlp_def(d, hidden, axes_in=("embed", "mlp"), axes_out=("mlp", "embed"),
            bias=False):
    """SwiGLU MLP (gate/up/down), the Qwen2/LLaMA FFN."""
    return {
        "gate": dense_def(d, hidden, axes_in, bias=bias, bias_axis="mlp"),
        "up": dense_def(d, hidden, axes_in, bias=bias, bias_axis="mlp"),
        "down": dense_def(hidden, d, axes_out, bias=bias, bias_axis="embed"),
    }


def mlp(p, x):
    from repro.models.act_sharding import constrain

    h = constrain(jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x),
                  "mlp_hidden")
    return dense(p["down"], h)


def gelu_mlp_def(d, hidden, axes_in=("embed", "mlp"), axes_out=("mlp", "embed")):
    """GELU MLP with biases (BERT-style, used by bert4rec)."""
    return {
        "up": dense_def(d, hidden, axes_in, bias=True, bias_axis="mlp"),
        "down": dense_def(hidden, d, axes_out, bias=True, bias_axis="embed"),
    }


def gelu_mlp(p, x):
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


# ----------------------------- rope -----------------------------------------


def rope(x, positions, theta=10000.0):
    """x [..., S, H, D]; positions [..., S]. Rotates pairs (d, d + D/2)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ----------------------------- losses ---------------------------------------


def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy over valid positions. logits [..., V], labels [...]"""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
