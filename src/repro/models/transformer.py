"""Decoder-only LM (Qwen2/DeepSeek families): GQA + RoPE + SwiGLU (+ MoE),
scan-over-layers with rematerialization, train/prefill/decode entry points.

Parameters are stacked along a leading layer dimension and consumed by
``lax.scan`` — essential to keep HLO size flat for the 80-layer dry-runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models.act_sharding import constrain
from repro.models.attention import attention_def, decode_attention, self_attention
from repro.models.layers import dense, dense_def, mlp, mlp_def, rmsnorm, rmsnorm_def, softmax_xent
from repro.models.param import ParamDef, embed_init, is_def


def stack_defs(defs, n: int):
    """Lift a block's ParamDefs to stacked per-layer defs (leading dim n)."""

    def lift(d: ParamDef) -> ParamDef:
        def init(key, shape, dtype):
            keys = jax.random.split(key, n)
            return jax.vmap(lambda k: d.init(k, d.shape, dtype))(keys)

        return ParamDef((n, *d.shape), init, (None, *d.axes), d.dtype)

    return jax.tree.map(lift, defs, is_leaf=is_def)


def block_def(cfg, moe_layer: bool, d_ff: int | None = None):
    if moe_layer:
        ffn = moe_lib.moe_def(cfg, cfg.moe)
    else:
        ffn = mlp_def(cfg.d_model, d_ff or cfg.d_ff)
    return {
        "ln1": rmsnorm_def(cfg.d_model),
        "attn": attention_def(cfg),
        "ln2": rmsnorm_def(cfg.d_model),
        "ffn": ffn,
    }


def lm_def(cfg):
    d, v = cfg.d_model, cfg.vocab
    defs = {"embed": ParamDef((v, d), embed_init(0.02), ("vocab", "embed"))}
    md = cfg.moe
    if md is None:
        defs["blocks"] = stack_defs(block_def(cfg, False), cfg.n_layers)
    else:
        if md.first_dense:
            defs["dense_blocks"] = stack_defs(
                block_def(cfg, False, d_ff=md.d_ff_dense or cfg.d_ff),
                md.first_dense,
            )
        defs["moe_blocks"] = stack_defs(
            block_def(cfg, True), cfg.n_layers - md.first_dense
        )
    defs["final_norm"] = rmsnorm_def(d)
    if not cfg.tie_embeddings:
        defs["lm_head"] = dense_def(d, v, ("embed", "vocab"))
    return defs


def _block_apply(bp, x, positions, cfg, moe_layer: bool):
    h, kv = self_attention(bp["attn"], rmsnorm(bp["ln1"], x), positions, cfg)
    x = constrain(x + h, "lm_act")
    hin = rmsnorm(bp["ln2"], x)
    if moe_layer:
        f, aux = moe_lib.moe_apply(bp["ffn"], hin, cfg, cfg.moe)
    else:
        f, aux = mlp(bp["ffn"], hin), jnp.float32(0.0)
    return constrain(x + f, "lm_act"), aux, kv


def _scan_group(blocks, x, positions, cfg, moe_layer, collect_cache):
    def body(carry, bp):
        x, aux = carry
        x2, a, kv = _block_apply(bp, x, positions, cfg, moe_layer)
        ys = kv if collect_cache else None
        return (x2, aux + a), ys

    if cfg.remat:
        body = jax.checkpoint(body)
    if not cfg.scan:  # unrolled (cost-probe path: HLO counts every layer)
        n = jax.tree.leaves(blocks)[0].shape[0]
        aux = jnp.float32(0.0)
        kvs = []
        for i in range(n):
            bp = jax.tree.map(lambda a_: a_[i], blocks)
            (x, aux), kv = body((x, aux), bp)
            kvs.append(kv)
        if collect_cache:
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
        else:
            kvs = None
        return x, aux, kvs
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.float32(0.0)), blocks)
    return x, aux, kvs


def forward(params, tokens, cfg, collect_cache: bool = False):
    """tokens [B, S] -> (logits [B, S, V] f32, aux_loss, cache dict)."""
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = constrain(jnp.take(params["embed"], tokens, axis=0).astype(dtype),
                  "lm_act")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux = jnp.float32(0.0)
    cache = {}
    if cfg.moe is None:
        x, a, kv = _scan_group(params["blocks"], x, positions, cfg, False,
                               collect_cache)
        aux += a
        cache["blocks"] = kv
    else:
        if cfg.moe.first_dense:
            x, a, kv = _scan_group(params["dense_blocks"], x, positions, cfg,
                                   False, collect_cache)
            aux += a
            cache["dense_blocks"] = kv
        x, a, kv = _scan_group(params["moe_blocks"], x, positions, cfg, True,
                               collect_cache)
        aux += a
        cache["moe_blocks"] = kv
    x = rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    else:
        logits = dense(params["lm_head"], x).astype(jnp.float32)
    logits = constrain(logits, "lm_logits")
    return logits, aux, (cache if collect_cache else None)


def loss_fn(params, batch, cfg):
    if cfg.loss_chunks > 1:
        return _chunked_loss_fn(params, batch, cfg)
    logits, aux, _ = forward(params, batch["tokens"], cfg)
    loss = softmax_xent(logits, batch["labels"], batch.get("mask"))
    return loss + aux, {"xent": loss, "aux": aux}


def _chunked_loss_fn(params, batch, cfg):
    """Sequence-chunked cross-entropy (§Perf iteration L2): never
    materializes the full [B, S, V] f32 logits — each S-chunk's logits are
    computed, reduced to (nll_sum, count), and rematerialized in backward."""
    hidden, aux = _hidden(params, batch["tokens"], cfg)
    b, s, d = hidden.shape
    nc = cfg.loss_chunks
    assert s % nc == 0, (s, nc)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    hc = hidden.reshape(b, nc, s // nc, d).swapaxes(0, 1)
    lc = batch["labels"].reshape(b, nc, s // nc).swapaxes(0, 1)
    mc = mask.reshape(b, nc, s // nc).swapaxes(0, 1)

    @jax.checkpoint
    def chunk(h, lab, msk):
        if cfg.tie_embeddings:
            logits = h.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
        else:
            logits = dense(params["lm_head"], h).astype(jnp.float32)
        logits = constrain(logits, "lm_logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return ((lse - ll) * msk).sum(), msk.sum()

    def body(carry, xs):
        tot, cnt = carry
        t, c = chunk(*xs)
        return (tot + t, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hc, lc, mc))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux, {"xent": loss, "aux": aux}


def _hidden(params, tokens, cfg):
    """Forward up to the final norm (no logits)."""
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = constrain(jnp.take(params["embed"], tokens, axis=0).astype(dtype),
                  "lm_act")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux = jnp.float32(0.0)
    if cfg.moe is None:
        x, a, _ = _scan_group(params["blocks"], x, positions, cfg, False, False)
        aux += a
    else:
        if cfg.moe.first_dense:
            x, a, _ = _scan_group(params["dense_blocks"], x, positions, cfg,
                                  False, False)
            aux += a
        x, a, _ = _scan_group(params["moe_blocks"], x, positions, cfg, True,
                              False)
        aux += a
    return rmsnorm(params["final_norm"], x), aux


def prefill(params, tokens, cfg):
    """Returns (last-position logits [B, V], cache). Cache entries are
    (k, v) stacked [L, B, S, Hkv, D] per block group."""
    logits, _, cache = forward(params, tokens, cfg, collect_cache=True)
    return logits[:, -1], cache


def decode_step(params, cache, token, pos, cfg):
    """One decode step. cache: dict group -> (k [L,B,Smax,Hkv,D], v ...);
    token [B, 1] int32; pos scalar int32 (current length). Returns
    (logits [B, V] f32, new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)

    def group(blocks, kc, vc, x, moe_layer):
        def body(x, xs):
            bp, k_l, v_l = xs
            h, k_new, v_new = decode_attention(
                bp["attn"], rmsnorm(bp["ln1"], x), k_l, v_l, pos, cfg
            )
            x = x + h
            hin = rmsnorm(bp["ln2"], x)
            if moe_layer:
                f, _ = moe_lib.moe_apply(bp["ffn"], hin, cfg, cfg.moe)
            else:
                f = mlp(bp["ffn"], hin)
            return x + f, (k_new, v_new)

        if not cfg.scan:  # unrolled cost-probe path
            ks, vs = [], []
            n = jax.tree.leaves(blocks)[0].shape[0]
            for i in range(n):
                bp = jax.tree.map(lambda a_: a_[i], blocks)
                x, (k2, v2) = body(x, (bp, kc[i], vc[i]))
                ks.append(k2)
                vs.append(v2)
            return x, (jnp.stack(ks), jnp.stack(vs))
        x, (kc2, vc2) = jax.lax.scan(body, x, (blocks, kc, vc))
        return x, (kc2, vc2)

    new_cache = {}
    if cfg.moe is None:
        x, new_cache["blocks"] = group(params["blocks"], *cache["blocks"], x, False)
    else:
        if cfg.moe.first_dense:
            x, new_cache["dense_blocks"] = group(
                params["dense_blocks"], *cache["dense_blocks"], x, False
            )
        x, new_cache["moe_blocks"] = group(
            params["moe_blocks"], *cache["moe_blocks"], x, True
        )
    x = rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    else:
        logits = dense(params["lm_head"], x).astype(jnp.float32)
    logits = constrain(logits, "lm_logits")
    return logits[:, 0], new_cache


def cache_shapes(cfg, batch: int, seq: int, groups=True):
    """ShapeDtypeStructs for a decode cache (used by input_specs + serving)."""
    hd = cfg.hd
    dt = jnp.dtype(cfg.dtype)

    def kv(n_layers):
        shp = (n_layers, batch, seq, cfg.n_kv_heads, hd)
        return (jax.ShapeDtypeStruct(shp, dt), jax.ShapeDtypeStruct(shp, dt))

    if cfg.moe is None:
        return {"blocks": kv(cfg.n_layers)}
    out = {}
    if cfg.moe.first_dense:
        out["dense_blocks"] = kv(cfg.moe.first_dense)
    out["moe_blocks"] = kv(cfg.n_layers - cfg.moe.first_dense)
    return out
