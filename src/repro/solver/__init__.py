"""``repro.solver`` — the end-to-end static-pivoting sparse direct solver
(DESIGN.md §12): AWPM matching as pivot order, MC64-style scalings from
dual potentials, dependency-light sparse LU (static or threshold
pivoting, GESP perturbation), and mixed-precision iterative refinement.
Public entry point: :func:`solve_linear_system`.
"""
from repro.solver.lu import CsrMatrix, LUFactorization, LUStats, sparse_lu
from repro.solver.pipeline import (PIVOTING_MODES, SolveReport,
                                   solve_linear_system)
from repro.solver.pivoting import (ScaledPivoting, awpm_pivoting,
                                   from_matching, identity_pivoting,
                                   reference_pivoting)
from repro.solver.refine import RefineResult, lu_solve_once, refine

__all__ = [
    "CsrMatrix",
    "LUFactorization",
    "LUStats",
    "PIVOTING_MODES",
    "RefineResult",
    "ScaledPivoting",
    "SolveReport",
    "awpm_pivoting",
    "from_matching",
    "identity_pivoting",
    "lu_solve_once",
    "refine",
    "reference_pivoting",
    "solve_linear_system",
    "sparse_lu",
]
