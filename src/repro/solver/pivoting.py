"""Matching -> static pivoting: permutation + MC64-style scalings
(DESIGN.md §12).

The point of computing a heavy-weight perfect matching on ``|A|`` is that
it tells a sparse direct solver where its pivots are BEFORE factorization
(the paper's §1 motivation; SuperLU_DIST's use of MC64/AWPM). This module
turns a ``MatchResult`` into the three arrays the solver needs:

- ``row_perm`` — the row permutation placing every matched entry on the
  diagonal (``(P A)[j, j] = A[mate_row[j], j]``);
- ``dr`` / ``dc`` — row/column scaling vectors recovered from the LP-dual
  potentials of ``core.dual`` (via the public
  :meth:`~repro.core.dual.DualCertificate.potentials` accessor).

The scaling recovery is the MC64 identity: with log2-scaled weights
``w_ij = log2|a_ij| - log2(max_i |a_ij|)`` and feasible duals
``u_i + v_j >= w_ij`` (tight on matched edges), setting

  ``dr_i = 2^(-u_i)``,  ``dc_j = 2^(-v_j) / max_i |a_ij|``

gives ``dr_i * |a_ij| * dc_j = 2^(w_ij - u_i - v_j) <= 1`` on EVERY entry,
with equality on matched (tight) edges. After the row permutation the
scaled matrix therefore has unit diagonal entries and everything else at
most 1 in magnitude — exactly the "dominant diagonal" a no-numerical-
pivoting factorization needs. When the certificate is not tight the
matched diagonal lands at ``2^(-slack_j) <= 1`` instead of exactly 1; the
report carries ``scaled_diag_min`` so that degradation is visible, never
silent.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "ScaledPivoting",
    "awpm_pivoting",
    "from_matching",
    "identity_pivoting",
    "reference_pivoting",
]


@dataclasses.dataclass(frozen=True)
class ScaledPivoting:
    """Row permutation + row/col scalings for one n x n system.

    ``row_perm[j]`` is the ORIGINAL row index placed on diagonal position
    j of the permuted matrix. ``certificate`` is the dual certificate the
    scalings were recovered from (None for :func:`identity_pivoting`).
    """

    n: int
    row_perm: np.ndarray  # [n] int64
    dr: np.ndarray  # [n] float64 row scalings (original row order)
    dc: np.ndarray  # [n] float64 column scalings
    certificate: object = None  # DualCertificate | None
    mode: str = "none"

    def __post_init__(self):
        if sorted(self.row_perm.tolist()) != list(range(self.n)):
            raise ValueError(
                f"row_perm is not a permutation of 0..{self.n - 1} — the "
                f"matching must be perfect for static pivoting")

    @property
    def row_position(self) -> np.ndarray:
        """Inverse map: original row i lands at position row_position[i]."""
        pos = np.empty(self.n, np.int64)
        pos[self.row_perm] = np.arange(self.n, dtype=np.int64)
        return pos

    def scaled_coo(self, row, col, val):
        """COO triples of the permuted-scaled matrix
        ``P (D_r A D_c)``: entry (i, j, a) -> (pos[i], j, dr_i * a * dc_j).
        Complex values scale by the real dr/dc and keep their phase."""
        row = np.asarray(row, np.int64)
        col = np.asarray(col, np.int64)
        val = np.asarray(val)
        out_dtype = np.complex128 if np.iscomplexobj(val) else np.float64
        return (self.row_position[row], col,
                val.astype(out_dtype) * self.dr[row] * self.dc[col])

    def scale_rhs(self, b):
        """``b`` of ``A x = b`` -> the permuted-scaled system's RHS
        ``P D_r b`` (last axis is n; leading batch axes pass through)."""
        b = np.asarray(b)
        return (b * self.dr)[..., self.row_perm]

    def unscale_solution(self, y):
        """Solution ``y`` of the permuted-scaled system -> ``x = D_c y``
        solving the original ``A x = b``."""
        return np.asarray(y) * self.dc

    def scaled_diag(self, row, col, val):
        """|diagonal| of the permuted-scaled matrix (== 1 everywhere when
        the certificate is tight) — the honesty metric for how dominant
        the static pivots actually are."""
        pr, pc, pv = self.scaled_coo(row, col, val)
        diag = np.zeros(self.n, np.float64)
        on = pr == pc
        diag[pr[on]] = np.abs(pv[on])
        return diag


def _colmax_abs(col, val, n):
    a = np.abs(np.asarray(val)).astype(np.float64)  # |complex| is real
    if (a == 0.0).any():
        raise ValueError(
            "static pivoting is undefined on explicit zero entries — drop "
            "them first (repro.solver.pipeline does)")
    cmax = np.zeros(n, np.float64)
    np.maximum.at(cmax, np.asarray(col), a)
    return a, cmax


def from_matching(row, col, val, n: int, mate_row,
                  mode: str = "awpm") -> ScaledPivoting:
    """Build the permutation + scalings from a perfect matching on the
    entries' magnitudes. ``val`` may be real or complex; weights and duals
    are computed on ``|val|`` in the MC64 log2-scaled metric, so the
    recovered scalings are exactly the MC64 ones when the matching is
    optimal (tight certificate)."""
    from repro.core.dual import dual_certificate
    from repro.data.weight_transforms import log2_scaled

    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    a, cmax = _colmax_abs(col, val, n)
    w = log2_scaled(row, col, a, n)
    cert = dual_certificate(row, col, w, n, mate_row)
    u, v = cert.potentials()
    dr = np.exp2(-u)
    dc = np.exp2(-v) / np.maximum(cmax, np.finfo(np.float64).tiny)
    perm = np.asarray(mate_row, np.int64).reshape(-1)[:n]
    return ScaledPivoting(n=n, row_perm=perm, dr=dr, dc=dc,
                          certificate=cert, mode=mode)


def identity_pivoting(n: int) -> ScaledPivoting:
    """No pivoting, no scaling — the contrast arm of the experiments."""
    return ScaledPivoting(n=n, row_perm=np.arange(n, dtype=np.int64),
                          dr=np.ones(n), dc=np.ones(n), certificate=None,
                          mode="none")


def awpm_pivoting(row, col, val, n: int, options=None):
    """The production path: AWPM matching on the MC64 log2-scaled
    magnitudes through the ``solve()`` facade, then
    :func:`from_matching`. Returns ``(ScaledPivoting, MatchResult)``."""
    from repro.core.api import MatchingProblem, SolveOptions, solve
    from repro.data.weight_transforms import log2_scaled_nonneg

    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    a = np.abs(np.asarray(val))
    # the engine solves on the non-negative lift (decision-invariant,
    # f32-friendly); the certificate/scalings use the shift-free metric
    w = log2_scaled_nonneg(row, col, a, n)
    problem = MatchingProblem.from_coo(row, col, w, n)
    result = solve(problem, options or SolveOptions())
    mate = np.asarray(result.mate_row)[..., :n]
    return from_matching(row, col, val, n, mate, mode="awpm"), result


def reference_pivoting(row, col, val, n: int):
    """The MC64-style reference arm: EXACT maximum-weight perfect matching
    (scipy Hungarian oracle) on the same log2-scaled magnitudes, then
    :func:`from_matching` — so "AWPM vs reference" isolates the matching
    quality, with identical scaling recovery on both arms. Returns
    ``(ScaledPivoting, mate_row)``."""
    from repro.core import ref
    from repro.data.weight_transforms import log2_scaled

    if not ref.HAVE_SCIPY:
        raise RuntimeError(
            "reference pivoting needs scipy's linear_sum_assignment for "
            "the exact MC64-style matching — use pivoting='awpm' (no "
            "scipy dependency) or install scipy")
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    a = np.abs(np.asarray(val))
    w = log2_scaled(row, col, a, n)
    dense = np.full((n, n), -np.inf, np.float64)
    struct = np.zeros((n, n), bool)
    dense[row, col] = w
    struct[row, col] = True
    dense[~struct] = 0.0
    mate, _ = ref.exact_mwpm(dense, struct)
    return from_matching(row, col, val, n, mate, mode="reference"), mate
