"""End-to-end static-pivoting linear solver (DESIGN.md §12).

``solve_linear_system(A, b, pivoting=...)`` is the repo's answer to "so
does the matching actually help?": it composes every layer built so far —

  preflight (core.preflight, structural audit)
    -> AWPM matching (core.api.solve) or exact reference or nothing
    -> permutation + MC64 scalings from dual potentials (solver.pivoting)
    -> static-pivot sparse LU with GESP perturbation (solver.lu)
    -> f32 triangular solves + f64 iterative refinement (solver.refine)

and returns ONE typed :class:`SolveReport` carrying the full audit trail:
what preflight saw, how dominant the matched diagonal was, how much fill
and pivot growth the factorization paid, the whole refinement residual
trajectory, and the true float64 residual of the returned x against the
ORIGINAL (unscaled, unpermuted) system. The three ``pivoting`` arms are
the experiment of ``results/fill_experiments.py``:

- ``"awpm"`` — the paper's pipeline (approximate matching, static pivots);
- ``"reference"`` — exact MC64-style matching (scipy Hungarian oracle),
  same scaling recovery, isolating matching quality;
- ``"none"`` — no permutation, no scaling: the contrast arm that is
  ALLOWED to fail, and whose failure on ill-conditioned instances is the
  reproduced result.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.solver import pivoting as _pivoting
from repro.solver.lu import CsrMatrix, LUStats, sparse_lu
from repro.solver.refine import RefineResult, refine

__all__ = ["PIVOTING_MODES", "SolveReport", "solve_linear_system"]

PIVOTING_MODES = ("awpm", "none", "reference")


@dataclasses.dataclass(frozen=True)
class SolveReport:
    """Everything one ``solve_linear_system`` call learned.

    ``x`` solves the ORIGINAL ``A x = b`` (scalings/permutations are
    internal); ``residual`` is its true float64 relative residual
    ``||b - A x||_2 / ||b||_2`` per RHS, recomputed from scratch — the
    number the acceptance gate reads, independent of anything the
    refinement loop believed. ``converged`` is ``residual <= tol``.
    """

    x: np.ndarray  # [n] or [B, n]
    pivoting: str
    preflight: object  # core.preflight.PreflightReport
    pivot: _pivoting.ScaledPivoting
    lu_stats: LUStats
    refinement: RefineResult
    residual: np.ndarray  # [B] float64 true relative residuals
    converged: np.ndarray  # [B] bool: residual <= tol
    tol: float
    scaled_diag_min: float  # min |diag| after permute+scale (1.0 ideal)
    matching_weight: float | None = None  # log2-metric weight (awpm/ref)
    matching_tight: bool | None = None  # dual certificate converged

    @property
    def ok(self) -> bool:
        return bool(np.asarray(self.converged).all())

    def summary(self) -> str:
        res = float(np.max(self.residual))
        s = self.lu_stats
        return (f"pivoting={self.pivoting} n={s.n} nnz={s.nnz_in} "
                f"fill={s.fill_ratio:.2f} growth={s.pivot_growth:.3g} "
                f"perturbed={s.perturbed_pivots} "
                f"diag_min={self.scaled_diag_min:.3g} "
                f"sweeps={int(np.max(self.refinement.iterations))} "
                f"residual={res:.3e} "
                f"{'CONVERGED' if self.ok else 'FAILED'}")


def _as_coo(a):
    """Accept a dense [n, n] array, a CsrMatrix, or a (row, col, val, n)
    COO tuple; return deduped, zero-dropped host triples."""
    from repro.sparse.csr import dedupe_coo_sum

    if isinstance(a, CsrMatrix):
        row = np.repeat(np.arange(a.n, dtype=np.int64),
                        np.diff(a.indptr).astype(np.int64))
        col, val, n = np.asarray(a.indices, np.int64), a.data, a.n
    elif isinstance(a, tuple) and len(a) == 4:
        row, col, val, n = a
        row = np.asarray(row, np.int64)
        col = np.asarray(col, np.int64)
        val = np.asarray(val)
        n = int(n)
    else:
        dense = np.asarray(a)
        if dense.ndim != 2 or dense.shape[0] != dense.shape[1]:
            raise ValueError(
                f"A must be square 2-D (or CsrMatrix / (row, col, val, n) "
                f"COO), got shape {dense.shape}")
        row, col = np.nonzero(dense)
        val, n = dense[row, col], dense.shape[0]
    row, col, val = dedupe_coo_sum(row, col, val, n_cols=n)
    keep = val != 0
    dtype = np.complex128 if np.iscomplexobj(val) else np.float64
    return (np.asarray(row[keep], np.int64), np.asarray(col[keep], np.int64),
            np.asarray(val[keep], dtype), n)


def solve_linear_system(a, b, *, pivoting: str = "awpm",
                        lu_mode: str = "static", lu_threshold: float = 0.1,
                        tol: float = 1e-10, max_iter: int = 40,
                        options=None, check: bool = True) -> SolveReport:
    """Solve ``A x = b`` with matching-based static pivoting.

    ``a``: dense square array, :class:`CsrMatrix`, or ``(row, col, val,
    n)`` COO (real or complex; duplicates summed, explicit zeros
    dropped). ``b``: ``[n]`` or batched ``[B, n]``. ``pivoting`` is one
    of :data:`PIVOTING_MODES`; ``lu_mode="threshold"`` swaps the
    factorization to classical threshold partial pivoting (comparison
    arm, any pivoting mode). ``check=False`` downgrades structural
    preflight failures from an exception to a report-carried finding —
    only ``pivoting="none"`` can proceed past one (a matching needs a
    perfect matching to exist).

    Never raises on NUMERICAL failure: a diverged refinement comes back
    as ``report.ok == False`` with the trajectory attached. That is the
    contract ``results/fill_experiments.py`` depends on — the "none" arm
    failing is data, not a crash.
    """
    from repro.core.api import MatchingProblem
    from repro.core.preflight import PreflightError, preflight

    if pivoting not in PIVOTING_MODES:
        raise ValueError(
            f"pivoting must be one of {PIVOTING_MODES}, got {pivoting!r}")
    row, col, val, n = _as_coo(a)
    b = np.asarray(b)
    if b.shape[-1] != n:
        raise ValueError(f"b has width {b.shape[-1]}, matrix order is {n}")

    # preflight the MATCHING view (structure is shared with the linear
    # system: an empty row/col is singular either way)
    problem = MatchingProblem.from_coo(row, col, np.abs(val), n)
    report = preflight(problem)
    if not report.solvable and (check or pivoting != "none"):
        raise PreflightError(report)

    matching_weight = matching_tight = None
    if pivoting == "awpm":
        pivot, result = _pivoting.awpm_pivoting(row, col, val, n,
                                                options=options)
        if not bool(np.asarray(result.perfect).all()):
            raise PreflightError(report, (
                "AWPM did not reach a perfect matching — static pivoting "
                "needs one. Preflight was clean, so this is an engine "
                "limit; try pivoting='reference'."))
    elif pivoting == "reference":
        pivot, _ = _pivoting.reference_pivoting(row, col, val, n)
    else:
        pivot = _pivoting.identity_pivoting(n)
    if pivot.certificate is not None:
        matching_weight = float(pivot.certificate.weight)
        matching_tight = bool(pivot.certificate.tight)

    pr, pc, pv = pivot.scaled_coo(row, col, val)
    diag = pivot.scaled_diag(row, col, val)
    scaled = CsrMatrix.from_coo(pr, pc, pv, n)
    factor = sparse_lu(scaled, mode=lu_mode, threshold=lu_threshold)

    # refine in the scaled frame (that is where the factors live), then
    # map back: A x = b  <=>  (P Dr A Dc) y = P Dr b,  x = Dc y
    sb = pivot.scale_rhs(b)
    refinement = refine(scaled, factor, sb,
                        tol=max(tol * 1e-2, 1e-14), max_iter=max_iter)
    y = refinement.x
    x = pivot.unscale_solution(y)

    # the verdict: true residual against the ORIGINAL system, f64
    acc = np.complex128 if (np.iscomplexobj(val) or np.iscomplexobj(b)) \
        else np.float64
    xb = (x[None, :] if x.ndim == 1 else x).astype(acc)
    bb = (b[None, :] if b.ndim == 1 else b).astype(acc)
    ax_t = np.zeros((n, bb.shape[0]), acc)  # [n, B]: A @ x per lane
    np.add.at(ax_t, row, val[:, None] * xb[:, col].T)
    rr = bb - ax_t.T
    bnorm = np.linalg.norm(bb, axis=-1)
    bnorm = np.where(bnorm == 0.0, 1.0, bnorm)
    residual = np.linalg.norm(rr, axis=-1) / bnorm
    converged = np.isfinite(residual) & (residual <= tol)

    return SolveReport(
        x=x, pivoting=pivoting, preflight=report, pivot=pivot,
        lu_stats=factor.stats, refinement=refinement,
        residual=residual, converged=converged, tol=float(tol),
        scaled_diag_min=float(diag.min()) if n else 1.0,
        matching_weight=matching_weight, matching_tight=matching_tight)
