"""Dependency-light sparse LU with threshold/static pivoting (DESIGN.md §12).

This is deliberately NOT a SuperLU clone — it is the smallest factorization
that makes the paper's claim *measurable*: that a heavy-weight perfect
matching (AWPM/MC64) applied as a **static** row permutation + scaling
replaces numerical pivoting. To measure that we need a factorization that

- can run with numerical pivoting OFF (``mode="static"``: pivots are taken
  from the diagonal as-given, exactly what a distributed solver does after
  committing to the matching-based permutation), and
- tracks the two quantities the sparse-direct literature reports:
  **fill-in** (nnz(L) + nnz(U) vs nnz(A)) and **pivot growth**
  (max|U| / max|A|) — the stability proxy that explodes when static pivots
  are bad and stays O(1) when the matching put the heavy entries on the
  diagonal.

Static mode uses SuperLU's GESP trick: a pivot whose magnitude falls below
``sqrt(eps(dtype)) * max|A|`` is *perturbed* up to that floor (sign/phase
preserved) instead of aborting — the factorization always completes, and
iterative refinement (``repro.solver.refine``) either repairs the
perturbation or diverges, which is the honest, observable failure mode.
``mode="threshold"`` is the classical comparison arm: partial pivoting
that accepts the diagonal when it is within ``threshold`` of the column
max (threshold=1.0 == plain partial pivoting).

Everything is host numpy, rows held as dicts during elimination
(right-looking, values exactly reproducible run-to-run); CSR in/out.
Intended for the fixture/experiment scale (n up to a few thousand), not
for HPC-scale matrices — the measurement, not the speed, is the point.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CsrMatrix", "LUFactorization", "LUStats", "sparse_lu"]

MODES = ("static", "threshold")


@dataclasses.dataclass(frozen=True)
class CsrMatrix:
    """Minimal CSR triple (no scipy dependency). ``data`` is float64 or
    complex128; rows are sorted by column index."""

    n: int
    indptr: np.ndarray  # [n + 1] int64
    indices: np.ndarray  # [nnz] int64
    data: np.ndarray  # [nnz]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n), dtype=self.data.dtype)
        for i in range(self.n):
            lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
            out[i, self.indices[lo:hi]] = self.data[lo:hi]
        return out

    @staticmethod
    def from_coo(row, col, val, n: int) -> "CsrMatrix":
        row = np.asarray(row, np.int64)
        col = np.asarray(col, np.int64)
        val = np.asarray(val)
        order = np.lexsort((col, row))
        row, col, val = row[order], col[order], val[order]
        indptr = np.zeros(n + 1, np.int64)
        np.cumsum(np.bincount(row, minlength=n), out=indptr[1:])
        return CsrMatrix(n=n, indptr=indptr, indices=col,
                         data=np.array(val, copy=True))


@dataclasses.dataclass(frozen=True)
class LUStats:
    """The two headline sparse-direct metrics plus the pivoting audit
    trail. ``fill_ratio`` counts L's implicit unit diagonal once (in U)."""

    n: int
    nnz_in: int
    nnz_l: int  # strict lower triangle of L (unit diag not stored)
    nnz_u: int
    fill_ratio: float  # (nnz_l + nnz_u) / nnz_in
    pivot_growth: float  # max|U| / max|A|
    min_pivot: float  # smallest |pivot| actually used (post-perturbation)
    perturbed_pivots: int  # static mode: pivots bumped to the GESP floor
    swaps: int  # threshold mode: rows moved off the diagonal
    mode: str


@dataclasses.dataclass(frozen=True)
class LUFactorization:
    """``P_internal A = L U`` where ``row_perm[k]`` is the input row
    eliminated at step k (identity in static mode — that is the contract:
    static pivoting commits to the caller's permutation). ``L`` stores the
    strict lower triangle (unit diagonal implicit); ``U`` includes the
    diagonal pivots."""

    L: CsrMatrix
    U: CsrMatrix
    row_perm: np.ndarray  # [n] int64
    stats: LUStats


def _pivot_floor(amax: float, dtype) -> float:
    # GESP perturbation floor: sqrt(eps) of the SOLVE precision times
    # max|A|. The solve runs factors in float32/complex64 downstream, so
    # eps(float32) is the honest scale even though elimination is f64.
    del dtype
    return float(np.sqrt(np.finfo(np.float32).eps)) * amax


def sparse_lu(a: CsrMatrix, mode: str = "static",
              threshold: float = 0.1) -> LUFactorization:
    """Factor ``a`` (square CSR) as ``P A = L U``.

    ``mode="static"``: no row exchanges ever — pivot k is entry (k, k) of
    the matrix AS GIVEN, perturbed up to the GESP floor when too small.
    ``mode="threshold"``: threshold partial pivoting — at step k the
    diagonal row keeps the pivot if ``|a_kk| >= threshold * max_r |a_rk|``,
    else the max row is swapped in; a structurally zero column raises.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if not 0.0 < threshold <= 1.0:
        raise ValueError(f"threshold must be in (0, 1], got {threshold}")
    n = a.n
    complex_in = np.iscomplexobj(a.data)
    work_dtype = np.complex128 if complex_in else np.float64
    amax = float(np.abs(a.data).max()) if a.nnz else 0.0
    if amax == 0.0:
        raise ValueError("cannot factor an all-zero matrix")
    floor = _pivot_floor(amax, work_dtype)

    # rows as dicts {col: val}; `where` is the current position of each
    # original row (threshold swaps permute positions, not data)
    rows = []
    for i in range(n):
        lo, hi = int(a.indptr[i]), int(a.indptr[i + 1])
        rows.append(dict(zip(a.indices[lo:hi].tolist(),
                             a.data[lo:hi].astype(work_dtype).tolist())))
    pos_to_orig = list(range(n))

    l_rows = [dict() for _ in range(n)]  # keyed by ORIGINAL row index
    u_indptr = np.zeros(n + 1, np.int64)
    u_indices, u_data = [], []
    perturbed = swaps = 0
    min_pivot = np.inf
    u_max = 0.0

    for k in range(n):
        if mode == "threshold":
            # column max over the not-yet-eliminated positions
            best_pos, best_mag = -1, 0.0
            for p in range(k, n):
                v = rows[pos_to_orig[p]].get(k)
                if v is not None and abs(v) > best_mag:
                    best_pos, best_mag = p, abs(v)
            if best_pos < 0:
                raise ValueError(
                    f"structurally singular at column {k}: no remaining "
                    f"row has an entry there")
            diag_mag = abs(rows[pos_to_orig[k]].get(k, 0.0))
            if diag_mag < threshold * best_mag:
                pos_to_orig[k], pos_to_orig[best_pos] = \
                    pos_to_orig[best_pos], pos_to_orig[k]
                swaps += 1
        piv_row = pos_to_orig[k]
        work = rows[piv_row]
        pivot = work.get(k, work_dtype(0.0))
        if mode == "threshold":
            # partial pivoting already maximized the pivot: only a
            # genuinely negligible one (f64 round-off scale) is singular
            if abs(pivot) <= n * np.finfo(np.float64).eps * amax:
                raise ValueError(
                    f"numerically singular at step {k}: best pivot "
                    f"{abs(pivot):.3e} is round-off against max|A| "
                    f"{amax:.3e} even with partial pivoting")
        elif abs(pivot) < floor:
            # GESP: bump to the floor, keep sign/phase, count it
            phase = pivot / abs(pivot) if abs(pivot) > 0.0 else 1.0
            pivot = work_dtype(phase * floor)
            work[k] = pivot
            perturbed += 1
        min_pivot = min(min_pivot, abs(pivot))

        # U row k: cols >= k of the pivot row
        u_cols = sorted(c for c in work if c >= k)
        u_indptr[k + 1] = u_indptr[k] + len(u_cols)
        u_indices.extend(u_cols)
        for c in u_cols:
            u_data.append(work[c])
            u_max = max(u_max, abs(work[c]))
        u_row = [(c, work[c]) for c in u_cols if c > k]

        # eliminate col k from every remaining row (right-looking update)
        for p in range(k + 1, n):
            r = pos_to_orig[p]
            tgt = rows[r]
            v = tgt.pop(k, None)
            if v is None:
                continue
            mult = v / pivot
            l_rows[r][k] = mult
            for c, uv in u_row:
                nv = tgt.get(c, work_dtype(0.0)) - mult * uv
                if nv == 0.0:
                    tgt.pop(c, None)  # exact cancellation: drop, keep
                else:  # the fill count value-honest
                    tgt[c] = nv
        rows[piv_row] = {}  # eliminated; free the memory

    # assemble L in elimination order (position space): row k of L holds
    # the multipliers of the row eliminated at step k
    l_indptr = np.zeros(n + 1, np.int64)
    l_indices, l_data = [], []
    for k in range(n):
        lr = l_rows[pos_to_orig[k]]
        cols = sorted(lr)
        l_indptr[k + 1] = l_indptr[k] + len(cols)
        l_indices.extend(cols)
        l_data.extend(lr[c] for c in cols)

    row_perm = np.asarray(pos_to_orig, np.int64)
    L = CsrMatrix(n=n, indptr=l_indptr,
                  indices=np.asarray(l_indices, np.int64),
                  data=np.asarray(l_data, work_dtype))
    U = CsrMatrix(n=n, indptr=u_indptr,
                  indices=np.asarray(u_indices, np.int64),
                  data=np.asarray(u_data, work_dtype))
    stats = LUStats(
        n=n, nnz_in=a.nnz, nnz_l=L.nnz, nnz_u=U.nnz,
        fill_ratio=(L.nnz + U.nnz) / max(a.nnz, 1),
        pivot_growth=u_max / amax,
        min_pivot=float(min_pivot),
        perturbed_pivots=perturbed, swaps=swaps, mode=mode)
    return LUFactorization(L=L, U=U, row_perm=row_perm, stats=stats)
