"""Triangular solves + mixed-precision iterative refinement (DESIGN.md §12).

The paper's pipeline commits to static pivots BEFORE factorization, so the
factorization is cheap-but-approximate and **iterative refinement** is
where accuracy is recovered — or visibly lost, which is the experiment:
AWPM-pivoted systems converge in a handful of sweeps, unpivoted
ill-conditioned systems diverge or stall. This module implements that
loop with the precision split real solvers use:

- the L/U factors are demoted to **float32/complex64** and the triangular
  sweeps run as jnp ``fori_loop`` kernels (the "fast, low-precision
  solve"),
- residuals ``r = b - A x`` are computed in **float64/complex128** host
  numpy against the ORIGINAL sparse matrix (the "accurate residual"),
  and corrections accumulate into a float64 iterate.

That split is what makes the refinement trajectory meaningful: a single
f32 solve lands around 1e-6; refinement against the f64 residual walks it
to ~1e-15 — unless pivot growth destroyed the factors, in which case the
trajectory visibly stalls or explodes. Per-RHS ``converged`` /
``diverged`` / ``stalled`` flags plus the full residual history are
returned, never just a final number.

Batching: the triangular sweeps are written once over ``[B, n]``
right-hand sides; a single RHS is solved as its own B=1 batch of the SAME
kernel (multiply+sum inner products, no shape-dependent blocking), so
batched and single solves agree bit-for-bit lane by lane — asserted by
``tests/test_solver.py``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.solver.lu import CsrMatrix, LUFactorization

__all__ = ["RefineResult", "lu_solve_once", "refine"]


# --------------------------------------------------------------------------
# jnp triangular sweeps (the low-precision inner solver)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=())
def _solve_unit_lower(l_strict, b):
    """x of (I + L_strict) x = b, forward sweep, b: [B, n].

    Row k's inner product is a masked multiply+sum over the full width —
    identical arithmetic for every batch size (no triangular blocking), so
    B=1 and B=8 lanes agree bit-for-bit.
    """
    n = b.shape[-1]

    def body(k, x):
        s = jnp.sum(l_strict[k] * x, axis=-1)
        return x.at[:, k].set(b[:, k] - s)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


@functools.partial(jax.jit, static_argnames=())
def _solve_upper(u_strict, u_diag, b):
    """x of (diag(u_diag) + U_strict) x = b, backward sweep, b: [B, n]."""
    n = b.shape[-1]

    def body(i, x):
        k = n - 1 - i
        s = jnp.sum(u_strict[k] * x, axis=-1)
        return x.at[:, k].set((b[:, k] - s) / u_diag[k])

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def _dense_factors(factor: LUFactorization):
    """Demote the CSR factors to dense f32/c64 sweep operands once."""
    complex_in = np.iscomplexobj(factor.U.data)
    dt = np.complex64 if complex_in else np.float32
    with np.errstate(over="ignore"):  # growth-blown factors overflow f32
        l_strict = factor.L.to_dense().astype(dt)  # on purpose: the inf
        u_strict = factor.U.to_dense().astype(dt)  # surfaces as divergence
    u_diag = np.diag(u_strict).copy()
    np.fill_diagonal(u_strict, 0)
    return jnp.asarray(l_strict), jnp.asarray(u_strict), jnp.asarray(u_diag)


def lu_solve_once(factor: LUFactorization, b: np.ndarray) -> np.ndarray:
    """One low-precision solve ``x ~ A^-1 b`` through the factors
    (applies the factorization's internal row permutation). ``b`` is
    ``[n]`` or ``[B, n]``; the single-RHS form is the B=1 lift."""
    l_strict, u_strict, u_diag = _dense_factors(factor)
    b = np.asarray(b)
    single = b.ndim == 1
    bb = b[None, :] if single else b
    x = _apply_factors(l_strict, u_strict, u_diag, factor.row_perm, bb)
    x = np.asarray(x, dtype=np.complex128 if np.iscomplexobj(u_diag)
                   else np.float64)
    return x[0] if single else x


def _apply_factors(l_strict, u_strict, u_diag, row_perm, b):
    dt = l_strict.dtype
    pb = jnp.asarray(np.asarray(b)[..., row_perm], dtype=dt)
    y = _solve_unit_lower(l_strict, pb)
    return _solve_upper(u_strict, u_diag, y)


# --------------------------------------------------------------------------
# the refinement loop (high-precision residuals, host side)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RefineResult:
    """Outcome of refining a batch of B right-hand sides.

    ``residuals[t, b]`` is lane b's relative residual
    ``||r||_2 / ||rhs||_2`` before iteration t (so ``residuals[0]`` is the
    quality of the raw f32 solve's starting point — all-ones, since x
    starts at 0). Frozen lanes (converged / diverged / stalled) repeat
    their final residual in later rows, keeping the array rectangular.
    """

    x: np.ndarray  # [B, n] float64 / complex128
    residuals: np.ndarray  # [T, B] float64 relative residuals
    iterations: np.ndarray  # [B] int64 — sweeps actually applied per lane
    converged: np.ndarray  # [B] bool
    diverged: np.ndarray  # [B] bool
    stalled: np.ndarray  # [B] bool
    tol: float

    @property
    def all_converged(self) -> bool:
        return bool(self.converged.all())

    @property
    def final_residual(self) -> np.ndarray:
        """[B] — each lane's last recorded relative residual."""
        return self.residuals[-1]


def _csr_matvec(a: CsrMatrix, x: np.ndarray) -> np.ndarray:
    """f64/c128 host matvec ``A @ x`` for x: [B, n] (exact residual path —
    deliberately NOT the f32 device path being refined)."""
    out = np.zeros_like(x)
    for i in range(a.n):
        lo, hi = int(a.indptr[i]), int(a.indptr[i + 1])
        # multiply + pairwise sum over the LAST axis only: accumulation
        # order per lane is independent of B (a BLAS `@` here picks
        # shape-dependent kernels and breaks batched/single bit-equality)
        out[:, i] = np.sum(x[:, a.indices[lo:hi]] * a.data[lo:hi], axis=-1)
    return out


def refine(a: CsrMatrix, factor: LUFactorization, b: np.ndarray, *,
           tol: float = 1e-12, max_iter: int = 40,
           stall_window: int = 3, stall_factor: float = 0.5,
           divergence_factor: float = 1e4) -> RefineResult:
    """Iteratively refine ``A x = b`` through the (possibly perturbed,
    possibly garbage) factors of ``a``.

    ``b`` is ``[n]`` or ``[B, n]``; a single RHS runs as the B=1 lift of
    the batched path and is squeezed on return. Per lane, iteration stops
    on the first of: **converged** (relative residual <= tol),
    **diverged** (residual non-finite, or > divergence_factor x the best
    seen), **stalled** (no ``stall_factor`` improvement across
    ``stall_window`` consecutive sweeps), or ``max_iter``. Frozen lanes
    stop updating — their x is exactly what it was at freeze time — while
    live lanes continue, so one bad RHS never poisons its batch.
    """
    b = np.asarray(b)
    single = b.ndim == 1
    complex_sys = np.iscomplexobj(a.data) or np.iscomplexobj(b)
    acc = np.complex128 if complex_sys else np.float64
    bb = (b[None, :] if single else b).astype(acc)
    B, n = bb.shape
    if n != a.n:
        raise ValueError(f"rhs width {n} != matrix order {a.n}")

    l_strict, u_strict, u_diag = _dense_factors(factor)
    bnorm = np.linalg.norm(bb, axis=-1)
    bnorm = np.where(bnorm == 0.0, 1.0, bnorm)

    x = np.zeros((B, n), acc)
    live = np.ones(B, bool)
    converged = np.zeros(B, bool)
    diverged = np.zeros(B, bool)
    iterations = np.zeros(B, np.int64)
    best = np.full(B, np.inf)
    since_improve = np.zeros(B, np.int64)
    history = []

    for _ in range(max_iter + 1):
        r = bb - _csr_matvec(a, x)
        rel = np.linalg.norm(r, axis=-1) / bnorm
        # frozen lanes keep their freeze-time residual on the record
        if history:
            rel = np.where(live, rel, history[-1])
        history.append(rel)

        hit = live & (rel <= tol)
        converged |= hit
        live &= ~hit
        blown = live & (~np.isfinite(rel) | (rel > divergence_factor *
                                             np.minimum(best, 1.0)))
        diverged |= blown
        live &= ~blown
        improved = rel < stall_factor * best
        since_improve = np.where(improved, 0, since_improve + 1)
        best = np.minimum(best, np.where(np.isfinite(rel), rel, np.inf))
        stalled_now = live & (since_improve >= stall_window)
        live &= ~stalled_now
        if not live.any():
            break

        # one low-precision correction sweep; frozen lanes masked out so
        # their x (and thus their recorded residual) never moves again
        d = np.asarray(
            _apply_factors(l_strict, u_strict, u_diag, factor.row_perm, r),
            dtype=acc)
        d = np.where(np.isfinite(d), d, 0.0)
        x = x + np.where(live[:, None], d, 0.0)
        iterations += live.astype(np.int64)

    stalled = ~(converged | diverged) & (np.asarray(history[-1]) > tol)
    result = RefineResult(
        x=x[0] if single else x,
        residuals=np.asarray(history),
        iterations=iterations,
        converged=converged,
        diverged=diverged,
        stalled=stalled,
        tol=float(tol))
    return result
