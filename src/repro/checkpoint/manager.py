"""Sharded, atomic, resharding-on-restore checkpoint manager.

Layout: <dir>/step_<N>/ holding one .npy per flattened leaf + manifest.json
(tree structure, shapes, dtypes, opt step). Writes go to step_<N>.tmp and are
renamed only after fsync — a crashed save can never corrupt the latest
checkpoint (restart safety for node failures, per the brief).

Restore accepts a DIFFERENT mesh/sharding than the one that saved (elastic
scaling): leaves are loaded as host arrays and re-placed with the target
sharding. An async mode offloads serialization to a worker thread so the
train loop overlaps checkpoint IO with compute.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = False):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread = None

    # ------------------------------ save ---------------------------------

    def save(self, step: int, params, opt_state=None, extra: dict | None = None):
        state = {"params": params}
        if opt_state is not None:
            state["opt"] = opt_state
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {})
            )
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, extra: dict):
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        leaves, treedef = jax.tree.flatten(host_state)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "shapes": [list(x.shape) for x in leaves],
            "dtypes": [str(x.dtype) for x in leaves],
            "extra": extra,
        }
        for i, leaf in enumerate(leaves):
            np.save(tmp / f"leaf_{i:05d}.npy", leaf)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        fd = os.open(tmp, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ----------------------------- restore --------------------------------

    def list_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, like=None, shardings=None):
        """Returns (params, opt_state | None, step). ``like`` is a pytree
        prototype used to rebuild structure; ``shardings`` (same structure)
        re-shards onto the current mesh (elastic restore)."""
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = [np.load(d / f"leaf_{i:05d}.npy")
                  for i in range(manifest["n_leaves"])]
        if like is not None:
            _, treedef = jax.tree.flatten(like)
            state = jax.tree.unflatten(treedef, leaves)
        else:
            raise ValueError("restore requires a `like` prototype tree")
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                state, shardings,
            )
        params = state["params"]
        opt = state.get("opt")
        return params, opt, manifest["step"]

    def restore_latest(self, like=None, shardings=None):
        steps = self.list_steps()
        if not steps:
            return None
        return self.restore(steps[-1], like=like, shardings=shardings)
