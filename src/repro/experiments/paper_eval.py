"""Per-matrix AWPM quality evaluation in the paper's metric (DESIGN.md §8).

The paper's claim is about REAL matrices: AWPM weights "very close to the
optimum" on SuiteSparse instances under MC64 log-scaled weights. This
module is that experiment's harness:

  - cases: checked-in Matrix Market fixtures (``tests/data/*.mtx``,
    loaded through ``repro.data.mtx`` with a per-fixture weight transform)
    plus instances of the synthetic ``core.graph.matrix_suite``;
  - sweep: every case through the ``solve()``/``Matcher`` facade across
    local backends (reference / xla / pallas) and device grids (1x1 in
    process; larger grids in a subprocess with fake host devices, the
    tests/_subproc.py constraint);
  - evidence per (case, engine): matching weight, AWAC iterations, wall
    time, the LP-dual certified ratio bound (``core.dual``), the exact
    ratio when the ``ref.exact_mwpm`` oracle is tractable, and
    bit-identity against the reference backend.

``run_eval`` RAISES on a correctness violation — an unsound certificate
(bound < exact optimum), a backend disagreeing with reference, or an
imperfect matching — so the CI docs job's ``--quick`` smoke is an
executable soundness check, not just a timing pass. Outputs: a per-matrix
markdown table under ``results/`` and ``BENCH_paper_eval.json`` at the
repo root (same row schema as every BENCH file; gated by
``benchmarks/check_regression.py``).
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import pathlib
import re
import subprocess
import sys
import time
from typing import Sequence

import numpy as np

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_FIXTURE_DIR = REPO_ROOT / "tests" / "data"

#: per-fixture weight transform: the paper metric (MC64 log2-scaled, lifted
#: non-negative) where magnitudes span decades; |a_ij| for the symmetric /
#: integer fixtures; pattern files are already unit-weight.
FIXTURE_TRANSFORMS = {
    "circuit8": "log2_scaled_nonneg",
    "bands6_sym": "abs",
    "mesh5_pat": None,
    "count4_int": "abs",
    "illcond9": "log2_scaled_nonneg",
    "zcoil7": "log2_scaled_nonneg",
}

# engines swept: local backends + device grids (grid rows use the Matcher
# plan()-once path with backend "auto")
LOCAL_BACKENDS = ("reference", "xla", "pallas")
GRIDS = ((1, 1), (2, 2))

_ROW_MARK = "PAPER_EVAL_ROW "  # subprocess -> parent protocol


@dataclasses.dataclass(frozen=True)
class EvalCase:
    """One instance to evaluate: a built problem + reporting metadata."""

    name: str
    problem: object  # MatchingProblem, single instance
    source: str  # "fixture" | "synthetic"
    transform: str  # weight metric label for the table
    nnz: int


@dataclasses.dataclass
class EvalRecord:
    """One (case, engine) measurement — a row of the per-matrix table."""

    name: str
    source: str
    transform: str
    engine: str  # "reference" | "xla" | "pallas" | "grid1x1" | "grid2x2"
    n: int
    nnz: int
    weight: float
    upper_bound: float
    ratio_bound: float | None  # certified lower bound on weight/OPT (None: no valid bound)
    ratio_exact: float | None  # vs ref.exact_mwpm when tractable
    tight: bool
    awac_iters: int
    wall_s: float
    perfect: bool
    identical_to_reference: bool
    certified_sound: bool  # bound >= exact optimum (True when no oracle ran)


def fixture_cases(fixture_dir=None) -> list[EvalCase]:
    """Load every checked-in ``.mtx`` fixture with its paper-metric
    transform (unknown files default to ``abs``)."""
    from repro.data.mtx import load_problem

    fixture_dir = pathlib.Path(fixture_dir or DEFAULT_FIXTURE_DIR)
    cases = []
    for path in sorted(fixture_dir.glob("*.mtx")):
        transform = FIXTURE_TRANSFORMS.get(path.stem, "abs")
        problem, coo = load_problem(path, transform=transform)
        cases.append(EvalCase(
            name=path.stem, problem=problem, source="fixture",
            transform=transform or "pattern", nnz=coo.nnz))
    if not cases:
        raise FileNotFoundError(f"no .mtx fixtures under {fixture_dir}")
    return cases


def extra_mtx_cases(paths) -> list[EvalCase]:
    """Cases for out-of-tree ``.mtx`` files (the ``--download``-fetched
    SuiteSparse instances). Unknown stems default to the paper metric
    (``log2_scaled_nonneg``) — these ARE the paper's instances."""
    from repro.data.mtx import load_problem

    cases = []
    for p in paths:
        path = pathlib.Path(p)
        transform = FIXTURE_TRANSFORMS.get(path.stem, "log2_scaled_nonneg")
        problem, coo = load_problem(path, transform=transform)
        cases.append(EvalCase(
            name=path.stem, problem=problem, source="suitesparse",
            transform=transform or "pattern", nnz=coo.nnz))
    return cases


def synthetic_cases(count: int = 10, n: int = 96,
                    transform=None) -> list[EvalCase]:
    """A slice of the synthetic suite (already §6.1-normalized; pass
    ``transform`` to re-measure it in another metric, e.g. the paper's
    log2-scaled one)."""
    from repro.core.api import MatchingProblem
    from repro.core.graph import matrix_suite
    from repro.data.weight_transforms import get_transform

    cases = []
    for name, g in matrix_suite(n_matrices=count, n=n):
        nnz = g.nnz
        if transform is None:
            problem = MatchingProblem.from_graph(g)
            label = "rowcol"
        else:
            mask = np.arange(g.capacity) < g.nnz
            row, col = g.row[mask], g.col[mask]
            val = get_transform(transform)(row, col, g.val[mask], g.n)
            problem = MatchingProblem.from_coo(row, col, val, g.n)
            label = transform if isinstance(transform, str) else "custom"
        cases.append(EvalCase(name=name, problem=problem, source="synthetic",
                              transform=label, nnz=nnz))
    return cases


def _exact_optimum(case: EvalCase):
    """ref.exact_mwpm on a densified instance, or None when intractable."""
    from repro.core import ref

    if not ref.HAVE_SCIPY:
        return None
    p = case.problem
    n = p.n
    row = np.asarray(p.row)
    col = np.asarray(p.col)
    val = np.asarray(p.val)
    m = (row < n) & (col < n)
    dense = np.zeros((n, n), np.float32)
    struct = np.zeros((n, n), bool)
    dense[row[m], col[m]] = val[m]
    struct[row[m], col[m]] = True
    _, opt = ref.exact_mwpm(dense, struct)
    return float(opt)


def _record(case: EvalCase, engine: str, res, wall_s: float, opt,
            ref_mate, tol: float = 1e-5) -> EvalRecord:
    from repro.core.dual import certify

    cert = certify(case.problem, res)
    mate = np.asarray(res.mate_row)
    identical = bool(np.array_equal(mate, ref_mate)) if ref_mate is not None \
        else True
    scale = max(1.0, abs(opt)) if opt is not None else 1.0
    sound = True if opt is None else \
        bool(cert.upper_bound >= opt - tol * scale)
    ratio_exact = None if opt in (None, 0.0) else float(cert.weight / opt)
    return EvalRecord(
        name=case.name, source=case.source, transform=case.transform,
        engine=engine, n=case.problem.n, nnz=case.nnz,
        weight=float(cert.weight), upper_bound=float(cert.upper_bound),
        ratio_bound=cert.ratio_bound_or(None), ratio_exact=ratio_exact,
        tight=bool(cert.tight), awac_iters=int(np.asarray(res.awac_iters)),
        wall_s=float(wall_s), perfect=bool(np.asarray(res.perfect)),
        identical_to_reference=identical, certified_sound=sound)


def _check(rec: EvalRecord) -> None:
    problems = []
    if not rec.perfect:
        problems.append("matching is not perfect")
    if not rec.certified_sound:
        problems.append(
            f"UNSOUND certificate: upper_bound={rec.upper_bound:.6f} < "
            f"exact optimum")
    if not rec.identical_to_reference:
        problems.append("result differs from the reference backend")
    if problems:
        raise AssertionError(
            f"paper_eval {rec.name} [{rec.engine}]: " + "; ".join(problems))


def _case_aux(case: EvalCase, oracle_max_n: int) -> tuple:
    """The per-case comparison baseline, computed ONCE per sweep: the exact
    optimum (when tractable) and the reference-backend mates every other
    engine must match bit-for-bit — even when 'reference' is not itself in
    the swept backends, so identical_to_reference is always a real
    comparison, never a default."""
    from repro.core.api import SolveOptions, solve

    opt = _exact_optimum(case) if case.problem.n <= oracle_max_n else None
    ref_res = solve(case.problem, SolveOptions(backend="reference"))
    return opt, np.asarray(ref_res.mate_row)


def _eval_local(case: EvalCase, backends: Sequence[str],
                aux: tuple) -> list[EvalRecord]:
    from repro.core.api import SolveOptions, solve

    opt, ref_mate = aux
    records = []
    for backend in backends:
        opts = SolveOptions(backend=backend)
        solve(case.problem, opts)  # warmup: compile outside the timing
        t0 = time.perf_counter()
        res = solve(case.problem, opts)
        np.asarray(res.mate_row)  # materialize before stopping the clock
        wall = time.perf_counter() - t0
        rec = _record(case, backend, res, wall, opt, ref_mate)
        _check(rec)
        records.append(rec)
    return records


def _cases_from_spec(spec: dict) -> list[EvalCase]:
    """Build the case list from a JSON-able spec — the same dict drives the
    in-process sweep and the fake-device subprocess, so both sides hold the
    identical (deterministic) case list."""
    cases = []
    if spec.get("fixtures", True):
        cases += fixture_cases(spec.get("fixture_dir"))
    if spec.get("extra_mtx"):
        cases += extra_mtx_cases(spec["extra_mtx"])
    if spec.get("synthetic_count", 0):
        cases += synthetic_cases(spec["synthetic_count"],
                                 spec.get("synthetic_n", 96),
                                 spec.get("synthetic_transform"))
    keep = spec.get("names")
    if keep is not None:
        cases = [c for c in cases if c.name in set(keep)]
    return cases


def _eval_grid(cases: Sequence[EvalCase], spec: dict, grid: tuple[int, int],
               oracle_max_n: int, aux_by_name: dict) -> list[EvalRecord]:
    """One grid's rows for every case. In-process when enough devices are
    attached (reusing the sweep's per-case oracle/reference baselines),
    else one subprocess with fake host devices (the
    ``--xla_force_host_platform_device_count`` must-precede-jax rule;
    baselines are recomputed child-side)."""
    import jax

    pr, pc = grid
    if pr * pc <= jax.device_count():
        return _eval_grid_inproc(cases, grid, oracle_max_n, aux_by_name)
    return _eval_grid_subproc(spec, grid, oracle_max_n, n_cases=len(cases))


def _eval_grid_inproc(cases, grid, oracle_max_n, aux_by_name=None):
    import jax

    from repro.core.api import SolveOptions, plan
    from repro.core.dist import make_mesh

    pr, pc = grid
    mesh = make_mesh((pr, pc))
    engine = f"grid{pr}x{pc}"
    records = []
    for case in cases:
        opt, ref_mate = (aux_by_name or {}).get(case.name) or \
            _case_aux(case, oracle_max_n)
        matcher = plan(case.problem, SolveOptions(grid=mesh))
        matcher(case.problem)  # warmup: partition + compile
        t0 = time.perf_counter()
        res = matcher(case.problem)
        jax.block_until_ready(res.mate_row)
        wall = time.perf_counter() - t0
        rec = _record(case, engine, res, wall, opt, ref_mate)
        _check(rec)
        records.append(rec)
    return records


_CHILD_SCRIPT = r"""
import json, sys
sys.path.insert(0, {src!r})
from repro.experiments import paper_eval

records = paper_eval._eval_grid_inproc(
    paper_eval._cases_from_spec(json.loads({spec!r})), {grid!r},
    {oracle_max_n!r})
for r in records:
    print({mark!r} + json.dumps(r.__dict__), flush=True)
"""


def _eval_grid_subproc(spec, grid, oracle_max_n, n_cases):
    pr, pc = grid
    script = _CHILD_SCRIPT.format(
        src=str(REPO_ROOT / "src"), spec=json.dumps(spec), grid=tuple(grid),
        oracle_max_n=oracle_max_n, mark=_ROW_MARK)
    env = dict(os.environ)
    # strip any inherited device-count token entirely — XLA aborts on
    # unknown flags, so the stale token can't just be renamed
    inherited = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                       env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={pr * pc} {inherited}"
    ).strip()
    env.setdefault("PYTHONPATH", str(REPO_ROOT / "src"))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"grid {pr}x{pc} subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    records = []
    for line in proc.stdout.splitlines():
        if line.startswith(_ROW_MARK):
            records.append(EvalRecord(**json.loads(line[len(_ROW_MARK):])))
    if len(records) != n_cases:
        raise RuntimeError(
            f"grid {pr}x{pc} subprocess returned {len(records)} rows for "
            f"{n_cases} cases\n--- stdout ---\n{proc.stdout}")
    return records


DEFAULT_SPEC = {"fixtures": True, "synthetic_count": 10, "synthetic_n": 96}
QUICK_SPEC = {"fixtures": True, "synthetic_count": 3, "synthetic_n": 48}


def run_eval(spec: dict | None = None,
             backends: Sequence[str] = LOCAL_BACKENDS,
             grids: Sequence[tuple[int, int]] = GRIDS,
             oracle_max_n: int = 256) -> list[EvalRecord]:
    """The full sweep: every case in ``spec`` (see :func:`_cases_from_spec`;
    default :data:`DEFAULT_SPEC`) x (local ``backends`` + device ``grids``).
    Raises on any soundness / bit-identity / perfection violation (see
    module docstring)."""
    spec = dict(DEFAULT_SPEC if spec is None else spec)
    cases = _cases_from_spec(spec)
    aux_by_name = {c.name: _case_aux(c, oracle_max_n) for c in cases}
    records = []
    for case in cases:
        records += _eval_local(case, backends, aux_by_name[case.name])
    for grid in grids:
        records += _eval_grid(cases, spec, grid, oracle_max_n, aux_by_name)
    return records


# --------------------------------------------------------------------------
# outputs: per-matrix markdown table + BENCH_paper_eval.json
# --------------------------------------------------------------------------


def _fmt_ratio(x) -> str:
    # None: dual.bound_valid was False (no certified ratio); NaN can no
    # longer reach here — DualCertificate.ratio_bound raises instead.
    if x is None or x != x:
        return "-"
    return f"{x:.4f}"


def to_markdown(records: Sequence[EvalRecord]) -> str:
    lines = [
        "# Paper evaluation: AWPM quality per matrix",
        "",
        "Generated by `experiments/run_paper_eval.py` (DESIGN.md §8). "
        "`ratio>=` is the LP-dual certified lower bound on weight/OPT "
        "(tight=True: certified optimal); `ratio` is vs the exact oracle "
        "where tractable.",
        "",
        "| matrix | src | metric | engine | n | nnz | weight | bound "
        "| ratio>= | ratio | tight | iters | ms |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        lines.append(
            f"| {r.name} | {r.source} | {r.transform} | {r.engine} "
            f"| {r.n} | {r.nnz} | {r.weight:.4f} | {r.upper_bound:.4f} "
            f"| {_fmt_ratio(r.ratio_bound)} | {_fmt_ratio(r.ratio_exact)} "
            f"| {r.tight} | {r.awac_iters} | {r.wall_s * 1e3:.1f} |")
    return "\n".join(lines) + "\n"


def to_bench_rows(records: Sequence[EvalRecord]) -> list[dict]:
    """BENCH row schema (name / us_per_call / derived) with the
    ``certified_sound`` / ``identical_to_reference`` flags
    ``benchmarks/check_regression.py`` gates on."""
    rows = []
    for r in records:
        derived = (
            f"weight={r.weight:.4f};bound={r.upper_bound:.4f};"
            f"ratio_bound={_fmt_ratio(r.ratio_bound)};"
            f"iters={r.awac_iters};tight={r.tight};"
            f"certified_sound={r.certified_sound};"
            f"identical_to_reference={r.identical_to_reference}")
        if r.ratio_exact is not None:
            derived += f";ratio_exact={r.ratio_exact:.4f}"
        rows.append({"name": f"paper_eval_{r.name}_{r.engine}",
                     "us_per_call": round(r.wall_s * 1e6, 1),
                     "derived": derived})
    return rows


def write_outputs(records: Sequence[EvalRecord], wall_clock_s: float,
                  out_dir=None, bench_path=None, quick: bool = False):
    """Persist the markdown table (results/) + BENCH_paper_eval.json."""
    import jax

    out_dir = pathlib.Path(out_dir or REPO_ROOT / "results")
    out_dir.mkdir(parents=True, exist_ok=True)
    table = out_dir / "paper_eval.md"
    table.write_text(to_markdown(records))
    rec = {
        "suite": "paper_eval",
        "ok": True,
        "wall_clock_s": round(wall_clock_s, 3),
        "rows": to_bench_rows(records),
        "metadata": {
            "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "quick": quick,
        },
    }
    bench_path = pathlib.Path(bench_path or REPO_ROOT / "BENCH_paper_eval.json")
    bench_path.write_text(json.dumps(rec, indent=1))
    return table, bench_path
