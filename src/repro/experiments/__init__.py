"""Real-matrix evaluation subsystem (DESIGN.md §8): Matrix Market fixtures
+ the synthetic suite, swept across backends and device grids through the
``solve()``/``Matcher`` facade, with LP-dual certified approximation-ratio
bounds. CLI entry point: ``experiments/run_paper_eval.py``."""
from repro.experiments.paper_eval import (
    DEFAULT_SPEC,
    QUICK_SPEC,
    EvalCase,
    EvalRecord,
    fixture_cases,
    run_eval,
    synthetic_cases,
    write_outputs,
)

__all__ = [
    "DEFAULT_SPEC",
    "QUICK_SPEC",
    "EvalCase",
    "EvalRecord",
    "fixture_cases",
    "run_eval",
    "synthetic_cases",
    "write_outputs",
]
