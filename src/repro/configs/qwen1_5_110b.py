"""qwen1.5-110b [hf:Qwen/Qwen1.5-110B]: 80L d_model=8192 64H (GQA kv=8)
d_ff=49152 vocab=152064 — QKV bias."""
from repro.configs.base import LMConfig


def config():
    return LMConfig("qwen1.5-110b", n_layers=80, d_model=8192, n_heads=64,
                    n_kv_heads=8, d_ff=49152, vocab=152064, head_dim=128,
                    qkv_bias=True, rope_theta=1e6)


def reduced():
    return LMConfig("qwen1.5-110b-smoke", n_layers=3, d_model=96, n_heads=8,
                    n_kv_heads=2, d_ff=256, vocab=512, head_dim=16,
                    qkv_bias=True, dtype="float32")
