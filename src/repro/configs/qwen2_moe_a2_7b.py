"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d_model=2048 16H
d_ff(expert)=1408 vocab=151936, 60 routed top-4 + 4 shared (gated).
``router`` selects the paper-faithful top-k baseline or the AWPM router
(the paper's matching technique; DESIGN.md §4)."""
from repro.configs.base import LMConfig, MoECfg


def config(router: str = "topk"):
    return LMConfig("qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
                    n_kv_heads=16, d_ff=5632, vocab=151936, head_dim=128,
                    qkv_bias=True, rope_theta=1e6,
                    moe=MoECfg(n_experts=60, top_k=4, d_ff_expert=1408,
                               n_shared=4, d_ff_shared=5632, shared_gate=True,
                               router=router))


def reduced(router: str = "topk"):
    return LMConfig("qwen2-moe-a2.7b-smoke", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=4, d_ff=128, vocab=512, head_dim=16,
                    qkv_bias=True, dtype="float32",
                    moe=MoECfg(n_experts=6, top_k=4, d_ff_expert=32,
                               n_shared=2, d_ff_shared=64, shared_gate=True,
                               router=router))
