"""graphsage-reddit [arXiv:1706.02216]: 2 layers, d_hidden=128, mean
aggregator, sample sizes 25-10."""
from repro.configs.base import GNNConfig


def config():
    return GNNConfig("graphsage-reddit", "graphsage", n_layers=2, d_hidden=128,
                     extra=(("aggregator", "mean"), ("sample_sizes", (25, 10))))


def reduced():
    return GNNConfig("graphsage-reddit-smoke", "graphsage", n_layers=2,
                     d_hidden=16,
                     extra=(("aggregator", "mean"), ("sample_sizes", (5, 3))))
