"""qwen2-7b [arXiv:2407.10671]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias."""
from repro.configs.base import LMConfig


def config():
    return LMConfig("qwen2-7b", n_layers=28, d_model=3584, n_heads=28,
                    n_kv_heads=4, d_ff=18944, vocab=152064, head_dim=128,
                    qkv_bias=True, rope_theta=1e6)


def reduced():
    return LMConfig("qwen2-7b-smoke", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=192, vocab=512, head_dim=16,
                    qkv_bias=True, dtype="float32")
