"""bert4rec [arXiv:1904.06690]: embed_dim=64, 2 blocks, 2 heads, seq_len=200,
bidirectional sequence encoder; 1M-item table for the retrieval cell."""
from repro.configs.base import RecSysConfig


def config():
    return RecSysConfig("bert4rec", "bert4rec", embed_dim=64, n_blocks=2,
                        n_heads=2, seq_len=200, n_items=1_000_000)


def reduced():
    return RecSysConfig("bert4rec-smoke", "bert4rec", embed_dim=16, n_blocks=2,
                        n_heads=2, seq_len=16, n_items=500)
