"""The paper's own 'architecture': distributed AWPM matching itself, as a
dry-runnable + roofline-analyzable config (beyond the 10 assigned archs)."""
from repro.configs.base import MatchingConfig


def config():
    return MatchingConfig("awpm-matching", n=4_194_304, avg_degree=16)


def reduced():
    return MatchingConfig("awpm-matching-smoke", n=128, avg_degree=5)
