"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    GNNConfig,
    LMConfig,
    MatchingConfig,
    MoECfg,
    RecSysConfig,
    ShapeSpec,
    shapes_for,
)

_MODULES = {
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen2-7b": "qwen2_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "graphsage-reddit": "graphsage_reddit",
    "equiformer-v2": "equiformer_v2",
    "dimenet": "dimenet",
    "graphcast": "graphcast",
    "bert4rec": "bert4rec",
    "awpm-matching": "awpm_paper",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "awpm-matching")
ALL_ARCHS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False, **kw):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return (mod.reduced(**kw) if reduced else mod.config(**kw))


def list_archs():
    return ALL_ARCHS
