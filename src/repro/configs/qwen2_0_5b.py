"""qwen2-0.5b [arXiv:2407.10671]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias, tied embeddings."""
from repro.configs.base import LMConfig


def config():
    return LMConfig("qwen2-0.5b", n_layers=24, d_model=896, n_heads=14,
                    n_kv_heads=2, d_ff=4864, vocab=151936, head_dim=64,
                    qkv_bias=True, tie_embeddings=True, rope_theta=1e6)


def reduced():
    return LMConfig("qwen2-0.5b-smoke", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
                    qkv_bias=True, tie_embeddings=True, dtype="float32")
