"""equiformer-v2 [arXiv:2306.12059]: 12 layers, d_hidden=128, l_max=6,
m_max=2, 8 heads, SO(2)-eSCN equivariant graph attention."""
from repro.configs.base import GNNConfig


def config():
    return GNNConfig("equiformer-v2", "equiformer_v2", n_layers=12, d_hidden=128,
                     extra=(("l_max", 6), ("m_max", 2), ("n_heads", 8)))


def reduced():
    return GNNConfig("equiformer-v2-smoke", "equiformer_v2", n_layers=2,
                     d_hidden=16, extra=(("l_max", 2), ("m_max", 1),
                                         ("n_heads", 4)))
