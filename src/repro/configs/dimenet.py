"""dimenet [arXiv:2003.03123]: 6 blocks, d_hidden=128, n_bilinear=8,
n_spherical=7, n_radial=6 — directional (triplet) message passing."""
from repro.configs.base import GNNConfig


def config():
    return GNNConfig("dimenet", "dimenet", n_layers=6, d_hidden=128,
                     extra=(("n_bilinear", 8), ("n_spherical", 7),
                            ("n_radial", 6)))


def reduced():
    return GNNConfig("dimenet-smoke", "dimenet", n_layers=2, d_hidden=16,
                     extra=(("n_bilinear", 4), ("n_spherical", 3),
                            ("n_radial", 4)))
