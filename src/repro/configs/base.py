"""Config dataclasses for all architecture families + shape specs."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    router: str = "topk"  # "topk" (paper-faithful baseline) | "awpm" (ours)
    capacity_factor: float = 1.25
    first_dense: int = 0  # leading dense layers (deepseek-moe style)
    d_ff_dense: int = 0  # hidden of the leading dense layers
    shared_gate: bool = False  # sigmoid gate on shared expert (qwen2-moe)
    router_swap_rounds: int = 4  # AWPM router 4-cycle improvement rounds
    router_block: int = 2048  # AWPM routing block (per-shard granularity)
    dispatch_groups: int = 0  # top-k grouped dispatch (0 = global, baseline)
    aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = True
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    moe: MoECfg | None = None
    dtype: str = "bfloat16"
    remat: bool = True
    scan: bool = True  # scan-over-layers; False unrolls (cost-probe path)
    loss_chunks: int = 0  # sequence-chunked xent (0 = full logits buffer)
    attention_impl: str = "xla"  # "xla" | "pallas"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def family(self) -> str:
        return "lm"


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # graphsage | dimenet | equiformer_v2 | graphcast
    n_layers: int
    d_hidden: int
    extra: tuple[tuple[str, Any], ...] = ()
    dtype: str = "float32"
    remat: bool = True

    def opt(self, key, default=None):
        return dict(self.extra).get(key, default)

    @property
    def family(self) -> str:
        return "gnn"


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str  # bert4rec
    embed_dim: int
    n_blocks: int
    n_heads: int
    seq_len: int
    n_items: int = 1_000_000
    d_ff_mult: int = 4
    dtype: str = "float32"

    @property
    def padded_items(self) -> int:
        """Item-table rows (n_items + mask + pad), rounded up so the
        row-sharded table divides any mesh axis product up to 512."""
        return -(-(self.n_items + 2) // 512) * 512

    @property
    def family(self) -> str:
        return "recsys"


@dataclasses.dataclass(frozen=True)
class MatchingConfig:
    """The paper's own 'architecture': distributed AWPM on a sparse matrix."""

    name: str
    n: int
    avg_degree: float
    kind: str = "uniform"
    max_iter: int = 64
    a2a_slack: float = 2.0

    @property
    def family(self) -> str:
        return "matching"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell. Interpretation depends on the family:
    lm:      seq_len, global_batch; mode train|prefill|decode
    gnn:     n_nodes, n_edges, d_feat, batch_nodes/fanout (sampled), batch
    recsys:  batch, n_candidates
    """

    name: str
    mode: str
    dims: tuple[tuple[str, int], ...]

    def d(self, key, default=0) -> int:
        return dict(self.dims).get(key, default)


LM_SHAPES = (
    ShapeSpec("train_4k", "train", (("seq_len", 4096), ("global_batch", 256))),
    ShapeSpec("prefill_32k", "prefill", (("seq_len", 32768), ("global_batch", 32))),
    ShapeSpec("decode_32k", "decode", (("seq_len", 32768), ("global_batch", 128))),
    ShapeSpec("long_500k", "decode", (("seq_len", 524288), ("global_batch", 1))),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "train",
              (("n_nodes", 2708), ("n_edges", 10556), ("d_feat", 1433))),
    ShapeSpec("minibatch_lg", "train",
              (("n_nodes", 232965), ("n_edges", 114615892), ("batch_nodes", 1024),
               ("fanout1", 15), ("fanout2", 10), ("d_feat", 602))),
    ShapeSpec("ogb_products", "train",
              (("n_nodes", 2449029), ("n_edges", 61859140), ("d_feat", 100))),
    ShapeSpec("molecule", "train",
              (("n_nodes", 30), ("n_edges", 64), ("batch", 128), ("d_feat", 16))),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", (("batch", 65536),)),
    ShapeSpec("serve_p99", "serve", (("batch", 512),)),
    ShapeSpec("serve_bulk", "serve", (("batch", 262144),)),
    ShapeSpec("retrieval_cand", "retrieval",
              (("batch", 1), ("n_candidates", 1_000_000))),
)

MATCHING_SHAPES = (
    ShapeSpec("match_4m", "match", (("n", 4_194_304), ("avg_degree", 16))),
    ShapeSpec("match_16m", "match", (("n", 16_777_216), ("avg_degree", 8))),
)


def shapes_for(cfg) -> tuple[ShapeSpec, ...]:
    return {
        "lm": LM_SHAPES,
        "gnn": GNN_SHAPES,
        "recsys": RECSYS_SHAPES,
        "matching": MATCHING_SHAPES,
    }[cfg.family]
