"""deepseek-moe-16b [arXiv:2401.06066]: 28L d_model=2048 16H d_ff(expert)=1408
vocab=102400, 64 routed top-6 + 2 shared, fine-grained, first layer dense
(d_ff=10944)."""
from repro.configs.base import LMConfig, MoECfg


def config(router: str = "topk"):
    return LMConfig("deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
                    n_kv_heads=16, d_ff=10944, vocab=102400, head_dim=128,
                    qkv_bias=False, rope_theta=1e4,
                    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408,
                               n_shared=2, d_ff_shared=2816, first_dense=1,
                               d_ff_dense=10944, router=router))


def reduced(router: str = "topk"):
    return LMConfig("deepseek-moe-16b-smoke", n_layers=3, d_model=64, n_heads=4,
                    n_kv_heads=4, d_ff=160, vocab=512, head_dim=16,
                    qkv_bias=False, dtype="float32",
                    moe=MoECfg(n_experts=8, top_k=6, d_ff_expert=24,
                               n_shared=2, d_ff_shared=48, first_dense=1,
                               d_ff_dense=160, router=router))
