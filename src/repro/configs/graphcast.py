"""graphcast [arXiv:2212.12794]: 16 processor layers, d_hidden=512,
mesh_refinement=6, sum aggregation, n_vars=227 (encoder-processor-decoder)."""
from repro.configs.base import GNNConfig


def config():
    return GNNConfig("graphcast", "graphcast", n_layers=16, d_hidden=512,
                     extra=(("mesh_refinement", 6), ("n_vars", 227)))


def reduced():
    return GNNConfig("graphcast-smoke", "graphcast", n_layers=2, d_hidden=24,
                     extra=(("mesh_refinement", 2), ("n_vars", 12)))
