"""AdamW + global-norm clipping + cosine schedule (self-contained, no optax
dependency). Optimizer state mirrors the param tree (m, v) so it shards with
the same PartitionSpecs as the parameters (FSDP-friendly)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    m: object  # pytree like params
    v: object


def init_opt_state(params) -> OptState:
    z = jax.tree.map(jnp.zeros_like, params)
    return OptState(jnp.zeros((), jnp.int32), z,
                    jax.tree.map(jnp.zeros_like, params))


def abstract_opt_state(abstract_params) -> OptState:
    return OptState(
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                     abstract_params),
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                     abstract_params),
    )


def opt_specs(param_specs):
    """PartitionSpecs for OptState given the param spec tree."""
    from jax.sharding import PartitionSpec as P

    return OptState(P(), param_specs, param_specs)


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, step.astype(jnp.float32))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / b1c
        vh = v2 / b2c
        p2 = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gn, "lr": lr}
