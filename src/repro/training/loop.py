"""Train-step construction + host-side training loop (with checkpointing,
straggler monitoring, and elastic restart hooks)."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    grad_accum: int = 1):
    """loss_fn(params, batch) -> (loss, aux). Returns
    train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1 microbatches along the leading batch axis (batch dims must
    divide) — the standard memory lever for the 110B-scale configs."""

    def compute_grads(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, aux), grads = compute_grads(params, batch)
        else:
            def micro(i):
                return jax.tree.map(
                    lambda x: x.reshape(grad_accum, -1, *x.shape[1:])[i], batch
                )

            def body(carry, i):
                gacc, lacc = carry
                (l, _), g = compute_grads(params, micro(i))
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)), jnp.arange(grad_accum)
            )
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def train(params, loss_fn, data_fn, opt_cfg: AdamWConfig, n_steps: int,
          log_every: int = 20, checkpoint_mgr=None, checkpoint_every: int = 0,
          straggler_monitor=None, start_step: int = 0):
    """Host loop. data_fn(step) -> batch (numpy). Returns (params, history)."""
    opt_state = init_opt_state(params)
    if checkpoint_mgr is not None and start_step == 0:
        restored = checkpoint_mgr.restore_latest(
            like={"params": params, "opt": opt_state})
        if restored is not None:
            params, opt_state, start_step = restored
            start_step += 1

    step_fn = jax.jit(make_train_step(loss_fn, opt_cfg))
    history = []
    for step in range(start_step, n_steps):
        t0 = time.perf_counter()
        batch = jax.tree.map(jnp.asarray, data_fn(step))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if straggler_monitor is not None:
            straggler_monitor.record(step, dt)
        if step % log_every == 0 or step == n_steps - 1:
            history.append({"step": step, "loss": loss, "dt": dt})
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms)", flush=True)
        if checkpoint_mgr is not None and checkpoint_every \
                and step and step % checkpoint_every == 0:
            checkpoint_mgr.save(step, params, opt_state)
    return params, opt_state, history
