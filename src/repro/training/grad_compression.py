"""Gradient compression for bandwidth-bound data parallelism.

Two composable schemes (both with error feedback so compression noise is
unbiased over time — Karimireddy et al., arXiv:1901.09847):

- int8 quantization: per-tensor symmetric scale, all-reduce runs on 1/4 the
  bytes (decode after the sum).
- top-k sparsification: keep the k largest-|g| entries per tensor, exchange
  (values, indices); the residual is fed back into the next step.

``compressed_psum`` is the shard_map building block; ``CompressedState``
carries the error-feedback residuals between steps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressedState(NamedTuple):
    residual: object  # pytree like grads


def init_state(grads_like) -> CompressedState:
    return CompressedState(jax.tree.map(jnp.zeros_like, grads_like))


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_int8_psum(grads, state: CompressedState, axis_name):
    """Error-feedback int8 all-reduce (use inside shard_map over data axis)."""

    def one(g, r):
        gc = g + r
        q, scale = quantize_int8(gc)
        deq = dequantize_int8(q, scale)
        new_r = gc - deq
        # int32 accumulate of int8 payloads: 4x fewer exchanged bytes when
        # the backend sends int8 and upcasts at the reducer; we emulate the
        # numerics with an int32 psum of the int8 values.
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.pmax(scale, axis_name)  # conservative shared scale
        return summed.astype(jnp.float32) * scale_sum \
            / jax.lax.psum(1, axis_name), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), CompressedState(
        tdef.unflatten([o[1] for o in out]))


def topk_sparsify(x, k_frac: float = 0.01):
    """Keep the top-k |values|; returns (dense reconstruction, residual)."""
    flat = x.reshape(-1)
    k = max(1, int(k_frac * flat.shape[0]))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(x.shape), (x - kept.reshape(x.shape))


def compress_topk(grads, state: CompressedState, k_frac: float = 0.01):
    """Error-feedback top-k (exchange k values+indices instead of the dense
    tensor; here returns the dense reconstruction for the optimizer)."""

    def one(g, r):
        kept, res = topk_sparsify(g + r, k_frac)
        return kept, res

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), CompressedState(
        tdef.unflatten([o[1] for o in out]))
