"""Paper-eval runner (DESIGN.md §8): sweep the Matrix Market fixtures +
synthetic-suite matrices across backends (reference / xla / pallas) and
device grids (1x1 / 2x2) through the ``solve()``/``Matcher`` facade;
certify every result with LP-dual potentials; fail loudly on unsound
bounds, backend disagreement, or imperfect matchings.

Outputs: ``results/paper_eval.md`` (per-matrix table) and
``BENCH_paper_eval.json`` at the repo root (gated in CI by
``benchmarks/check_regression.py``).

  PYTHONPATH=src python experiments/run_paper_eval.py [--quick]
      [--backends reference,xla,pallas] [--grids 1x1,2x2]
      [--suite-count 10] [--suite-n 96] [--transform log2_scaled_nonneg]
      [--no-persist] [--download [--instances Freescale1,rajat31]
      [--cache-dir DIR]]

``--download`` is the only network path in the repo (opt-in, sha256-pinned
cache via ``repro.data.suitesparse``); without it the sweep runs entirely
on checked-in fixtures.

``--quick`` is the CI docs-job smoke: fixtures + 3 small synthetic
matrices, reference/xla backends, the 1x1 grid — every correctness check
still runs, only the sweep is smaller.
"""
import argparse
import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.experiments import paper_eval  # noqa: E402


def _parse_grids(text: str):
    grids = []
    for tok in text.split(","):
        tok = tok.strip()
        if not tok:
            continue
        try:
            pr, pc = (int(t) for t in tok.split("x"))
        except ValueError:
            raise SystemExit(f"bad grid {tok!r}: expected PRxPC, e.g. 2x2")
        grids.append((pr, pc))
    return grids


def main() -> None:
    ap = argparse.ArgumentParser(
        description="AWPM quality evaluation in the paper's metric")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fixtures + 3 small synthetic matrices, "
                         "reference/xla, 1x1 grid")
    ap.add_argument("--backends", default=None,
                    help="comma list from reference,xla,pallas "
                         "(default: all three; --quick: reference,xla)")
    ap.add_argument("--grids", default=None,
                    help="comma list of PRxPC grids (default: 1x1,2x2; "
                         "--quick: 1x1). Grids beyond the attached device "
                         "count run in a fake-device subprocess.")
    ap.add_argument("--suite-count", type=int, default=None,
                    help="number of synthetic suite matrices (default 10)")
    ap.add_argument("--suite-n", type=int, default=None,
                    help="synthetic matrix size (default 96)")
    ap.add_argument("--transform", default=None,
                    help="re-measure the synthetic suite in this weight "
                         "metric (e.g. log2_scaled_nonneg); default: its "
                         "native rowcol normalization")
    ap.add_argument("--oracle-max-n", type=int, default=256,
                    help="run the exact scipy oracle up to this n")
    ap.add_argument("--no-persist", action="store_true",
                    help="skip writing results/ + BENCH_paper_eval.json")
    ap.add_argument("--download", action="store_true",
                    help="OPT-IN network: fetch SuiteSparse instances "
                         "(sha256-pinned cache) and sweep them too. CI "
                         "never passes this — fixtures need no network.")
    ap.add_argument("--instances", default=None,
                    help="with --download: comma list of registry names or "
                         "Group/name specs (default: the paper registry)")
    ap.add_argument("--cache-dir", default=None,
                    help="SuiteSparse cache dir (default: "
                         "$REPRO_SUITESPARSE_CACHE or "
                         "~/.cache/repro-suitesparse)")
    args = ap.parse_args()

    spec = dict(paper_eval.QUICK_SPEC if args.quick
                else paper_eval.DEFAULT_SPEC)
    if args.suite_count is not None:
        spec["synthetic_count"] = args.suite_count
    if args.suite_n is not None:
        spec["synthetic_n"] = args.suite_n
    if args.transform is not None:
        spec["synthetic_transform"] = args.transform
    if args.instances and not args.download:
        raise SystemExit("--instances needs --download (no implicit network)")
    if args.download:
        from repro.data import suitesparse

        names = ([t.strip() for t in args.instances.split(",") if t.strip()]
                 if args.instances else None)
        fetched = suitesparse.fetch_paper_instances(names,
                                                    cache=args.cache_dir)
        spec["extra_mtx"] = sorted(str(p) for p in fetched.values())
        print(f"# suitesparse: {len(fetched)} instance(s) cached under "
              f"{suitesparse.cache_dir(args.cache_dir)}")
    backends = (args.backends.split(",") if args.backends
                else (["reference", "xla"] if args.quick
                      else list(paper_eval.LOCAL_BACKENDS)))
    grids = _parse_grids(args.grids) if args.grids \
        else ([(1, 1)] if args.quick else list(paper_eval.GRIDS))

    t0 = time.perf_counter()
    records = paper_eval.run_eval(spec, backends=backends, grids=grids,
                                  oracle_max_n=args.oracle_max_n)
    wall = time.perf_counter() - t0
    print(paper_eval.to_markdown(records))
    n_tight = sum(r.tight for r in records)
    bounds = [r.ratio_bound for r in records if r.ratio_bound is not None]
    print(f"# {len(records)} rows in {wall:.1f}s: {n_tight} certified "
          f"optimal, min certified ratio bound "
          f"{min(bounds):.4f}" if bounds else "# no ratio bounds", flush=True)
    if not args.no_persist:
        table, bench = paper_eval.write_outputs(records, wall,
                                                quick=args.quick)
        print(f"# wrote {table.relative_to(REPO_ROOT)} and {bench.name} "
              f"({len(records)} rows)")


if __name__ == "__main__":
    main()
