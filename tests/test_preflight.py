"""Preflight sanitization + degrade policies (core.preflight, DESIGN.md §9):
every degenerate input is typed and located, sanitize repairs exactly the
fatal data issues, and the solve pipeline short-circuits AWAC on infeasible
instances under every policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    InfeasibleProblemError,
    MatchingProblem,
    PreflightError,
    SolveOptions,
    graph,
    solve,
)
from repro.core.preflight import PreflightReport, preflight, sanitize


def _problem(n=12, seed=0, **kw):
    return MatchingProblem.from_graph(
        graph.generate(n, avg_degree=4.0, seed=seed, **kw))


def _with_edit(p, pos, row=None, col=None, val=None):
    r = np.asarray(p.row).copy()
    c = np.asarray(p.col).copy()
    v = np.asarray(p.val).copy()
    if row is not None:
        r[pos] = row
    if col is not None:
        c[pos] = col
    if val is not None:
        v[pos] = val
    return MatchingProblem(row=r, col=c, val=v, n=p.n)


# --------------------------------------------------------------------------
# the structural pass
# --------------------------------------------------------------------------


def test_clean_problem_reports_ok():
    report = preflight(_problem())
    assert report.ok and report.solvable
    assert report.summary() == "preflight: clean"


def test_nonfinite_weight_is_fatal_and_located():
    p = _with_edit(_problem(), 3, val=np.nan)
    report = preflight(p)
    assert not report.ok
    (issue,) = report.fatal
    assert issue.kind == "nonfinite_weight"
    assert issue.severity == "fatal"
    assert 3 in issue.where
    assert not report.solvable


def test_duplicate_edge_is_fatal():
    p = _problem()
    r = np.asarray(p.row)
    # copy edge 0 over edge 1 -> exact duplicate coordinates
    p = _with_edit(p, 1, row=int(r[0]), col=int(np.asarray(p.col)[0]))
    report = preflight(p)
    assert any(i.kind == "duplicate_edge" for i in report.fatal)


def test_negative_weight_is_warning_only():
    p = _with_edit(_problem(), 0, val=-2.5)
    report = preflight(p)
    assert not report.ok
    assert report.solvable  # warnings never block
    (issue,) = report.warnings
    assert issue.kind == "negative_weight"


def test_empty_column_is_structural():
    g = graph.generate(10, avg_degree=3.0, seed=1)
    keep = np.asarray(g.col) != 4
    p = MatchingProblem.from_coo(np.asarray(g.row)[keep],
                                 np.asarray(g.col)[keep],
                                 np.asarray(g.val)[keep], g.n)
    report = preflight(p)
    kinds = {i.kind for i in report.structural}
    assert "empty_col" in kinds
    assert not report.solvable


def test_mcm_screen_finds_hall_deficiency():
    # no empty row or column, yet infeasible: columns {0, 1, 2} are only
    # reachable from rows {0, 1} (a Hall violator the cheap degree check
    # cannot see — only the MCM screen catches it)
    row = np.array([0, 0, 0, 1, 1, 1, 2, 3])
    col = np.array([0, 1, 2, 0, 1, 2, 3, 3])
    val = np.ones(8)
    p = MatchingProblem.from_coo(row, col, val, 4)
    assert preflight(p).ok  # cheap pass sees nothing
    report = preflight(p, feasibility=True)
    assert report.checked_feasibility
    (issue,) = report.structural
    assert issue.kind == "deficient" and issue.count == 1


def test_batched_issues_carry_instance_index():
    good = _problem(n=10, seed=0)
    bad = _with_edit(_problem(n=10, seed=1), 2, val=np.inf)
    report = preflight(MatchingProblem.stack([good, bad]))
    (issue,) = report.fatal
    assert issue.instance == 1


# --------------------------------------------------------------------------
# sanitize
# --------------------------------------------------------------------------


def test_sanitize_drops_nonfinite_and_merges_duplicates_keep_max():
    p = _problem(n=10)
    r = np.asarray(p.row)
    c = np.asarray(p.col)
    real = int((r < p.n).sum())
    # duplicate edge 0 with a heavier weight, NaN out edge 2
    p_bad = _with_edit(p, 1, row=int(r[0]), col=int(c[0]), val=99.0)
    p_bad = _with_edit(p_bad, 2, val=np.nan)
    clean, report = sanitize(p_bad)
    assert report.fatal
    assert clean.cap == p.cap  # planned Matcher shapes still match
    rc = np.asarray(clean.row)
    vc = np.asarray(clean.val)
    assert int((rc < p.n).sum()) == real - 2  # one dup + one NaN gone
    # keep-max: the surviving (r0, c0) edge carries the heavier weight
    at = (rc == int(r[0])) & (np.asarray(clean.col) == int(c[0]))
    assert vc[at] == pytest.approx(99.0)


def test_sanitize_is_identity_on_clean_problems():
    p = _problem()
    clean, report = sanitize(p)
    assert clean is p and report.ok


# --------------------------------------------------------------------------
# solve() integration: the three policies
# --------------------------------------------------------------------------


def _deficient(n=12, seed=2, victim=5):
    g = graph.generate(n, avg_degree=4.0, seed=seed)
    keep = np.asarray(g.col) != victim
    return MatchingProblem.from_coo(np.asarray(g.row)[keep],
                                    np.asarray(g.col)[keep],
                                    np.asarray(g.val)[keep], g.n)


def test_raise_policy_rejects_fatal_and_infeasible():
    with pytest.raises(PreflightError):
        solve(_with_edit(_problem(), 0, val=np.nan))
    with pytest.raises(InfeasibleProblemError) as exc:
        solve(_deficient())
    assert not exc.value.report.solvable


def test_sanitize_policy_repairs_data_but_still_raises_on_structure():
    g = graph.generate(12, avg_degree=4.0, seed=0)
    real = np.asarray(g.row) < g.n
    p = MatchingProblem.from_coo(
        np.asarray(g.row)[real], np.asarray(g.col)[real],
        np.asarray(g.val)[real], g.n, capacity=int(real.sum()) + 4)
    res_clean = solve(p)
    # NaN in a padding slot: sanitization restores exactly p
    pad = int(np.flatnonzero(np.asarray(p.row) >= p.n)[-1])
    p_nan = _with_edit(p, pad, row=0, col=0, val=np.nan)
    res = solve(p_nan, SolveOptions(on_invalid="sanitize"))
    assert np.array_equal(np.asarray(res.mate_row),
                          np.asarray(res_clean.mate_row))
    assert res.diagnosis is not None  # what was repaired is reported
    with pytest.raises(InfeasibleProblemError):
        solve(_deficient(), SolveOptions(on_invalid="sanitize"))


def test_degrade_policy_serves_maximal_matching_with_diagnosis():
    res = solve(_deficient(victim=5),
                SolveOptions(on_invalid="degrade", max_iter=10**6))
    assert not bool(res.perfect)
    assert int(res.awac_iters) == 0  # AWAC short-circuited after MCM
    assert np.asarray(res.mate_row)[5] == 12  # sentinel for the victim
    report = res.diagnosis
    assert isinstance(report, PreflightReport) and not report.solvable
    kinds = {i.kind for i in report.issues}
    assert {"empty_col", "deficient"} <= kinds


def test_degrade_batched_mixed_feasibility():
    feasible = _problem(n=12, seed=3)
    res = solve(MatchingProblem.stack([feasible, _deficient()]),
                SolveOptions(on_invalid="degrade"))
    perfect = np.asarray(res.perfect)
    assert bool(perfect[0]) and not bool(perfect[1])
    assert [i.instance for i in res.diagnosis.structural] == [1, 1]


def test_feasible_solve_is_unchanged_and_diagnosis_none():
    p = _problem()
    res = solve(p)
    assert bool(res.perfect) and res.diagnosis is None


def test_diagnosis_survives_pytree_roundtrip():
    res = solve(_deficient(), SolveOptions(on_invalid="degrade"))
    leaves, treedef = jax.tree_util.tree_flatten(res)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.diagnosis == res.diagnosis


def test_preflight_skipped_under_jit():
    # traced solves cannot run host-side checks; the pipeline must still
    # trace (and the early exit is weight-level, not diagnosis-level)
    p = _problem()

    @jax.jit
    def f(row, col, val):
        q = MatchingProblem(row=row, col=col, val=val, n=p.n)
        return solve(q).weight

    w = f(jnp.asarray(p.row), jnp.asarray(p.col), jnp.asarray(p.val))
    assert float(w) == pytest.approx(float(solve(p).weight))
