"""Fused AWAC sweep engine vs the seed reference — bit-identical winners.

Covers the three Step-C backends on padded COO instances:
  * reference  — seed jnp path (global lex search + two-pass reductions)
  * xla        — CSR-windowed lookup + packed-key one-pass segment_max
  * pallas     — fused ``awac_sweep`` kernel (interpret mode on CPU)
including gain ties, the all-padding instance, and the no-candidate case.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import graph, single
from repro.kernels.cycle_gain.ops import awac_sweep_winners
from repro.sparse.csr import max_row_nnz, row_ptr_from_sorted, window_depth
from repro.sparse.ops import segment_max_with_payload

KINDS = ["uniform", "circuit", "antigreedy", "banded", "powerlaw"]


def _mcm_state(g):
    row, col, val = jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val)
    st = single.greedy_maximal(row, col, val, g.n)
    st = single.mcm(row, col, val, g.n, st.mate_row, st.mate_col)
    return row, col, val, st


def _winners_all_backends(row, col, val, n, st, min_gain=1e-6):
    rp = row_ptr_from_sorted(row, n)
    ws = window_depth(max_row_nnz(row, n))
    ref = single.awac_cwinners(row, col, val, n, st, min_gain)
    xla = single.awac_cwinners_fused(row, col, val, rp, n, st, min_gain, ws)
    with enable_x64():  # packed-key one-pass reduction branch
        xla64 = single.awac_cwinners_fused(row, col, val, rp, n, st,
                                           min_gain, ws)
    pal = awac_sweep_winners(row, col, val, rp, st.mate_row, st.mate_col,
                             st.u, st.v, jnp.float32(min_gain), n=n,
                             window_steps=ws, te=128)
    return ref, xla, xla64, pal


def _assert_identical(ref, others, msg):
    names = ["Cgain", "Ci", "Cw1", "Cw2"]
    for tag, other in others.items():
        for nm, a, b in zip(names, ref, other):
            np.testing.assert_array_equal(
                np.array(a), np.array(b), err_msg=f"{msg}: {tag} {nm}")


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cwinners_bit_identical_across_backends(kind, seed):
    g = graph.generate(72, avg_degree=5.0, kind=kind, seed=seed)
    row, col, val, st = _mcm_state(g)
    ref, xla, xla64, pal = _winners_all_backends(row, col, val, g.n, st)
    _assert_identical(ref, {"xla": xla, "xla-packed": xla64, "pallas": pal},
                      f"{kind}/{seed}")


def test_cwinners_with_gain_ties():
    # quantized weights force exact f32 gain ties across different rows of
    # the same column; the smallest-row tie-break must agree everywhere
    n = 32
    g0 = graph.generate(n, avg_degree=6.0, kind="uniform", seed=3,
                        normalize=False)
    real = np.asarray(g0.row) < n
    val = (np.round(np.asarray(g0.val)[real] * 4) / 4 + 0.25).astype(np.float32)
    g = graph.from_coo(np.asarray(g0.row)[real], np.asarray(g0.col)[real],
                       val, n)
    row, col, val, st = _mcm_state(g)
    ref, xla, xla64, pal = _winners_all_backends(row, col, val, g.n, st)
    _assert_identical(ref, {"xla": xla, "xla-packed": xla64, "pallas": pal},
                      "ties")


def test_cwinners_all_padding():
    n = 16
    cap = 48
    row = jnp.full((cap,), n, jnp.int32)
    col = jnp.full((cap,), n, jnp.int32)
    val = jnp.zeros((cap,), jnp.float32)
    st = single.empty_state(n)
    ref, xla, xla64, pal = _winners_all_backends(row, col, val, n, st)
    _assert_identical(ref, {"xla": xla, "xla-packed": xla64, "pallas": pal},
                      "all-padding")
    assert np.all(np.isneginf(np.array(ref[0])))
    assert np.all(np.array(ref[1]) == n)


def test_cwinners_no_candidates():
    # perfect diagonal matching with no off-diagonal edges: no 4-cycles
    n = 12
    row = np.arange(n, dtype=np.int32)
    col = np.arange(n, dtype=np.int32)
    val = np.linspace(0.5, 1.0, n).astype(np.float32)
    g = graph.from_coo(row, col, val, n)
    rowj, colj, valj = jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val)
    st = single.state_from_mates(rowj, colj, valj, n, np.arange(n),
                                 np.arange(n))
    ref, xla, xla64, pal = _winners_all_backends(rowj, colj, valj, n, st)
    _assert_identical(ref, {"xla": xla, "xla-packed": xla64, "pallas": pal},
                      "no-candidates")
    assert np.all(np.isneginf(np.array(ref[0])))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_full_awac_loop_matches_reference(backend):
    g = graph.generate(64, avg_degree=6.0, kind="antigreedy", seed=11)
    row, col, val = jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val)
    st = single.greedy_maximal(row, col, val, g.n)
    st = single.mcm(row, col, val, g.n, st.mate_row, st.mate_col)
    sR, iR = single.awac(row, col, val, g.n, st, backend="reference")
    sB, iB = single.awac(row, col, val, g.n, st, backend=backend)
    assert int(iR) == int(iB)
    for a, b in zip(sR, sB):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_packed_segment_max_matches_two_pass():
    rng = np.random.default_rng(4)
    m, ns = 4000, 129
    vals = jnp.asarray(np.round(rng.uniform(-1, 1, m), 2), jnp.float32)
    vals = vals.at[:50].set(-jnp.inf)  # explicit -inf entries
    pay = jnp.asarray(rng.integers(0, 1 << 20, m), jnp.int32)
    seg = jnp.asarray(rng.integers(0, ns + 1, m), jnp.int32)  # incl. dump seg
    g1, p1 = segment_max_with_payload(vals, pay, seg, ns + 1)
    with enable_x64():
        g2, p2 = segment_max_with_payload(vals, pay, seg, ns + 1)
    np.testing.assert_array_equal(np.array(g1), np.array(g2))
    np.testing.assert_array_equal(np.array(p1), np.array(p2))
