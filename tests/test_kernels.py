"""Per-kernel interpret=True validation vs pure-jnp oracles (shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cycle_gain import cycle_gain_padded, cycle_gain_ref
from repro.kernels.embedding_bag import embedding_bag_padded, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.flash_attention.ops import attention

# ----------------------------- cycle_gain ---------------------------------


@pytest.mark.parametrize("m,n", [(64, 128), (256, 256), (300, 200), (8, 640)])
@pytest.mark.parametrize("density", [0.1, 0.5, 1.0])
def test_cycle_gain_matches_ref(m, n, density):
    rng = np.random.default_rng(m * 1000 + n + int(density * 10))
    a = rng.uniform(0.1, 1.0, (m, n)) * (rng.random((m, n)) < density)
    a2 = rng.uniform(0.1, 1.0, (m, n)) * (rng.random((m, n)) < density)
    u = rng.uniform(0.0, 1.0, m).astype(np.float32)
    v = rng.uniform(0.0, 1.0, n).astype(np.float32)
    a = jnp.asarray(a, jnp.float32)
    a2 = jnp.asarray(a2, jnp.float32)
    gk, rk = cycle_gain_padded(a, a2, jnp.asarray(u), jnp.asarray(v),
                               tm=128, tn=128)
    gr, rr = cycle_gain_ref(a, a2, jnp.asarray(u), jnp.asarray(v))
    np.testing.assert_allclose(np.array(gk), np.array(gr), rtol=1e-6)
    np.testing.assert_array_equal(np.array(rk), np.array(rr))


def test_cycle_gain_tiling_invariance():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.1, 1, (384, 384)) * (rng.random((384, 384)) < 0.3),
                    jnp.float32)
    a2 = jnp.asarray(rng.uniform(0.1, 1, (384, 384)) * (rng.random((384, 384)) < 0.3),
                     jnp.float32)
    u = jnp.asarray(rng.uniform(0, 1, 384), jnp.float32)
    v = jnp.asarray(rng.uniform(0, 1, 384), jnp.float32)
    g1, r1 = cycle_gain_padded(a, a2, u, v, tm=128, tn=128)
    g2, r2 = cycle_gain_padded(a, a2, u, v, tm=384, tn=384)
    np.testing.assert_allclose(np.array(g1), np.array(g2), rtol=1e-6)
    np.testing.assert_array_equal(np.array(r1), np.array(r2))


def test_cycle_gain_empty_columns():
    a = jnp.zeros((64, 128), jnp.float32)
    a2 = jnp.zeros((64, 128), jnp.float32)
    g, r = cycle_gain_padded(a, a2, jnp.zeros(64), jnp.zeros(128))
    assert np.all(np.array(r) == -1)
    assert np.all(np.isneginf(np.array(g)))


# --------------------------- flash attention -------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,hkv,s,d", [
    (1, 4, 4, 256, 64),
    (2, 8, 2, 256, 64),   # GQA 4:1
    (1, 2, 1, 512, 128),  # MQA
])
def test_flash_attention_matches_ref(b, h, hkv, s, d, causal, dtype):
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    o = flash_attention(q, k, v, causal=causal, tq=128, tk=128)
    o_ref = attention_ref(q, k, v, causal=causal)
    rtol, atol = (2e-2, 2e-2) if dtype == jnp.bfloat16 else (2e-5, 2e-5)
    np.testing.assert_allclose(np.array(o, np.float32), np.array(o_ref, np.float32),
                               rtol=rtol, atol=atol)


def test_flash_attention_grad_path():
    # custom_vjp recompute backward matches full-jnp grads
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    qm = jnp.swapaxes(q, 1, 2)
    km = jnp.swapaxes(k, 1, 2)
    vm = jnp.swapaxes(v, 1, 2)

    def loss_kernel(q, k, v):
        return attention(q, k, v, causal=True, use_kernel=True).sum()

    def loss_ref(q, k, v):
        return attention(q, k, v, causal=True, use_kernel=False).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(qm, km, vm)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(qm, km, vm)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.array(a), np.array(b_), rtol=1e-4, atol=1e-4)


# ---------------------------- embedding bag --------------------------------


@pytest.mark.parametrize("b,l,v,d", [(16, 8, 1024, 64), (8, 32, 600, 32),
                                     (33, 5, 2000, 128)])
def test_embedding_bag_matches_ref(b, l, v, d):
    rng = np.random.default_rng(b + l)
    idx = rng.integers(-1, v, (b, l)).astype(np.int32)  # -1 = padding
    w = rng.uniform(0, 1, (b, l)).astype(np.float32)
    table = rng.normal(size=(v, d)).astype(np.float32)
    out = embedding_bag_padded(jnp.asarray(idx), jnp.asarray(w), jnp.asarray(table),
                               tb=8, tv=256)
    ref = embedding_bag_ref(jnp.asarray(idx), jnp.asarray(w), jnp.asarray(table))
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=1e-5, atol=1e-5)


def test_embedding_bag_all_padding():
    idx = jnp.full((8, 4), -1, jnp.int32)
    w = jnp.ones((8, 4), jnp.float32)
    table = jnp.ones((256, 16), jnp.float32)
    out = embedding_bag_padded(idx, w, table, tb=8, tv=256)
    np.testing.assert_array_equal(np.array(out), 0.0)


# ---------------------------- router swap ----------------------------------


@pytest.mark.parametrize("t,e", [(128, 8), (300, 60), (512, 64)])
def test_router_swap_matches_ref(t, e):
    from repro.kernels.router_swap import router_swap_padded, router_swap_ref

    rng = np.random.default_rng(t + e)
    aff = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, e, t), jnp.int32)
    cur = jnp.take_along_axis(aff, assign[:, None], axis=1)[:, 0]
    gk, rk = router_swap_padded(aff, assign, cur, ti=128, tj=128)
    gr, rr = router_swap_ref(aff, assign, cur)
    np.testing.assert_allclose(np.array(gk), np.array(gr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.array(rk), np.array(rr))


def test_router_swap_mutual_best_consistency():
    """The kernel's winners drive the same mutual-best swaps as the XLA path
    in moe.swap_improve."""
    from repro.kernels.router_swap import router_swap_ref

    rng = np.random.default_rng(0)
    t, e = 64, 8
    aff = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, e, t), jnp.int32)
    cur = jnp.take_along_axis(aff, assign[:, None], axis=1)[:, 0]
    g, bp = router_swap_ref(aff, assign, cur)
    tok = np.arange(t)
    bp_np = np.array(bp)
    mutual = (bp_np[bp_np[tok]] == tok) & (np.array(g) > 1e-6)
    # mutual-best pairs must be symmetric
    for i in np.nonzero(mutual)[0]:
        assert mutual[bp_np[i]]
