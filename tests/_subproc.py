"""Helper to run test payloads in a fresh process with a fake multi-device
XLA platform (device count must be set before jax initializes, so it cannot
be done inside the pytest process, which already holds 1 CPU device)."""
import os
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def run_with_devices(script: str, n_devices: int, timeout: int = 900):
    env = dict(os.environ)
    # strip any inherited device-count token entirely (e.g. the CI
    # multi-device job exports one at the job level) — XLA aborts on
    # unknown flags, so the stale token can't just be renamed
    inherited = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                       env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} {inherited}"
    ).strip()
    env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout
