"""Distributed AWPM (shard_map, 2D grid) vs single-device — bit-identical.

Runs in subprocesses because the fake device count must be set before jax
initializes (see DESIGN.md; the dry-run has the same constraint)."""
import pytest

from _subproc import run_with_devices

DIST_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import graph, ref, single
from repro.core.dist import GridSpec, DistAWPM, default_caps

try:  # jax >= 0.6: explicit Auto axis types
    from jax.sharding import AxisType
    mesh = jax.make_mesh({mesh_shape}, {mesh_axes}, axis_types=(AxisType.Auto,)*{nax})
except ImportError:  # jax 0.4.x: all axes are Auto already
    mesh = jax.make_mesh({mesh_shape}, {mesh_axes})
spec = GridSpec(mesh, {row_axes}, "model")
for seed in range(3):
    g = graph.generate(64, avg_degree=6.0, kind="{kind}", seed=seed)
    struct = g.structure_dense()
    caps = default_caps(g.n, g.nnz, spec.pr, spec.pc, slack=8.0)
    drv = DistAWPM(spec, g.n, cap=((g.nnz // (spec.pr*spec.pc) + 63)//64*64 + 64),
                   a2a_caps=caps)
    st, iters, dropped = drv.run(g)
    assert int(dropped) == 0
    mrD = np.array(st.mate_row[:g.n])
    ref.check_matching(struct, mrD)
    assert ref.is_perfect(mrD, g.n)
    stS, _ = single.awpm(jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val), g.n)
    assert np.array_equal(mrD, np.array(stS.mate_row[:g.n])), "dist != single"
print("OK")
"""


@pytest.mark.parametrize("kind", ["uniform", "antigreedy"])
def test_dist_awpm_4x4_matches_single(kind):
    script = DIST_SCRIPT.format(
        mesh_shape="(4, 4)", mesh_axes='("data", "model")', nax=2,
        row_axes='("data",)', kind=kind,
    )
    out = run_with_devices(script, 16)
    assert "OK" in out


def test_dist_awpm_multipod_matches_single():
    script = DIST_SCRIPT.format(
        mesh_shape="(2, 2, 4)", mesh_axes='("pod", "data", "model")', nax=3,
        row_axes='("pod", "data")', kind="uniform",
    )
    out = run_with_devices(script, 16)
    assert "OK" in out


OVERFLOW_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import graph, ref, single
from repro.core.dist import GridSpec, DistAWPM

try:
    from jax.sharding import AxisType
    mesh = jax.make_mesh((4, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
except ImportError:
    mesh = jax.make_mesh((4, 4), ("data", "model"))
spec = GridSpec(mesh, ("data",), "model")
g = graph.generate(64, avg_degree=8.0, kind="uniform", seed=5)
struct = g.structure_dense()
# deliberately tiny bucket capacities -> overflow; result must STILL be a
# valid perfect matching (dropped candidates just delay augmentations)
drv = DistAWPM(spec, g.n, cap=((g.nnz // 16 + 63)//64*64 + 64), a2a_caps=(4, 4))
st, iters, dropped = drv.run(g)
mr = np.array(st.mate_row[:g.n])
ref.check_matching(struct, mr)
assert ref.is_perfect(mr, g.n)
w = float(single.matching_weight(st, g.n))
dense = g.to_dense().astype(np.float32)
_, opt = ref.exact_mwpm(dense, struct)
assert w >= 0.5 * opt  # still a heavy matching even with drops
print("OK dropped=", int(dropped))
"""


def test_dist_awpm_overflow_safe():
    out = run_with_devices(OVERFLOW_SCRIPT, 16)
    assert "OK" in out
