"""Mathematical correctness of model building blocks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn.common import real_spherical_harmonics, sh_degree_index
from repro.models.layers import rope, softmax_xent


def _ref_real_sph_harm(theta, phi, l, m):
    """Reference real spherical harmonics from scipy's complex Y_lm."""
    try:  # scipy >= 1.15: sph_harm_y(l, m, polar, azimuth)
        from scipy.special import sph_harm_y
    except ImportError:  # older scipy: sph_harm(m, l, azimuth, polar)
        from scipy.special import sph_harm

        def sph_harm_y(l, m, polar, azimuth):
            return sph_harm(m, l, azimuth, polar)

    y = sph_harm_y(l, abs(m), theta, phi)
    if m == 0:
        return y.real
    if m > 0:
        return np.sqrt(2) * (-1) ** m * y.real
    return np.sqrt(2) * (-1) ** m * y.imag


@pytest.mark.parametrize("l_max", [2, 4, 6])
def test_spherical_harmonics_match_scipy(l_max):
    rng = np.random.default_rng(0)
    v = rng.normal(size=(50, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    theta = np.arccos(np.clip(v[:, 2], -1, 1))
    phi = np.arctan2(v[:, 1], v[:, 0])
    ours = np.array(real_spherical_harmonics(jnp.asarray(v, jnp.float32), l_max))
    ls, ms = sh_degree_index(l_max)
    for k, (l, m) in enumerate(zip(ls, ms)):
        ref = _ref_real_sph_harm(theta, phi, int(l), int(m))
        # our convention may differ from Condon-Shortley by (-1)^m: compare
        # up to that fixed sign per (l, m)
        a, b = ours[:, k], ref
        sign = np.sign(np.sum(a * b)) or 1.0
        np.testing.assert_allclose(a, sign * b, rtol=2e-3, atol=2e-3,
                                   err_msg=f"l={l} m={m}")


def test_spherical_harmonics_degree_norm_rotation_invariant():
    """Sum_m Y_lm(v)^2 is rotation invariant (addition theorem)."""
    rng = np.random.default_rng(1)
    v = rng.normal(size=(20, 3))
    q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    l_max = 6
    ls, _ = sh_degree_index(l_max)
    y1 = np.array(real_spherical_harmonics(jnp.asarray(v, jnp.float32), l_max))
    y2 = np.array(real_spherical_harmonics(jnp.asarray(v @ q.T, jnp.float32),
                                           l_max))
    for l in range(l_max + 1):
        sel = ls == l
        n1 = (y1[:, sel] ** 2).sum(1)
        n2 = (y2[:, sel] ** 2).sum(1)
        np.testing.assert_allclose(n1, n2, rtol=1e-3, atol=1e-4)
        # addition theorem: sum_m |Y_lm|^2 = (2l+1)/4pi
        np.testing.assert_allclose(n1, (2 * l + 1) / (4 * np.pi), rtol=1e-3)


def test_rope_relative_position_property():
    """<rope(q, p1), rope(k, p2)> depends only on p2 - p1."""
    rng = np.random.default_rng(2)
    d = 64
    q = jnp.asarray(rng.normal(size=(1, 1, 1, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, d)), jnp.float32)

    def score(p1, p2):
        qr = rope(q, jnp.full((1, 1), p1, jnp.int32), theta=1e4)
        kr = rope(k, jnp.full((1, 1), p2, jnp.int32), theta=1e4)
        return float(jnp.sum(qr * kr))

    assert abs(score(3, 10) - score(103, 110)) < 1e-3
    assert abs(score(0, 5) - score(40, 45)) < 1e-3
    assert abs(score(0, 5) - score(0, 6)) > 1e-4  # but not position-free


def test_rope_preserves_norm():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    y = rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.array(y), axis=-1),
                               np.linalg.norm(np.array(x), axis=-1), rtol=1e-5)


def test_softmax_xent_matches_manual():
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(6, 10)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 10, 6), jnp.int32)
    ours = float(softmax_xent(logits, labels))
    p = np.exp(np.array(logits) - np.array(logits).max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = -np.mean(np.log(p[np.arange(6), np.array(labels)]))
    assert abs(ours - ref) < 1e-5
