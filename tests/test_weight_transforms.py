"""Weight transforms (repro.data.weight_transforms, DESIGN.md §8): the MC64
log2-scaled metric vs a hand-computed oracle, the decision-invariance of
the non-negative lift, and the composition plumbing."""
import numpy as np
import pytest

from repro.core import SolveOptions, solve
from repro.data.mtx import load_problem
from repro.data.weight_transforms import (
    compose,
    get_transform,
    log2_scaled,
    log2_scaled_nonneg,
    mc64_cost,
    rowcol_normalized,
)

# 3x3 oracle:  A = [[4, 1, .], [2, 8, 1], [., 2, 16]]
# column maxes 4, 8, 16 -> w_ij = log2|a_ij| - log2(colmax):
#   (0,0): 0   (1,0): -1   (0,1): -3   (1,1): 0   (2,1): -2
#   (1,2): -4  (2,2): 0
ROW = np.array([0, 1, 0, 1, 2, 1, 2])
COL = np.array([0, 0, 1, 1, 1, 2, 2])
VAL = np.array([4.0, 2.0, 1.0, 8.0, 2.0, 1.0, 16.0])
EXPECTED = np.array([0.0, -1.0, -3.0, 0.0, -2.0, -4.0, 0.0])


def test_log2_scaled_hand_oracle():
    w = log2_scaled(ROW, COL, VAL, 3)
    assert np.array_equal(w, EXPECTED)
    # the per-column max is exactly 0 (the MC64 optimality anchor)
    for j in range(3):
        assert w[COL == j].max() == 0.0


def test_log2_scaled_handles_signs():
    # the metric sees |a_ij|: flipping signs changes nothing
    w = log2_scaled(ROW, COL, -VAL, 3)
    assert np.array_equal(w, EXPECTED)


def test_mc64_cost_is_negated_weight():
    assert np.array_equal(mc64_cost(ROW, COL, VAL, 3),
                          -log2_scaled(ROW, COL, VAL, 3))


def test_nonneg_lift_is_constant_shift():
    w = log2_scaled(ROW, COL, VAL, 3)
    wn = log2_scaled_nonneg(ROW, COL, VAL, 3)
    assert wn.min() == 0.0
    shift = wn - w
    assert np.allclose(shift, shift[0])  # one global constant


def test_zero_entries_rejected():
    with pytest.raises(ValueError, match="zero entries"):
        log2_scaled(ROW, COL, np.array([4.0, 0.0, 1, 8, 2, 1, 16]), 3)


def test_rowcol_normalized_bounds():
    v = rowcol_normalized(ROW, COL, VAL, 3)
    assert v.max() <= 1.0 and v.min() > 0.0


def test_compose_order():
    t = compose("abs", lambda r, c, v, n: v * 2.0)
    assert np.array_equal(t(ROW, COL, -VAL, 3), 2.0 * VAL)


def test_get_transform_errors():
    with pytest.raises(KeyError, match="unknown weight transform"):
        get_transform("nope")
    with pytest.raises(TypeError):
        get_transform(42)
    assert get_transform(log2_scaled) is log2_scaled
    assert get_transform(["abs"])(ROW, COL, -VAL, 3).min() > 0


def test_nonneg_lift_is_decision_invariant(tmp_path):
    """Every 4-cycle gain and every argmax the engine takes is invariant
    under a constant per-edge shift, so the raw (<= 0) and lifted metrics
    must produce bit-identical matchings — on every backend."""
    p_raw, _ = load_problem("tests/data/circuit8.mtx",
                            transform="log2_scaled")
    p_lift, _ = load_problem("tests/data/circuit8.mtx",
                             transform="log2_scaled_nonneg")
    for backend in ("reference", "xla"):
        r_raw = solve(p_raw, SolveOptions(backend=backend))
        r_lift = solve(p_lift, SolveOptions(backend=backend))
        assert np.array_equal(np.asarray(r_raw.mate_row),
                              np.asarray(r_lift.mate_row)), backend
        assert int(r_raw.awac_iters) == int(r_lift.awac_iters)
