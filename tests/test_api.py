"""Differential + contract suite for the unified solve()/Matcher facade
(repro.core.api, DESIGN.md §7).

Contracts under test:
  - ``solve()`` is bit-identical (mates, duals, AND iteration counts) to
    every legacy entry point it subsumes — ``single.awpm`` on every local
    backend, ``batch.awpm_batched`` on every local backend, and
    ``dist.awpm_dist_batched`` on mesh shapes {1x1, 2x2, 2x4} (the
    multi-device shapes run in an 8-fake-device subprocess, see
    tests/_subproc.py).
  - The legacy entry points are deprecation shims: they emit
    DeprecationWarning and still return bit-identical results.
  - ``SolveOptions`` validates eagerly with clear errors (unknown backend,
    bad grid shape, bad capacities) and a too-small distributed ``cap``
    raises at partition time instead of silently truncating edges.
  - ``plan()``/``Matcher`` reuse one planned engine across calls and reject
    problems that do not match the planned spec.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_with_devices
from repro.core import (
    MatchingProblem,
    MatchResult,
    ProblemSpec,
    SolveOptions,
    batch,
    graph,
    plan,
    single,
    solve,
)

LOCAL_BACKENDS = ("reference", "xla", "pallas")


def _graphs(n=32):
    kinds = [("uniform", 0), ("antigreedy", 7), ("circuit", 2), ("banded", 3)]
    return [graph.generate(n, avg_degree=4.0 + (i % 3), kind=k, seed=s)
            for i, (k, s) in enumerate(kinds)]


def _assert_state_identical(res: MatchResult, state, iters, n, msg=""):
    assert np.array_equal(np.array(res.mate_row), np.array(state.mate_row)), msg
    assert np.array_equal(np.array(res.mate_col), np.array(state.mate_col)), msg
    assert np.array_equal(np.array(res.awac_iters), np.array(iters)), msg


def _legacy(fn, *args, **kwargs):
    """Call a deprecated entry point asserting it warns."""
    with pytest.warns(DeprecationWarning, match="deprecated"):
        return fn(*args, **kwargs)


# --------------------------------------------------------------------------
# local differential: solve() vs single.awpm / batch.awpm_batched
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", LOCAL_BACKENDS)
def test_solve_single_bit_identical_to_legacy(backend):
    for g in _graphs():
        res = solve(MatchingProblem.from_graph(g),
                    SolveOptions(backend=backend))
        st, iters = _legacy(
            single.awpm, jnp.asarray(g.row), jnp.asarray(g.col),
            jnp.asarray(g.val), g.n, backend=backend)
        _assert_state_identical(res, st, iters, g.n, backend)
        assert bool(res.perfect)
        assert float(res.weight) == float(single.matching_weight(st, g.n))


@pytest.mark.parametrize("backend", LOCAL_BACKENDS)
def test_solve_batched_bit_identical_to_legacy(backend):
    gs = _graphs()
    problem = MatchingProblem.stack(gs)
    res = solve(problem, SolveOptions(backend=backend))
    st, iters = _legacy(batch.awpm_batched, problem.row, problem.col,
                        problem.val, problem.n, backend=backend)
    _assert_state_identical(res, st, iters, problem.n, backend)
    assert np.array(res.perfect).all()
    # ... and per instance to the single-instance facade route
    for i in range(len(gs)):
        ri = solve(MatchingProblem.from_graph(gs[i]),
                   SolveOptions(backend=backend))
        assert np.array_equal(np.array(res.mate_row[i]),
                              np.array(ri.mate_row))
        assert int(res.awac_iters[i]) == int(ri.awac_iters)


def test_solve_respects_max_iter_and_min_gain():
    g = _graphs()[1]  # antigreedy: needs AWAC rounds
    p = MatchingProblem.from_graph(g)
    r0 = solve(p, SolveOptions(max_iter=0))
    assert int(r0.awac_iters) == 0
    r_full = solve(p)
    assert int(r_full.awac_iters) > 0
    assert float(r_full.weight) > float(r0.weight)
    # a huge min_gain admits no candidate cycles -> AWAC converges in 1 round
    r_gate = solve(p, SolveOptions(min_gain=1e9))
    assert int(r_gate.awac_iters) == 1
    assert float(r_gate.weight) == float(r0.weight)


# --------------------------------------------------------------------------
# 1x1-grid dispatch in-process (single device); multi-device in subprocess
# --------------------------------------------------------------------------


def _mesh_1x1():
    from repro.core.dist import make_mesh

    return make_mesh((1, 1))


def test_solve_grid_1x1_bit_identical_and_dist_shim_warns():
    gs = _graphs()
    problem = MatchingProblem.stack(gs)
    local = solve(problem)
    for backend in ("auto", "fused", "xla"):
        res = solve(problem, SolveOptions(grid=_mesh_1x1(), backend=backend))
        _assert_state_identical(res, local, local.awac_iters, problem.n,
                                f"grid 1x1 {backend}")
    # the deprecated one-shot dist entry point: warns, same bits
    from repro.core import dist

    st, iters, dropped = _legacy(
        dist.awpm_dist_batched, np.asarray(problem.row),
        np.asarray(problem.col), np.asarray(problem.val), problem.n,
        dist.GridSpec(_mesh_1x1()))
    assert int(dropped) == 0
    _assert_state_identical(local, st, iters, problem.n, "dist shim")
    # single-instance problems lift to B=1 on the grid
    p0 = MatchingProblem.from_graph(gs[0])
    r0 = solve(p0, SolveOptions(grid=_mesh_1x1()))
    rl = solve(p0)
    _assert_state_identical(r0, rl, rl.awac_iters, p0.n, "B=1 lift")
    assert np.shape(r0.mate_row) == (p0.n + 1,)


DIST_SCRIPT = r"""
import warnings
import numpy as np, jax
from repro.core import MatchingProblem, SolveOptions, batch, graph, plan, solve
from repro.core.dist import GridSpec, awpm_dist_batched, make_mesh

n = 32
gs = [graph.generate(n, avg_degree=4.0 + (i % 3), kind=k, seed=s)
      for i, (k, s) in enumerate([("uniform", 0), ("antigreedy", 7),
                                  ("circuit", 2), ("banded", 3)])]
problem = MatchingProblem.stack(gs)
oracle = solve(problem)  # local batched facade route (pinned to single.awpm)

for shape in ((1, 1), (2, 2), (2, 4)):
    spec = GridSpec(make_mesh(shape))
    res = solve(problem, SolveOptions(grid=spec))
    assert np.array_equal(np.array(res.mate_row), np.array(oracle.mate_row)), shape
    assert np.array_equal(np.array(res.awac_iters),
                          np.array(oracle.awac_iters)), shape

    # plan once, run twice: same planned engine, same bits
    matcher = plan(problem, SolveOptions(grid=spec))
    r1 = matcher(problem)
    r2 = matcher(problem)
    for a, b in ((r1, oracle), (r2, oracle)):
        assert np.array_equal(np.array(a.mate_row), np.array(b.mate_row)), shape
        assert np.array_equal(np.array(a.awac_iters),
                              np.array(b.awac_iters)), shape

    # legacy one-shot entry point: deprecation warning + identical bits
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        st, iters, dropped = awpm_dist_batched(
            np.asarray(problem.row), np.asarray(problem.col),
            np.asarray(problem.val), n, spec)
    assert any(issubclass(x.category, DeprecationWarning) for x in w), shape
    assert int(dropped) == 0
    assert np.array_equal(np.array(st.mate_row), np.array(oracle.mate_row)), shape
    assert np.array_equal(np.array(iters), np.array(oracle.awac_iters)), shape

# eager options validation that needs a real multi-device mesh: a
# local-sweep backend off the 1x1 grid is rejected at construction
try:
    SolveOptions(grid=GridSpec(make_mesh((2, 2))), backend="xla")
    raise SystemExit("xla backend on a 2x2 grid did not raise")
except ValueError as e:
    assert "1x1 grid" in str(e)

# single-instance lift on a multi-device grid
p0 = MatchingProblem.from_graph(gs[1])
r0 = solve(p0, SolveOptions(grid=GridSpec(make_mesh((2, 2)))))
rl = solve(p0)
assert np.array_equal(np.array(r0.mate_row), np.array(rl.mate_row))
assert int(r0.awac_iters) == int(rl.awac_iters)
print("OK")
"""


def test_solve_and_matcher_across_mesh_shapes():
    """solve()/Matcher vs the local oracle and the legacy dist entry point
    on mesh shapes {1x1, 2x2, 2x4} (8 fake devices)."""
    out = run_with_devices(DIST_SCRIPT, 8)
    assert "OK" in out


# --------------------------------------------------------------------------
# deprecation shims (local, in-process)
# --------------------------------------------------------------------------


def test_legacy_shims_warn_and_match_solve():
    g = _graphs()[0]
    p = MatchingProblem.from_graph(g)
    res = solve(p)
    st, iters = _legacy(single.awpm, jnp.asarray(g.row), jnp.asarray(g.col),
                        jnp.asarray(g.val), g.n)
    _assert_state_identical(res, st, iters, g.n)

    pb = MatchingProblem.stack([g, g])
    resb = solve(pb)
    stb, itersb = _legacy(batch.awpm_batched, pb.row, pb.col, pb.val, pb.n)
    _assert_state_identical(resb, stb, itersb, pb.n)


def test_legacy_dist_factories_warn():
    from repro.core import dist

    spec = dist.GridSpec(_mesh_1x1())
    # record=True exposes the attributed filename: the warning must point
    # at THIS call site (the migration target), not the dataclass-generated
    # __init__ or the shim internals
    with pytest.warns(DeprecationWarning, match="DistBatchedAWPM") as rec:
        dist.DistBatchedAWPM(spec, 8)
    assert rec[0].filename == __file__
    with pytest.warns(DeprecationWarning, match="DistAWPM") as rec:
        dist.DistAWPM(spec, 8, cap=16, a2a_caps=(16, 16))
    assert rec[0].filename == __file__
    with pytest.warns(DeprecationWarning, match="make_awpm_dist_batched") as rec:
        dist.make_awpm_dist_batched(spec, 8, 1, 16, (16, 16))
    assert rec[0].filename == __file__


# --------------------------------------------------------------------------
# SolveOptions / MatchingProblem validation error paths
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kwargs,match", [
    (dict(backend="bogus"), "unknown backend"),
    (dict(backend="fused"), "requires SolveOptions.grid"),
    (dict(max_iter=-1), "max_iter"),
    (dict(max_iter=1.5), "max_iter"),
    (dict(min_gain=float("nan")), "min_gain"),
    (dict(min_gain=-1.0), "min_gain"),
    (dict(window_steps=0), "window_steps"),
    (dict(window_steps=True), "window_steps"),
    (dict(cap=0), "cap must be"),
    (dict(cap=64), "requires SolveOptions.grid"),
    (dict(a2a_caps=(8, 8)), "requires SolveOptions.grid"),
    (dict(a2a_caps=(8,)), "a2a_caps"),
    (dict(packed=True), "requires SolveOptions.grid"),
    (dict(grid="nope"), "grid must be"),
], ids=lambda x: str(x)[:40])
def test_options_validation_errors(kwargs, match):
    with pytest.raises(ValueError, match=match):
        SolveOptions(**kwargs)


def test_options_accept_numpy_integers():
    o = SolveOptions(max_iter=np.int32(100), window_steps=np.int64(8))
    assert o.max_iter == 100 and type(o.max_iter) is int
    assert o.window_steps == 8 and type(o.window_steps) is int
    od = SolveOptions(grid=_mesh_1x1(), cap=np.int64(128),
                      a2a_caps=(np.int32(8), np.int32(16)))
    assert od.cap == 128 and od.a2a_caps == (8, 16)


def test_options_bad_grid_shape():
    mesh = jax.make_mesh((1, 1), ("x", "y"))
    with pytest.raises(ValueError, match="bad grid shape"):
        SolveOptions(grid=mesh)
    # (xla/pallas off the 1x1 grid is also rejected eagerly — covered in
    # the multi-device subprocess script, which can build a 2x2 mesh)


def test_dist_cap_too_small_refuses_to_truncate():
    problem = MatchingProblem.stack(_graphs())
    with pytest.raises(ValueError, match="refusing to truncate"):
        solve(problem, SolveOptions(grid=_mesh_1x1(), cap=4))


def test_dist_user_a2a_caps_that_drop_raise():
    """Undersized user-supplied exchange buckets would silently break the
    bit-identity contract (requests dropped mid-exchange), so the facade
    raises instead of returning a degraded matching."""
    problem = MatchingProblem.stack(_graphs())
    with pytest.raises(RuntimeError, match="dropped"):
        solve(problem, SolveOptions(grid=_mesh_1x1(), a2a_caps=(1, 1)))


def test_undersized_window_steps_clamps_up_not_breaks():
    """An explicit window_steps below the measured need is clamped UP
    (extra depth never changes results; under-depth would silently miss
    completion edges) — results stay bit-identical on every route."""
    gs = _graphs()
    problem = MatchingProblem.stack(gs)
    oracle = solve(problem)
    for opts in (SolveOptions(window_steps=1, backend="xla"),
                 SolveOptions(window_steps=1, grid=_mesh_1x1())):
        res = solve(problem, opts)
        _assert_state_identical(res, oracle, oracle.awac_iters, problem.n,
                                str(opts))
    p0 = MatchingProblem.from_graph(gs[1])
    r0 = solve(p0, SolveOptions(window_steps=1, backend="xla"))
    rl = solve(p0)
    _assert_state_identical(r0, rl, rl.awac_iters, p0.n)
    # ... and under jit, where the need cannot be measured: the provable
    # window_depth(min(cap, n)) bound stands in, same bits as eager
    rj = jax.jit(
        lambda pr: solve(pr, SolveOptions(window_steps=1, backend="xla"))
    )(p0)
    _assert_state_identical(rj, rl, rl.awac_iters, p0.n, "jit clamp")


def test_matcher_dist_plan_time_engine_build_is_reused():
    from repro.core.dist import _make_awpm_dist_batched

    problem = MatchingProblem.stack(_graphs())
    matcher = plan(problem, SolveOptions(grid=_mesh_1x1()))
    info = _make_awpm_dist_batched.cache_info()
    matcher(problem)
    after = _make_awpm_dist_batched.cache_info()
    assert after.misses == info.misses, "first call rebuilt the engine"
    assert after.hits == info.hits + 1
    # an undersized window_steps pin is lifted to the block bound at plan
    # time, so the cache-hit property survives the override too
    m2 = plan(problem, SolveOptions(grid=_mesh_1x1(), window_steps=1))
    info2 = _make_awpm_dist_batched.cache_info()
    m2(problem)
    after2 = _make_awpm_dist_batched.cache_info()
    assert after2.misses == info2.misses, "undersized pin rebuilt the engine"


def test_problem_and_result_identity_semantics():
    """Array-field pytrees use identity == / hash (eq=False): comparing or
    hashing must never raise the numpy truth-value/unhashable errors."""
    g = _graphs()[0]
    p = MatchingProblem.from_graph(g)
    q = MatchingProblem.from_graph(g)
    assert p == p and p != q  # identity, no ambiguous-truth-value raise
    assert {p: 1}[p] == 1  # hashable
    r = solve(p, SolveOptions(max_iter=0))
    assert r == r and hash(r) == hash(r)


def test_matcher_dist_denser_than_prototype_gives_replan_error():
    """A prototype-planned block capacity has zero headroom; a same-spec
    but denser problem must fail with re-plan guidance, not the
    partition-internal plan_block_cap advice."""
    n, cap = 16, 64
    ii = np.arange(n, dtype=np.int32)
    sparse = MatchingProblem.from_coo(ii, ii, np.full(n, 0.5, np.float32),
                                      n, capacity=cap)
    g = graph.generate(n, avg_degree=3.0, kind="uniform", seed=0)
    m = np.arange(g.capacity) < g.nnz
    dense = MatchingProblem.from_coo(g.row[m], g.col[m], g.val[m], n,
                                     capacity=cap)
    matcher = plan(sparse, SolveOptions(grid=_mesh_1x1()))
    assert np.array_equal(np.array(matcher(sparse).mate_row[:n]), ii)
    with pytest.raises(ValueError, match="plan\\(\\) again"):
        matcher(dense)


def test_matcher_dist_rejects_cap_mismatch():
    problem = MatchingProblem.stack(_graphs())
    matcher = plan(problem, SolveOptions(grid=_mesh_1x1()))
    wrong_cap = MatchingProblem(
        row=np.asarray(problem.row)[:, :-8],
        col=np.asarray(problem.col)[:, :-8],
        val=np.asarray(problem.val)[:, :-8], n=problem.n)
    with pytest.raises(ValueError, match="planned cap"):
        matcher(wrong_cap)


def test_problem_validation_and_constructors():
    g = _graphs()[0]
    with pytest.raises(ValueError, match="shapes differ"):
        MatchingProblem(row=g.row, col=g.col[:-1], val=g.val, n=g.n)
    with pytest.raises(ValueError, match="expected"):
        MatchingProblem(row=g.row.reshape(1, 1, -1),
                        col=g.col.reshape(1, 1, -1),
                        val=g.val.reshape(1, 1, -1), n=g.n)
    with pytest.raises(ValueError, match="at least one"):
        MatchingProblem.stack([])
    with pytest.raises(TypeError, match="BipartiteGraphs or MatchingProblems"):
        MatchingProblem.stack([object()])
    with pytest.raises(TypeError, match="MatchingProblem"):
        solve("not a problem")
    with pytest.raises(TypeError, match="SolveOptions"):
        solve(MatchingProblem.from_graph(g), options="fast please")

    # from_coo sorts + pads; stack accepts problems and graphs alike
    rng = np.random.default_rng(0)
    order = rng.permutation(g.nnz)
    m = np.arange(g.capacity) < g.nnz
    p1 = MatchingProblem.from_coo(g.row[m][order], g.col[m][order],
                                  g.val[m][order], g.n)
    p2 = MatchingProblem.from_graph(g)
    assert np.array_equal(np.asarray(p1.row), np.asarray(p2.row))
    st = MatchingProblem.stack([p1, g])
    assert st.batch_size == 2 and st.n == g.n
    assert np.array_equal(np.asarray(st.row[0]), np.asarray(st.row[1]))
    assert p1.batch_size is None and not p1.is_batched and st.is_batched
    assert p1.spec == ProblemSpec(n=g.n, cap=p1.cap, batch=None)
    # numpy integers (off array shapes) normalize instead of failing
    assert ProblemSpec(n=np.int32(8), cap=np.int64(16),
                       batch=np.int32(2)) == ProblemSpec(8, 16, 2)
    pnp = MatchingProblem(row=g.row, col=g.col, val=g.val, n=np.int32(g.n))
    assert plan(pnp).problem_spec.n == g.n


def test_problem_is_a_pytree():
    g = _graphs()[0]
    p = MatchingProblem.from_graph(g)

    @jax.jit
    def weight_inside_jit(problem):
        res = solve(problem, SolveOptions(backend="reference"))
        return res.weight, res.awac_iters

    w, iters = weight_inside_jit(p)
    res = solve(p, SolveOptions(backend="reference"))
    assert float(w) == float(res.weight)
    assert int(iters) == int(res.awac_iters)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert len(leaves) == 3
    p2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert p2.n == p.n and np.array_equal(np.asarray(p2.row),
                                          np.asarray(p.row))


def test_solve_grid_under_jit_raises_clearly():
    """The distributed route partitions on the host; under jit it must fail
    with the facade's own message, not an opaque tracer-conversion error."""
    problem = MatchingProblem.stack(_graphs()[:2])
    opts = SolveOptions(grid=_mesh_1x1())
    with pytest.raises(TypeError, match="outside\\s+jit"):
        jax.jit(lambda p: solve(p, opts))(problem)
    # a partially-traced problem (only val is a tracer) must hit the same
    # clear error, not an opaque tracer-conversion failure
    r, c = np.asarray(problem.row), np.asarray(problem.col)
    with pytest.raises(TypeError, match="outside\\s+jit"):
        jax.jit(lambda v: solve(
            MatchingProblem(row=r, col=c, val=v, n=problem.n), opts)
        )(problem.val)


def test_solve_under_jit_default_options_bit_identical():
    """jit(solve) with DEFAULT options (auto -> xla on CPU) must work and
    stay bit-identical to the eager call: the packed-key x64 scopes are
    skipped inside an outer trace (single._x64_scope) and the two-pass
    fallback reductions take over."""
    gs = _graphs()
    jit_solve = jax.jit(lambda pr: solve(pr))
    p = MatchingProblem.from_graph(gs[1])
    eager = solve(p)
    jitted = jit_solve(p)
    _assert_state_identical(jitted, eager, eager.awac_iters, p.n, "single")
    pb = MatchingProblem.stack(gs)
    eb = solve(pb)
    jb = jax.jit(lambda pr: solve(pr))(pb)
    _assert_state_identical(jb, eb, eb.awac_iters, pb.n, "batched")


# --------------------------------------------------------------------------
# Matcher (local): spec pinning + reuse
# --------------------------------------------------------------------------


def test_matcher_local_reuse_and_spec_checks():
    gs = _graphs()
    problem = MatchingProblem.stack(gs)
    matcher = plan(problem, SolveOptions(backend="xla"))
    r1 = matcher(problem)
    r2 = matcher(MatchingProblem.stack(list(reversed(gs))))
    oracle = solve(problem, SolveOptions(backend="xla"))
    _assert_state_identical(r1, oracle, oracle.awac_iters, problem.n)
    assert np.array_equal(np.array(r2.mate_row[::-1]),
                          np.array(r1.mate_row))

    single_p = MatchingProblem.from_graph(gs[0])
    with pytest.raises(ValueError, match="does not match the planned spec"):
        matcher(single_p)
    wrong_cap = MatchingProblem(
        row=np.asarray(problem.row)[:, :-8], col=np.asarray(problem.col)[:, :-8],
        val=np.asarray(problem.val)[:, :-8], n=problem.n)
    with pytest.raises(ValueError, match="planned cap"):
        matcher(wrong_cap)
    with pytest.raises(TypeError, match="ProblemSpec or a prototype"):
        plan("spec?")

    # plan from a bare ProblemSpec (no prototype data)
    m2 = plan(ProblemSpec(n=problem.n, cap=problem.cap,
                          batch=problem.batch_size))
    r3 = m2(problem)
    ref = solve(problem)
    _assert_state_identical(r3, ref, ref.awac_iters, problem.n)
