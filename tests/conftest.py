"""Tier-1 wall-clock budget: any test NOT marked ``slow`` that takes
longer than ``REPRO_TEST_BUDGET_S`` seconds (default 60) fails the
session, even if it passed.

The tier-1 job runs with ``--durations=15`` so the slowest tests are
always visible in the CI log; this hook turns that visibility into a
gate. A test that legitimately needs more than the budget gets
``@pytest.mark.slow`` — explicitly, so reviewers see the opt-out in the
diff — instead of silently inflating the suite every push.
"""
import os

import pytest

BUDGET_S = float(os.environ.get("REPRO_TEST_BUDGET_S", "60"))

_over_budget: list[tuple[str, float]] = []


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    report = outcome.get_result()
    if report.when != "call" or report.duration <= BUDGET_S:
        return
    if item.get_closest_marker("slow") is None:
        _over_budget.append((item.nodeid, report.duration))


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if not _over_budget:
        return
    terminalreporter.section("slow-test budget")
    for nodeid, duration in _over_budget:
        terminalreporter.write_line(
            f"OVER BUDGET {nodeid}: {duration:.1f}s > {BUDGET_S:.0f}s "
            f"(mark it @pytest.mark.slow or make it faster)")


def pytest_sessionfinish(session, exitstatus):
    if _over_budget:
        session.exitstatus = 1
