"""Static pivoting (paper §6.6): AWPM permutation must rescue a pivot-free LU."""
import jax.numpy as jnp
import numpy as np

from repro.core import graph, pivot, ref, single


def _ill_system(n=60, seed=0):
    """Diagonally weak matrix: no-pivot LU is unstable without permutation."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n)) * (rng.random((n, n)) < 0.2)
    # plant a heavy off-diagonal perfect matching
    perm = rng.permutation(n)
    a[perm, np.arange(n)] = rng.uniform(5.0, 10.0, n) * rng.choice([-1, 1], n)
    np.fill_diagonal(a, rng.uniform(0, 1e-8, n))  # tiny diagonal
    x_true = np.ones(n)
    return a, a @ x_true, x_true


def test_awpm_pivoting_recovers_solution():
    a, b, x_true = _ill_system()
    n = a.shape[0]
    a_s, _, _ = pivot.equilibrate(a)
    rr, cc = np.nonzero(a_s)
    g = graph.from_coo(rr.astype(np.int32), cc.astype(np.int32),
                       np.abs(a_s[rr, cc]).astype(np.float32), n, pad_align=8)
    st, _ = single.awpm(jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val), n)
    mr = np.array(st.mate_row[:n])
    x = pivot.static_pivot_solve(a, b, mr)
    err = pivot.relative_error(x, x_true)
    assert err < 1e-6, f"AWPM static pivoting failed: err={err}"


def test_awpm_permutation_close_to_exact_mwpm_quality():
    a, b, x_true = _ill_system(seed=3)
    n = a.shape[0]
    a_s, _, _ = pivot.equilibrate(a)
    rr, cc = np.nonzero(a_s)
    vals = np.abs(a_s[rr, cc]).astype(np.float32)
    g = graph.from_coo(rr.astype(np.int32), cc.astype(np.int32), vals, n)
    # product metric (MC64 option 5 analogue): log weights
    glog = pivot.log_transformed(g)
    st, _ = single.awpm(jnp.asarray(glog.row), jnp.asarray(glog.col),
                        jnp.asarray(glog.val), n)
    mr = np.array(st.mate_row[:n])
    dense_log = np.where(g.structure_dense(),
                         np.log(np.maximum(np.abs(g.to_dense()), 1e-30)), 0.0)
    struct = g.structure_dense()
    _, opt = ref.exact_mwpm(dense_log.astype(np.float32), struct)
    w = float(np.sum(dense_log[mr, np.arange(n)]))
    # log-weights are negative, so the 2/3 *ratio* guarantee does not apply in
    # log space; require the diagonal PRODUCT within 2x of the optimal product
    # (paper Table 6.3 shows MC64/AWPM products agree on most but not all
    # systems — e.g. circuit5M differs).
    assert np.exp(w - opt) >= 0.5


def test_lu_nopivot_known():
    a = np.array([[4.0, 3.0], [6.0, 3.0]])
    ell, u = pivot.lu_nopivot(a)
    np.testing.assert_allclose(ell @ u, a, rtol=1e-12)
