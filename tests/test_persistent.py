"""Persistent whole-iteration AWAC kernel + measured dispatch layer.

Contracts under test (ISSUE 7):
  - ``backend="pallas_persistent"`` (the whole AWAC loop — sweeps,
    select/augment, convergence — inside ONE ``pallas_call``) is
    bit-identical to every other local backend: mates, duals, AND iteration
    counts, single and batched, including the max_iter=0 and go0=False
    short-circuits.
  - ``awac_sweep_batched`` rejects an illegal edge tile with a located
    ValueError (not a ``python -O``-strippable assert), and the ops
    wrappers' ``te=None`` clamp small instances UP to one legal tile.
  - ``kernels.backend.resolve_execution`` no longer conflates "not TPU"
    with "interpreter": every compiled-lowering platform resolves to
    ``interpret=False`` and the resolved mode is recorded.
  - ``kernels.dispatch`` (the measured table behind ``backend="auto"``)
    looks up the winner per platform/shape class with the documented
    fallback chain, degrades to None (-> platform heuristic) on a missing
    or corrupt table, and ``MatchResult.execution`` records the honest
    backend/source/interpreter triple.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MatchingProblem, SolveOptions, batch, graph, single, solve
from repro.kernels import backend as kbackend
from repro.kernels import dispatch as kdispatch
from repro.kernels.cycle_gain.awac_sweep import awac_sweep_batched
from repro.kernels.cycle_gain.ops import awac_persistent_loop
from repro.sparse.csr import row_ptr_from_sorted

BACKENDS = ("reference", "xla", "pallas", "pallas_persistent")
KINDS = ["uniform", "circuit", "antigreedy", "banded", "powerlaw"]
STATE_FIELDS = ("mate_row", "mate_col", "u", "v")


def _mcm_state(g):
    row, col, val = jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val)
    st = single.greedy_maximal(row, col, val, g.n)
    st = single.mcm(row, col, val, g.n, st.mate_row, st.mate_col)
    return row, col, val, st


def _assert_states_equal(ref, other, msg):
    for nm, a, b in zip(STATE_FIELDS, ref, other):
        np.testing.assert_array_equal(np.array(a), np.array(b),
                                      err_msg=f"{msg}: {nm}")


# --------------------------------------------------------------------------
# tentpole: persistent loop bit-identity (state AND iteration counts)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_persistent_loop_bit_identical(kind):
    g = graph.generate(72, avg_degree=5.0, kind=kind, seed=KINDS.index(kind))
    row, col, val, st = _mcm_state(g)
    sR, iR = single.awac(row, col, val, g.n, st, backend="reference")
    for b in BACKENDS[1:]:
        sB, iB = single.awac(row, col, val, g.n, st, backend=b)
        assert int(iB) == int(iR), f"{kind}: {b} iters {int(iB)} != {int(iR)}"
        _assert_states_equal(sR, sB, f"{kind}: {b}")


def test_persistent_loop_actually_iterates():
    # antigreedy instances force AWAC rounds; the persistent in-kernel
    # while_loop must count them identically to the host loop
    g = graph.generate(96, avg_degree=6.0, kind="antigreedy", seed=11)
    row, col, val, st = _mcm_state(g)
    _, iR = single.awac(row, col, val, g.n, st, backend="reference")
    _, iP = single.awac(row, col, val, g.n, st, backend="pallas_persistent")
    assert int(iR) > 0
    assert int(iP) == int(iR)


def test_persistent_max_iter_zero_is_noop():
    g = graph.generate(40, avg_degree=5.0, kind="antigreedy", seed=3)
    row, col, val, st = _mcm_state(g)
    sP, iP = single.awac(row, col, val, g.n, st, max_iter=0,
                         backend="pallas_persistent")
    assert int(iP) == 0
    _assert_states_equal(st, sP, "max_iter=0")


def test_persistent_go0_false_skips_loop():
    # the degrade-infeasible gate: go0=False must return the input state
    # unchanged with a zero iteration count (whole loop skipped on-chip)
    g = graph.generate(32, avg_degree=5.0, kind="antigreedy", seed=5)
    row, col, val, st = _mcm_state(g)
    rp = row_ptr_from_sorted(row, g.n)
    ws = single._resolve_window_steps(row, g.n, None)
    mr, mc, u, v, it = awac_persistent_loop(
        row, col, val, rp, st.mate_row, st.mate_col, st.u, st.v,
        jnp.float32(1e-6), jnp.array(False), n=g.n, window_steps=ws,
        max_iter=1000)
    assert int(it) == 0
    _assert_states_equal(st, (mr, mc, u, v), "go0=False")


def test_persistent_batched_matches_single_and_xla():
    n = 48
    kinds = [("uniform", 0), ("antigreedy", 7), ("circuit", 2), ("banded", 3)]
    graphs = [graph.generate(n, avg_degree=4.0 + (i % 3), kind=k, seed=s)
              for i, (k, s) in enumerate(kinds)]
    row, col, val = batch.stack_graphs(graphs)
    mr, mc = batch.greedy_maximal_batched(row, col, val, n)
    mr, mc = batch.mcm_batched(row, col, val, n, mr, mc)
    st = batch.state_from_mates_batched(row, col, val, n, mr, mc)
    sX, iX = batch.awac_batched(row, col, val, n, st, backend="xla")
    sP, iP = batch.awac_batched(row, col, val, n, st,
                                backend="pallas_persistent")
    np.testing.assert_array_equal(np.array(iP), np.array(iX))
    _assert_states_equal(sX, sP, "batched")
    # and per instance vs its own single-instance persistent run
    for b in range(len(graphs)):
        st1 = single.MatchState(st.mate_row[b], st.mate_col[b], st.u[b],
                                st.v[b])
        s1, i1 = single.awac(row[b], col[b], val[b], n, st1,
                             backend="pallas_persistent")
        assert int(i1) == int(iP[b])
        for nm, a, bb in zip(STATE_FIELDS, s1, sP):
            np.testing.assert_array_equal(
                np.array(a), np.array(bb[b]), err_msg=f"instance {b}: {nm}")


def test_persistent_small_cap_clamps_up():
    # cap < 128: te=None must clamp up to one legal lane tile (PR 4 padding
    # policy) instead of tripping the divisibility ValueError
    n = 12
    rng = np.random.default_rng(9)
    row = np.repeat(np.arange(n, dtype=np.int32), 3)
    col = np.stack([np.arange(n), (np.arange(n) + 1) % n,
                    (np.arange(n) + 5) % n], axis=1).astype(np.int32).ravel()
    val = rng.uniform(0.1, 1.0, row.size).astype(np.float32)
    g = graph.from_coo(row, col, val, n)
    assert g.capacity < 128
    rowj, colj, valj, st = _mcm_state(g)
    sR, iR = single.awac(rowj, colj, valj, n, st, backend="reference")
    for b in ("pallas", "pallas_persistent"):
        sB, iB = single.awac(rowj, colj, valj, n, st, backend=b)
        assert int(iB) == int(iR)
        _assert_states_equal(sR, sB, f"small-cap {b}")


def test_persistent_invariant_to_tiling_and_forced_interpret():
    # the edge tiling and the execution mode are performance knobs, never
    # semantic ones: every legal te and an explicitly forced interpret flag
    # must produce the same bits as the auto-selected configuration
    g = graph.generate(96, avg_degree=6.0, kind="antigreedy", seed=2)
    row, col, val, st = _mcm_state(g)
    rp = row_ptr_from_sorted(row, g.n)
    ws = single._resolve_window_steps(row, g.n, None)

    def run(**kw):
        return awac_persistent_loop(
            row, col, val, rp, st.mate_row, st.mate_col, st.u, st.v,
            jnp.float32(1e-6), jnp.array(True), n=g.n, window_steps=ws,
            max_iter=1000, **kw)

    base = run()  # te=None (roofline plan), interpret=None (auto)
    for kw in ({"te": 128}, {"te": 256}, {"interpret": True},
               {"te": 128, "interpret": True}):
        other = run(**kw)
        assert int(other[4]) == int(base[4]), kw
        _assert_states_equal(base[:4], other[:4], f"variant {kw}")


# --------------------------------------------------------------------------
# satellite: the bare-assert bugfix (awac_sweep_batched tile check)
# --------------------------------------------------------------------------


def test_sweep_rejects_illegal_tile_with_valueerror():
    n, cap, b = 8, 256, 1
    row = jnp.full((b, cap), n, jnp.int32)
    col = jnp.full((b, cap), n, jnp.int32)
    val = jnp.zeros((b, cap), jnp.float32)
    rp = jnp.zeros((b, n + 2), jnp.int32)
    mates = jnp.full((b, n + 1), n, jnp.int32)
    duals = jnp.zeros((b, n + 1), jnp.float32)
    for te in (64, 100, 192):  # not a x128 multiple / doesn't divide cap
        with pytest.raises(ValueError, match="multiple of 128"):
            awac_sweep_batched(row, col, val, rp, mates, mates, duals, duals,
                               jnp.float32(1e-6), n=n, te=te,
                               window_steps=3, interpret=True)


def test_persistent_rejects_illegal_tile_with_valueerror():
    g = graph.generate(16, avg_degree=3.0, kind="uniform", seed=0)
    row, col, val, st = _mcm_state(g)
    rp = row_ptr_from_sorted(row, g.n)
    with pytest.raises(ValueError, match="128"):
        awac_persistent_loop(row, col, val, rp, st.mate_row, st.mate_col,
                             st.u, st.v, jnp.float32(1e-6), jnp.array(True),
                             n=g.n, window_steps=3, max_iter=4, te=100)


# --------------------------------------------------------------------------
# satellite: resolve_execution (non-TPU != interpreter)
# --------------------------------------------------------------------------


def test_resolve_execution_per_platform(monkeypatch):
    for plat, expect in [("cpu", True), ("tpu", False), ("gpu", False),
                         ("cuda", False), ("rocm", False)]:
        monkeypatch.setattr(jax, "default_backend", lambda p=plat: p)
        mode = kbackend.resolve_execution(None)
        assert mode.interpret is expect, (plat, mode)
        assert mode.platform == plat
        assert mode.forced is False
        assert mode.ran_interpreted is expect
        assert mode.describe() == f"interpret={expect}"


def test_resolve_execution_explicit_wins_and_is_recorded(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert kbackend.resolve_interpret(True) is True
    last = kbackend.last_execution()
    assert last.forced is True and last.interpret is True
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert kbackend.resolve_interpret(False) is False
    assert kbackend.last_execution().forced is True


def test_kernel_wrappers_record_last_execution():
    g = graph.generate(24, avg_degree=4.0, kind="uniform", seed=1)
    row, col, val, st = _mcm_state(g)
    single.awac(row, col, val, g.n, st, backend="pallas")
    last = kbackend.last_execution()
    assert last is not None
    assert last.platform == jax.default_backend()
    expect = jax.default_backend() not in kbackend.COMPILED_PLATFORMS
    assert last.interpret is expect


# --------------------------------------------------------------------------
# satellite: measured dispatch table behind backend="auto"
# --------------------------------------------------------------------------


def test_dispatch_shape_class():
    assert kdispatch.shape_class(None) == "single_large"
    assert kdispatch.shape_class(kdispatch.SMALL_N) == "single_small"
    assert kdispatch.shape_class(kdispatch.SMALL_N + 1) == "single_large"
    assert kdispatch.shape_class(64, batch=1) == "single_small"
    assert kdispatch.shape_class(64, batch=2) == "batched_small"
    assert kdispatch.shape_class(None, batch=8) == "batched_large"


def test_dispatch_lookup_and_fallback_chain(tmp_path):
    p = tmp_path / "table.json"
    kdispatch.save_table(
        {"cpu/single_small": {"winner": "xla",
                              "us_per_iter": {"xla": 1.0, "reference": 2.0}},
         "cpu/batched_large": {"winner": "pallas_persistent",
                               "us_per_iter": {"pallas_persistent": 1.0}}},
        {"note": "unit fixture"}, p)
    # exact class hits
    assert kdispatch.choose_backend(n=16, platform="cpu", path=p) == "xla"
    assert kdispatch.choose_backend(n=512, batch=4, platform="cpu",
                                    path=p) == "pallas_persistent"
    # same-kind fallback: single_large -> single_small measurement
    assert kdispatch.choose_backend(n=512, platform="cpu", path=p) == "xla"
    # same-kind fallback: batched_small -> batched_large measurement
    assert kdispatch.choose_backend(n=16, batch=4, platform="cpu",
                                    path=p) == "pallas_persistent"
    # unmeasured platform: None, never a guess
    assert kdispatch.choose_backend(n=16, platform="tpu", path=p) is None
    kdispatch.clear_cache()


def test_dispatch_missing_or_corrupt_table_degrades_to_none(tmp_path):
    kdispatch.clear_cache()
    missing = tmp_path / "nope.json"
    assert kdispatch.choose_backend(n=16, platform="cpu", path=missing) is None
    corrupt = tmp_path / "bad.json"
    corrupt.write_text("{not json", encoding="utf-8")
    assert kdispatch.choose_backend(n=16, platform="cpu", path=corrupt) is None
    wrong_shape = tmp_path / "wrong.json"
    wrong_shape.write_text(json.dumps({"entries": []}), encoding="utf-8")
    assert kdispatch.choose_backend(n=16, platform="cpu",
                                    path=wrong_shape) is None
    empty_winner = tmp_path / "empty.json"
    empty_winner.write_text(json.dumps(
        {"entries": {"cpu/single_small": {"winner": "",
                                          "us_per_iter": {}}}}),
        encoding="utf-8")
    assert kdispatch.choose_backend(n=16, platform="cpu",
                                    path=empty_winner) is None
    kdispatch.clear_cache()


def test_resolve_backend_consults_table_then_heuristic(tmp_path, monkeypatch):
    plat = jax.default_backend()
    p = tmp_path / "t.json"
    kdispatch.save_table(
        {f"{plat}/single_small": {"winner": "reference",
                                  "us_per_iter": {"reference": 1.0}}},
        {}, p)
    monkeypatch.setenv(kdispatch.TABLE_ENV_VAR, str(p))
    kdispatch.clear_cache()
    assert single.resolve_backend("auto", n=16) == "reference"
    # explicit backends pass through untouched
    assert single.resolve_backend("pallas_persistent") == "pallas_persistent"
    # no table -> the labeled heuristic
    monkeypatch.setenv(kdispatch.TABLE_ENV_VAR, str(tmp_path / "absent.json"))
    kdispatch.clear_cache()
    heur = single.resolve_backend("auto", n=16)
    assert heur == ("pallas" if plat == "tpu" else "xla")
    kdispatch.clear_cache()


def test_committed_table_routes_auto_to_measured_winner():
    # the acceptance check: on a platform the committed BENCH_dispatch.json
    # covers, backend="auto" must route to that measured winner
    table = kdispatch.load_table(kdispatch.DEFAULT_TABLE_PATH)
    assert table is not None, "BENCH_dispatch.json must be committed"
    plat = jax.default_backend()
    key = f"{plat}/single_large"
    if key not in table["entries"]:
        pytest.skip(f"no committed measurements for platform {plat!r}")
    entry = table["entries"][key]
    winner = entry["winner"]
    assert winner == min(entry["us_per_iter"], key=entry["us_per_iter"].get)
    assert single.resolve_backend("auto", n=2048) == winner
    # honest labeling: pallas rows on interpreter-only platforms say so
    for b, flag in entry.get("interpret", {}).items():
        assert flag is (plat not in kbackend.COMPILED_PLATFORMS), (b, flag)


# --------------------------------------------------------------------------
# satellite: MatchResult.execution (honest dispatch record) + api guards
# --------------------------------------------------------------------------


def _problem(n=24):
    g = graph.generate(n, avg_degree=4.0, kind="uniform", seed=0)
    return MatchingProblem(row=g.row, col=g.col, val=g.val, n=g.n)


def test_solve_records_explicit_execution():
    prob = _problem()
    res = solve(prob, SolveOptions(backend="reference"))
    assert res.execution.backend == "reference"
    assert res.execution.source == "explicit"
    assert res.execution.ran_interpreted is None


@pytest.mark.parametrize("backend", ["pallas", "pallas_persistent"])
def test_solve_records_interpreter_flag(backend):
    prob = _problem()
    res = solve(prob, SolveOptions(backend=backend))
    assert res.execution.backend == backend
    expect = jax.default_backend() not in kbackend.COMPILED_PLATFORMS
    assert res.execution.ran_interpreted is expect


def test_solve_records_table_vs_heuristic_source(tmp_path, monkeypatch):
    prob = _problem()
    plat = jax.default_backend()
    p = tmp_path / "t.json"
    kdispatch.save_table(
        {f"{plat}/single_small": {"winner": "xla",
                                  "us_per_iter": {"xla": 1.0}}}, {}, p)
    monkeypatch.setenv(kdispatch.TABLE_ENV_VAR, str(p))
    kdispatch.clear_cache()
    res = solve(prob, SolveOptions(backend="auto"))
    assert res.execution.backend == "xla"
    assert res.execution.source == "table"
    monkeypatch.setenv(kdispatch.TABLE_ENV_VAR, str(tmp_path / "absent.json"))
    kdispatch.clear_cache()
    res = solve(prob, SolveOptions(backend="auto"))
    assert res.execution.source == "heuristic"
    assert res.execution.backend in ("xla", "pallas")
    kdispatch.clear_cache()


def test_solve_persistent_backend_end_to_end():
    prob = _problem(n=40)
    ref = solve(prob, SolveOptions(backend="reference"))
    per = solve(prob, SolveOptions(backend="pallas_persistent"))
    np.testing.assert_array_equal(np.array(ref.mate_row),
                                  np.array(per.mate_row))
    np.testing.assert_array_equal(np.array(ref.mate_col),
                                  np.array(per.mate_col))
    assert int(ref.awac_iters) == int(per.awac_iters)
    assert float(ref.weight) == float(per.weight)
    assert bool(per.perfect)


def test_persistent_backend_rejects_grid():
    from repro.core.dist import make_mesh

    with pytest.raises(ValueError, match="pallas_persistent"):
        SolveOptions(backend="pallas_persistent", grid=make_mesh((1, 1)))
