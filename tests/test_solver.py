"""Differential tests for the static-pivoting solver (DESIGN.md §12):
``repro.solver`` against dense numpy references on every checked-in
fixture, the static-vs-threshold factorization contrast, the
AWPM-converges / unpivoted-diverges refinement result, and the
batched-RHS bit-consistency contract."""
import pathlib

import numpy as np
import pytest

import repro.solver as solver
from repro.core import ref
from repro.core.dual import dual_certificate
from repro.core.preflight import PreflightError
from repro.data.mtx import read_mtx
from repro.data.weight_transforms import log2_scaled
from repro.solver import (CsrMatrix, awpm_pivoting, identity_pivoting,
                          lu_solve_once, refine, solve_linear_system,
                          sparse_lu)

DATA = pathlib.Path(__file__).parent / "data"
FIXTURES = sorted(p.stem for p in DATA.glob("*.mtx"))


def load(stem):
    coo = read_mtx(DATA / f"{stem}.mtx")
    val = np.asarray(coo.val)
    dtype = np.complex128 if np.iscomplexobj(val) else np.float64
    return (np.asarray(coo.row, np.int64), np.asarray(coo.col, np.int64),
            val.astype(dtype), coo.nrows)


def dense_of(row, col, val, n):
    out = np.zeros((n, n), dtype=val.dtype)
    np.add.at(out, (row, col), val)
    return out


def rhs_for(n, val, seed=11):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal(n)
    if np.iscomplexobj(val):
        b = b + 1j * rng.standard_normal(n)
    return b


# --------------------------------------------------------------------------
# sparse LU: reconstruction + the static/threshold contrast
# --------------------------------------------------------------------------


@pytest.mark.parametrize("stem", FIXTURES)
def test_threshold_lu_reconstructs_fixture(stem):
    """Threshold partial pivoting must factor every fixture exactly:
    ``A[row_perm] == (I + L_strict) @ U`` to factorization round-off."""
    row, col, val, n = load(stem)
    a = CsrMatrix.from_coo(row, col, val, n)
    f = sparse_lu(a, mode="threshold")
    pa = a.to_dense()[f.row_perm]
    lu = (np.eye(n) + f.L.to_dense()) @ f.U.to_dense()
    amax = float(np.abs(val).max())
    tol = 64 * n * np.finfo(np.float64).eps * amax * \
        max(f.stats.pivot_growth, 1.0)
    assert np.max(np.abs(pa - lu)) <= tol
    assert f.stats.mode == "threshold"
    assert f.stats.nnz_l + f.stats.nnz_u == \
        pytest.approx(f.stats.fill_ratio * f.stats.nnz_in)


@pytest.mark.parametrize("stem", FIXTURES)
def test_static_lu_on_awpm_scaled_system_is_tame(stem):
    """After AWPM permutation + MC64 scalings, STATIC (no-pivoting) LU is
    numerically safe: unit diagonal in, O(1) pivot growth out, zero GESP
    perturbations — the claim the whole subsystem exists to measure."""
    row, col, val, n = load(stem)
    pivot, _ = awpm_pivoting(row, col, val, n)
    scaled = CsrMatrix.from_coo(*pivot.scaled_coo(row, col, val), n)
    f = sparse_lu(scaled, mode="static")
    assert np.array_equal(f.row_perm, np.arange(n))  # static commits
    assert f.stats.swaps == 0
    assert f.stats.perturbed_pivots == 0
    assert f.stats.pivot_growth <= 4.0
    pa = scaled.to_dense()
    lu = (np.eye(n) + f.L.to_dense()) @ f.U.to_dense()
    assert np.max(np.abs(pa - lu)) <= 64 * n * np.finfo(np.float64).eps


def test_static_vs_threshold_growth_contrast():
    """The factorization-level version of the paper's story on the
    planted ill-conditioned fixture: unpivoted static LU suffers
    astronomical pivot growth (and GESP floors most pivots), threshold
    partial pivoting keeps growth O(1) by swapping rows."""
    row, col, val, n = load("illcond9")
    a = CsrMatrix.from_coo(row, col, val, n)
    static = sparse_lu(a, mode="static")
    tpp = sparse_lu(a, mode="threshold")
    assert static.stats.pivot_growth > 1e12
    assert static.stats.perturbed_pivots > 0
    assert tpp.stats.pivot_growth <= 10.0
    assert tpp.stats.perturbed_pivots == 0
    assert tpp.stats.swaps > 0


def test_gesp_floor_on_missing_diagonal():
    """A structurally absent pivot does not abort static mode: GESP bumps
    it to the floor and counts the perturbation (refinement then decides
    whether the result is usable — here it is not, which is fine)."""
    a = CsrMatrix.from_coo([0, 1], [1, 0], [2.0, 3.0], 2)
    f = sparse_lu(a, mode="static")
    assert f.stats.perturbed_pivots >= 1
    floor = float(np.sqrt(np.finfo(np.float32).eps)) * 3.0
    assert f.stats.min_pivot == pytest.approx(floor)


def test_sparse_lu_rejects_bad_inputs():
    a = CsrMatrix.from_coo([0, 1], [0, 1], [1.0, 1.0], 2)
    with pytest.raises(ValueError, match="mode"):
        sparse_lu(a, mode="full")
    with pytest.raises(ValueError, match="threshold"):
        sparse_lu(a, mode="threshold", threshold=0.0)
    with pytest.raises(ValueError, match="structurally singular"):
        # column 1 is empty: threshold pivoting has nothing to swap in
        sparse_lu(CsrMatrix.from_coo([0, 1], [0, 0], [1.0, 1.0], 2),
                  mode="threshold")
    with pytest.raises(ValueError, match="all-zero"):
        sparse_lu(CsrMatrix.from_coo([], [], [], 2))


# --------------------------------------------------------------------------
# end-to-end: differential against dense numpy on every fixture
# --------------------------------------------------------------------------


@pytest.mark.parametrize("stem", FIXTURES)
def test_solve_matches_dense_reference(stem):
    row, col, val, n = load(stem)
    b = rhs_for(n, val)
    rep = solve_linear_system((row, col, val, n), b, pivoting="awpm")
    assert rep.ok, rep.summary()
    dense = dense_of(row, col, val, n)
    x_ref = np.linalg.solve(dense, b)
    cond = np.linalg.cond(dense)
    err = np.linalg.norm(rep.x - x_ref) / np.linalg.norm(x_ref)
    assert err <= 100 * cond * max(float(np.max(rep.residual)), 1e-16), \
        f"{stem}: rel error {err:.3e} vs cond {cond:.3e}"
    # the report's residual is the TRUE f64 residual of the returned x
    rr = np.linalg.norm(b - dense @ rep.x) / np.linalg.norm(b)
    assert float(np.max(rep.residual)) == pytest.approx(rr, rel=1e-6)


@pytest.mark.parametrize("stem", FIXTURES)
def test_awpm_scaled_diagonal_is_unit(stem):
    """Every fixture's AWPM certificate is tight, so the MC64 scaling
    identity must land the matched diagonal at exactly 1 and every scaled
    entry at most 1 — dominance by construction, not luck."""
    row, col, val, n = load(stem)
    pivot, result = awpm_pivoting(row, col, val, n)
    assert bool(np.asarray(result.perfect).all())
    assert pivot.certificate.tight
    diag = pivot.scaled_diag(row, col, val)
    assert np.allclose(diag, 1.0, atol=1e-9)
    _, _, pv = pivot.scaled_coo(row, col, val)
    assert float(np.abs(pv).max()) <= 1.0 + 1e-9
    # the permutation round-trips: original row i sits at row_position[i]
    assert np.array_equal(pivot.row_perm[pivot.row_position],
                          np.arange(n))


def test_contrast_awpm_converges_unpivoted_diverges():
    """The headline result on the planted ill-conditioned fixture: the
    identical factorization+refinement pipeline converges with AWPM static
    pivoting and fails without it."""
    row, col, val, n = load("illcond9")
    b = rhs_for(n, val)
    good = solve_linear_system((row, col, val, n), b, pivoting="awpm")
    bad = solve_linear_system((row, col, val, n), b, pivoting="none")
    assert good.ok
    assert float(np.max(good.residual)) <= 1e-10
    assert good.lu_stats.pivot_growth <= 4.0
    assert not bad.ok
    assert bad.lu_stats.pivot_growth > 1e12
    assert float(np.max(bad.residual)) > 1e-6
    assert bool((bad.refinement.diverged | bad.refinement.stalled).all())
    # threshold partial pivoting also rescues it — matching replaces
    # exactly the work the classical solver spends at factor time
    tpp = solve_linear_system((row, col, val, n), b, pivoting="none",
                              lu_mode="threshold")
    assert tpp.ok


@pytest.mark.skipif(not ref.HAVE_SCIPY, reason="needs scipy oracle")
def test_reference_arm_matches_awpm_on_fixtures():
    """AWPM vs the exact Hungarian matching, identical scaling recovery:
    on the fixtures both arms converge with unit scaled diagonals."""
    for stem in ("circuit8", "illcond9"):
        row, col, val, n = load(stem)
        b = rhs_for(n, val)
        rep = solve_linear_system((row, col, val, n), b,
                                  pivoting="reference")
        assert rep.ok, f"{stem}: {rep.summary()}"
        assert rep.matching_tight
        assert rep.scaled_diag_min == pytest.approx(1.0, abs=1e-9)


def test_input_forms_agree_bitwise():
    """Dense array, CsrMatrix, and COO tuple are the same system — the
    returned x must be bit-identical across input forms."""
    row, col, val, n = load("circuit8")
    b = rhs_for(n, val)
    from_coo = solve_linear_system((row, col, val, n), b)
    from_dense = solve_linear_system(dense_of(row, col, val, n), b)
    from_csr = solve_linear_system(CsrMatrix.from_coo(row, col, val, n), b)
    assert np.array_equal(from_coo.x, from_dense.x)
    assert np.array_equal(from_coo.x, from_csr.x)


def test_complex_fixture_solves():
    row, col, val, n = load("zcoil7")
    assert np.iscomplexobj(val)
    b = rhs_for(n, val)
    rep = solve_linear_system((row, col, val, n), b)
    assert rep.ok
    assert np.iscomplexobj(rep.x)
    x_ref = np.linalg.solve(dense_of(row, col, val, n), b)
    assert np.linalg.norm(rep.x - x_ref) <= 1e-8 * np.linalg.norm(x_ref)


# --------------------------------------------------------------------------
# batching: the bit-consistency contract
# --------------------------------------------------------------------------


@pytest.mark.parametrize("batch", [2, 4, 8])
def test_batched_rhs_bit_identical_to_single(batch):
    """Solving [B, n] right-hand sides must reproduce each single-RHS
    solve bit-for-bit, lane by lane — the triangular sweeps and the
    residual path are written to be shape-invariant (DESIGN.md §12)."""
    row, col, val, n = load("circuit8")
    rng = np.random.default_rng(23)
    bs = rng.standard_normal((batch, n))
    rep_b = solve_linear_system((row, col, val, n), bs)
    assert rep_b.x.shape == (batch, n)
    assert rep_b.ok
    for lane in range(batch):
        rep_1 = solve_linear_system((row, col, val, n), bs[lane])
        assert np.array_equal(rep_b.x[lane], rep_1.x), f"lane {lane}"
        assert rep_b.residual[lane] == rep_1.residual[0]


def test_refine_freezes_lanes_independently():
    """One diverging lane must not poison its batch: refine illcond9's
    garbage static factors with a batch, and every lane freezes with its
    own flag while the array stays rectangular."""
    row, col, val, n = load("illcond9")
    a = CsrMatrix.from_coo(row, col, val, n)
    f = sparse_lu(a, mode="static")
    rng = np.random.default_rng(5)
    b = rng.standard_normal((3, n))
    out = refine(a, f, b, tol=1e-12, max_iter=10)
    assert out.residuals.shape[1] == 3
    assert out.x.shape == (3, n)
    assert not out.converged.any()
    assert bool((out.diverged | out.stalled).all())
    # frozen lanes repeat their freeze-time residual on the record
    assert np.array_equal(out.residuals[-1], out.final_residual)


def test_lu_solve_once_single_is_b1_lift():
    row, col, val, n = load("bands6_sym")
    pivot, _ = awpm_pivoting(row, col, val, n)
    scaled = CsrMatrix.from_coo(*pivot.scaled_coo(row, col, val), n)
    f = sparse_lu(scaled, mode="static")
    b = rhs_for(n, val)
    x1 = lu_solve_once(f, b)
    xb = lu_solve_once(f, np.stack([b, 2.0 * b]))
    assert x1.shape == (n,) and xb.shape == (2, n)
    assert np.array_equal(x1, xb[0])
    # a single f32 pass already lands near the f32 noise floor
    rel = np.linalg.norm(b - scaled.to_dense() @ x1) / np.linalg.norm(b)
    assert rel < 1e-4


# --------------------------------------------------------------------------
# structural failure modes + dual potentials accessor
# --------------------------------------------------------------------------


def test_structural_singularity_raises_preflight():
    # column 2 has no entries: no perfect matching, no pivot order
    row = np.array([0, 1, 2])
    col = np.array([0, 1, 0])
    val = np.array([1.0, 2.0, 3.0])
    with pytest.raises(PreflightError):
        solve_linear_system((row, col, val, 3), np.ones(3))
    # check=False only lets the UNMATCHED arm proceed past preflight
    with pytest.raises(PreflightError):
        solve_linear_system((row, col, val, 3), np.ones(3),
                            pivoting="awpm", check=False)


def test_solve_rejects_bad_arguments():
    row, col, val, n = load("bands6_sym")
    with pytest.raises(ValueError, match="pivoting"):
        solve_linear_system((row, col, val, n), np.ones(n),
                            pivoting="partial")
    with pytest.raises(ValueError, match="width"):
        solve_linear_system((row, col, val, n), np.ones(n + 1))
    with pytest.raises(ValueError, match="square"):
        solve_linear_system(np.ones((2, 3)), np.ones(3))


def test_potentials_accessor_is_feasible_and_copied():
    """``DualCertificate.potentials()`` — the hook the MC64 scaling
    recovery consumes: feasible on every edge, tight on matched edges,
    and returning copies the caller can freely mutate."""
    row, col, val, n = load("circuit8")
    a = np.abs(val)
    w = log2_scaled(row, col, a, n)
    _, result = awpm_pivoting(row, col, val, n)
    mate = np.asarray(result.mate_row)[:n]
    cert = dual_certificate(row, col, w, n, mate)
    u, v = cert.potentials()
    assert u.dtype == v.dtype == np.float64
    slack = u[row] + v[col] - w
    assert float(slack.min()) >= -1e-9  # feasible everywhere
    matched = mate[col] == row
    assert cert.tight
    assert float(np.abs(slack[matched]).max()) <= 1e-9
    u[:] = -1e9  # mutating the return must not corrupt the certificate
    u2, _ = cert.potentials()
    assert float(u2.min()) > -1e9


def test_identity_pivoting_is_noop():
    p = identity_pivoting(4)
    b = np.arange(4.0)
    assert np.array_equal(p.scale_rhs(b), b)
    assert np.array_equal(p.unscale_solution(b), b)
    with pytest.raises(ValueError, match="permutation"):
        solver.ScaledPivoting(n=2, row_perm=np.array([0, 0]),
                              dr=np.ones(2), dc=np.ones(2))


# --------------------------------------------------------------------------
# property test (hypothesis optional) + export surface
# --------------------------------------------------------------------------


def test_property_random_dominant_systems_converge():
    """Hypothesis sweep: on random row-dominant systems with wildly
    scaled rows, AWPM static pivoting always converges to the dense
    reference (skipped where hypothesis is not installed — the CI solver
    job runs it)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = hypothesis.strategies

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(st.integers(3, 14), st.integers(0, 10_000))
    def check(n, seed):
        rng = np.random.default_rng(seed)
        row, col, val = [], [], []
        for i in range(n):
            d = float(np.exp2(rng.integers(-20, 20))) * (1.0 + rng.random())
            row.append(i)
            col.append(i)
            val.append(d)
            for j in ((i + 1) % n, (i + 5) % n):
                if j != i:
                    row.append(i)
                    col.append(j)
                    val.append(0.2 * d * (0.1 + rng.random()))
        row, col = np.array(row), np.array(col)
        val = np.array(val)
        b = rng.standard_normal(n)
        rep = solve_linear_system((row, col, val, n), b)
        assert rep.ok, rep.summary()
        dense = dense_of(row, col, val, n)
        x_ref = np.linalg.solve(dense, b)
        err = np.linalg.norm(rep.x - x_ref) / np.linalg.norm(x_ref)
        assert err <= 100 * np.linalg.cond(dense) * 1e-10

    check()


def test_solver_export_surface():
    expected = [
        "CsrMatrix",
        "LUFactorization",
        "LUStats",
        "PIVOTING_MODES",
        "RefineResult",
        "ScaledPivoting",
        "SolveReport",
        "awpm_pivoting",
        "from_matching",
        "identity_pivoting",
        "lu_solve_once",
        "reference_pivoting",
        "refine",
        "solve_linear_system",
        "sparse_lu",
    ]
    assert sorted(solver.__all__) == expected
    for name in solver.__all__:
        assert hasattr(solver, name)
