"""Public-API snapshot: the exported surfaces of ``repro.core``,
``repro.data``, and ``repro.solver`` are contracts — additions are
deliberate (update the snapshot in the same PR that extends the facade),
removals/renames are breaking and must not happen silently. Also guards
the facade acceptance rule: no consumer (pivot, moe, examples,
benchmarks) may call a legacy matching entry point directly anymore."""
import pathlib
import re

import repro.core as core
import repro.data as data
import repro.solver as solver_mod

REPO = pathlib.Path(__file__).resolve().parents[1]

# the one public surface (DESIGN.md §7): facade types + callables first,
# then the submodules and the graph utilities that predate the facade
EXPECTED_EXPORTS = [
    "BACKENDS",
    "BipartiteGraph",
    "DualCertificate",
    "InfeasibleProblemError",
    "MIN_GAIN",
    "MatchResult",
    "Matcher",
    "MatchingProblem",
    "ON_INVALID",
    "PreflightError",
    "PreflightReport",
    "ProblemSpec",
    "SolveOptions",
    "api",
    "batch",
    "certify",
    "dual",
    "dual_certificate",
    "from_coo",
    "generate",
    "graph",
    "matrix_suite",
    "pivot",
    "plan",
    "preflight",
    "ref",
    "single",
    "solve",
]

EXPECTED_API_EXPORTS = [
    "BACKENDS",
    "MIN_GAIN",
    "MatchResult",
    "Matcher",
    "MatchingProblem",
    "ON_INVALID",
    "ProblemSpec",
    "SolveOptions",
    "plan",
    "solve",
]


# the ingestion facade: suitesparse (opt-in network) rides next to the
# fixture loaders, never silently replacing them
EXPECTED_DATA_EXPORTS = [
    "matrices",
    "mtx",
    "suitesparse",
    "weight_transforms",
]

# the solver subsystem (DESIGN.md §12): matching-as-pivoting end to end
EXPECTED_SOLVER_EXPORTS = [
    "CsrMatrix",
    "LUFactorization",
    "LUStats",
    "PIVOTING_MODES",
    "RefineResult",
    "ScaledPivoting",
    "SolveReport",
    "awpm_pivoting",
    "from_matching",
    "identity_pivoting",
    "lu_solve_once",
    "reference_pivoting",
    "refine",
    "solve_linear_system",
    "sparse_lu",
]


def test_core_export_snapshot():
    assert sorted(core.__all__) == EXPECTED_EXPORTS
    for name in core.__all__:
        assert hasattr(core, name), f"__all__ exports missing name {name}"


def test_api_export_snapshot():
    assert sorted(core.api.__all__) == EXPECTED_API_EXPORTS
    for name in core.api.__all__:
        assert hasattr(core.api, name)
    # the facade re-exports are the same objects, not copies
    assert core.solve is core.api.solve
    assert core.MatchingProblem is core.api.MatchingProblem
    assert core.MIN_GAIN == core.single.MIN_GAIN == core.ref.MIN_GAIN


def test_data_export_snapshot():
    assert sorted(data.__all__) == EXPECTED_DATA_EXPORTS
    for name in data.__all__:
        assert hasattr(data, name)


def test_solver_export_snapshot():
    assert sorted(solver_mod.__all__) == EXPECTED_SOLVER_EXPORTS
    for name in solver_mod.__all__:
        assert hasattr(solver_mod, name)
    # the certificate accessor the solver's scaling recovery depends on
    assert callable(core.DualCertificate.potentials)


# --------------------------------------------------------------------------
# no consumer calls a legacy entry point directly anymore
# --------------------------------------------------------------------------

# the deprecated names (word-bounded, so e.g. bench_awpm_batched and
# awpm_route don't match)
_LEGACY = re.compile(
    r"\bsingle\.awpm\b|\bawpm_batched\b|\bawpm_dist_batched\b"
    r"|\bDistAWPM\b|\bDistBatchedAWPM\b|\bmake_awpm_dist_batched\b")

CONSUMER_FILES = [
    "src/repro/core/pivot.py",
    "src/repro/models/moe.py",
    *sorted(str(p.relative_to(REPO)) for p in (REPO / "examples").glob("*.py")),
    *sorted(str(p.relative_to(REPO)) for p in (REPO / "benchmarks").glob("*.py")),
]


def test_no_consumer_calls_legacy_entry_points():
    offenders = []
    for rel in CONSUMER_FILES:
        for lineno, line in enumerate(
                (REPO / rel).read_text().splitlines(), 1):
            code = line.split("#", 1)[0]
            if _LEGACY.search(code):
                offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "legacy matching entry points must go through repro.core.api:\n"
        + "\n".join(offenders))
