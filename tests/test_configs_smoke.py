"""Per-architecture smoke tests: instantiate the REDUCED config of the same
family and run one forward/train step on CPU, asserting output shapes and no
NaNs (the FULL configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import ShapeSpec
from repro.data import graphs as G
from repro.models import build_defs, build_loss
from repro.models.param import count_params, init_params

LM_ARCHS = ["qwen2-0.5b", "qwen1.5-110b", "qwen2-7b", "qwen2-moe-a2.7b",
            "deepseek-moe-16b"]
GNN_ARCHS = ["graphsage-reddit", "equiformer-v2", "dimenet", "graphcast"]


def _assert_finite(x, name):
    arr = np.asarray(jax.device_get(x), np.float32)
    assert np.isfinite(arr).all(), f"{name}: non-finite values"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    defs = build_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    assert count_params(defs) > 0
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((b, s), jnp.float32)}
    loss_fn = build_loss(cfg)
    (loss, aux), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch), has_aux=True)(params)
    _assert_finite(loss, arch)
    for leaf in jax.tree.leaves(grads):
        _assert_finite(leaf, f"{arch} grads")


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode(arch):
    from repro.models import transformer as T

    cfg = get_config(arch, reduced=True)
    params = init_params(build_defs(cfg), jax.random.PRNGKey(0))
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    logits, cache = jax.jit(lambda p, t: T.prefill(p, t, cfg))(params, tokens)
    assert logits.shape == (b, cfg.vocab)
    _assert_finite(logits, arch)
    smax = s + 2

    def grow(kv):
        k, v = kv
        kb = jnp.zeros((k.shape[0], b, smax, *k.shape[3:]), k.dtype)
        return kb.at[:, :, :s].set(k), jnp.zeros_like(kb).at[:, :, :s].set(v)

    cache = {g: grow(kv) for g, kv in cache.items()}
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lg, cache = jax.jit(lambda p, c, t: T.decode_step(p, c, t, jnp.int32(s), cfg))(
        params, cache, tok)
    assert lg.shape == (b, cfg.vocab)
    _assert_finite(lg, f"{arch} decode")


def test_moe_awpm_router_variant():
    cfg = get_config("qwen2-moe-a2.7b", reduced=True, router="awpm")
    params = init_params(build_defs(cfg), jax.random.PRNGKey(0))
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((b, s), jnp.float32)}
    loss, aux = jax.jit(build_loss(cfg))(params, batch)
    _assert_finite(loss, "awpm-router")


def _gnn_batch(cfg, shape_name):
    if cfg.kind == "graphcast":
        return jax.tree.map(jnp.asarray,
                            G.random_graphcast_batch(120, cfg.opt("n_vars", 12)))
    coords = cfg.kind in ("dimenet", "equiformer_v2")
    if shape_name == "molecule":
        gb = G.random_graph(60, 128, 8, seed=0, coords=coords, n_graphs=4,
                            triplets=cfg.kind == "dimenet")
    else:
        gb = G.random_graph(80, 240, 8, n_classes=7, seed=0, coords=coords,
                            triplets=cfg.kind == "dimenet")
    return jax.tree.map(jnp.asarray, gb)


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("shape_name", ["full_graph_sm", "molecule"])
def test_gnn_smoke(arch, shape_name):
    cfg = get_config(arch, reduced=True)
    shape = ShapeSpec(shape_name, "train", (("d_feat", 8),))
    defs = build_defs(cfg, shape)
    params = init_params(defs, jax.random.PRNGKey(0))
    gb = _gnn_batch(cfg, shape_name)
    loss_fn = build_loss(cfg)
    (loss, _), grads = jax.value_and_grad(lambda p: loss_fn(p, gb),
                                          has_aux=True)(params)
    _assert_finite(loss, arch)
    for leaf in jax.tree.leaves(grads):
        _assert_finite(leaf, f"{arch} grads")


def test_gnn_minibatch_sampled_blocks():
    """graphsage on real sampled blocks (the minibatch_lg regime, reduced)."""
    from repro.models.gnn.common import GraphBatch
    from repro.models.gnn.sampler import build_csr, sample_blocks

    cfg = get_config("graphsage-reddit", reduced=True)
    rng = np.random.default_rng(0)
    n, e = 2000, 12000
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    feats = rng.normal(size=(n, 8)).astype(np.float32)
    labels = rng.integers(0, 7, n).astype(np.int32)
    ptr, nbrs = build_csr(n, src, dst)
    blocks = sample_blocks(ptr, nbrs, rng.integers(0, n, 16), [5, 3], rng)
    nt = len(blocks.node_ids)
    gb = GraphBatch(
        node_feat=jnp.asarray(feats[blocks.node_ids]),
        edge_src=jnp.asarray(blocks.edge_src),
        edge_dst=jnp.asarray(blocks.edge_dst),
        labels=jnp.asarray(labels[blocks.node_ids]),
    )
    shape = ShapeSpec("minibatch_lg", "train", (("d_feat", 8),))
    params = init_params(build_defs(cfg, shape), jax.random.PRNGKey(0))
    loss, logits = build_loss(cfg)(params, gb)
    _assert_finite(loss, "sage-minibatch")
    assert logits.shape == (nt, 41)


def test_recsys_smoke():
    from repro.models.recsys import bert4rec

    cfg = get_config("bert4rec", reduced=True)
    params = init_params(build_defs(cfg), jax.random.PRNGKey(0))
    b = 4
    seq = jax.random.randint(jax.random.PRNGKey(1), (b, cfg.seq_len), 0,
                             cfg.n_items)
    batch = {"item_seq": seq, "labels": seq,
             "mask": (jax.random.uniform(jax.random.PRNGKey(2),
                                         (b, cfg.seq_len)) < 0.2).astype(
                 jnp.float32)}
    (loss, _), grads = jax.value_and_grad(
        lambda p: build_loss(cfg)(p, batch), has_aux=True)(params)
    _assert_finite(loss, "bert4rec")
    scores = bert4rec.serve_scores(params, seq, cfg)
    assert scores.shape == (b, cfg.padded_items)
    r = bert4rec.retrieval_scores(params, seq[:1],
                                  jnp.arange(64, dtype=jnp.int32), cfg)
    assert r.shape == (1, 64)
    _assert_finite(r, "retrieval")


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    c = get_config("qwen2-0.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (24, 896, 14, 2, 4864, 151936)
    c = get_config("qwen1.5-110b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (80, 8192, 64, 8, 49152, 152064)
    c = get_config("qwen2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (28, 3584, 28, 4, 18944, 152064)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k,
            c.moe.d_ff_expert, c.vocab, c.moe.n_shared) == (
        24, 2048, 60, 4, 1408, 151936, 4)
    c = get_config("deepseek-moe-16b")
    assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k,
            c.moe.d_ff_expert, c.vocab, c.moe.n_shared) == (
        28, 2048, 64, 6, 1408, 102400, 2)
    c = get_config("graphsage-reddit")
    assert (c.n_layers, c.d_hidden) == (2, 128)
    c = get_config("equiformer-v2")
    assert (c.n_layers, c.d_hidden, c.opt("l_max"), c.opt("m_max"),
            c.opt("n_heads")) == (12, 128, 6, 2, 8)
    c = get_config("dimenet")
    assert (c.n_layers, c.d_hidden, c.opt("n_bilinear"), c.opt("n_spherical"),
            c.opt("n_radial")) == (6, 128, 8, 7, 6)
    c = get_config("graphcast")
    assert (c.n_layers, c.d_hidden, c.opt("n_vars")) == (16, 512, 227)
    c = get_config("bert4rec")
    assert (c.embed_dim, c.n_blocks, c.n_heads, c.seq_len) == (64, 2, 2, 200)
    assert len(ASSIGNED_ARCHS) == 10
