"""Hypothesis property tests on the matching system's invariants."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import batch, graph, ref, single
from repro.core.api import MatchingProblem, SolveOptions, solve
from repro.sparse.ops import lex_searchsorted

SET = dict(max_examples=25, deadline=None)
LOCAL_BACKENDS = ("reference", "xla", "pallas")


@st.composite
def planted_graph(draw, n=None):
    if n is None:
        n = draw(st.integers(8, 40))
    deg = draw(st.floats(2.0, 6.0))
    kind = draw(st.sampled_from(["uniform", "circuit", "antigreedy", "banded"]))
    seed = draw(st.integers(0, 10_000))
    return graph.generate(n, avg_degree=deg, kind=kind, seed=seed)


@st.composite
def deficient_problem(draw):
    """A planted graph with every edge of one column removed — structurally
    infeasible (that column can never be matched), with the victim column
    drawn so the deficiency is not always at the boundary."""
    g = draw(planted_graph())
    victim = draw(st.integers(0, g.n - 1))
    keep = np.asarray(g.col) != victim
    row = np.asarray(g.row)[keep]
    col = np.asarray(g.col)[keep]
    val = np.asarray(g.val)[keep]
    return MatchingProblem.from_coo(row, col, val, g.n), victim


@st.composite
def planted_batch(draw):
    """A batch of heterogeneous planted graphs (mixed kinds/degrees/seeds)
    sharing n, stacked to a common padded capacity."""
    n = draw(st.integers(8, 24))
    b = draw(st.integers(2, 4))
    return [draw(planted_graph(n=n)) for _ in range(b)]


@given(planted_graph())
@settings(**SET)
def test_awpm_perfect_valid_and_two_thirds_optimal(g):
    dense = g.to_dense().astype(np.float32)
    struct = g.structure_dense()
    st_, iters = single.awpm(jnp.asarray(g.row), jnp.asarray(g.col),
                             jnp.asarray(g.val), g.n)
    mr = np.array(st_.mate_row[: g.n])
    mc = np.array(st_.mate_col[: g.n])
    ref.check_matching(struct, mr)
    assert ref.is_perfect(mr, g.n)
    # Pettie-Sanders: no augmenting 4-cycle => >= 2/3-optimal
    assert ref.find_augmenting_4cycle(dense, struct, mr, mc) is None
    _, opt = ref.exact_mwpm(dense, struct)
    w = float(single.matching_weight(st_, g.n))
    assert w >= (2.0 / 3.0) * opt - 1e-4


@given(planted_batch())
@settings(max_examples=15, deadline=None)
def test_awpm_batched_perfect_valid_and_two_thirds_optimal(gs):
    """Every instance routed through the batched engine satisfies the same
    invariants the sequential engine guarantees: a valid perfect matching
    that admits no augmenting 4-cycle and is >= 2/3-optimal."""
    n = gs[0].n
    row, col, val = batch.stack_graphs(gs)
    stB, _ = batch.awpm_batched(row, col, val, n)
    assert bool(batch.is_perfect_batched(stB, n).all())
    weights = np.array(batch.matching_weight_batched(stB, n))
    for i, g in enumerate(gs):
        dense = g.to_dense().astype(np.float32)
        struct = g.structure_dense()
        mr = np.array(stB.mate_row[i, :n])
        mc = np.array(stB.mate_col[i, :n])
        ref.check_matching(struct, mr)
        assert ref.is_perfect(mr, n)
        assert ref.find_augmenting_4cycle(dense, struct, mr, mc) is None
        _, opt = ref.exact_mwpm(dense, struct)
        assert weights[i] >= (2.0 / 3.0) * opt - 1e-4


@given(planted_graph())
@settings(**SET)
def test_awac_round_never_decreases_weight_and_stays_perfect(g):
    dense = g.to_dense().astype(np.float32)
    struct = g.structure_dense()
    mr, mc = ref.greedy_maximal(dense, struct)
    mr, mc = ref.mcm_kuhn(dense, struct, mr, mc)
    w_prev = ref.matching_weight(dense, mr)
    for _ in range(50):
        surv, n_cand = ref.awac_round_select(dense, struct, mr, mc)
        if not surv:
            break
        mr, mc = ref.apply_cycles(mr, mc, surv)
        ref.check_matching(struct, mr)
        assert ref.is_perfect(mr, g.n)
        w = ref.matching_weight(dense, mr)
        assert w > w_prev - 1e-6
        w_prev = w


@given(planted_graph())
@settings(**SET)
def test_survivor_cycles_are_vertex_disjoint(g):
    dense = g.to_dense().astype(np.float32)
    struct = g.structure_dense()
    mr, mc = ref.greedy_maximal(dense, struct)
    mr, mc = ref.mcm_kuhn(dense, struct, mr, mc)
    surv, _ = ref.awac_round_select(dense, struct, mr, mc)
    rows, cols = set(), set()
    for i, j in surv:
        r2, c2 = mr[j], mc[i]
        for r in (i, r2):
            assert r not in rows
            rows.add(r)
        for c in (j, c2):
            assert c not in cols
            cols.add(c)


@given(deficient_problem())
@settings(max_examples=15, deadline=None)
def test_infeasible_short_circuits_consistently_across_backends(arg):
    """Structurally deficient instances must terminate promptly with
    ``perfect=False`` under ``on_invalid="degrade"`` — AWAC preserves
    cardinality, so its round budget is pure waste on an imperfect matching
    and the pipeline short-circuits after MCM (``awac_iters == 0`` even with
    an absurd ``max_iter``). The maximal matching and its sentinel slots
    must agree bit-for-bit across every local backend."""
    problem, victim = arg
    n = problem.n
    mates = {}
    for backend in LOCAL_BACKENDS:
        opts = SolveOptions(backend=backend, on_invalid="degrade",
                            max_iter=10**6)
        t0 = time.perf_counter()
        res = solve(problem, opts)
        dt = time.perf_counter() - t0
        # timing assertion: O(MCM) work, never max_iter AWAC rounds (a
        # million rounds at ~ms each would be hours, not seconds)
        assert dt < 5.0, f"{backend}: {dt:.1f}s — AWAC was not skipped?"
        assert not bool(res.perfect)
        assert int(res.awac_iters) == 0
        mr = np.asarray(res.mate_row)
        mc = np.asarray(res.mate_col)
        assert mr.shape == mc.shape == (n + 1,)
        assert mr[victim] == n  # the deficient column is unmatched
        assert res.diagnosis is not None and not res.diagnosis.solvable
        mates[backend] = (mr, mc)
        # the partial matching is still consistent: matched pairs mutual,
        # unmatched slots hold the sentinel n
        matched = np.nonzero(mr[:n] < n)[0]
        assert np.array_equal(mc[mr[matched]], matched)
    ref_mr, ref_mc = mates["reference"]
    for backend in LOCAL_BACKENDS[1:]:
        mr, mc = mates[backend]
        assert np.array_equal(mr, ref_mr), f"{backend} mate_row diverges"
        assert np.array_equal(mc, ref_mc), f"{backend} mate_col diverges"


def test_infeasible_short_circuits_on_1x1_grid():
    """The distributed route honours the same degrade short-circuit and
    produces the same sentinel mates as the local engines (1x1 grid runs
    in-process; the multi-device variant lives in tests/test_chaos.py)."""
    import jax

    g = graph.generate(16, avg_degree=4.0, kind="uniform", seed=3)
    keep = np.asarray(g.col) != 5
    problem = MatchingProblem.from_coo(np.asarray(g.row)[keep],
                                       np.asarray(g.col)[keep],
                                       np.asarray(g.val)[keep], g.n)
    local = solve(problem, SolveOptions(on_invalid="degrade", max_iter=10**6))
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    dist = solve(problem, SolveOptions(grid=mesh, on_invalid="degrade",
                                       max_iter=10**6))
    assert not bool(dist.perfect) and int(dist.awac_iters) == 0
    assert np.array_equal(np.asarray(dist.mate_row),
                          np.asarray(local.mate_row))
    assert np.array_equal(np.asarray(dist.mate_col),
                          np.asarray(local.mate_col))
    assert dist.diagnosis is not None and not dist.diagnosis.solvable


@given(
    st.lists(st.tuples(st.integers(0, 30), st.integers(0, 30)), min_size=1,
             max_size=60),
    st.lists(st.tuples(st.integers(0, 31), st.integers(0, 31)), min_size=1,
             max_size=20),
)
@settings(**SET)
def test_lex_searchsorted_matches_python(pairs, queries):
    pairs = sorted(set(pairs))
    kr = jnp.array([p[0] for p in pairs], jnp.int32)
    kc = jnp.array([p[1] for p in pairs], jnp.int32)
    qr = jnp.array([q[0] for q in queries], jnp.int32)
    qc = jnp.array([q[1] for q in queries], jnp.int32)
    pos, found = lex_searchsorted(kr, kc, qr, qc)
    pset = set(pairs)
    for k, q in enumerate(queries):
        assert bool(found[k]) == (q in pset)
        if q in pset:
            assert pairs[int(pos[k])] == q
