"""MoE router + dispatch unit tests (incl. the AWPM router = the paper's
technique applied to token->expert assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import (
    awpm_route,
    awpm_route_batched,
    balanced_assign,
    balanced_assign_batched,
    swap_improve,
    swap_improve_batched,
    topk_route,
)


def _logits(t, e, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(t, e)),
                       jnp.float32)


def test_topk_route_slots_and_weights():
    t, e, k, cap = 64, 8, 2, 24
    lg = _logits(t, e)
    topi, slot, w, keep, aux = topk_route(lg, k, cap)
    assert topi.shape == (t, k) and slot.shape == (t, k)
    # slots unique within each expert among kept entries
    pairs = set()
    for i in range(t):
        for j in range(k):
            if bool(keep[i, j]):
                key = (int(topi[i, j]), int(slot[i, j]))
                assert key not in pairs
                assert int(slot[i, j]) < cap
                pairs.add(key)
    np.testing.assert_allclose(np.array(w.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux) > 0


@pytest.mark.parametrize("t,e", [(64, 8), (60, 6), (128, 16)])
def test_balanced_assign_exact_balance(t, e):
    cap = t // e
    a = balanced_assign(_logits(t, e, seed=1), cap)
    load = np.bincount(np.array(a), minlength=e)
    assert (load == cap).all()


def test_balanced_assign_respects_preference_when_uncontested():
    # two tokens, two experts, clear preferences
    lg = jnp.asarray([[5.0, 0.0], [0.0, 5.0]], jnp.float32)
    a = balanced_assign(lg, 1)
    assert a.tolist() == [0, 1]


def test_swap_improve_monotone_and_balanced():
    t, e = 96, 8
    lg = _logits(t, e, seed=2)
    a0 = balanced_assign(lg, t // e)
    aff0 = float(jnp.take_along_axis(lg, a0[:, None], 1).sum())
    a1 = swap_improve(lg, a0, rounds=8)
    aff1 = float(jnp.take_along_axis(lg, a1[:, None], 1).sum())
    assert aff1 >= aff0 - 1e-5
    load = np.bincount(np.array(a1), minlength=e)
    assert (load == t // e).all()


def test_swap_improve_finds_obvious_swap():
    # token 0 on expert 1, token 1 on expert 0, both prefer the other
    lg = jnp.asarray([[10.0, 0.0], [0.0, 10.0]], jnp.float32)
    a0 = jnp.asarray([1, 0], jnp.int32)
    a1 = swap_improve(lg, a0, rounds=1)
    assert a1.tolist() == [0, 1]


def test_awpm_route_distinct_experts_and_unique_slots():
    t, e, k = 64, 8, 3
    cap = t // e
    lg = _logits(t, e, seed=3)
    topi, slot, w, keep, aux = awpm_route(lg, k, cap, swap_rounds=2)
    # distinct experts per token across the k rounds (soft constraint: the
    # finite penalty makes duplicates possible but rare — see awpm_route)
    n_dup = sum(1 for i in range(t)
                if len({int(topi[i, j]) for j in range(k)}) != k)
    assert n_dup <= 0.05 * t, f"{n_dup}/{t} tokens with duplicate experts"
    # perfect balance per round
    for j in range(k):
        load = np.bincount(np.array(topi[:, j]), minlength=e)
        assert (load == cap).all()
    # globally unique (expert, slot) pairs
    pairs = set(zip(np.array(topi).reshape(-1).tolist(),
                    np.array(slot).reshape(-1).tolist()))
    assert len(pairs) == t * k
    np.testing.assert_allclose(np.array(w.sum(-1)), 1.0, rtol=1e-5)


def test_batched_router_matches_per_group_vmap():
    """The one-dispatch batched router (used by moe_apply) must assign every
    group exactly as the per-group routing would: the per-group masks only
    freeze converged groups, never change an active group's rounds."""
    g_n, t, e, k = 3, 32, 4, 2
    cap = t // e
    lg = jnp.stack([_logits(t, e, seed=s) for s in range(g_n)])
    tiB, slB, wB, keepB, auxB = awpm_route_batched(lg, k, cap, swap_rounds=3)
    tiV, slV, wV, _, _ = jax.vmap(
        lambda l: awpm_route(l, k, cap, swap_rounds=3))(lg)
    np.testing.assert_array_equal(np.array(tiB), np.array(tiV))
    np.testing.assert_array_equal(np.array(slB), np.array(slV))
    np.testing.assert_allclose(np.array(wB), np.array(wV), rtol=1e-6)
    # building blocks agree with their single-group wrappers per group
    aff = lg
    aB = balanced_assign_batched(aff, cap)
    sB = swap_improve_batched(aff, aB, rounds=4)
    for i in range(g_n):
        np.testing.assert_array_equal(
            np.array(aB[i]), np.array(balanced_assign(aff[i], cap)))
        np.testing.assert_array_equal(
            np.array(sB[i]), np.array(swap_improve(aff[i], aB[i], rounds=4)))


@pytest.mark.parametrize("router,groups", [("topk", 0), ("topk", 4),
                                           ("awpm", 0)])
def test_moe_apply_grouped_dispatch(router, groups):
    from repro.configs.base import LMConfig, MoECfg
    from repro.models.moe import moe_apply, moe_def
    from repro.models.param import init_params

    cfg = LMConfig("t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab=128, dtype="float32",
                   moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=16,
                              router=router, dispatch_groups=groups,
                              router_block=16))
    p = init_params(moe_def(cfg, cfg.moe), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    y, aux = jax.jit(lambda p, x: moe_apply(p, x, cfg, cfg.moe))(p, x)
    assert y.shape == x.shape
    assert np.isfinite(np.array(y)).all()


def test_moe_grouped_equals_global_for_group_multiple():
    """With identical per-group capacity, grouped top-k must route each token
    to the same experts as global top-k (slots differ, outputs agree)."""
    from repro.configs.base import LMConfig, MoECfg
    from repro.models.moe import moe_apply, moe_def
    from repro.models.param import init_params

    base = dict(n_experts=4, top_k=1, d_ff_expert=16, capacity_factor=100.0)
    cfg_g = LMConfig("t", 1, 32, 2, 2, 64, 128, dtype="float32",
                     moe=MoECfg(**base, router="topk", dispatch_groups=0))
    cfg_2 = LMConfig("t", 1, 32, 2, 2, 64, 128, dtype="float32",
                     moe=MoECfg(**base, router="topk", dispatch_groups=2))
    p = init_params(moe_def(cfg_g, cfg_g.moe), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y1, _ = moe_apply(p, x, cfg_g, cfg_g.moe)
    y2, _ = moe_apply(p, x, cfg_2, cfg_2.moe)
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=2e-4,
                               atol=2e-5)


def test_chunked_loss_matches_full():
    """loss_chunks path: identical loss + grads to the full-logits path."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.param import init_params

    cfg = get_config("qwen2-0.5b", reduced=True)
    cfg8 = dataclasses.replace(cfg, loss_chunks=8)
    p = init_params(T.lm_def(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((2, 16), jnp.float32)}
    l1 = float(T.loss_fn(p, batch, cfg)[0])
    l2 = float(T.loss_fn(p, batch, cfg8)[0])
    assert abs(l1 - l2) < 1e-5
    ga = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(p)
    gb = jax.grad(lambda p: T.loss_fn(p, batch, cfg8)[0])(p)
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4,
                                   atol=1e-5)
