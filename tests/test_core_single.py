"""Single-device AWPM vs numpy oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph, ref, single

KINDS = ["uniform", "circuit", "antigreedy", "banded", "powerlaw"]


def _setup(n=48, deg=5.0, kind="uniform", seed=0):
    g = graph.generate(n, avg_degree=deg, kind=kind, seed=seed)
    dense = g.to_dense().astype(np.float32)
    struct = g.structure_dense()
    arrs = (jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val))
    return g, dense, struct, arrs


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_awac_matches_parallel_rule_oracle(kind, seed):
    g, dense, struct, (row, col, val) = _setup(kind=kind, seed=seed)
    mr0, mc0 = ref.greedy_maximal(dense, struct)
    mr1, mc1 = ref.mcm_kuhn(dense, struct, mr0, mc0)
    mrR, mcR, _ = ref.awac_parallel_rule(dense, struct, mr1.copy(), mc1.copy())

    st = single.state_from_mates(row, col, val, g.n, mr1, mc1)
    stJ, _ = single.awac(row, col, val, g.n, st, max_iter=500)
    assert np.array_equal(np.array(stJ.mate_row[: g.n]), mrR)


@pytest.mark.parametrize("kind", KINDS)
def test_full_pipeline_valid_perfect_and_two_thirds(kind):
    g, dense, struct, (row, col, val) = _setup(kind=kind, seed=11)
    st, iters = single.awpm(row, col, val, g.n)
    mr = np.array(st.mate_row[: g.n])
    ref.check_matching(struct, mr)
    assert ref.is_perfect(mr, g.n)
    w = float(single.matching_weight(st, g.n))
    assert abs(w - ref.matching_weight(dense, mr)) < 1e-3
    _, opt = ref.exact_mwpm(dense, struct)
    assert w >= (2.0 / 3.0) * opt - 1e-4
    # termination invariant: no augmenting 4-cycle remains
    mc = np.array(st.mate_col[: g.n])
    assert ref.find_augmenting_4cycle(dense, struct, mr, mc) is None


def test_greedy_maximal_is_maximal():
    g, dense, struct, (row, col, val) = _setup(seed=3)
    st = single.greedy_maximal(row, col, val, g.n)
    mr = np.array(st.mate_row[: g.n])
    mc = np.array(st.mate_col[: g.n])
    rr, cc = np.nonzero(struct)
    both_free = (mc[rr] == g.n) & (mr[cc] == g.n)
    assert not both_free.any(), "greedy matching is not maximal"


def test_greedy_weight_at_least_half_of_max_weight_matching():
    # greedy maximal by weight is a 1/2-approx of max-weight matching
    g, dense, struct, (row, col, val) = _setup(seed=4)
    mrg, _ = ref.greedy_maximal(dense, struct)
    w = ref.matching_weight(dense, mrg)
    _, opt = ref.exact_mwpm(dense, struct)
    assert w >= 0.5 * opt - 1e-5


@pytest.mark.parametrize("seed", [0, 5, 9])
def test_mcm_reaches_perfect(seed):
    g, dense, struct, (row, col, val) = _setup(seed=seed, deg=3.0)
    st0 = single.greedy_maximal(row, col, val, g.n)
    st = single.mcm(row, col, val, g.n, st0.mate_row, st0.mate_col)
    assert bool(single.is_perfect(st, g.n))
    mr = np.array(st.mate_row[: g.n])
    ref.check_matching(struct, mr)


def test_mcm_maximum_on_deficient_graph():
    # graph WITHOUT a guaranteed perfect matching: cardinality must equal
    # the true maximum (Kuhn's reference)
    rng = np.random.default_rng(0)
    n, m = 30, 60
    rr = rng.integers(0, n, m).astype(np.int32)
    cc = rng.integers(0, n, m).astype(np.int32)
    vv = rng.uniform(0.1, 1.0, m).astype(np.float32)
    key = rr.astype(np.int64) * n + cc
    _, idx = np.unique(key, return_index=True)
    rr, cc, vv = rr[idx], cc[idx], vv[idx]
    g = graph.from_coo(rr, cc, vv, n)
    dense = g.to_dense().astype(np.float32)
    struct = g.structure_dense()
    mrK, _ = ref.mcm_kuhn(dense, struct)
    card_ref = int((mrK < n).sum())
    st0 = single.greedy_maximal(jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val), n)
    st = single.mcm(jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val), n,
                    st0.mate_row, st0.mate_col)
    card = int((np.array(st.mate_row[:n]) < n).sum())
    assert card == card_ref
    ref.check_matching(struct, np.array(st.mate_row[:n]))


def test_state_from_mates_uv():
    g, dense, struct, (row, col, val) = _setup(seed=6)
    mr, mc = ref.greedy_maximal(dense, struct)
    mr, mc = ref.mcm_kuhn(dense, struct, mr, mc)
    st = single.state_from_mates(row, col, val, g.n, mr, mc)
    u = np.array(st.u[: g.n])
    v = np.array(st.v[: g.n])
    ii = np.arange(g.n)
    np.testing.assert_allclose(u, dense[ii, mc[ii]], rtol=1e-6)
    np.testing.assert_allclose(v, dense[mr[ii], ii], rtol=1e-6)


def test_awac_weight_monotone_nondecreasing():
    g, dense, struct, (row, col, val) = _setup(seed=8, kind="antigreedy")
    mr, mc = ref.greedy_maximal(dense, struct)
    mr, mc = ref.mcm_kuhn(dense, struct, mr, mc)
    st = single.state_from_mates(row, col, val, g.n, mr, mc)
    w0 = float(single.matching_weight(st, g.n))
    stJ, _ = single.awac(row, col, val, g.n, st)
    assert float(single.matching_weight(stJ, g.n)) >= w0 - 1e-5
