"""Dual-certificate soundness (repro.core.dual, DESIGN.md §8): the bound
must never undercut the exact optimum, must be tight exactly when the
matching is optimal, and the potentials must be feasible by direct check."""
import numpy as np
import pytest

from repro.core import MatchingProblem, SolveOptions, graph, ref, solve
from repro.core.dual import certify, dual_certificate

pytestmark = pytest.mark.skipif(not ref.HAVE_SCIPY,
                                reason="exact oracle needs scipy")

SUITE = graph.matrix_suite(n_matrices=20, n=48)


def _exact(g):
    dense = g.to_dense().astype(np.float32)
    struct = g.structure_dense()
    _, opt = ref.exact_mwpm(dense, struct)
    return float(opt)


@pytest.mark.parametrize("name,g", SUITE, ids=[n for n, _ in SUITE])
def test_certificate_sound_on_every_suite_instance(name, g):
    """certified_bound >= exact optimum on EVERY instance, equality (tight)
    exactly on the instances where the oracle says we hit the optimum."""
    problem = MatchingProblem.from_graph(g)
    res = solve(problem)
    cert = certify(problem, res)
    opt = _exact(g)
    scale = max(1.0, abs(opt))
    assert cert.upper_bound >= opt - 1e-6 * scale, \
        f"{name}: bound {cert.upper_bound} < optimum {opt}"
    assert cert.weight <= cert.upper_bound + 1e-6 * scale
    at_optimum = abs(cert.weight - opt) <= 1e-5 * scale
    if at_optimum:
        assert cert.tight, f"{name}: optimal matching but loose certificate"
        assert cert.upper_bound == pytest.approx(opt, rel=1e-5)
        assert cert.ratio_bound == 1.0
    else:
        assert not cert.tight
        assert 0.0 < cert.ratio_bound < 1.0
    # feasibility by direct check: u_i + v_j >= w_ij on every edge
    row = np.asarray(problem.row)
    col = np.asarray(problem.col)
    val = np.asarray(problem.val, np.float64)
    m = row < problem.n
    slack = cert.u[row[m]] + cert.v[col[m]] - val[m]
    assert slack.min() >= -1e-9 * scale


def test_suboptimal_matching_still_sound():
    """Cut AWAC off (max_iter=0): the perfect-but-unrefined matching gets
    a sound, non-tight certificate whose bound still clears the optimum."""
    g = graph.generate(48, avg_degree=6.0, kind="antigreedy", seed=3)
    problem = MatchingProblem.from_graph(g)
    res0 = solve(problem, SolveOptions(max_iter=0))
    res = solve(problem)
    assert bool(np.asarray(res0.perfect))
    cert0 = certify(problem, res0)
    opt = _exact(g)
    assert float(np.asarray(res0.weight)) < float(np.asarray(res.weight))
    assert cert0.upper_bound >= opt - 1e-6
    assert not cert0.tight
    assert cert0.slack > 0


def test_batched_certify_matches_per_instance():
    gs = [graph.generate(24, avg_degree=4.0, kind=k, seed=s)
          for s, k in enumerate(("uniform", "antigreedy", "circuit"))]
    batched = MatchingProblem.stack(gs)
    res = solve(batched)
    certs = certify(batched, res)
    assert len(certs) == 3
    for g, cert in zip(gs, certs):
        single = MatchingProblem.from_graph(g)
        alone = certify(single, solve(single))
        assert cert.upper_bound == pytest.approx(alone.upper_bound)
        assert cert.tight == alone.tight


def test_imperfect_matching_rejected():
    g = graph.generate(8, avg_degree=3.0, seed=0)
    problem = MatchingProblem.from_graph(g)
    with pytest.raises(ValueError, match="PERFECT"):
        dual_certificate(problem.row, problem.col, problem.val, problem.n,
                         np.full(8, 8))


def test_matching_off_the_edge_list_rejected():
    row = np.array([0, 1])
    col = np.array([0, 1])
    val = np.array([1.0, 1.0])
    with pytest.raises(ValueError, match="not in the edge list"):
        dual_certificate(row, col, val, 2, np.array([1, 0]))


def test_row_matched_twice_rejected():
    row = np.array([0, 0, 1])
    col = np.array([0, 1, 0])
    val = np.array([1.0, 1.0, 1.0])
    with pytest.raises(ValueError, match="twice"):
        dual_certificate(row, col, val, 2, np.array([0, 0]))
