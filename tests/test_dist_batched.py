"""Differential harness for the distributed-batched AWPM engine (DESIGN.md §5).

Contract under test: ``core.dist.awpm_dist_batched`` is bit-identical per
instance to ``core.batch.awpm_batched`` (itself pinned to
``core.single.awpm``) on every mesh shape — including the per-instance AWAC
iteration counts, with the drop-free ``safe_a2a_caps`` defaults.

Every mesh test runs in a subprocess with 8 fake host devices, because the
device count must be set before jax initializes (see tests/_subproc.py).
The CI ``multi-device`` job runs this file on both jax versions so both
shard_map spellings stay exercised on real multi-device meshes.

In-process tests cover the host-side capacity planning: per-block ``cap``
comes from the TRUE max block occupancy, and an explicit cap below it
raises instead of silently truncating edges.
"""
import numpy as np
import pytest

from _subproc import run_with_devices

HEADER = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import batch, graph, ref, single
from repro.core.single import MatchState
from repro.core.dist import (DistBatchedAWPM, GridSpec, awpm_dist_batched,
                             make_mesh)


def check_identical(stB, itB, stD, itD, msg=""):
    assert np.array_equal(np.array(itB), np.array(itD)), \
        (msg, np.array(itB), np.array(itD))
    for nm, x, y in zip(("mate_row", "mate_col", "u", "v"), stB, stD):
        assert np.array_equal(np.array(x), np.array(y)), (msg, nm)
"""


# --------------------------------------------------------------------------
# bit-identity across mesh shapes
# --------------------------------------------------------------------------

MESH_SCRIPT = HEADER + r"""
spec = GridSpec(make_mesh({mesh_shape}))
assert (spec.pr, spec.pc) == {mesh_shape}
n = 32
gs = [graph.generate(n, avg_degree=4.0 + (i % 3), kind=k, seed=s)
      for i, (k, s) in enumerate([("uniform", 0), ("antigreedy", 7),
                                  ("circuit", 2), ("banded", 3)])]
row, col, val = batch.stack_graphs(gs)
stB, itB = batch.awpm_batched(row, col, val, n)
assert bool(batch.is_perfect_batched(stB, n).all())
for backend in {backends}:
    stD, itD, dropped = awpm_dist_batched(
        np.array(row), np.array(col), np.array(val), n, spec, backend=backend)
    assert int(dropped) == 0, backend
    check_identical(stB, itB, stD, itD, backend)
print("OK")
"""


@pytest.mark.parametrize("mesh_shape,backends", [
    # 1x1: the block is the instance — also routes the core.batch fused
    # sweep backends (incl. the batch-grid Pallas kernel) through shard_map
    ((1, 1), ("fused", "xla", "pallas")),
    ((2, 2), ("fused", "reference")),
    # both 8-device orientations, matching the CI multi-device job
    ((2, 4), ("fused",)),
    ((4, 2), ("fused",)),
], ids=["1x1", "2x2", "2x4", "4x2"])
def test_dist_batched_bit_identical(mesh_shape, backends):
    script = MESH_SCRIPT.format(mesh_shape=mesh_shape, backends=backends)
    out = run_with_devices(script, 8)
    assert "OK" in out


# --------------------------------------------------------------------------
# mixed per-instance convergence (1 vs ~21 AWAC iterations in one batch)
# --------------------------------------------------------------------------

MIXED_SCRIPT = HEADER + r"""
n = 40
# overlapping heavy 4-cycles: from the diagonal matching, AWAC needs ~n/2
# sequential augmentation rounds (the slow-convergence extreme)
rows, cols, vals = [], [], []
for i in range(n):
    rows.append(i), cols.append(i), vals.append(0.1)
for i in range(n - 1):
    w = 0.5 + 0.4 * i / n
    rows += [i, i + 1]
    cols += [i + 1, i]
    vals += [w, w]
slow = graph.from_coo(np.array(rows, np.int32), np.array(cols, np.int32),
                      np.array(vals, np.float32), n)
fast = graph.generate(n, avg_degree=3.0, kind="circuit", seed=2)
row, col, val = batch.stack_graphs([slow, fast])

# per-instance initial states: diagonal matching for the chain, greedy + MCM
# for the circuit instance (its usual pipeline entry into AWAC)
st_slow = single.state_from_mates(row[0], col[0], val[0], n,
                                  np.arange(n), np.arange(n))
st0 = single.greedy_maximal(row[1], col[1], val[1], n)
st_fast = single.mcm(row[1], col[1], val[1], n, st0.mate_row, st0.mate_col)
stacked = MatchState(*(jnp.stack([a, b]) for a, b in zip(st_slow, st_fast)))

stB, itB = batch.awac_batched(row, col, val, n, stacked)
spec = GridSpec(make_mesh((2, 2)))
drv = DistBatchedAWPM(spec, n)
stD, itD, dropped = drv.run(np.array(row), np.array(col), np.array(val),
                            state=stacked)
assert int(dropped) == 0
check_identical(stB, itB, stD, itD, "mixed")
its = np.array(itD)
assert its[0] >= 20 and its[1] <= 2, its  # genuinely mixed speeds
print("OK")
"""


def test_mixed_convergence_speeds_within_batch():
    """The early finisher's state must stay frozen (bit-exact) on every
    device while the slow instance keeps exchanging and augmenting."""
    out = run_with_devices(MIXED_SCRIPT, 8)
    assert "OK" in out


# --------------------------------------------------------------------------
# degenerate blocks and error paths (one subprocess, 2x2 grid)
# --------------------------------------------------------------------------

DEGENERATE_SCRIPT = HEADER + r"""
spec = GridSpec(make_mesh((2, 2)))

# --- n=1: three of the four devices own only out-of-range padding ---
g1 = graph.from_coo(np.array([0]), np.array([0]), np.array([0.7], np.float32), 1)
row, col, val = batch.stack_graphs([g1, g1])
stB, itB = batch.awpm_batched(row, col, val, 1)
stD, itD, dropped = awpm_dist_batched(np.array(row), np.array(col),
                                      np.array(val), 1, spec)
assert int(dropped) == 0
check_identical(stB, itB, stD, itD, "n1")

# --- empty local blocks: one instance's edges all sit in the two diagonal
# blocks of the 2x2 grid, so its off-diagonal blocks are pure padding ---
n = 16
rows = list(range(n)) + list(range(8)) + list(range(8, 16))
cols = list(range(n)) + [(i + 1) % 8 for i in range(8)] \
    + [8 + (i + 1) % 8 for i in range(8)]
rng = np.random.default_rng(0)
vals = rng.uniform(0.1, 1.0, len(rows)).astype(np.float32)
diag_blocks = graph.from_coo(np.array(rows, np.int32),
                             np.array(cols, np.int32), vals, n)
normal = graph.generate(n, avg_degree=4.0, kind="uniform", seed=1)
row, col, val = batch.stack_graphs([diag_blocks, normal])
stB, itB = batch.awpm_batched(row, col, val, n)
stD, itD, dropped = awpm_dist_batched(np.array(row), np.array(col),
                                      np.array(val), n, spec)
assert int(dropped) == 0
check_identical(stB, itB, stD, itD, "empty-block")

# --- all-ties: every weight equal, only tie-breaks decide ---
gs = []
for seed in (0, 1):
    g0 = graph.generate(n, avg_degree=4.0, kind="uniform", seed=seed,
                        normalize=False)
    real = np.asarray(g0.row) < n
    gs.append(graph.from_coo(np.asarray(g0.row)[real],
                             np.asarray(g0.col)[real],
                             np.full(int(real.sum()), 0.5, np.float32), n))
row, col, val = batch.stack_graphs(gs)
stB, itB = batch.awpm_batched(row, col, val, n)
stD, itD, dropped = awpm_dist_batched(np.array(row), np.array(col),
                                      np.array(val), n, spec)
assert int(dropped) == 0
check_identical(stB, itB, stD, itD, "all-ties")

# --- error paths: unknown backend; local-sweep backends off the 1x1 grid ---
try:
    awpm_dist_batched(np.array(row), np.array(col), np.array(val), n, spec,
                      backend="bogus")
    raise SystemExit("bogus backend did not raise")
except ValueError as e:
    assert "unknown dist AWAC backend" in str(e)
try:
    awpm_dist_batched(np.array(row), np.array(col), np.array(val), n, spec,
                      backend="xla")
    raise SystemExit("xla backend on 2x2 did not raise")
except ValueError as e:
    assert "1x1 grid" in str(e)
print("OK")
"""


def test_degenerate_blocks_and_error_paths():
    out = run_with_devices(DEGENERATE_SCRIPT, 8)
    assert "OK" in out


# --------------------------------------------------------------------------
# consumers: MoE routing and pivot permutations through the dist engine
# --------------------------------------------------------------------------

CONSUMER_SCRIPT = HEADER + r"""
from repro.core import pivot
from repro.models.moe import matching_route_batched

spec = GridSpec(make_mesh((2, 2)))

# pivot: distributed-batched row permutations == local batched ones
rng = np.random.default_rng(0)
mats = [np.diag(rng.uniform(1.0, 2.0, 12)) + rng.uniform(0, 0.2, (12, 12))
        for _ in range(3)]
pL, iL = pivot.batched_pivot_permutations(mats)
pD, iD = pivot.batched_pivot_permutations(mats, mesh=spec)
assert np.array_equal(pL, pD) and np.array_equal(np.array(iL), np.array(iD))

# MoE: all groups routed through the dist engine == the local batched path
g, e, cap, k = 2, 4, 2, 2
t = e * cap
logits = jnp.asarray(rng.standard_normal((g, t, e)).astype(np.float32))
outL = matching_route_batched(logits, k, cap)
outD = matching_route_batched(logits, k, cap, dist_spec=spec)
for nm, a, b in zip(("expert", "slot", "w", "keep", "aux"), outL, outD):
    assert np.array_equal(np.array(a), np.array(b)), nm
print("OK")
"""


def test_consumers_route_through_dist_engine():
    out = run_with_devices(CONSUMER_SCRIPT, 8)
    assert "OK" in out


# --------------------------------------------------------------------------
# hypothesis planted-matching property under the simulated 8-device mesh
# --------------------------------------------------------------------------

HYPOTHESIS_SCRIPT = HEADER + r"""
from hypothesis import given, settings, strategies as st

spec = GridSpec(make_mesh((2, 4)))
n = 16


@st.composite
def planted_batch(draw):
    gs = []
    for _ in range(2):
        deg = draw(st.floats(2.0, 5.0))
        kind = draw(st.sampled_from(
            ["uniform", "circuit", "antigreedy", "banded"]))
        seed = draw(st.integers(0, 10_000))
        gs.append(graph.generate(n, avg_degree=deg, kind=kind, seed=seed))
    return gs


@given(planted_batch())
@settings(max_examples=8, deadline=None)
def prop(gs):
    row, col, val = batch.stack_graphs(gs)
    stD, itD, dropped = awpm_dist_batched(np.array(row), np.array(col),
                                          np.array(val), n, spec)
    assert int(dropped) == 0
    # a perfect matching is planted -> the dist result is perfect and valid
    assert bool(batch.is_perfect_batched(stD, n).all())
    for i, g in enumerate(gs):
        ref.check_matching(g.structure_dense(), np.array(stD.mate_row[i, :n]))
    stB, itB = batch.awpm_batched(row, col, val, n)
    check_identical(stB, itB, stD, itD, "planted")


prop()
print("OK")
"""


def test_planted_matching_property_on_8_devices():
    pytest.importorskip("hypothesis")
    out = run_with_devices(HYPOTHESIS_SCRIPT, 8)
    assert "OK" in out


# --------------------------------------------------------------------------
# in-process: capacity planning from true block occupancy (bugfix)
# --------------------------------------------------------------------------


def _skewed_batch(n=16, cap=40):
    """One dense row (all its edges land in a single grid row) next to a
    uniform instance — the case the old uniform nnz/(pr*pc) estimate
    undercounts."""
    row = np.full((2, cap), n, np.int32)
    col = np.full((2, cap), n, np.int32)
    val = np.zeros((2, cap), np.float32)
    # instance 0: row 0 holds n entries, plus the off-diagonal fill
    r0 = np.concatenate([np.zeros(n, np.int32),
                         np.arange(1, n, dtype=np.int32)])
    c0 = np.concatenate([np.arange(n, dtype=np.int32),
                         np.arange(1, n, dtype=np.int32)])
    order = np.lexsort((c0, r0))
    row[0, : r0.size], col[0, : r0.size] = r0[order], c0[order]
    val[0, : r0.size] = 0.5
    # instance 1: plain diagonal
    row[1, :n] = col[1, :n] = np.arange(n, dtype=np.int32)
    val[1, :n] = 0.5
    return row, col, val


def test_block_cap_from_true_occupancy():
    from repro.sparse.partition import (block_occupancy, plan_block_cap,
                                        partition_coo_2d_batched)

    n = 16
    row, col, val = _skewed_batch(n)
    occ = block_occupancy(row, col, n, 2, 2)
    assert occ.shape == (2, 2, 2)
    # the dense row puts 8 diagonal + 7 fill + 8 dense entries into the two
    # top blocks; the uniform estimate (31 / 4 ~ 8) would truncate
    assert int(occ[0].max()) > (int(occ[0].sum()) + 3) // 4
    cap = plan_block_cap(row, col, n, 2, 2)
    assert cap >= int(occ.max())
    part = partition_coo_2d_batched(row, col, val, n, 2, 2)
    assert part.cap == cap
    # every real edge survives the partition (nothing truncated)
    assert int((part.row < n).sum()) == int((row < n).sum())
    np.testing.assert_array_equal(part.nnz.sum(axis=(0, 1)),
                                  (row < n).sum(axis=1))


def test_partition_refuses_to_truncate():
    from repro.sparse.partition import partition_coo_2d, \
        partition_coo_2d_batched

    n = 16
    row, col, val = _skewed_batch(n)
    with pytest.raises(ValueError, match="refusing to truncate"):
        partition_coo_2d_batched(row, col, val, n, 2, 2, cap=8)
    m = row[0] < n
    with pytest.raises(ValueError, match="refusing to truncate"):
        partition_coo_2d(row[0][m], col[0][m], val[0][m], n, 2, 2, cap=8)
    with pytest.raises(ValueError, match="batched"):
        partition_coo_2d_batched(row[0], col[0], val[0], n, 2, 2)
