"""Dry-run launcher smoke: lower+compile representative cells on the
production meshes (subprocess — needs 512 fake devices)."""
import pytest

from _subproc import run_with_devices

SCRIPT = r"""
import os
assert os.environ["XLA_FLAGS"].startswith("--xla_force_host_platform_device_count=512")
from repro.launch.dryrun import run_cell
for arch, shape, mesh in {cells}:
    rec = run_cell(arch, shape, mesh, probe=False)
    assert rec["ok"], (arch, shape, mesh, rec.get("error"))
    assert rec["chips"] == (512 if mesh == "multi" else 256)
    assert rec["flops_per_device"] > 0
    rl = rec["roofline"]
    assert rl["dominant"] in ("compute", "memory", "collective")
print("OK")
"""


@pytest.mark.slow
def test_dryrun_lm_single_and_multi():
    cells = [("qwen2-0.5b", "train_4k", "single"),
             ("qwen2-0.5b", "prefill_32k", "multi")]
    out = run_with_devices(SCRIPT.format(cells=cells), 512, timeout=1200)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_gnn_and_matching():
    cells = [("graphsage-reddit", "molecule", "single"),
             ("awpm-matching", "match_4m", "multi")]
    out = run_with_devices(SCRIPT.format(cells=cells), 512, timeout=1200)
    assert "OK" in out


def test_collective_bytes_parser():
    from repro.roofline.analysis import collective_bytes, shape_bytes

    assert shape_bytes("f32[2,4,4]") == 128
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("f32[256,7,4096]{2,1,0}, f32[256,7]") == \
        256 * 7 * 4096 * 4 + 256 * 7 * 4
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = (f32[4,4]{1,0}, f32[4]{0}) all-reduce(%a, %b), to_apply=%sum
  %a2a = bf16[2,8]{1,0} all-to-all(%y), dimensions={0}
  %st = f32[8]{0} all-reduce-start(%z)
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-gather"] == 16 * 128 * 4
    assert out["bytes"]["all-reduce"] == (16 * 4 + 4 * 4) + 8 * 4
    assert out["bytes"]["all-to-all"] == 2 * 8 * 2
    assert out["counts"]["all-reduce"] == 2


def _fixture(name):
    import pathlib

    return (pathlib.Path(__file__).parent / "data" / name).read_text()


def test_collective_bytes_async_start_tuples():
    # the -start tuple is (operand, result, u32[] contexts...): only the
    # result portion is payload — counting the whole tuple double-counts the
    # operand alias and adds the context scalars — and every -done half is
    # excluded entirely (its start was already counted)
    from repro.roofline.analysis import collective_bytes

    out = collective_bytes(_fixture("hlo_async_collectives.txt"))
    assert out["bytes"]["all-gather"] == 512 * 256 * 4  # not + 64*256*4
    assert out["bytes"]["all-reduce"] == 1024 * 4  # non-tuple start shape
    assert out["bytes"]["all-to-all"] == 2 * (1 * 256 * 4)  # result tuple
    assert out["bytes"]["collective-permute"] == 8 * 512 * 4  # not doubled
    assert out["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "all-to-all": 1, "reduce-scatter": 0,
                             "collective-permute": 1}
    # degenerate start tuple without a separate result element: count the
    # single payload element, never the context scalar
    one = collective_bytes(
        "  %cps = (f32[8]{0}, u32[]) collective-permute-start(%x)\n")
    assert one["bytes"]["collective-permute"] == 8 * 4


def test_collective_bytes_real_cpu_dump():
    # dumped HLO from a jit'd shard_map on 8 fake CPU devices (see the
    # fixture header): one instruction of every kind, incl. the decomposed
    # sync all-to-all whose TUPLE result must sum all elements
    from repro.roofline.analysis import collective_bytes

    out = collective_bytes(_fixture("hlo_cpu_collectives.txt"))
    assert out["bytes"]["all-gather"] == 2 * 8 * 512 * 4
    assert out["bytes"]["all-reduce"] == 4 * 256 * 4
    assert out["bytes"]["all-to-all"] == 4 * (1 * 256 * 4)
    assert out["bytes"]["reduce-scatter"] == 1 * 256 * 4
    assert out["bytes"]["collective-permute"] == 8 * 512 * 4
    # operand references like "%all-to-all.2" inside get-tuple-element /
    # fusion lines must not count as instructions
    assert all(c == 1 for c in out["counts"].values())
    assert out["total"] == sum(out["bytes"].values())


def test_useful_flops_sane():
    from repro.configs import get_config
    from repro.configs.base import shapes_for
    from repro.roofline.analysis import useful_flops

    for arch in ("qwen2-0.5b", "qwen2-moe-a2.7b", "bert4rec",
                 "graphsage-reddit", "awpm-matching"):
        cfg = get_config(arch)
        for s in shapes_for(cfg):
            mf = useful_flops(arch, s.name, s.mode, cfg, s)
            assert mf > 0, (arch, s.name)
