"""Chaos harness (runtime.chaos, DESIGN.md §9): injector mechanics and the
in-process detect/survive cases. The full fault matrix on an 8-fake-device
2x4 grid is the dedicated CI chaos job (`python -m repro.runtime.chaos`);
here a 2x2 subprocess case keeps a real multi-device exchange fault under
tier-1."""
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_with_devices
from repro.core import (
    MatchingProblem,
    PreflightError,
    SolveOptions,
    batch,
    dist,
    graph,
    single,
    solve,
)
from repro.runtime import chaos
from repro.runtime.resilient import (
    ResilientOptions,
    TransientFault,
    VerificationError,
    resilient_solve,
)


def _problem(n=16, seed=0):
    return MatchingProblem.from_graph(
        graph.generate(n, avg_degree=4.0, seed=seed))


# --------------------------------------------------------------------------
# injector mechanics
# --------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        chaos.FaultSpec("meteor_strike")
    with pytest.raises(ValueError, match="stage"):
        chaos.FaultSpec("drop", stage=3)


def test_selected_is_deterministic_and_counts():
    valid = jnp.array([[True, False, True, True, False, True]])
    a = np.asarray(chaos._selected(valid, seed=7, count=2))
    b = np.asarray(chaos._selected(valid, seed=7, count=2))
    assert np.array_equal(a, b)
    assert a.sum() == 2
    assert not (a & ~np.asarray(valid)).any()  # only valid entries chosen
    c = np.asarray(chaos._selected(valid, seed=8, count=2))
    assert not np.array_equal(a, c)  # the seed rotates the positions


def test_inject_installs_and_restores_taps():
    assert dist._EXCHANGE_TAP is None and batch._CONVERGENCE_TAP is None
    with chaos.inject(chaos.FaultSpec("drop", stage=1)):
        assert dist._EXCHANGE_TAP is not None
        assert batch._CONVERGENCE_TAP is None
    assert dist._EXCHANGE_TAP is None
    with chaos.inject(chaos.FaultSpec("flip_converged")):
        assert batch._CONVERGENCE_TAP is not None
        assert dist._EXCHANGE_TAP is None
    assert batch._CONVERGENCE_TAP is None


def test_failing_backend_counts_and_restores():
    orig = single._awpm
    with chaos.failing_backend("xla", fail_times=2) as state:
        with pytest.raises(TransientFault):
            solve(_problem(), SolveOptions(backend="xla"))
        assert state["n"] == 1
        # other backends pass through untouched
        assert bool(solve(_problem(),
                          SolveOptions(backend="reference")).perfect)
    assert single._awpm is orig


# --------------------------------------------------------------------------
# in-process detect/survive cases (no multi-device mesh needed)
# --------------------------------------------------------------------------


def test_flip_converged_detected_by_convergence_audit():
    # an instance whose reference solve needs >= 3 AWAC rounds: stopping
    # after round 1 provably leaves an augmenting 4-cycle. Batched problems
    # route every local rung through the tainted batched loop, so the
    # verify_convergence audit is the only thing standing between a
    # "looks converged" result and the caller.
    p, _ = chaos._pick_instance(48, 6.0, min_awac_iters=3)
    pb = MatchingProblem.stack([p, p])
    with chaos.inject(chaos.FaultSpec("flip_converged", count=1)):
        with pytest.raises(VerificationError) as exc:
            resilient_solve(
                pb, resilience=ResilientOptions(verify_convergence=True))
    assert any(a.outcome == "verify_failed" for a in exc.value.report.attempts)


def test_nan_input_detected_or_sanitized():
    p = _problem()
    ref = solve(p)
    # NaN into a padding slot via a widened capacity: sanitize restores p
    real = np.asarray(p.row) < p.n
    row = np.concatenate([np.asarray(p.row)[real], [0]])
    col = np.concatenate([np.asarray(p.col)[real], [0]])
    val = np.concatenate([np.asarray(p.val)[real], [np.nan]])
    p_nan = MatchingProblem.from_coo(row[:-1], col[:-1], val[:-1], p.n,
                                     capacity=int(real.sum()) + 2)
    r = np.asarray(p_nan.row).copy()
    c = np.asarray(p_nan.col).copy()
    v = np.asarray(p_nan.val).copy()
    pad = np.flatnonzero(r >= p.n)[-1]
    r[pad], c[pad], v[pad] = 0, 0, np.nan
    p_nan = MatchingProblem(row=r, col=c, val=v, n=p.n)
    with pytest.raises(PreflightError):
        solve(p_nan)
    rr = resilient_solve(p_nan, SolveOptions(on_invalid="sanitize"))
    assert np.array_equal(np.asarray(rr.result.mate_row),
                          np.asarray(ref.mate_row))


def test_assert_all_ok_raises_on_silent_corruption():
    records = [
        {"fault": "drop@stage1", "mode": "detect", "ok": True, "detail": ""},
        {"fault": "drop@stage1", "mode": "survive", "ok": False,
         "detail": "served a corrupted matching"},
    ]
    with pytest.raises(AssertionError, match="drop@stage1"):
        chaos.assert_all_ok(records)
    assert chaos.assert_all_ok(records[:1]) == records[:1]


# --------------------------------------------------------------------------
# a real multi-device exchange fault (2x2 subprocess)
# --------------------------------------------------------------------------


CHAOS_2X2 = r"""
import jax, numpy as np
from repro.core import api, dist
from repro.runtime import chaos
from repro.runtime.resilient import resilient_solve

mesh = jax.make_mesh((2, 2), ("data", "model"))
p, ref = chaos._pick_instance(32, 5.0, min_awac_iters=1)
gopts = api.SolveOptions(grid=mesh, exchange_check=True)
assert api.solve(p, gopts).perfect  # clean baseline through the grid
for kind, stage in (("drop", 1), ("corrupt_weight", 2)):
    fault = chaos.FaultSpec(kind, stage=stage, seed=7)
    with chaos.inject(fault):
        try:
            api.solve(p, gopts)
            raise SystemExit(f"{kind}@stage{stage} not detected")
        except dist.ExchangeIntegrityError:
            pass
        rr = resilient_solve(p, gopts)
        assert chaos._bit_identical(rr.result, ref), kind
        assert rr.report.degraded, kind
print("CHAOS_2X2_OK")
"""


def test_exchange_faults_detected_and_survived_2x2():
    out = run_with_devices(CHAOS_2X2, 4)
    assert "CHAOS_2X2_OK" in out
