"""Matrix Market ingestion (repro.data.mtx, DESIGN.md §8): round-trips are
bit-equal, symmetric storage expands correctly, malformed files fail with
located errors, and load_problem lands in the canonical MatchingProblem."""
import pathlib

import numpy as np
import pytest

from repro.core.api import MatchingProblem
from repro.data.mtx import MatrixMarketError, load_problem, read_mtx, write_mtx

DATA = pathlib.Path(__file__).parent / "data"
FIXTURES = sorted(DATA.glob("*.mtx"))


# --------------------------------------------------------------------------
# read -> write -> read round trip
# --------------------------------------------------------------------------


@pytest.mark.parametrize("path", FIXTURES, ids=lambda p: p.stem)
def test_roundtrip_bit_equal(path, tmp_path):
    a = read_mtx(path, expand_symmetry=False)
    out = tmp_path / path.name
    write_mtx(out, a.row, a.col, None if a.field == "pattern" else a.val,
              shape=(a.nrows, a.ncols), field=a.field, symmetry=a.symmetry)
    b = read_mtx(out, expand_symmetry=False)
    assert (b.nrows, b.ncols, b.field, b.symmetry) == \
        (a.nrows, a.ncols, a.field, a.symmetry)
    assert np.array_equal(a.row, b.row)
    assert np.array_equal(a.col, b.col)
    # bit-equal: compare the raw float64 payloads, not approximately
    assert a.val.tobytes() == b.val.tobytes()


def test_roundtrip_exotic_values(tmp_path):
    # shortest-repr writing must round-trip values that decimal formatting
    # with a fixed precision would mangle
    row = np.arange(5)
    col = np.arange(5)
    val = np.array([0.1, 1e-300, 1.7976931348623157e308, -3.141592653589793,
                    2.0 ** -52])
    out = tmp_path / "exotic.mtx"
    write_mtx(out, row, col, val, shape=(5, 5))
    b = read_mtx(out)
    assert b.val.tobytes() == val.tobytes()


# --------------------------------------------------------------------------
# symmetry expansion
# --------------------------------------------------------------------------


def test_symmetric_expansion():
    stored = read_mtx(DATA / "bands6_sym.mtx", expand_symmetry=False)
    full = read_mtx(DATA / "bands6_sym.mtx", expand_symmetry=True)
    n_diag = int((stored.row == stored.col).sum())
    assert not stored.expanded and full.expanded
    assert full.nnz == 2 * stored.nnz - n_diag
    # every off-diagonal entry has its mirror with the same value
    d = {(int(i), int(j)): v for i, j, v in zip(full.row, full.col, full.val)}
    for i, j, v in zip(stored.row, stored.col, stored.val):
        assert d[(int(j), int(i))] == v


def test_skew_symmetric_expansion(tmp_path):
    out = tmp_path / "skew.mtx"
    write_mtx(out, [1, 2], [0, 0], [2.5, -0.75], shape=(3, 3),
              symmetry="skew-symmetric")
    m = read_mtx(out)
    d = {(int(i), int(j)): v for i, j, v in zip(m.row, m.col, m.val)}
    assert d[(0, 1)] == -2.5 and d[(0, 2)] == 0.75


def test_skew_symmetric_diagonal_rejected(tmp_path):
    out = tmp_path / "bad_skew.mtx"
    out.write_text("%%MatrixMarket matrix coordinate real skew-symmetric\n"
                   "2 2 1\n1 1 3.0\n")
    with pytest.raises(MatrixMarketError, match="diagonal"):
        read_mtx(out)


def test_symmetric_mixed_triangles_rejected(tmp_path):
    # storing BOTH triangles would double every mirrored weight after
    # expansion + duplicate assembly — must be a located error, not
    # silent corruption
    out = tmp_path / "mixed.mtx"
    out.write_text("%%MatrixMarket matrix coordinate real symmetric\n"
                   "2 2 3\n1 1 1.0\n2 1 5.0\n1 2 5.0\n")
    with pytest.raises(MatrixMarketError, match="ONE triangle"):
        read_mtx(out)
    with pytest.raises(MatrixMarketError, match="ONE triangle"):
        write_mtx(tmp_path / "w.mtx", [1, 0], [0, 1], [5.0, 5.0],
                  shape=(2, 2), symmetry="symmetric")
    # either single triangle alone stays accepted
    for rows, cols in ([(2,), (1,)], [(1,), (2,)]):
        out.write_text("%%MatrixMarket matrix coordinate real symmetric\n"
                       f"2 2 1\n{rows[0]} {cols[0]} 5.0\n")
        assert read_mtx(out).nnz == 2


def test_pattern_reads_unit_weights():
    m = read_mtx(DATA / "mesh5_pat.mtx")
    assert m.field == "pattern"
    assert np.array_equal(m.val, np.ones(m.nnz))


def test_integer_field_exact():
    m = read_mtx(DATA / "count4_int.mtx")
    assert m.field == "integer"
    assert np.array_equal(m.val, np.trunc(m.val))
    assert -3.0 in m.val.tolist()


def test_complex_field_reads():
    m = read_mtx(DATA / "zcoil7.mtx")
    assert m.field == "complex"
    assert m.val.dtype == np.complex128
    assert (m.val.imag != 0).any()


def test_hermitian_expansion(tmp_path):
    out = tmp_path / "herm.mtx"
    write_mtx(out, [0, 1, 2], [0, 0, 1], [2.0, 1.0 - 3.0j, 0.5j],
              shape=(3, 3), symmetry="hermitian")
    m = read_mtx(out)
    d = {(int(i), int(j)): v for i, j, v in zip(m.row, m.col, m.val)}
    # mirrors are CONJUGATED (hermitian), not copied (symmetric)
    assert d[(0, 1)] == 1.0 + 3.0j and d[(1, 0)] == 1.0 - 3.0j
    assert d[(1, 2)] == -0.5j
    assert d[(0, 0)] == 2.0  # real diagonal stays on the diagonal once


def test_hermitian_nonreal_diagonal_rejected(tmp_path):
    with pytest.raises(MatrixMarketError, match="diagonal"):
        write_mtx(tmp_path / "w.mtx", [0], [0], [1.0 + 1.0j],
                  shape=(2, 2), symmetry="hermitian")


def test_load_problem_complex_magnitude():
    problem, coo = load_problem(DATA / "zcoil7.mtx", transform="abs")
    val = np.asarray(problem.val)
    row = np.asarray(problem.row)
    m = row < problem.n
    # matching weights are |a_ij| — real, positive, magnitude order kept
    assert not np.iscomplexobj(val)
    assert (val[m] > 0).all()
    assert val[m].max() == pytest.approx(np.abs(coo.val).max(), rel=1e-6)


# --------------------------------------------------------------------------
# malformed input: every error names the file and line
# --------------------------------------------------------------------------


@pytest.mark.parametrize("content,match", [
    ("", "empty file"),
    ("%%NotMatrixMarket matrix coordinate real general\n1 1 0\n", "banner"),
    ("%%MatrixMarket matrix array real general\n1 1\n0.5\n", "coordinate"),
    ("%%MatrixMarket tensor coordinate real general\n1 1 0\n", "object"),
    ("%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 2.0\n",
     "bad 'complex' entry|expected 4 tokens"),
    ("%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 2.0 nan\n",
     "non-finite"),
    ("%%MatrixMarket matrix coordinate real hermitian\n1 1 1\n1 1 2.0\n",
     "hermitian.*complex|complex"),
    ("%%MatrixMarket matrix coordinate complex hermitian\n2 2 1\n"
     "1 1 2.0 1.0\n", "diagonal"),
    ("%%MatrixMarket matrix coordinate real general\nnot a size line\n",
     "size line"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n",
     "declared 2 entries but found 1"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0\n"
     "2 2 1.0\n", "more than the declared"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n",
     "outside the declared"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n",
     "1-based"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 abc\n",
     "bad 'real' entry"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 nan\n",
     "non-finite value 'nan'"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n"
     "2 2 inf\n", "non-finite value 'inf'"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 1\n1 2 -Infinity\n",
     "non-finite value '-Infinity'"),
    ("%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 NaN\n",
     "non-finite value 'NaN'"),
    ("%%MatrixMarket matrix coordinate pattern general\n2 2 1\n1 1 1.0\n",
     "expected 2 tokens"),
    ("%%MatrixMarket matrix coordinate real symmetric\n2 3 1\n1 1 1.0\n",
     "square"),
])
def test_malformed_rejected(tmp_path, content, match):
    out = tmp_path / "bad.mtx"
    out.write_text(content)
    with pytest.raises(MatrixMarketError, match=match):
        read_mtx(out)
    assert True  # errors must be MatrixMarketError, never bare crashes


def test_error_names_file_and_line(tmp_path):
    out = tmp_path / "where.mtx"
    out.write_text("%%MatrixMarket matrix coordinate real general\n"
                   "% comment\n2 2 1\n9 9 1.0\n")
    with pytest.raises(MatrixMarketError, match=r"where\.mtx:4"):
        read_mtx(out)


def test_nonfinite_value_error_is_located(tmp_path):
    # the file position is only known at parse time — preflight would catch
    # the NaN later but could not say which line it came from
    out = tmp_path / "naned.mtx"
    out.write_text("%%MatrixMarket matrix coordinate real general\n"
                   "3 3 3\n1 1 1.0\n2 2 nan\n3 3 1.0\n")
    with pytest.raises(MatrixMarketError, match=r"naned\.mtx:4.*non-finite"):
        read_mtx(out)


def test_write_mtx_rejects_nonfinite(tmp_path):
    with pytest.raises(MatrixMarketError, match="non-finite"):
        write_mtx(tmp_path / "w.mtx", [0, 1], [0, 1], [1.0, float("inf")],
                  shape=(2, 2))


# --------------------------------------------------------------------------
# load_problem: the ingestion pipeline into MatchingProblem
# --------------------------------------------------------------------------


def test_load_problem_canonical():
    problem, coo = load_problem(DATA / "circuit8.mtx", transform="abs")
    assert isinstance(problem, MatchingProblem)
    assert problem.n == coo.nrows == 8
    row = np.asarray(problem.row)
    col = np.asarray(problem.col)
    m = row < problem.n
    # repo-wide convention: lex-sorted, padded with (n, n, 0)
    key = row.astype(np.int64) * 64 + col
    assert np.array_equal(key, np.sort(key))
    assert np.array_equal(row[~m], np.full((~m).sum(), 8))
    assert np.asarray(problem.val)[~m].sum() == 0


def test_load_problem_sums_duplicates(tmp_path):
    out = tmp_path / "dup.mtx"
    out.write_text("%%MatrixMarket matrix coordinate real general\n"
                   "2 2 4\n1 1 1.5\n1 1 2.0\n2 2 1.0\n2 1 0.25\n")
    problem, _ = load_problem(out, transform=None)
    row = np.asarray(problem.row)
    val = np.asarray(problem.val)
    assert val[(row == 0)][0] == pytest.approx(3.5)  # 1.5 + 2.0 assembled


def test_load_problem_drops_zeros_and_cancellations(tmp_path):
    out = tmp_path / "zeros.mtx"
    out.write_text("%%MatrixMarket matrix coordinate real general\n"
                   "2 2 5\n1 1 1.0\n1 2 0.0\n2 1 4.0\n2 2 -4.0\n2 2 4.0\n")
    problem, _ = load_problem(out, transform=None)
    row = np.asarray(problem.row)
    nnz = int((row < problem.n).sum())
    assert nnz == 2  # explicit zero and the cancelled (2,2) pair are gone


def test_load_problem_requires_square(tmp_path):
    out = tmp_path / "rect.mtx"
    out.write_text("%%MatrixMarket matrix coordinate real general\n"
                   "2 3 1\n1 1 1.0\n")
    with pytest.raises(MatrixMarketError, match="square"):
        load_problem(out)
