"""Sparse substrate unit tests."""
import jax.numpy as jnp
import numpy as np

from repro.sparse import (
    coo_sddmm,
    coo_spmm,
    coo_to_padded_csr,
    partition_coo_2d,
    segment_max_with_payload,
    segment_softmax,
)
from repro.sparse.ops import segment_argmax_tie


def _rand_coo(n, m, seed=0):
    rng = np.random.default_rng(seed)
    rr = rng.integers(0, n, m).astype(np.int32)
    cc = rng.integers(0, n, m).astype(np.int32)
    key = rr.astype(np.int64) * n + cc
    _, idx = np.unique(key, return_index=True)
    rr, cc = rr[idx], cc[idx]
    vv = rng.uniform(0.1, 1.0, rr.shape[0]).astype(np.float32)
    return rr, cc, vv


def test_coo_spmm_matches_dense():
    n = 37
    rr, cc, vv = _rand_coo(n, 200)
    x = np.random.default_rng(1).normal(size=(n, 8)).astype(np.float32)
    a = np.zeros((n, n), np.float32)
    a[rr, cc] = vv
    # pad
    pad = 17
    row = jnp.concatenate([jnp.asarray(rr), jnp.full((pad,), n, jnp.int32)])
    col = jnp.concatenate([jnp.asarray(cc), jnp.full((pad,), n, jnp.int32)])
    val = jnp.concatenate([jnp.asarray(vv), jnp.zeros((pad,), jnp.float32)])
    xj = jnp.concatenate([jnp.asarray(x), jnp.zeros((1, 8), jnp.float32)])
    y = coo_spmm(row, col, val, xj, n)
    np.testing.assert_allclose(np.array(y), a @ x, rtol=1e-5, atol=1e-5)


def test_coo_sddmm():
    n = 19
    rr, cc, _ = _rand_coo(n, 80)
    a = np.random.default_rng(2).normal(size=(n, 6)).astype(np.float32)
    b = np.random.default_rng(3).normal(size=(n, 6)).astype(np.float32)
    out = coo_sddmm(jnp.asarray(rr), jnp.asarray(cc), jnp.asarray(a), jnp.asarray(b))
    expect = (a @ b.T)[rr, cc]
    np.testing.assert_allclose(np.array(out), expect, rtol=1e-5, atol=1e-5)


def test_segment_softmax_sums_to_one():
    seg = jnp.array([0, 0, 1, 1, 1, 3], jnp.int32)
    logits = jnp.array([0.5, -1.0, 2.0, 2.0, 0.0, 5.0], jnp.float32)
    p = segment_softmax(logits, seg, 4)
    sums = np.zeros(4)
    np.add.at(sums, np.array(seg), np.array(p))
    np.testing.assert_allclose(sums[[0, 1, 3]], 1.0, rtol=1e-6)


def test_segment_max_with_payload_ties():
    vals = jnp.array([1.0, 2.0, 2.0, 0.5], jnp.float32)
    seg = jnp.array([0, 0, 0, 1], jnp.int32)
    payload = jnp.array([10, 7, 3, 2], jnp.int32)
    m, p = segment_max_with_payload(vals, payload, seg, 3)
    assert float(m[0]) == 2.0 and int(p[0]) == 3  # tie -> smaller payload
    assert int(p[2]) == -1  # empty segment


def test_segment_argmax_tie_key():
    vals = jnp.array([2.0, 2.0, 1.0], jnp.float32)
    tie = jnp.array([5, 3, 1], jnp.int32)
    seg = jnp.array([0, 0, 0], jnp.int32)
    m, idx = segment_argmax_tie(vals, tie, seg, 1)
    assert int(idx[0]) == 1  # max value, smallest tie key


def test_partition_2d_roundtrip():
    n = 50
    rr, cc, vv = _rand_coo(n, 300, seed=7)
    part = partition_coo_2d(rr, cc, vv, n, 4, 2)
    got = set()
    for a in range(4):
        for b in range(2):
            k = int(part.nnz[a, b])
            for t in range(k):
                i, j, w = int(part.row[a, b, t]), int(part.col[a, b, t]), float(part.val[a, b, t])
                assert i // part.br == a and j // part.bc == b
                got.add((i, j, np.float32(w)))
    expect = set(zip(rr.tolist(), cc.tolist(), vv.tolist()))
    assert got == expect
    # per-block lex sort
    for a in range(4):
        for b in range(2):
            k = int(part.nnz[a, b])
            pairs = list(zip(part.row[a, b, :k].tolist(), part.col[a, b, :k].tolist()))
            assert pairs == sorted(pairs)


def test_padded_csr():
    rr, cc, vv = _rand_coo(11, 40, seed=9)
    csr = coo_to_padded_csr(rr, cc, vv, 11, 11, capacity=64)
    assert csr.capacity == 64
    assert csr.row_ptr[-1] == csr.nnz
    for i in range(11):
        s, e = csr.row_ptr[i], csr.row_ptr[i + 1]
        assert (csr.row[s:e] == i).all()
        assert (np.diff(csr.col[s:e]) > 0).all()
