"""Paper-eval harness smoke (repro.experiments, DESIGN.md §8): the sweep
runs end to end in-process, every certificate is sound, the log-scaled
fixtures solve bit-identically across backends, and the BENCH rows carry
the flags the CI regression gate greps for."""
import json

import numpy as np
import pytest

from repro.core import ref
from repro.experiments import paper_eval

TINY_SPEC = {"fixtures": True, "synthetic_count": 2, "synthetic_n": 24}


@pytest.fixture(scope="module")
def records():
    return paper_eval.run_eval(TINY_SPEC, backends=("reference", "xla"),
                               grids=[(1, 1)])


def test_sweep_shape(records):
    cases = paper_eval._cases_from_spec(TINY_SPEC)
    # per case: reference + xla + the 1x1 grid row
    assert len(records) == 3 * len(cases)
    engines = {r.engine for r in records}
    assert engines == {"reference", "xla", "grid1x1"}
    assert {r.source for r in records} == {"fixture", "synthetic"}


def test_every_row_checked(records):
    for r in records:
        assert r.perfect
        assert r.certified_sound
        assert r.identical_to_reference
        assert r.weight <= r.upper_bound + 1e-6 * max(1.0, abs(r.upper_bound))


@pytest.mark.skipif(not ref.HAVE_SCIPY, reason="exact oracle needs scipy")
def test_fixture_bounds_match_oracle(records):
    # acceptance: every certified ratio bound is sound vs the ref.py exact
    # optimum where computable — run_eval already raises otherwise, but pin
    # the reported numbers here too
    for r in records:
        if r.ratio_exact is not None and r.ratio_bound is not None:
            assert r.ratio_bound <= r.ratio_exact + 1e-6


def test_log_scaled_fixture_bit_identical_across_backends(records):
    # acceptance: the log-scaled fixture solves bit-identically through
    # solve() on reference and xla — same weight, same iteration count
    rows = {r.engine: r for r in records if r.name == "circuit8"}
    assert rows["reference"].transform == "log2_scaled_nonneg"
    assert rows["reference"].weight == rows["xla"].weight
    assert rows["reference"].awac_iters == rows["xla"].awac_iters
    assert rows["xla"].identical_to_reference


def test_bench_rows_carry_gate_flags(records):
    rows = paper_eval.to_bench_rows(records)
    assert all(r["name"].startswith("paper_eval_") for r in rows)
    for r in rows:
        assert "certified_sound=True" in r["derived"]
        assert "identical_to_reference=True" in r["derived"]
        assert r["us_per_call"] > 0
    # the regression gate actually parses these flags
    import sys
    sys.path.insert(0, str(paper_eval.REPO_ROOT))
    try:
        from benchmarks.check_regression import _ident_flags
    finally:
        sys.path.pop(0)
    flags = _ident_flags(rows[0]["derived"])
    assert ("certified_sound", True) in flags
    assert ("identical_to_reference", True) in flags


def test_identity_flag_is_a_real_comparison_without_reference_backend():
    # identical_to_reference must come from an actual reference solve even
    # when "reference" is not in the swept backends
    spec = {"fixtures": True, "synthetic_count": 0, "names": ["circuit8"]}
    recs = paper_eval.run_eval(spec, backends=("xla",), grids=[])
    (r,) = recs
    assert r.engine == "xla" and r.identical_to_reference


def test_markdown_table(records):
    md = paper_eval.to_markdown(records)
    header = [ln for ln in md.splitlines() if ln.startswith("| matrix")][0]
    assert header.count("|") == md.splitlines()[-1].count("|")
    assert "circuit8" in md and "grid1x1" in md


def test_write_outputs(tmp_path, records):
    table, bench = paper_eval.write_outputs(
        records, 1.0, out_dir=tmp_path, bench_path=tmp_path / "bench.json",
        quick=True)
    rec = json.loads(bench.read_text())
    assert rec["suite"] == "paper_eval"
    assert len(rec["rows"]) == len(records)
    assert rec["metadata"]["quick"] is True
    assert table.read_text().startswith("# Paper evaluation")


def test_unsound_or_divergent_rows_raise():
    rec = paper_eval.EvalRecord(
        name="x", source="fixture", transform="abs", engine="xla", n=4,
        nnz=4, weight=1.0, upper_bound=0.5, ratio_bound=1.0,
        ratio_exact=None, tight=False, awac_iters=1, wall_s=0.0,
        perfect=True, identical_to_reference=True, certified_sound=False)
    with pytest.raises(AssertionError, match="UNSOUND"):
        paper_eval._check(rec)
    rec2 = paper_eval.EvalRecord(**{**rec.__dict__,
                                    "certified_sound": True,
                                    "identical_to_reference": False})
    with pytest.raises(AssertionError, match="differs from the reference"):
        paper_eval._check(rec2)


@pytest.mark.slow
def test_grid_subprocess_roundtrip():
    """The fake-device subprocess path used for grids beyond the attached
    device count: records must come back typed and checked."""
    spec = {"fixtures": True, "synthetic_count": 0, "names": ["circuit8"]}
    recs = paper_eval._eval_grid_subproc(spec, (2, 2), oracle_max_n=64,
                                         n_cases=1)
    (r,) = recs
    assert r.engine == "grid2x2"
    assert r.identical_to_reference and r.certified_sound and r.perfect
    assert np.isclose(r.ratio_bound, 1.0)
