"""Differential harness for the batched AWPM engine (DESIGN.md §4).

Contract under test: for every instance b and every backend,
``batch.awpm_batched(row, col, val, n)`` returns exactly the state and
iteration count ``single.awpm(row[b], col[b], val[b], n)`` would — including
batches whose instances converge at very different speeds (one in 1 AWAC
iteration, another in ~20), where the per-instance masks must freeze early
finishers bit-exactly while the rest keep iterating.

Also covers degenerate inputs (n=1, all-ties weights, single dense row) and
error paths (unknown backend, explicit window_steps / precomputed row_ptr
overrides) that previously had zero coverage.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batch, graph, single
from repro.core.single import MatchState
from repro.sparse.csr import batched_row_ptr_from_sorted, row_ptr_from_sorted

BACKENDS = ["reference", "xla", "pallas"]


def _assert_instance_identical(stS, itS, stB, itB, i, msg):
    assert int(itS) == int(itB[i]), f"{msg}: iters {int(itS)} != {int(itB[i])}"
    names = ["mate_row", "mate_col", "u", "v"]
    for nm, a, b in zip(names, stS, (stB.mate_row[i], stB.mate_col[i],
                                     stB.u[i], stB.v[i])):
        np.testing.assert_array_equal(np.array(a), np.array(b),
                                      err_msg=f"{msg}: {nm}")


def _heterogeneous_batch(n=48):
    kinds = [("uniform", 0), ("antigreedy", 11), ("circuit", 2),
             ("banded", 3), ("powerlaw", 5)]
    gs = [graph.generate(n, avg_degree=5.0 + (i % 3), kind=k, seed=s)
          for i, (k, s) in enumerate(kinds)]
    return gs, batch.stack_graphs(gs)


@pytest.mark.parametrize("backend", BACKENDS)
def test_awpm_batched_bit_identical_per_instance(backend):
    n = 48
    gs, (row, col, val) = _heterogeneous_batch(n)
    stB, itB = batch.awpm_batched(row, col, val, n, backend=backend)
    assert bool(batch.is_perfect_batched(stB, n).all())
    for i in range(len(gs)):
        stS, itS = single.awpm(row[i], col[i], val[i], n, backend=backend)
        _assert_instance_identical(stS, itS, stB, itB, i,
                                   f"{backend}/instance{i}")


def _chain_graph(n):
    """Overlapping heavy 4-cycles: from the diagonal matching, AWAC's
    vertex-disjointness + deterministic fallback force ~n/2 sequential
    augmentation rounds — the slow-convergence extreme."""
    rows, cols, vals = [], [], []
    for i in range(n):
        rows.append(i), cols.append(i), vals.append(0.1)
    for i in range(n - 1):
        w = 0.5 + 0.4 * i / n
        rows += [i, i + 1]
        cols += [i + 1, i]
        vals += [w, w]
    return graph.from_coo(np.array(rows, np.int32), np.array(cols, np.int32),
                          np.array(vals, np.float32), n)


@pytest.mark.parametrize("backend", BACKENDS)
def test_mixed_convergence_speeds_within_batch(backend):
    """One instance needs ~20 AWAC iterations, the other converges in 1:
    the early finisher's state must stay frozen (bit-exact) while the slow
    instance keeps augmenting."""
    n = 40
    slow = _chain_graph(n)  # ~n/2 iterations from the diagonal matching
    fast = graph.generate(n, avg_degree=3.0, kind="circuit", seed=2)
    gs = [slow, fast]
    row, col, val = batch.stack_graphs(gs)

    # per-instance initial states: diagonal matching for the chain, greedy +
    # MCM for the circuit instance (its usual pipeline entry into AWAC)
    st_slow = single.state_from_mates(row[0], col[0], val[0], n,
                                      np.arange(n), np.arange(n))
    st0 = single.greedy_maximal(row[1], col[1], val[1], n)
    st_fast = single.mcm(row[1], col[1], val[1], n, st0.mate_row,
                         st0.mate_col)
    stacked = MatchState(*(jnp.stack([a, b]) for a, b in
                           zip(st_slow, st_fast)))

    stB, itB = batch.awac_batched(row, col, val, n, stacked, backend=backend)
    its = []
    for i, st_i in enumerate((st_slow, st_fast)):
        stS, itS = single.awac(row[i], col[i], val[i], n, st_i,
                               backend=backend)
        _assert_instance_identical(stS, itS, stB, itB, i,
                                   f"{backend}/mixed{i}")
        its.append(int(itS))
    assert its[0] >= 20 and its[1] <= 2, its  # genuinely mixed speeds


# --------------------------------------------------------------------------
# degenerate inputs
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_degenerate_n1(backend):
    g = graph.from_coo(np.array([0]), np.array([0]),
                       np.array([0.7], np.float32), 1)
    row, col, val = batch.stack_graphs([g, g])
    stB, itB = batch.awpm_batched(row, col, val, 1, backend=backend)
    assert bool(batch.is_perfect_batched(stB, 1).all())
    np.testing.assert_array_equal(np.array(stB.mate_row[:, 0]), [0, 0])
    stS, itS = single.awpm(row[0], col[0], val[0], 1, backend=backend)
    _assert_instance_identical(stS, itS, stB, itB, 0, f"{backend}/n1")


@pytest.mark.parametrize("backend", BACKENDS)
def test_degenerate_all_ties(backend):
    """Every weight equal: every gain ties at 0 and the smallest-row/
    smallest-payload tie-breaks are all that decide — must agree with the
    sequential engine everywhere."""
    n = 24
    gs = []
    for seed in (0, 1):
        g0 = graph.generate(n, avg_degree=4.0, kind="uniform", seed=seed,
                            normalize=False)
        real = np.asarray(g0.row) < n
        gs.append(graph.from_coo(np.asarray(g0.row)[real],
                                 np.asarray(g0.col)[real],
                                 np.full(int(real.sum()), 0.5, np.float32),
                                 n))
    row, col, val = batch.stack_graphs(gs)
    stB, itB = batch.awpm_batched(row, col, val, n, backend=backend)
    for i in range(len(gs)):
        stS, itS = single.awpm(row[i], col[i], val[i], n, backend=backend)
        _assert_instance_identical(stS, itS, stB, itB, i,
                                   f"{backend}/ties{i}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_degenerate_single_dense_row(backend):
    """One row holds n entries (the widest possible CSR window) next to an
    instance with ordinary degrees — the shared window_steps must cover
    both."""
    n = 16
    rng = np.random.default_rng(3)
    rows = np.concatenate([np.zeros(n, np.int32), np.arange(1, n, dtype=np.int32)])
    cols = np.concatenate([np.arange(n, dtype=np.int32),
                           rng.permutation(n - 1).astype(np.int32)])
    vals = rng.uniform(0.1, 1.0, rows.shape[0]).astype(np.float32)
    dense_row = graph.from_coo(rows, cols, vals, n)
    sparse = graph.generate(n, avg_degree=3.0, kind="uniform", seed=1)
    row, col, val = batch.stack_graphs([dense_row, sparse])
    stB, itB = batch.awpm_batched(row, col, val, n, backend=backend)
    for i in range(2):
        stS, itS = single.awpm(row[i], col[i], val[i], n, backend=backend)
        _assert_instance_identical(stS, itS, stB, itB, i,
                                   f"{backend}/dense{i}")


# --------------------------------------------------------------------------
# error paths and explicit overrides
# --------------------------------------------------------------------------


def test_unknown_backend_raises():
    n = 8
    g = graph.generate(n, avg_degree=3.0, seed=0)
    row, col, val = batch.stack_graphs([g])
    st = single.empty_state(n)
    with pytest.raises(ValueError, match="unknown AWAC backend"):
        single.awac(jnp.asarray(g.row), jnp.asarray(g.col),
                    jnp.asarray(g.val), n, st, backend="bogus")
    stacked = MatchState(*(x[None] for x in st))
    with pytest.raises(ValueError, match="unknown AWAC backend"):
        batch.awac_batched(row, col, val, n, stacked, backend="bogus")
    with pytest.raises(ValueError, match="unknown AWAC backend"):
        batch.awpm_batched(row, col, val, n, backend="bogus")


def test_resolve_backend_passthrough_and_auto():
    assert single.resolve_backend("reference") == "reference"
    assert single.resolve_backend("xla") == "xla"
    assert single.resolve_backend("pallas") == "pallas"
    assert single.resolve_backend("auto") in ("xla", "pallas")


def test_explicit_window_steps_and_row_ptr_overrides():
    """Precomputed row_ptr and an oversized explicit window depth must not
    change any result (extra binary-search rounds are no-ops)."""
    n = 32
    gs = [graph.generate(n, avg_degree=5.0, kind=k, seed=s)
          for k, s in (("uniform", 0), ("antigreedy", 4))]
    row, col, val = batch.stack_graphs(gs)
    rp = batched_row_ptr_from_sorted(row, n)
    st0, it0 = batch.awpm_batched(row, col, val, n, backend="xla")
    st1, it1 = batch.awpm_batched(row, col, val, n, backend="xla",
                                  row_ptr=rp, window_steps=32)
    np.testing.assert_array_equal(np.array(it0), np.array(it1))
    for a, b in zip(st0, st1):
        np.testing.assert_array_equal(np.array(a), np.array(b))
    # same override contract on the sequential engine
    g = gs[0]
    r, c, v = jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val)
    sp = row_ptr_from_sorted(r, n)
    sA, iA = single.awpm(r, c, v, n, backend="xla")
    stg = single.greedy_maximal(r, c, v, n)
    stg = single.mcm(r, c, v, n, stg.mate_row, stg.mate_col)
    sB, iB = single.awac(r, c, v, n, stg, backend="xla", row_ptr=sp,
                         window_steps=32)
    assert int(iA) == int(iB)
    for a, b in zip(sA, sB):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_max_iter_zero_runs_no_awac_iterations():
    """max_iter=0 must admit no AWAC iteration in either engine (the
    batched loop's initial active mask honors the bound)."""
    n = 16
    g = graph.generate(n, avg_degree=4.0, kind="antigreedy", seed=0)
    row, col, val = batch.stack_graphs([g, g])
    st = single.greedy_maximal(row[0], col[0], val[0], n)
    st = single.mcm(row[0], col[0], val[0], n, st.mate_row, st.mate_col)
    stacked = MatchState(*(jnp.stack([a, a]) for a in st))
    sB, iB = batch.awac_batched(row, col, val, n, stacked, max_iter=0)
    sS, iS = single.awac(row[0], col[0], val[0], n, st, max_iter=0)
    assert int(iS) == 0
    for i in range(2):
        _assert_instance_identical(sS, iS, sB, iB, i, f"max_iter0/{i}")


def test_stack_graphs_rejects_mixed_n():
    g1 = graph.generate(8, avg_degree=3.0, seed=0)
    g2 = graph.generate(9, avg_degree=3.0, seed=0)
    with pytest.raises(ValueError, match="share n"):
        batch.stack_graphs([g1, g2])


def test_batched_pivot_metric_validation():
    from repro.core import pivot

    with pytest.raises(ValueError, match="unknown pivot metric"):
        pivot.batched_pivot_permutations([np.eye(4)], metric="bogus")
