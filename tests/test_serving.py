"""Serving tier (repro.serving, DESIGN.md §11): routing, size classes,
deadline batching, plan caching, warm-start fallback, and the service's
bit-identity contract against the direct ``core.api`` solve.

Everything runs on an injected simulated clock (``now=``) — no sleeps,
no wall-clock flakiness; only the tiny n<=32 class solves touch jax.
"""
import numpy as np
import pytest

from repro.core import MatchingProblem, ProblemSpec, graph, plan, solve
from repro.serving import (
    DeadlineBatcher,
    MatchingService,
    PlanCache,
    ServiceConfig,
    ShardRouter,
    SizeClass,
    WarmStartCache,
    size_class_for,
    solve_with_seed,
)

# ------------------------------------------------------------------ helpers


def _identical(a, b):
    return (np.array_equal(np.asarray(a.mate_row), np.asarray(b.mate_row))
            and np.array_equal(np.asarray(a.mate_col), np.asarray(b.mate_col))
            and np.allclose(np.asarray(a.weight), np.asarray(b.weight)))


def _svc(**over):
    defaults = dict(num_shards=2, deadline_s=0.5, max_batch=4,
                    min_class_n=16, max_class_n=64)
    defaults.update(over)
    return MatchingService(ServiceConfig(**defaults), clock=lambda: 0.0)


# ------------------------------------------------------------- size classes


def test_size_class_ladder():
    cls = size_class_for(5, 12)
    assert cls == SizeClass(n=32, cap=64, batch=8)  # 12 + 27 dummies -> 64
    cls = size_class_for(48, 200)
    assert cls == SizeClass(n=64, cap=256, batch=8)  # 200 + 16 -> 256
    # cap always covers a full identity diagonal even for sparse instances
    cls = size_class_for(33, 0)
    assert cls.n == 64 and cls.cap >= 64
    # same class for nearby sizes: that is the whole point of the ladder
    assert size_class_for(30, 90) == size_class_for(27, 80)


def test_size_class_oversize_is_exact_batch_1():
    cls = size_class_for(5000, 60000, max_class_n=4096)
    assert cls.n == 5000 and cls.batch == 1
    assert cls.cap == 60000 and cls.cap % 8 == 0
    cls = size_class_for(4097, 10, max_class_n=4096)
    assert cls.n == 4097 and cls.batch == 1 and cls.cap >= 4097


def test_size_class_validation():
    with pytest.raises(ValueError):
        size_class_for(0, 5)
    with pytest.raises(ValueError):
        size_class_for(4, -1)
    with pytest.raises(ValueError):
        SizeClass(n=32, cap=16, batch=1)  # cannot hold its own filler


# ------------------------------------------------------------------ routing


def test_shard_router_deterministic_and_consistent():
    r1, r2 = ShardRouter(4), ShardRouter(4)
    keys = [f"user-{i}" for i in range(200)]
    assert [r1.shard_for(k) for k in keys] == [r2.shard_for(k) for k in keys]
    for k in keys:
        assert r1.shard_for(k) == r1.slot_for(k) % 4
        assert 0 <= r1.slot_for(k) < r1.total_slots
    # growing the fleet remaps slots, not the hash space
    r8 = ShardRouter(8, n_bits=r1.n_bits)
    for k in keys:
        assert r8.slot_for(k) == r1.slot_for(k)
    # slots partition exactly across shards
    all_slots = sorted(s for sh in range(4) for s in r1.slots_for_shard(sh))
    assert all_slots == list(range(r1.total_slots))


def test_shard_router_validation():
    with pytest.raises(ValueError):
        ShardRouter(0)
    with pytest.raises(ValueError):
        ShardRouter(4, n_bits=0)
    with pytest.raises(ValueError):
        ShardRouter(4).slots_for_shard(4)


# --------------------------------------------------------------- plan cache


def test_plan_cache_lru_eviction_and_replan():
    built = []

    def builder(tag):
        def build():
            built.append(tag)
            return f"plan-{tag}"
        return build

    cache = PlanCache(capacity=2)
    assert cache.get("a", builder("a")) == "plan-a"
    assert cache.get("b", builder("b")) == "plan-b"
    assert cache.get("a", builder("a")) == "plan-a"  # hit: a now MRU
    assert cache.get("c", builder("c")) == "plan-c"  # evicts b (LRU)
    assert "b" not in cache and "a" in cache
    assert cache.stats.evictions == 1
    # an evicted key coming back is re-planned transparently
    assert cache.get("b", builder("b")) == "plan-b"
    assert built == ["a", "b", "c", "b"]
    assert cache.stats.hits == 1 and cache.stats.misses == 4


def test_plan_cache_throwing_build_leaves_cache_untouched():
    cache = PlanCache(capacity=1)
    cache.get("a", lambda: "plan-a")
    with pytest.raises(RuntimeError):
        cache.get("boom", lambda: (_ for _ in ()).throw(RuntimeError("x")))
    assert cache.keys() == ["a"]
    assert cache.get("a", lambda: "never") == "plan-a"


# ------------------------------------------------------------------ batcher


def test_batcher_deadline_flush_with_partial_batch():
    b = DeadlineBatcher(deadline_s=0.5)
    assert b.add("k", "r0", now=0.0, max_batch=4) is None
    assert b.due(now=0.4) == [] and b.pending() == 1
    assert b.next_deadline() == pytest.approx(0.5)
    flushes = b.due(now=0.7)  # pumped late, as a simulated clock does
    assert len(flushes) == 1
    f = flushes[0]
    assert f.items == ("r0",) and f.reason == "deadline"
    # latency is charged to the deadline, not to the late pump
    assert f.dispatched_at == pytest.approx(0.5)
    assert b.pending() == 0 and b.next_deadline() is None


def test_batcher_full_flush_is_immediate():
    b = DeadlineBatcher(deadline_s=10.0)
    assert b.add("k", "r0", now=0.0, max_batch=2) is None
    f = b.add("k", "r1", now=0.1, max_batch=2)
    assert f is not None and f.reason == "full"
    assert f.items == ("r0", "r1") and f.dispatched_at == pytest.approx(0.1)


def test_batcher_drain_and_validation():
    b = DeadlineBatcher(deadline_s=0.5)
    b.add("k1", "a", now=0.0, max_batch=4)
    b.add("k2", "b", now=0.2, max_batch=4)
    flushes = {f.key: f for f in b.drain(now=0.3)}
    assert set(flushes) == {"k1", "k2"}
    assert all(f.reason == "drain" for f in flushes.values())
    # drain before the deadline charges only the time actually waited
    assert flushes["k2"].dispatched_at == pytest.approx(0.3)
    with pytest.raises(ValueError):
        DeadlineBatcher(-1.0)
    with pytest.raises(ValueError):
        b.add("k", "x", now=0.0, max_batch=0)


# --------------------------------------------------------------- warm cache


def test_warm_cache_stale_class_and_lru():
    c = WarmStartCache(capacity=2)
    mr, mc = np.arange(17, dtype=np.int32), np.arange(17, dtype=np.int32)
    c.put("u1", 16, mr, mc)
    got = c.seed_for("u1", 16)
    assert got is not None and np.array_equal(got[0], mr)
    # a seed from another size class is stale, never repaired
    assert c.seed_for("u1", 32) is None
    assert c.seed_for("nobody", 16) is None
    assert (c.stats.served, c.stats.stale, c.stats.absent) == (1, 1, 1)
    c.put("u2", 16, mr, mc)
    c.put("u3", 16, mr, mc)  # evicts u1 (capacity 2)
    assert len(c) == 2 and c.seed_for("u1", 16) is None
    with pytest.raises(ValueError):
        c.put("bad", 16, np.arange(5), np.arange(5))


def test_solve_with_seed_falls_back_cold_bit_identically():
    g = graph.generate(12, avg_degree=4.0, kind="uniform", seed=3)
    p = MatchingProblem.from_graph(g)
    matcher = plan(ProblemSpec(n=p.n, cap=p.cap))
    cold = matcher(p)
    for bad in [(np.zeros(5, np.int32), np.zeros(5, np.int32)),  # stale shape
                12.5,                                            # not a seed
                (np.zeros(13, np.int32),)]:                      # not a pair
        result, served_warm = solve_with_seed(matcher, p, bad)
        assert not served_warm
        assert _identical(result, cold)
    # a valid fixed-point seed is served warm and returns bit-identically
    result, served_warm = solve_with_seed(
        matcher, p, (np.asarray(cold.mate_row), np.asarray(cold.mate_col)))
    assert served_warm and _identical(result, cold)


# -------------------------------------------------------------- the service


def test_service_cold_lane_bit_identical_to_direct_solve():
    svc = _svc()
    gs = {f"user-{i}": graph.generate(13, avg_degree=4.0, seed=i)
          for i in range(3)}
    for key, g in gs.items():
        svc.submit(key, g, now=0.0)
    svc.drain(now=0.1)
    responses = svc.responses()
    assert len(responses) == 3
    for r in responses:
        assert r.ok and r.lane == "cold" and not r.served_warm
        direct = solve(MatchingProblem.from_graph(gs[r.key]))
        assert _identical(r.result, direct)
        assert r.result.perfect == direct.perfect
        assert r.result.mate_row.shape == (14,)  # stripped back to true n


def test_service_deadline_flush_then_warm_repeat():
    svc = _svc(num_shards=1)
    g = graph.generate(12, avg_degree=4.0, seed=7)
    svc.submit("u", g, now=0.0)
    assert svc.responses() == []  # queued: batch not full, deadline not hit
    svc.pump(now=1.0)  # past the 0.5s deadline
    (first,) = svc.responses()
    assert first.flush_reason == "deadline" and first.lane == "cold"
    assert first.dispatched_at == pytest.approx(0.5)  # charged to deadline
    assert first.batch_fill == 1  # partial batch, padded by fillers
    # the same key again: seeded from its own converged mates -> warm lane,
    # and (same instance, fixed-point seed) bit-identical to the cold result
    svc.submit("u", g, now=2.0)
    svc.pump(now=3.0)
    (second,) = svc.responses()
    assert second.served_warm and second.lane == "warm"
    assert _identical(second.result, first.result)
    stats = svc.stats()
    assert stats["served_warm"] == 1 and stats["served_cold"] == 1
    assert stats["warm_cache"]["served"] == 1


def test_service_oversize_request_gets_own_class_and_dispatches_now():
    svc = _svc(max_class_n=16, max_batch=4)
    g = graph.generate(20, avg_degree=4.0, seed=5)  # n > max_class_n
    svc.submit("big", g, now=0.0)
    (r,) = svc.responses()  # batch=1 class: full on arrival, no deadline wait
    assert r.flush_reason == "full" and r.batch_fill == 1
    assert r.size_class.n == 20 and r.size_class.batch == 1
    assert _identical(r.result, solve(MatchingProblem.from_graph(g)))


def test_service_poisoned_batchmate_degrades_alone():
    svc = _svc(num_shards=1)
    good = graph.generate(12, avg_degree=4.0, seed=11)
    # rows 0 and 1 both reach only column 0: structurally infeasible
    poisoned = MatchingProblem(
        row=np.array([0, 1], np.int32), col=np.array([0, 0], np.int32),
        val=np.array([1.0, 2.0], np.float32), n=2)
    svc.submit("good", good, now=0.0)
    svc.submit("poisoned", poisoned, now=0.0)
    svc.drain(now=0.1)
    by_key = {r.key: r for r in svc.responses()}
    assert by_key["poisoned"].ok  # degraded, not failed
    assert not by_key["poisoned"].result.perfect
    assert by_key["good"].result.perfect
    assert _identical(by_key["good"].result,
                      solve(MatchingProblem.from_graph(good)))
    assert svc.stats()["degraded"] == 1


def test_service_admission_sanitize_and_reject():
    nan_problem = MatchingProblem(
        row=np.array([0, 1], np.int32), col=np.array([1, 0], np.int32),
        val=np.array([np.nan, 1.0], np.float32), n=2)
    svc = _svc()  # default: sanitize
    svc.submit("u", nan_problem, now=0.0)
    svc.drain(now=0.1)
    (r,) = svc.responses()
    assert r.ok and "sanitized at admission" in r.error
    svc = _svc(admission="reject")
    svc.submit("u", nan_problem, now=0.0)
    (r,) = svc.responses()  # rejected synchronously, nothing queued
    assert not r.ok and r.lane == "rejected" and r.result is None
    assert svc.stats()["rejected"] == 1
    with pytest.raises(ValueError):
        ServiceConfig(admission="explode")


def test_service_plan_cache_eviction_replans():
    # capacity 1 with two alternating classes: every class switch evicts
    # and re-plans; results must stay correct through it
    svc = _svc(plan_capacity=1, max_batch=1, max_class_n=64)
    small = graph.generate(10, avg_degree=3.0, seed=1)   # class n=16
    large = graph.generate(20, avg_degree=3.0, seed=2)   # class n=32
    for t, (key, g) in enumerate([("s", small), ("l", large),
                                  ("s2", small), ("l2", large)]):
        svc.submit(key, g, now=float(t))  # max_batch=1: dispatches now
    responses = {r.key: r for r in svc.responses()}
    assert len(responses) == 4
    assert svc.plans.stats.evictions >= 2 and len(svc.plans) == 1
    for key, g in [("s", small), ("s2", small), ("l", large), ("l2", large)]:
        assert _identical(responses[key].result,
                          solve(MatchingProblem.from_graph(g)))
