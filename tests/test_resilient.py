"""Guarded execution (runtime.resilient, DESIGN.md §9): deadlines, bounded
retry, the backend degradation chain, and the post-solve verifier. Fault
injection comes from runtime.chaos; everything here runs in-process on a
1x1 grid at most."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import MatchingProblem, SolveOptions, graph, solve
from repro.core.dual import DualCertificate
from repro.runtime import chaos, elastic
from repro.runtime.resilient import (
    DeadlineExceededError,
    ResilientMatcher,
    ResilientOptions,
    TransientFault,
    VerificationError,
    _build_rungs,
    resilient_solve,
    verify_result,
)


def _problem(n=16, seed=0):
    return MatchingProblem.from_graph(
        graph.generate(n, avg_degree=4.0, seed=seed))


def _mesh11():
    return jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


# --------------------------------------------------------------------------
# the happy path
# --------------------------------------------------------------------------


def test_serves_first_rung_with_clean_report():
    rr = resilient_solve(_problem())
    assert bool(rr.result.perfect)
    assert not rr.report.degraded
    assert rr.report.backend_used.startswith("local ")
    (attempt,) = rr.report.attempts
    assert attempt.outcome == "ok" and attempt.retry == 0


def test_certify_attaches_dual_certificate():
    rr = resilient_solve(
        _problem(), resilience=ResilientOptions(certify=True,
                                                verify_convergence=True))
    assert isinstance(rr.report.certificate, DualCertificate)
    assert rr.report.certificate.upper_bound >= float(rr.result.weight) - 1e-6


def test_options_validation():
    with pytest.raises(ValueError, match="deadline_s"):
        ResilientOptions(deadline_s=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        ResilientOptions(max_retries=-1)


# --------------------------------------------------------------------------
# the verifier
# --------------------------------------------------------------------------


def test_verify_result_passes_on_honest_results():
    p = _problem()
    assert verify_result(p, solve(p)) == ()


def test_verify_result_catches_corruption():
    p = _problem()
    res = solve(p)
    mr = np.asarray(res.mate_row).copy()
    mr[0] = mr[1]  # two columns now claim one row
    bad = dataclasses.replace(res, mate_row=mr)
    fails = verify_result(p, bad)
    assert any("two columns to one row" in f for f in fails)
    # a forged weight is caught by the recompute
    forged = dataclasses.replace(res, weight=np.asarray(res.weight) + 1.0)
    assert any("recomputed weight" in f for f in verify_result(p, forged))
    # a forged perfect flag is caught by the matched-column count
    flagged = dataclasses.replace(res, perfect=np.asarray(False))
    assert any("perfect flag" in f for f in verify_result(p, flagged))


def test_verify_result_batched_labels_instances():
    p = MatchingProblem.stack([_problem(seed=0), _problem(seed=1)])
    res = solve(p)
    mc = np.asarray(res.mate_col).copy()
    mc[1, p.n] = 0  # corrupt instance 1's sentinel slot
    fails = verify_result(p, dataclasses.replace(res, mate_col=mc))
    assert fails and all(f.startswith("[instance 1]") for f in fails)


# --------------------------------------------------------------------------
# retry + degradation
# --------------------------------------------------------------------------


def test_transient_failure_retries_on_same_rung():
    p = _problem()
    with chaos.failing_backend("xla", "pallas", fail_times=1):
        rr = resilient_solve(p)
    assert [a.outcome for a in rr.report.attempts] == ["transient", "ok"]
    assert not rr.report.degraded
    assert bool(rr.result.perfect)


def test_persistent_failure_degrades_to_reference():
    p = _problem()
    ref = solve(p, SolveOptions(backend="reference"))
    with chaos.failing_backend("xla", "pallas"):
        rr = resilient_solve(p)
    assert rr.report.backend_used == "local reference"
    assert rr.report.degraded
    assert np.array_equal(np.asarray(rr.result.mate_row),
                          np.asarray(ref.mate_row))


def test_deadline_expires_with_report():
    p = _problem()
    with chaos.failing_backend("xla", "pallas", "reference",
                               exc_type=TransientFault):
        with pytest.raises(DeadlineExceededError) as exc:
            resilient_solve(p, resilience=ResilientOptions(
                deadline_s=0.2, max_retries=1000, backoff_s=0.05))
    assert all(a.outcome == "transient" for a in exc.value.report.attempts)


def test_every_rung_failing_raises_verification_error():
    p = _problem()
    with chaos.failing_backend("xla", "pallas", "reference",
                               exc_type=RuntimeError):
        with pytest.raises(VerificationError) as exc:
            resilient_solve(p, SolveOptions(backend="pallas"),
                            resilience=ResilientOptions(
                                max_retries=0, backoff_s=0.0))
    # one transient attempt per local rung (pallas, xla, ref), no retries
    assert len(exc.value.report.attempts) == 3


def test_request_errors_propagate_untouched():
    g = graph.generate(10, avg_degree=3.0, seed=1)
    keep = np.asarray(g.col) != 4
    infeasible = MatchingProblem.from_coo(np.asarray(g.row)[keep],
                                          np.asarray(g.col)[keep],
                                          np.asarray(g.val)[keep], g.n)
    from repro.core import InfeasibleProblemError

    with pytest.raises(InfeasibleProblemError):
        resilient_solve(infeasible)


# --------------------------------------------------------------------------
# the degradation chain itself
# --------------------------------------------------------------------------


def test_rung_labels_without_grid():
    labels = [lbl for lbl, _ in _build_rungs(SolveOptions(backend="pallas"))]
    assert labels == ["local pallas", "local xla", "local reference"]
    labels = [lbl for lbl, _ in _build_rungs(SolveOptions(backend="xla"))]
    assert labels == ["local xla", "local reference"]


def test_grid_rung_strips_distributed_knobs_on_fallback():
    rungs = _build_rungs(SolveOptions(grid=_mesh11(), exchange_check=True,
                                      packed=True))
    assert rungs[0][0] == "grid 1x1 (fused)"
    for label, opts in rungs[1:]:
        assert label.startswith("local ")
        assert opts.grid is None and not opts.exchange_check \
            and not opts.packed


def test_dead_fleet_skips_the_grid_rung():
    mesh = _mesh11()
    fleet = elastic.fail_hosts(elastic.initial_fleet(mesh),
                               [np.asarray(mesh.devices)[0, 0].id])
    labels = [lbl for lbl, _ in
              _build_rungs(SolveOptions(grid=mesh), fleet=fleet)]
    assert all(lbl.startswith("local ") for lbl in labels)


def test_grid_request_degrades_to_local_when_engine_dies():
    p = _problem()
    ref = solve(p)
    with chaos.failing_grid():
        rr = resilient_solve(p, SolveOptions(grid=_mesh11()))
    assert rr.report.degraded
    assert rr.report.backend_used.startswith("local ")
    assert np.array_equal(np.asarray(rr.result.mate_row),
                          np.asarray(ref.mate_row))


# --------------------------------------------------------------------------
# ResilientMatcher
# --------------------------------------------------------------------------


def test_resilient_matcher_serves_and_caches():
    p = _problem()
    m = ResilientMatcher(p)
    r1 = m(p)
    r2 = m(p)
    assert bool(r1.result.perfect)
    assert np.array_equal(np.asarray(r1.result.mate_row),
                          np.asarray(r2.result.mate_row))
    assert len(m._matchers) == 1  # one planned Matcher, reused


def test_resilient_matcher_degrades_like_solve():
    p = _problem()
    with chaos.failing_backend("xla", "pallas"):
        rr = ResilientMatcher(p)(p)
    assert rr.report.backend_used == "local reference"
