"""Training loop, optimizer, checkpoint, elastic, straggler, compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.straggler import StragglerMonitor
from repro.training.grad_compression import (
    CompressedState,
    compress_topk,
    dequantize_int8,
    init_state,
    quantize_int8,
)
from repro.training.loop import make_train_step, train
from repro.training.optimizer import AdamWConfig, init_opt_state


def _quad_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {}


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (4, 2)), "b": jnp.zeros((2,))}


def _toy_batch(step):
    rng = np.random.default_rng(step)
    x = rng.normal(size=(16, 4)).astype(np.float32)
    w_true = np.array([[1.0, -1], [2, 0.5], [-0.5, 1], [0, 2]], np.float32)
    return {"x": x, "y": x @ w_true}


def test_train_step_reduces_loss():
    params = _toy_params(jax.random.PRNGKey(0))
    cfg = AdamWConfig(lr=5e-2, warmup_steps=5, total_steps=200, weight_decay=0.0)
    step = jax.jit(make_train_step(_quad_loss, cfg))
    opt = init_opt_state(params)
    batch = jax.tree.map(jnp.asarray, _toy_batch(0))
    l0 = float(_quad_loss(params, batch)[0])
    for i in range(100):
        params, opt, m = step(params, opt, jax.tree.map(jnp.asarray, _toy_batch(i)))
    assert float(m["loss"]) < 0.1 * l0


def test_grad_accum_matches_full_batch():
    params = _toy_params(jax.random.PRNGKey(1))
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, clip_norm=1e9)
    batch = jax.tree.map(jnp.asarray, _toy_batch(3))
    s1 = make_train_step(_quad_loss, cfg)
    s4 = make_train_step(_quad_loss, cfg, grad_accum=4)
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p4, _, m4 = s4(params, init_opt_state(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5, atol=1e-6)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    params = _toy_params(jax.random.PRNGKey(2))
    opt = init_opt_state(params)
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(10, params, opt)
    mgr.save(20, params, opt)
    mgr.save(30, params, opt)
    assert mgr.list_steps() == [20, 30]  # keep=2 gc'd step 10
    p2, o2, step = mgr.restore_latest(like={"params": params, "opt": opt})
    assert step == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.array(a), np.array(b))
    # a stale .tmp dir must not be listed as a checkpoint
    (tmp_path / "step_000000040.tmp").mkdir()
    assert mgr.list_steps() == [20, 30]


def test_checkpoint_async(tmp_path):
    params = _toy_params(jax.random.PRNGKey(3))
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(1, params)
    mgr.wait()
    assert mgr.list_steps() == [1]


def test_train_loop_restores_from_checkpoint(tmp_path):
    params = _toy_params(jax.random.PRNGKey(4))
    cfg = AdamWConfig(lr=1e-2)
    mgr = CheckpointManager(tmp_path)
    p1, o1, hist = train(params, _quad_loss, _toy_batch, cfg, n_steps=6,
                         checkpoint_mgr=mgr, checkpoint_every=2, log_every=100)
    assert mgr.list_steps()
    # second run resumes from the saved step
    p2, o2, hist2 = train(params, _quad_loss, _toy_batch, cfg, n_steps=6,
                          checkpoint_mgr=mgr, checkpoint_every=2, log_every=100)
    assert int(o2.step) >= int(o1.step) - 4


def test_straggler_monitor_flags_slow_rank():
    mon = StragglerMonitor(warmup=3)
    for step in range(10):
        for rank in range(8):
            mon.record(step, 1.0 + (5.0 if rank == 3 else 0.0), rank)
    assert mon.slow_ranks() == [3]


def test_int8_quant_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(128,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.array(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.51 + 1e-6


def test_topk_error_feedback_converges():
    # error feedback: sum of compressed grads over steps ~ sum of true grads
    g = jnp.asarray(np.random.default_rng(1).normal(size=(256,)), jnp.float32)
    grads = {"g": g}
    state = init_state(grads)
    acc = jnp.zeros_like(g)
    n = 200
    for _ in range(n):
        out, state = compress_topk(grads, state, k_frac=0.1)
        acc = acc + out["g"]
    # error feedback bounds the residual, so the time-average converges to g
    np.testing.assert_allclose(np.array(acc / n), np.array(g), atol=0.1)


def test_elastic_mesh_shrink():
    # simulated: 4x2 grid, kill one device -> its data row is dropped
    from repro.runtime import elastic

    class FakeDev:
        def __init__(self, i):
            self.id = i

    grid = np.array([[FakeDev(r * 2 + c) for c in range(2)] for r in range(4)])
    fleet = elastic.FleetState(grid, np.ones(8, bool))
    fleet = elastic.fail_hosts(fleet, [5])  # device in row 2
    alive = fleet.alive.reshape(4, 2)
    rows_ok = alive.all(axis=1)
    assert rows_ok.tolist() == [True, True, False, True]
