"""The paper's motivating application end-to-end (§6.6): static pivoting for
a pivot-free sparse LU solve.

Builds an ill-conditioned system (tiny diagonal, heavy hidden permutation),
equilibrates, computes an AWPM row permutation on the log-weights (MC64
option-5 analogue), factorizes WITHOUT pivoting, and compares the solution
error against (a) no pre-pivoting and (b) the exact MWPM permutation.
The final section runs the same contrast through the full ``repro.solver``
subsystem (DESIGN.md §12): MC64 scalings from dual potentials, sparse LU
with GESP perturbation, and mixed-precision iterative refinement.

  PYTHONPATH=src python examples/static_pivoting_solver.py
"""
import numpy as np

from repro.core import MatchingProblem, graph, pivot, ref, solve
from repro.solver import solve_linear_system


def _ill_conditioned_system(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n)) * (rng.random((n, n)) < 0.15)
    hidden = rng.permutation(n)
    a[hidden, np.arange(n)] = rng.uniform(5, 10, n) * rng.choice([-1, 1], n)
    np.fill_diagonal(a, rng.uniform(0, 1e-9, n))
    x_true = np.ones(n)
    return a, a @ x_true, x_true


def main(n=120, seed=0):
    a, b, x_true = _ill_conditioned_system(n, seed)
    print(f"system: n={n}, nnz={int((a != 0).sum())}, diagonal ~1e-9")

    a_s, _, _ = pivot.equilibrate(a)
    rr, cc = np.nonzero(a_s)
    g = graph.from_coo(rr.astype(np.int32), cc.astype(np.int32),
                       np.abs(a_s[rr, cc]).astype(np.float32), n)
    glog = pivot.log_transformed(g)
    res = solve(MatchingProblem.from_graph(glog))
    mr = np.array(res.mate_row[:n])
    print(f"AWPM (product metric): perfect matching in "
          f"{int(res.awac_iters)} AWAC rounds")

    for name, perm in [("no pivoting", np.arange(n)), ("AWPM", mr)]:
        try:
            x = pivot.static_pivot_solve(a, b, perm)
            err = pivot.relative_error(x, x_true)
            print(f"  {name:12s}: relative error {err:.3e}")
        except ZeroDivisionError:
            print(f"  {name:12s}: LU FAILED (zero pivot)")

    dense_log = np.where(g.structure_dense(),
                         np.log(np.maximum(np.abs(g.to_dense()), 1e-30)),
                         0.0).astype(np.float32)
    mr_x, _ = ref.exact_mwpm(dense_log, g.structure_dense())
    x = pivot.static_pivot_solve(a, b, mr_x)
    print(f"  {'exact MWPM':12s}: relative error "
          f"{pivot.relative_error(x, x_true):.3e}")


def main_batched(n=96, n_systems=4, seed=0):
    """Pivot serving: B independent ill-conditioned systems, ALL row
    permutations from one batched ``api.solve`` dispatch, then a
    pivot-free LU solve per system."""
    systems = [_ill_conditioned_system(n, seed + i) for i in range(n_systems)]
    mats = [s[0] for s in systems]
    bs = [s[1] for s in systems]
    print(f"\nbatched pivot serving: {n_systems} systems, n={n}, "
          f"one matching dispatch")
    xs, iters = pivot.static_pivot_solve_batched(mats, bs)
    for i, (x, (_, _, x_true)) in enumerate(zip(xs, systems)):
        err = pivot.relative_error(x, x_true)
        print(f"  system {i}: AWAC iters={int(iters[i]):3d}  "
              f"relative error {err:.3e}")


def main_solver(n=32, seed=0):
    """The contrast through ``repro.solver.solve_linear_system`` — the
    full pipeline with MC64 scalings and iterative refinement. The system
    here compounds pivot growth every elimination step (tiny diagonal
    under a heavy cyclic band), so the unpivoted arm genuinely diverges —
    reported on the SolveReport, never raised — while AWPM static
    pivoting holds growth at 1 and converges in two sweeps."""
    rng = np.random.default_rng(seed)
    row, col, val = [], [], []
    for i in range(n):
        row += [i, i, i]
        col += [i, (i + 1) % n, (i + 3) % n]
        val += [1e-8 * (1.0 + rng.random()), 5.0 + 5.0 * rng.random(),
                0.01 + 0.09 * rng.random()]
    a = (np.array(row), np.array(col), np.array(val), n)
    b = rng.standard_normal(n)
    print(f"\nrepro.solver pipeline (DESIGN.md §12), compounding-growth "
          f"system (n={n}):")
    for arm in ("awpm", "none"):
        rep = solve_linear_system(a, b, pivoting=arm)
        print(f"  pivoting={arm:5s}: growth={rep.lu_stats.pivot_growth:.3g} "
              f"sweeps={int(np.max(rep.refinement.iterations))} "
              f"residual={float(np.max(rep.residual)):.3e} "
              f"{'CONVERGED' if rep.ok else 'FAILED (the reproduced result)'}")


if __name__ == "__main__":
    main()
    main_batched()
    main_solver()
