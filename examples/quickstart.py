"""Quickstart: approximate-weight perfect matching through the unified API.

Generates a synthetic matrix (planted perfect matching, paper-style
normalization), builds a ``MatchingProblem``, runs the full AWPM pipeline
(greedy maximal -> maximum cardinality -> augmenting 4-cycles) with one
``solve()`` call, and compares against the exact optimum. The same call
solves a whole batch — ``MatchingProblem.stack`` + the same options.

  PYTHONPATH=src python examples/quickstart.py [--n 400] [--kind antigreedy]
"""
import argparse

import numpy as np

from repro.core import MatchingProblem, SolveOptions, graph, ref, solve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--degree", type=float, default=6.0)
    ap.add_argument("--kind", default="antigreedy",
                    choices=list(graph.SUITE_KINDS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "xla", "pallas"])
    args = ap.parse_args()

    g = graph.generate(args.n, avg_degree=args.degree, kind=args.kind,
                       seed=args.seed)
    print(f"matrix: n={g.n} nnz={g.nnz} kind={args.kind}")

    problem = MatchingProblem.from_graph(g)
    res = solve(problem, SolveOptions(backend=args.backend))
    w = float(res.weight)
    print(f"AWPM solve():            perfect={bool(res.perfect)}, "
          f"{int(res.awac_iters)} AWAC rounds, weight {w:.3f}")

    # batched: the same facade solves many instances in one dispatch
    batch_problem = MatchingProblem.stack(
        [graph.generate(args.n, avg_degree=args.degree, kind=args.kind,
                        seed=args.seed + i) for i in range(4)])
    res_b = solve(batch_problem, SolveOptions(backend=args.backend))
    print(f"batched solve() (B=4):   perfect={np.array(res_b.perfect)}, "
          f"weights {np.round(np.array(res_b.weight), 2)}")
    assert np.array_equal(np.array(res_b.mate_row[0]), np.array(res.mate_row))

    dense = g.to_dense().astype(np.float32)
    struct = g.structure_dense()
    _, opt = ref.exact_mwpm(dense, struct)
    mr = np.array(res.mate_row[: g.n])
    ref.check_matching(struct, mr)
    print(f"optimum (Hungarian):     {opt:.3f}")
    print(f"approximation ratio:     {w / opt:.4f} "
          f"(paper: typically >= 0.99, always >= 2/3)")
    assert w / opt >= 2 / 3


if __name__ == "__main__":
    main()
