"""Quickstart: approximate-weight perfect matching on a sparse matrix.

Generates a synthetic matrix (planted perfect matching, paper-style
normalization), runs the full AWPM pipeline (greedy maximal -> maximum
cardinality -> augmenting 4-cycles), and compares against the exact optimum.

  PYTHONPATH=src python examples/quickstart.py [--n 400] [--kind antigreedy]
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import graph, ref, single


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--degree", type=float, default=6.0)
    ap.add_argument("--kind", default="antigreedy",
                    choices=list(graph.SUITE_KINDS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g = graph.generate(args.n, avg_degree=args.degree, kind=args.kind,
                       seed=args.seed)
    print(f"matrix: n={g.n} nnz={g.nnz} kind={args.kind}")

    row, col, val = jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val)
    st = single.greedy_maximal(row, col, val, g.n)
    w_greedy = float(single.matching_weight(st, g.n))
    card = int((np.array(st.mate_row[: g.n]) < g.n).sum())
    print(f"phase 1 greedy maximal:  cardinality {card}/{g.n}, weight {w_greedy:.3f}")

    st = single.mcm(row, col, val, g.n, st.mate_row, st.mate_col)
    w_mcm = float(single.matching_weight(st, g.n))
    print(f"phase 2 MCM:             perfect={bool(single.is_perfect(st, g.n))}, "
          f"weight {w_mcm:.3f}")

    st, iters = single.awac(row, col, val, g.n, st)
    w_awac = float(single.matching_weight(st, g.n))
    print(f"phase 3 AWAC:            {int(iters)} rounds, weight {w_awac:.3f}")

    dense = g.to_dense().astype(np.float32)
    struct = g.structure_dense()
    _, opt = ref.exact_mwpm(dense, struct)
    mr = np.array(st.mate_row[: g.n])
    ref.check_matching(struct, mr)
    print(f"optimum (Hungarian):     {opt:.3f}")
    print(f"approximation ratio:     {w_awac / opt:.4f} "
          f"(paper: typically >= 0.99, always >= 2/3)")
    assert w_awac / opt >= 2 / 3


if __name__ == "__main__":
    main()
