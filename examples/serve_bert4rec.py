"""Batched serving example: bert4rec next-item scoring + 1-vs-1M retrieval.

  PYTHONPATH=src python examples/serve_bert4rec.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_defs
from repro.models.param import init_params
from repro.models.recsys import bert4rec


def main():
    cfg = get_config("bert4rec", reduced=True)
    params = init_params(build_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    serve = jax.jit(lambda p, s: bert4rec.serve_scores(p, s, cfg))
    retrieve = jax.jit(
        lambda p, s, c: bert4rec.retrieval_scores(p, s, c, cfg))

    # batched online scoring (serve_p99-style)
    batch = jnp.asarray(rng.integers(0, cfg.n_items, (32, cfg.seq_len)),
                        jnp.int32)
    scores = serve(params, batch)
    jax.block_until_ready(scores)
    t0 = time.perf_counter()
    for _ in range(5):
        scores = serve(params, batch)
        jax.block_until_ready(scores)
    dt = (time.perf_counter() - t0) / 5
    top = jnp.argmax(scores, axis=-1)
    print(f"serve: batch=32 seq={cfg.seq_len} -> scores {scores.shape}, "
          f"{dt * 1e3:.1f} ms/batch; top items {np.array(top[:8])}")

    # retrieval: one user against a large candidate set (batched dot)
    cands = jnp.asarray(rng.choice(cfg.n_items, 400, replace=False), jnp.int32)
    r = retrieve(params, batch[:1], cands)
    best = np.array(cands)[np.argsort(-np.array(r[0]))[:5]]
    print(f"retrieval: 1 user x {len(cands)} candidates -> top-5 {best}")


if __name__ == "__main__":
    main()
