"""End-to-end training driver: MoE LM with the AWPM router (the paper's
technique as a routing feature), synthetic-but-learnable token stream,
checkpointing + straggler monitoring.

  PYTHONPATH=src python examples/train_lm_moe.py              # fast demo
  PYTHONPATH=src python examples/train_lm_moe.py --preset 100m --steps 300
"""
import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import LMConfig, MoECfg
from repro.data.tokens import TokenPipeline
from repro.models import build_defs, build_loss
from repro.models.param import count_params, init_params
from repro.runtime.straggler import StragglerMonitor
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig

PRESETS = {
    "tiny": LMConfig("moe-tiny", n_layers=2, d_model=128, n_heads=4,
                     n_kv_heads=2, d_ff=256, vocab=4096, dtype="float32",
                     remat=False,
                     moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=128,
                                router="awpm", router_block=512)),
    "100m": LMConfig("moe-100m", n_layers=8, d_model=512, n_heads=8,
                     n_kv_heads=4, d_ff=1536, vocab=32768, dtype="float32",
                     moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=512,
                                n_shared=1, d_ff_shared=512, router="awpm",
                                router_block=1024)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--router", default="awpm", choices=["awpm", "topk"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    import dataclasses

    cfg = PRESETS[args.preset]
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router=args.router))
    defs = build_defs(cfg)
    print(f"model {cfg.name}: {count_params(defs) / 1e6:.1f}M params, "
          f"router={cfg.moe.router}")
    params = init_params(defs, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg.vocab, args.batch, args.seq, seed=1)
    mon = StragglerMonitor()
    mgr = CheckpointManager(args.ckpt_dir, async_save=True)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                      weight_decay=0.01)
    params, opt_state, hist = train(
        params, build_loss(cfg), pipe.batch, opt, n_steps=args.steps,
        log_every=10, checkpoint_mgr=mgr, checkpoint_every=max(args.steps // 3, 1),
        straggler_monitor=mon)
    mgr.wait()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
