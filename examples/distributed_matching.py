"""Distributed AWPM on a 4x4 device grid (fake devices — the same shard_map
program that the 512-chip dry-run lowers).

  PYTHONPATH=src python examples/distributed_matching.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

try:  # jax >= 0.6
    from jax.sharding import AxisType  # noqa: E402
except ImportError:  # jax 0.4.x: axes are Auto already
    AxisType = None

from repro.core import graph, ref, single  # noqa: E402
from repro.core.dist import DistAWPM, GridSpec, default_caps  # noqa: E402


def main(n=256, degree=8.0, seed=0):
    if AxisType is None:
        mesh = jax.make_mesh((4, 4), ("data", "model"))
    else:
        mesh = jax.make_mesh((4, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
    spec = GridSpec(mesh, ("data",), "model")
    g = graph.generate(n, avg_degree=degree, kind="uniform", seed=seed)
    print(f"matrix n={g.n} nnz={g.nnz} on a {spec.pr}x{spec.pc} process grid "
          f"({len(jax.devices())} devices)")

    caps = default_caps(g.n, g.nnz, spec.pr, spec.pc, slack=4.0)
    drv = DistAWPM(spec, g.n,
                   cap=((g.nnz // 16 + 63) // 64 * 64 + 64), a2a_caps=caps)
    st, iters, dropped = drv.run(g)
    w = float(single.matching_weight(st, g.n))
    print(f"distributed AWPM: weight={w:.3f}, AWAC rounds={int(iters)}, "
          f"dropped-requests={int(dropped)}")

    stS, _ = single.awpm(jnp.asarray(g.row), jnp.asarray(g.col),
                         jnp.asarray(g.val), g.n)
    same = np.array_equal(np.array(st.mate_row[: g.n]),
                          np.array(stS.mate_row[: g.n]))
    print(f"bit-identical to single-device implementation: {same}")

    dense = g.to_dense().astype(np.float32)
    _, opt = ref.exact_mwpm(dense, g.structure_dense())
    print(f"approximation ratio: {w / opt:.4f}")


if __name__ == "__main__":
    main()
