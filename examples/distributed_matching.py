"""Distributed AWPM on a 4x4 device grid (fake devices — the same shard_map
program that the 512-chip dry-run lowers), through the unified API: the ONLY
change vs a local solve is ``SolveOptions(grid=mesh)``, and ``plan()`` gives
a compile-once/run-many ``Matcher`` for serving many batches.

  PYTHONPATH=src python examples/distributed_matching.py [--n 256]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import MatchingProblem, SolveOptions, graph, plan, ref, solve  # noqa: E402
from repro.core.dist import make_mesh  # noqa: E402


def main(n=256, degree=8.0, seed=0, batch=4):
    mesh = make_mesh((4, 4))
    g = graph.generate(n, avg_degree=degree, kind="uniform", seed=seed)
    print(f"matrix n={g.n} nnz={g.nnz} on a 4x4 process grid "
          f"({len(jax.devices())} devices)")

    # one-shot: identical call shape to the local path, plus grid=
    problem = MatchingProblem.from_graph(g)
    res = solve(problem, SolveOptions(grid=mesh))
    print(f"distributed solve(): weight={float(res.weight):.3f}, "
          f"AWAC rounds={int(res.awac_iters)}, perfect={bool(res.perfect)}")

    res_local = solve(problem)
    same = np.array_equal(np.array(res.mate_row[:n]),
                          np.array(res_local.mate_row[:n]))
    print(f"bit-identical to the local solve: {same}")
    assert same, "distributed result diverged from the local solve"

    dense = g.to_dense().astype(np.float32)
    _, opt = ref.exact_mwpm(dense, g.structure_dense())
    print(f"approximation ratio: {float(res.weight) / opt:.4f}")

    # serving: plan once (capacity planning + engine build), run many
    gs = [graph.generate(n, avg_degree=degree, kind="uniform", seed=seed + i)
          for i in range(batch)]
    batch_problem = MatchingProblem.stack(gs)
    matcher = plan(batch_problem, SolveOptions(grid=mesh))
    print(f"planned: {matcher}")
    res_b = matcher(batch_problem)
    res_b2 = matcher(MatchingProblem.stack(list(reversed(gs))))
    same_b = np.array_equal(np.array(res_b.mate_row[0]),
                            np.array(res_b2.mate_row[-1]))
    print(f"matcher: B={batch} weights="
          f"{np.round(np.array(res_b.weight), 2)}, reuse across calls "
          f"bit-identical: {same_b}")
    assert same_b, "Matcher reuse diverged across calls"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--degree", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    main(n=args.n, degree=args.degree, seed=args.seed, batch=args.batch)
