import time

import jax

# Rows emitted by the currently-running suite (drained by benchmarks.run to
# persist each suite's results into BENCH_<suite>.json at the repo root).
_ROWS = []


def time_call(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    _ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                  "derived": derived})


def drain_rows():
    rows = list(_ROWS)
    _ROWS.clear()
    return rows
