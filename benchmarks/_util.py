import time

import jax


def time_call(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
