"""Benchmark regression gate (CI satellite): compare a freshly measured
BENCH_*.json (kernels in the bench-gate job, paper_eval in the docs job)
against the committed baseline.

Two checks, per row name present in BOTH files:
  1. correctness flags (``weight_identical=…`` / ``weights_identical=…`` /
     ``identical_to_batched=…`` / ``identical_to_local=…`` /
     ``identical_to_reference=…`` / ``certified_sound=…`` in the derived
     field) must still be True —
     a False here means an engine stopped agreeing with its oracle (or a
     dual certificate stopped bounding the optimum), which is a
     correctness failure no matter how fast it got;
  2. per-row throughput must not regress by more than ``--factor`` (default
     2.5x; shared-runner wall clocks are noisy, so the gate only catches
     step-function regressions, not percent-level drift).

Rows that exist only on one side are reported but never fail the gate
(benches grow new rows every PR). Exits 1 on any violation.

Usage: python benchmarks/check_regression.py \
           --baseline /tmp/baseline.json --fresh BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys

IDENT_RE = re.compile(
    r"(weights?_identical|identical_to_batched|identical_to_local"
    r"|identical_to_reference|certified_sound)=(True|False)")


def _rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        rec = json.load(f)
    return {r["name"]: r for r in rec.get("rows", [])}


def _ident_flags(derived: str) -> list[tuple[str, bool]]:
    return [(m.group(1), m.group(2) == "True")
            for m in IDENT_RE.finditer(derived or "")]


def check(baseline: dict[str, dict], fresh: dict[str, dict],
          factor: float) -> list[str]:
    failures = []
    for name in sorted(set(baseline) & set(fresh)):
        b, f = baseline[name], fresh[name]
        for key, ok in _ident_flags(f.get("derived", "")):
            if not ok:
                failures.append(
                    f"{name}: correctness flag {key} is False "
                    f"(derived={f['derived']!r})")
        bu, fu = b.get("us_per_call"), f.get("us_per_call")
        if bu and fu and fu > factor * bu:
            failures.append(
                f"{name}: {fu:.1f}us vs baseline {bu:.1f}us "
                f"(> {factor}x regression)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--factor", type=float, default=2.5)
    args = ap.parse_args()
    baseline, fresh = _rows(args.baseline), _rows(args.fresh)
    only_b = sorted(set(baseline) - set(fresh))
    only_f = sorted(set(fresh) - set(baseline))
    if only_b:
        print(f"# rows only in baseline (ignored): {only_b}")
    if only_f:
        print(f"# new rows (not gated yet): {only_f}")
    failures = check(baseline, fresh, args.factor)
    for msg in failures:
        print(f"FAIL {msg}")
    n = len(set(baseline) & set(fresh))
    if failures:
        sys.exit(1)
    print(f"# regression gate OK: {n} shared rows within {args.factor}x, "
          f"all correctness flags True")


if __name__ == "__main__":
    main()
