"""Benchmark regression gate (CI satellite): compare a freshly measured
BENCH_*.json (kernels in the bench-gate job, paper_eval in the docs job)
against the committed baseline.

Two checks, per row name present in BOTH files:
  1. correctness flags (``weight_identical=…`` / ``weights_identical=…`` /
     ``identical_to_batched=…`` / ``identical_to_local=…`` /
     ``identical_to_reference=…`` / ``certified_sound=…`` in the derived
     field) must still be True —
     a False here means an engine stopped agreeing with its oracle (or a
     dual certificate stopped bounding the optimum), which is a
     correctness failure no matter how fast it got;
  2. per-row throughput must not regress by more than ``--factor`` (default
     2.5x; shared-runner wall clocks are noisy, so the gate only catches
     step-function regressions, not percent-level drift).

Rows that exist only on one side are reported but never fail the gate
(benches grow new rows every PR). Exits 1 on any violation.

A third, optional check gates the dispatch table that ``backend="auto"``
consults (``repro.kernels.dispatch``): given the committed table and a
freshly measured one (``--dispatch BASELINE FRESH``), every committed
winner must (a) actually be the argmin of its own committed measurements —
a table whose winner contradicts its numbers is corrupt — and (b) still be
within ``--dispatch-factor`` (default 1.2x) of the freshly measured best
for that shape class. A committed winner losing by more than that means
``auto`` is demonstrably mis-routing and the table must be regenerated.

Usage: python benchmarks/check_regression.py \
           --baseline /tmp/baseline.json --fresh BENCH_kernels.json \
           [--dispatch /tmp/dispatch.baseline.json BENCH_dispatch.json]
"""
from __future__ import annotations

import argparse
import json
import re
import sys

IDENT_RE = re.compile(
    r"(weights?_identical|identical_to_batched|identical_to_local"
    r"|identical_to_reference|certified_sound|warm_identical)=(True|False)")


def _rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        rec = json.load(f)
    return {r["name"]: r for r in rec.get("rows", [])}


def _ident_flags(derived: str) -> list[tuple[str, bool]]:
    return [(m.group(1), m.group(2) == "True")
            for m in IDENT_RE.finditer(derived or "")]


def check(baseline: dict[str, dict], fresh: dict[str, dict],
          factor: float) -> list[str]:
    failures = []
    for name in sorted(set(baseline) & set(fresh)):
        b, f = baseline[name], fresh[name]
        for key, ok in _ident_flags(f.get("derived", "")):
            if not ok:
                failures.append(
                    f"{name}: correctness flag {key} is False "
                    f"(derived={f['derived']!r})")
        bu, fu = b.get("us_per_call"), f.get("us_per_call")
        if bu and fu and fu > factor * bu:
            failures.append(
                f"{name}: {fu:.1f}us vs baseline {bu:.1f}us "
                f"(> {factor}x regression)")
    return failures


def _dispatch_entries(path: str) -> dict[str, dict]:
    with open(path) as f:
        return json.load(f).get("entries", {})


def check_dispatch(committed: dict[str, dict], fresh: dict[str, dict],
                   factor: float) -> list[str]:
    """Gate the committed auto-dispatch table against fresh measurements."""
    failures = []
    for key in sorted(committed):
        entry = committed[key]
        winner, us = entry.get("winner"), entry.get("us_per_iter", {})
        if not winner or not us:
            failures.append(f"dispatch {key}: malformed entry {entry!r}")
            continue
        if winner not in us:
            failures.append(
                f"dispatch {key}: winner {winner!r} has no measurement")
            continue
        best = min(us, key=us.get)
        if us[winner] > factor * us[best]:
            failures.append(
                f"dispatch {key}: committed winner {winner!r} "
                f"({us[winner]:.1f}us) contradicts its own measurements "
                f"(best {best!r} at {us[best]:.1f}us, > {factor}x)")
        f_us = fresh.get(key, {}).get("us_per_iter", {})
        if not f_us or winner not in f_us:
            continue  # class not re-measured here: report-only
        f_best = min(f_us, key=f_us.get)
        if f_us[winner] > factor * f_us[f_best]:
            failures.append(
                f"dispatch {key}: 'auto' would route to {winner!r} "
                f"({f_us[winner]:.1f}us fresh) but {f_best!r} measures "
                f"{f_us[f_best]:.1f}us (> {factor}x loss) — regenerate "
                f"BENCH_dispatch.json")
    return failures


def _derived_value(derived: str, key: str) -> float | None:
    m = re.search(rf"\b{re.escape(key)}=([0-9.eE+-]+)", derived or "")
    try:
        return float(m.group(1)) if m else None
    except ValueError:
        return None


def check_serving(rows: dict[str, dict], min_rps: float, max_p99_us: float,
                  min_speedup: float) -> list[str]:
    """Absolute SLO gates for a fresh ``BENCH_serving.json`` (fourth,
    optional check — ``--serving FRESH``).

    Unlike the baseline-relative throughput gate, serving is gated on
    *absolute* floors/ceilings: the stream runs on a simulated arrival
    clock, so its numbers are dominated by the configured load plus the
    measured solve walls, and an absolute bound catches the real
    failure modes (compile-per-request, a stalled batcher, warm path
    slower than cold) without flaking on runner-to-runner speed spread.
    The ``warm_identical`` correctness flag is enforced by the shared
    flag scan in :func:`check`; here it is enforced even WITHOUT a
    baseline (a fresh-only run must not skip it)."""
    failures = []
    for name in ("serving_throughput", "serving_latency",
                 "serving_warm_vs_cold"):
        if name not in rows:
            failures.append(f"serving: required row {name!r} is missing")
    for name, r in sorted(rows.items()):
        for key, ok in _ident_flags(r.get("derived", "")):
            if not ok:
                failures.append(
                    f"{name}: correctness flag {key} is False "
                    f"(derived={r['derived']!r})")
    r = rows.get("serving_throughput")
    if r is not None:
        rps = _derived_value(r.get("derived", ""), "throughput_rps")
        if rps is None:
            failures.append("serving_throughput: no throughput_rps in "
                            f"derived ({r.get('derived')!r})")
        elif rps < min_rps:
            failures.append(
                f"serving_throughput: {rps:.1f} rps under the "
                f"{min_rps:.1f} rps floor")
    r = rows.get("serving_latency")
    if r is not None:
        p99 = _derived_value(r.get("derived", ""), "p99_us")
        if p99 is None:
            failures.append("serving_latency: no p99_us in derived "
                            f"({r.get('derived')!r})")
        elif p99 > max_p99_us:
            failures.append(
                f"serving_latency: p99 {p99:.0f}us over the "
                f"{max_p99_us:.0f}us ceiling")
    r = rows.get("serving_warm_vs_cold")
    if r is not None:
        speedup = _derived_value(r.get("derived", ""), "speedup")
        if speedup is None:
            failures.append("serving_warm_vs_cold: no speedup in derived "
                            f"({r.get('derived')!r})")
        elif speedup < min_speedup:
            failures.append(
                f"serving_warm_vs_cold: warm-start speedup {speedup:.2f}x "
                f"under the {min_speedup:.2f}x floor (warm rematching must "
                f"beat cold solve on perturbed repeats)")
    return failures


def check_solver(rows: dict[str, dict], max_residual: float) -> list[str]:
    """Absolute gate for a fresh ``BENCH_solver.json`` (``--solver FRESH``).

    The solver experiments (``results/fill_experiments.py``) are gated on
    the claims they exist to demonstrate, not on timing:

    1. every ``awpm``-arm row (and every ``reference``-arm row present)
       must have converged with true relative residual <= ``max_residual``
       — AWPM static pivoting + iterative refinement must work on EVERY
       case;
    2. at least one case must show the contrast — its ``none`` arm failed
       (diverged/stalled refinement) while its ``awpm`` arm converged.
       That contrast IS the reproduced result; a sweep where unpivoted LU
       quietly succeeds everywhere no longer demonstrates anything.
    """
    failures = []
    by_case: dict[str, dict[str, dict]] = {}
    for name, r in rows.items():
        m = re.match(r"solver_(.+)_(awpm|reference|none|tpp)$", name)
        if m:
            by_case.setdefault(m.group(1), {})[m.group(2)] = r
    if not by_case:
        return ["solver: no solver_<case>_<arm> rows found"]
    for case in sorted(by_case):
        for arm in ("awpm", "reference"):
            r = by_case[case].get(arm)
            if r is None:
                if arm == "awpm":
                    failures.append(f"solver {case}: awpm row is missing")
                continue  # reference is optional (scipy-less runners)
            derived = r.get("derived", "")
            res = _derived_value(derived, "residual")
            if "converged=True" not in derived:
                failures.append(
                    f"solver {case} [{arm}]: did not converge "
                    f"(derived={derived!r})")
            elif res is None or res > max_residual:
                failures.append(
                    f"solver {case} [{arm}]: residual "
                    f"{res if res is not None else 'missing'} over the "
                    f"{max_residual:g} ceiling")
    contrast = [
        case for case, arms in sorted(by_case.items())
        if "converged=False" in arms.get("none", {}).get("derived", "")
        and "converged=True" in arms.get("awpm", {}).get("derived", "")]
    if not contrast:
        failures.append(
            "solver: no case shows the none-fails/awpm-converges contrast "
            "— the experiment no longer demonstrates that matching-based "
            "static pivoting replaces numerical pivoting")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline")
    ap.add_argument("--fresh")
    ap.add_argument("--factor", type=float, default=2.5)
    ap.add_argument("--dispatch", nargs=2,
                    metavar=("COMMITTED", "FRESH"),
                    help="gate the committed dispatch table against a "
                         "freshly measured one")
    ap.add_argument("--dispatch-factor", type=float, default=1.2)
    ap.add_argument("--serving", metavar="FRESH",
                    help="gate a fresh BENCH_serving.json on absolute "
                         "SLOs (throughput floor, p99 ceiling, warm-start "
                         "speedup floor + bit-identity flag)")
    ap.add_argument("--serving-min-rps", type=float, default=20.0)
    ap.add_argument("--serving-max-p99-us", type=float, default=250_000.0)
    ap.add_argument("--serving-min-speedup", type=float, default=1.05)
    ap.add_argument("--solver", metavar="FRESH",
                    help="gate a fresh BENCH_solver.json on absolute "
                         "claims: every awpm row converged under the "
                         "residual ceiling, and >= 1 case where the "
                         "unpivoted arm failed while awpm converged")
    ap.add_argument("--solver-max-residual", type=float, default=1e-10)
    args = ap.parse_args()
    if bool(args.baseline) != bool(args.fresh):
        ap.error("--baseline and --fresh go together")
    if not args.baseline and not args.serving and not args.solver:
        ap.error("nothing to do: pass --baseline/--fresh, --serving, "
                 "and/or --solver")
    failures = []
    n = 0
    if args.baseline:
        baseline, fresh = _rows(args.baseline), _rows(args.fresh)
        only_b = sorted(set(baseline) - set(fresh))
        only_f = sorted(set(fresh) - set(baseline))
        if only_b:
            print(f"# rows only in baseline (ignored): {only_b}")
        if only_f:
            print(f"# new rows (not gated yet): {only_f}")
        n = len(set(baseline) & set(fresh))
        failures += check(baseline, fresh, args.factor)
    if args.dispatch:
        failures += check_dispatch(
            _dispatch_entries(args.dispatch[0]),
            _dispatch_entries(args.dispatch[1]), args.dispatch_factor)
    if args.serving:
        failures += check_serving(
            _rows(args.serving), args.serving_min_rps,
            args.serving_max_p99_us, args.serving_min_speedup)
    if args.solver:
        failures += check_solver(_rows(args.solver),
                                 args.solver_max_residual)
    for msg in failures:
        print(f"FAIL {msg}")
    if failures:
        sys.exit(1)
    parts = []
    if args.baseline:
        parts.append(f"{n} shared rows within {args.factor}x, all "
                     f"correctness flags True")
    if args.dispatch:
        parts.append(f"dispatch winners within {args.dispatch_factor}x of "
                     f"fresh best")
    if args.serving:
        parts.append(f"serving SLOs met (>= {args.serving_min_rps:.0f} rps, "
                     f"p99 <= {args.serving_max_p99_us:.0f}us, warm >= "
                     f"{args.serving_min_speedup:.2f}x)")
    if args.solver:
        parts.append(f"solver: awpm converged <= "
                     f"{args.solver_max_residual:g} on every case, "
                     f"unpivoted-fails contrast present")
    print(f"# regression gate OK: {'; '.join(parts)}")


if __name__ == "__main__":
    main()
