"""Benchmark regression gate (CI satellite): compare a freshly measured
BENCH_*.json (kernels in the bench-gate job, paper_eval in the docs job)
against the committed baseline.

Two checks, per row name present in BOTH files:
  1. correctness flags (``weight_identical=…`` / ``weights_identical=…`` /
     ``identical_to_batched=…`` / ``identical_to_local=…`` /
     ``identical_to_reference=…`` / ``certified_sound=…`` in the derived
     field) must still be True —
     a False here means an engine stopped agreeing with its oracle (or a
     dual certificate stopped bounding the optimum), which is a
     correctness failure no matter how fast it got;
  2. per-row throughput must not regress by more than ``--factor`` (default
     2.5x; shared-runner wall clocks are noisy, so the gate only catches
     step-function regressions, not percent-level drift).

Rows that exist only on one side are reported but never fail the gate
(benches grow new rows every PR). Exits 1 on any violation.

A third, optional check gates the dispatch table that ``backend="auto"``
consults (``repro.kernels.dispatch``): given the committed table and a
freshly measured one (``--dispatch BASELINE FRESH``), every committed
winner must (a) actually be the argmin of its own committed measurements —
a table whose winner contradicts its numbers is corrupt — and (b) still be
within ``--dispatch-factor`` (default 1.2x) of the freshly measured best
for that shape class. A committed winner losing by more than that means
``auto`` is demonstrably mis-routing and the table must be regenerated.

Usage: python benchmarks/check_regression.py \
           --baseline /tmp/baseline.json --fresh BENCH_kernels.json \
           [--dispatch /tmp/dispatch.baseline.json BENCH_dispatch.json]
"""
from __future__ import annotations

import argparse
import json
import re
import sys

IDENT_RE = re.compile(
    r"(weights?_identical|identical_to_batched|identical_to_local"
    r"|identical_to_reference|certified_sound)=(True|False)")


def _rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        rec = json.load(f)
    return {r["name"]: r for r in rec.get("rows", [])}


def _ident_flags(derived: str) -> list[tuple[str, bool]]:
    return [(m.group(1), m.group(2) == "True")
            for m in IDENT_RE.finditer(derived or "")]


def check(baseline: dict[str, dict], fresh: dict[str, dict],
          factor: float) -> list[str]:
    failures = []
    for name in sorted(set(baseline) & set(fresh)):
        b, f = baseline[name], fresh[name]
        for key, ok in _ident_flags(f.get("derived", "")):
            if not ok:
                failures.append(
                    f"{name}: correctness flag {key} is False "
                    f"(derived={f['derived']!r})")
        bu, fu = b.get("us_per_call"), f.get("us_per_call")
        if bu and fu and fu > factor * bu:
            failures.append(
                f"{name}: {fu:.1f}us vs baseline {bu:.1f}us "
                f"(> {factor}x regression)")
    return failures


def _dispatch_entries(path: str) -> dict[str, dict]:
    with open(path) as f:
        return json.load(f).get("entries", {})


def check_dispatch(committed: dict[str, dict], fresh: dict[str, dict],
                   factor: float) -> list[str]:
    """Gate the committed auto-dispatch table against fresh measurements."""
    failures = []
    for key in sorted(committed):
        entry = committed[key]
        winner, us = entry.get("winner"), entry.get("us_per_iter", {})
        if not winner or not us:
            failures.append(f"dispatch {key}: malformed entry {entry!r}")
            continue
        if winner not in us:
            failures.append(
                f"dispatch {key}: winner {winner!r} has no measurement")
            continue
        best = min(us, key=us.get)
        if us[winner] > factor * us[best]:
            failures.append(
                f"dispatch {key}: committed winner {winner!r} "
                f"({us[winner]:.1f}us) contradicts its own measurements "
                f"(best {best!r} at {us[best]:.1f}us, > {factor}x)")
        f_us = fresh.get(key, {}).get("us_per_iter", {})
        if not f_us or winner not in f_us:
            continue  # class not re-measured here: report-only
        f_best = min(f_us, key=f_us.get)
        if f_us[winner] > factor * f_us[f_best]:
            failures.append(
                f"dispatch {key}: 'auto' would route to {winner!r} "
                f"({f_us[winner]:.1f}us fresh) but {f_best!r} measures "
                f"{f_us[f_best]:.1f}us (> {factor}x loss) — regenerate "
                f"BENCH_dispatch.json")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--factor", type=float, default=2.5)
    ap.add_argument("--dispatch", nargs=2,
                    metavar=("COMMITTED", "FRESH"),
                    help="gate the committed dispatch table against a "
                         "freshly measured one")
    ap.add_argument("--dispatch-factor", type=float, default=1.2)
    args = ap.parse_args()
    baseline, fresh = _rows(args.baseline), _rows(args.fresh)
    only_b = sorted(set(baseline) - set(fresh))
    only_f = sorted(set(fresh) - set(baseline))
    if only_b:
        print(f"# rows only in baseline (ignored): {only_b}")
    if only_f:
        print(f"# new rows (not gated yet): {only_f}")
    failures = check(baseline, fresh, args.factor)
    if args.dispatch:
        failures += check_dispatch(
            _dispatch_entries(args.dispatch[0]),
            _dispatch_entries(args.dispatch[1]), args.dispatch_factor)
    for msg in failures:
        print(f"FAIL {msg}")
    n = len(set(baseline) & set(fresh))
    if failures:
        sys.exit(1)
    extra = ""
    if args.dispatch:
        extra = (f", dispatch winners within {args.dispatch_factor}x of "
                 f"fresh best")
    print(f"# regression gate OK: {n} shared rows within {args.factor}x, "
          f"all correctness flags True{extra}")


if __name__ == "__main__":
    main()
