"""Paper Table 6.3: static pivoting quality — relative solution error of a
pivot-free LU after AWPM vs exact-MWPM vs identity permutation."""
import numpy as np

from benchmarks._util import row
from repro.core import MatchingProblem, graph, pivot, ref, solve


def _system(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n)) * (rng.random((n, n)) < 0.15)
    perm = rng.permutation(n)
    a[perm, np.arange(n)] = rng.uniform(5.0, 10.0, n) * rng.choice([-1, 1], n)
    np.fill_diagonal(a, np.where(np.abs(np.diag(a)) > 0, np.diag(a), 1e-10))
    x_true = np.ones(n)
    return a, a @ x_true, x_true


def run(n=80, n_systems=5):
    errs = {"awpm": [], "exact": [], "none": []}
    for seed in range(n_systems):
        a, b, x_true = _system(n, seed)
        a_s, _, _ = pivot.equilibrate(a)
        rr, cc = np.nonzero(a_s)
        g = graph.from_coo(rr.astype(np.int32), cc.astype(np.int32),
                           np.abs(a_s[rr, cc]).astype(np.float32), n)
        glog = pivot.log_transformed(g)
        res = solve(MatchingProblem.from_graph(glog))
        mr_awpm = np.array(res.mate_row[:n])
        dense_log = np.where(g.structure_dense(),
                             np.log(np.maximum(np.abs(g.to_dense()), 1e-30)),
                             0.0).astype(np.float32)
        mr_exact, _ = ref.exact_mwpm(dense_log, g.structure_dense())

        for name, mr in [("awpm", mr_awpm), ("exact", mr_exact),
                         ("none", np.arange(n))]:
            try:
                x = pivot.static_pivot_solve(a, b, mr)
                errs[name].append(pivot.relative_error(x, x_true))
            except ZeroDivisionError:
                errs[name].append(float("inf"))
    for name, es in errs.items():
        es = np.array(es)
        ok = np.isfinite(es)
        row(f"pivot_relerr_{name}", 0.0,
            f"median={np.median(es[ok]) if ok.any() else float('inf'):.2e};"
            f"failed={int((~ok).sum())}/{len(es)}")
    return errs


if __name__ == "__main__":
    run()
