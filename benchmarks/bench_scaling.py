"""Paper Fig 6.1/6.3: runtime vs problem size + strong-scaling model.

Real-TPU wall times are unavailable (CPU container); reported here:
  (a) measured single-device AWPM runtime across matrix sizes (the paper's
      "sequential AWPM" baseline),
  (b) the analytic strong-scaling model of §5.3 evaluated with v5e constants
      (alpha-beta costs of the 4 AWAC steps on a sqrt(p) x sqrt(p) grid),
      reproducing the shape of Fig 6.3,
  (c) measured AWAC per-round cost decomposition (requests, join, select).
  (d) measured distributed-BATCHED throughput (DESIGN.md §5): one
      planned ``Matcher`` dispatch for B instances on a simulated p-device
      2D grid, p in {1, 2, 4, 8} x B in {1, 8, 32}. Each p runs in a
      subprocess because the fake device count must be set before jax
      initializes (same constraint as tests/test_core_dist.py).
"""
import os
import pathlib
import re
import subprocess
import sys

import numpy as np

from benchmarks._util import row, time_call
from repro.core import MatchingProblem, graph, solve

REPO = pathlib.Path(__file__).resolve().parents[1]

# p -> 2D mesh shape (both orientations are CI-tested; the bench uses one)
DIST_MESHES = {1: (1, 1), 2: (1, 2), 4: (2, 2), 8: (2, 4)}

DIST_CHILD = r"""
import time
import numpy as np, jax
from repro.core import MatchingProblem, SolveOptions, graph, plan, solve
from repro.runtime.straggler import StragglerMonitor

p, pr, pc, n, deg = {p}, {pr}, {pc}, {n}, {deg}
mesh = jax.sharding.Mesh(
    np.array(jax.devices()[:p]).reshape(pr, pc), ("data", "model"))
# 1x1 grid routes Steps A+B+C through core.batch's fused sweep directly
backend = "xla" if p == 1 else "fused"
for b in (1, 8, 32):
    gs = [graph.generate(n, avg_degree=deg, kind="uniform", seed=s)
          for s in range(b)]
    problem = MatchingProblem.stack(gs)
    # plan once: capacity + bucket planning, engine build (the Matcher
    # replaces the old DistBatchedAWPM + make_awpm_dist_batched zoo); each
    # timed call is partition + one shard_map dispatch (serving shape)
    matcher = plan(problem, SolveOptions(grid=mesh, backend=backend))
    res = matcher(problem)  # compile + warmup
    jax.block_until_ready(res)
    # straggler monitor over the serving loop: each dispatch is one "step"
    # on serving rank 0 (simulated meshes share one host, so the cross-rank
    # z-score path is idle — slow_steps() is the single-rank alarm)
    mon = StragglerMonitor(alpha=0.2, threshold=2.0, warmup=5)
    reps = 6
    t0 = time.perf_counter()
    for step in range(reps):
        ts = time.perf_counter()
        out = matcher(problem)
        jax.block_until_ready(out)
        mon.record(step, time.perf_counter() - ts, rank=0)
    dt = (time.perf_counter() - t0) / reps
    slow = mon.slow_ranks() or mon.slow_steps()
    resL = solve(problem)
    ident = bool(np.array_equal(np.array(resL.mate_row),
                                np.array(res.mate_row)))
    # timed=serving marks the measurement-definition change vs the pre-facade
    # rows: each rep now includes host partition + device_put + dispatch
    # (the real serving shape), not just the compiled engine call — the two
    # regimes are not comparable under one name without this flag.
    print(f"ROW,awpm_dist_batched_p{{p}}_B{{b}},{{dt / b * 1e6:.1f}},"
          f"matchings_per_s={{b / dt:.1f}};mesh={{pr}}x{{pc}};"
          f"backend={{backend}};timed=serving;identical_to_local={{ident}};"
          f"straggler_flagged={{'|'.join(map(str, slow)) or 'none'}}",
          flush=True)
"""


def run_dist_batched(n: int = 24, deg: float = 6.0):
    """Distributed-batched matchings/sec rows via one subprocess per p."""
    for p, (pr, pc) in DIST_MESHES.items():
        env = dict(os.environ)
        # strip any inherited device-count token entirely — XLA aborts on
        # unknown flags, so the stale token can't just be renamed
        inherited = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                           env.get("XLA_FLAGS", ""))
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={p} {inherited}").strip()
        env["PYTHONPATH"] = f"{REPO / 'src'}:{env.get('PYTHONPATH', '')}"
        script = DIST_CHILD.format(p=p, pr=pr, pc=pc, n=n, deg=deg)
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True, env=env,
                              timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(
                f"dist bench subprocess p={p} failed\n{proc.stdout}\n"
                f"{proc.stderr}")
        for line in proc.stdout.splitlines():
            if line.startswith("ROW,"):
                _, name, us, derived = line.split(",", 3)
                row(name, float(us), derived)

ALPHA = 1e-6  # s per message (ICI latency)
BETA = 1.0 / 50e9  # s per byte per link
GAMMA = 1.0 / 197e12  # s per flop


def analytic_awac_round(n, m, p):
    """T = F + alpha*S + beta*W for one AWAC round on p devices (§5.3)."""
    flops = (m / p) * 16 + 8 * n  # relabel+join (edge work) + replicated O(n)
    words_a2a = 12 * m / p  # two-stage exchange, 12B/entry
    words_gather = 16 * n / np.sqrt(p)  # step C/D winner gathers
    msgs = 2 * np.sqrt(p) + 2
    return flops * GAMMA + ALPHA * msgs + BETA * (words_a2a + words_gather)


def run(sizes=(256, 512, 1024, 2048), deg=8.0):
    for n in sizes:
        g = graph.generate(n, avg_degree=deg, kind="uniform", seed=1)
        problem = MatchingProblem.from_graph(g)
        dt, res = time_call(lambda: solve(problem), iters=2, warmup=1)
        row(f"awpm_single_n{n}", dt * 1e6,
            f"m={g.nnz};iters={int(res.awac_iters)};"
            f"w={float(res.weight):.1f}")
    # strong-scaling model (paper Fig 6.3 analogue) for the match_4m cell
    n, m = 4_194_304, 67_108_864
    t1 = analytic_awac_round(n, m, 1)
    for p in (1, 4, 16, 64, 256, 512):
        tp = analytic_awac_round(n, m, p)
        row(f"awac_model_p{p}", tp * 1e6, f"speedup={t1 / tp:.1f}x")
    # measured distributed-batched throughput on simulated device grids
    run_dist_batched()
    return True


if __name__ == "__main__":
    run()
