"""Paper Fig 6.1/6.3: runtime vs problem size + strong-scaling model.

Real-TPU wall times are unavailable (CPU container); reported here:
  (a) measured single-device AWPM runtime across matrix sizes (the paper's
      "sequential AWPM" baseline),
  (b) the analytic strong-scaling model of §5.3 evaluated with v5e constants
      (alpha-beta costs of the 4 AWAC steps on a sqrt(p) x sqrt(p) grid),
      reproducing the shape of Fig 6.3,
  (c) measured AWAC per-round cost decomposition (requests, join, select).
"""
import numpy as np
import jax.numpy as jnp

from repro.core import graph, single
from benchmarks._util import row, time_call

ALPHA = 1e-6  # s per message (ICI latency)
BETA = 1.0 / 50e9  # s per byte per link
GAMMA = 1.0 / 197e12  # s per flop


def analytic_awac_round(n, m, p):
    """T = F + alpha*S + beta*W for one AWAC round on p devices (§5.3)."""
    flops = (m / p) * 16 + 8 * n  # relabel+join (edge work) + replicated O(n)
    words_a2a = 12 * m / p  # two-stage exchange, 12B/entry
    words_gather = 16 * n / np.sqrt(p)  # step C/D winner gathers
    msgs = 2 * np.sqrt(p) + 2
    return flops * GAMMA + ALPHA * msgs + BETA * (words_a2a + words_gather)


def run(sizes=(256, 512, 1024, 2048), deg=8.0):
    for n in sizes:
        g = graph.generate(n, avg_degree=deg, kind="uniform", seed=1)
        args = (jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val))
        dt, (st, iters) = time_call(
            lambda a=args: single.awpm(*a, g.n), iters=2, warmup=1)
        row(f"awpm_single_n{n}", dt * 1e6,
            f"m={g.nnz};iters={int(iters)};w={float(single.matching_weight(st, g.n)):.1f}")
    # strong-scaling model (paper Fig 6.3 analogue) for the match_4m cell
    n, m = 4_194_304, 67_108_864
    t1 = analytic_awac_round(n, m, 1)
    for p in (1, 4, 16, 64, 256, 512):
        tp = analytic_awac_round(n, m, p)
        row(f"awac_model_p{p}", tp * 1e6, f"speedup={t1 / tp:.1f}x")
    return True


if __name__ == "__main__":
    run()
