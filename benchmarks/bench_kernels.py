"""Kernel micro-benchmarks: Pallas (interpret mode — correctness-grade
timings on CPU; the TPU perf story lives in the roofline analysis) vs jnp
reference, plus arithmetic-intensity derivations for the v5e roofline, plus
the end-to-end AWAC iterations/sec contest between the seed implementation
and the fused sparse sweep engine (DESIGN.md §3)."""
import datetime

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import row, time_call
from repro.kernels import dispatch as kdispatch
from repro.kernels.backend import resolve_execution
from repro.kernels.cycle_gain import cycle_gain_padded, cycle_gain_ref
from repro.kernels.embedding_bag import embedding_bag_padded, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention


def _mode_note(backend: str) -> str:
    """``;interpret=`` annotation for Pallas rows — an interpreter timing
    must never be mistakable for a compiled-kernel timing."""
    if not backend.startswith("pallas"):
        return ""
    return ";" + resolve_execution(None).describe()


def _measure_awac_single(n: int, avg_degree: float, seed: int = 0,
                         emit_rows: bool = False):
    """Per-iteration AWAC time for every local backend on one synthetic
    instance. Returns ({backend: us_per_iter}, {pallas backend: interpret},
    {backend: weight})."""
    from repro.core import graph, single

    g = graph.generate(n, avg_degree=avg_degree, kind="uniform", seed=seed)
    r, c, v = jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val)
    st = single.greedy_maximal(r, c, v, g.n)
    st = single.mcm(r, c, v, g.n, st.mate_row, st.mate_col)

    us, interp, weights = {}, {}, {}
    for backend in kdispatch.MEASURED_BACKENDS:
        dt, (stf, iters) = time_call(
            lambda b=backend: single.awac(r, c, v, g.n, st, backend=b),
            iters=3, warmup=1,
        )
        iters = int(iters)
        w = float(single.matching_weight(stf, g.n))
        us[backend] = dt / max(iters, 1) * 1e6
        weights[backend] = w
        if backend.startswith("pallas"):
            interp[backend] = resolve_execution(None).interpret
        if emit_rows:
            row(f"awac_iter_{backend}_n{n}", us[backend],
                f"iters={iters};iters_per_s={iters / dt:.1f};weight={w:.4f}"
                + _mode_note(backend))
    return us, interp, weights


def bench_awac_sweep(n: int = 2048, avg_degree: float = 8.0):
    """End-to-end AWAC on a synthetic n x n instance: seed reference path vs
    the fused engines (CSR-windowed lookup + packed-key Step C; per-sweep
    and persistent whole-loop Pallas kernels). All backends run the
    identical select/augment semantics and must converge to the same
    matching weight; reports per-iteration time and iterations/sec, with
    Pallas rows annotated ``interpret=`` (interpreter timings are
    correctness-grade, never kernel timings). Returns (xla speedup vs
    reference, per-backend us, per-pallas-backend interpret flags)."""
    us, interp, weights = _measure_awac_single(n, avg_degree, emit_rows=True)
    speedup = us["reference"] / us["xla"]
    row(f"awac_fused_speedup_n{n}", us["xla"],
        f"speedup_vs_reference={speedup:.2f}x;"
        f"weight_identical="
        f"{abs(weights['reference'] - weights['xla']) == 0.0}")
    row(f"awac_persistent_speedup_n{n}", us["pallas_persistent"],
        f"speedup_vs_pallas_sweep={us['pallas'] / us['pallas_persistent']:.2f}x;"
        f"weight_identical="
        f"{abs(weights['reference'] - weights['pallas_persistent']) == 0.0}"
        + _mode_note("pallas_persistent"))
    return speedup, us, interp


def _measure_awac_batched(n: int, bsize: int, avg_degree: float = 6.0):
    """Per-iteration AWAC time for every local backend on a stacked batch
    (shared greedy+MCM state prep; only the AWAC phase is timed)."""
    from repro.core import MatchingProblem, batch, graph

    kinds = ("uniform", "circuit", "banded", "powerlaw", "antigreedy")
    gs = [graph.generate(n, avg_degree=avg_degree, kind=kinds[i % len(kinds)],
                         seed=i) for i in range(bsize)]
    p = MatchingProblem.stack(gs)
    r, c, v = p.row, p.col, p.val
    ws = batch._resolve_window_steps_batched(r, n, None)
    rp = batch.batched_row_ptr_from_sorted(r, n)
    mr, mc = batch.greedy_maximal_batched(r, c, v, n)
    mr, mc = batch.mcm_batched(r, c, v, n, mr, mc)
    st = batch._state_from_mates_windowed(r, c, v, rp, n, mr, mc, ws)

    us, interp = {}, {}
    for backend in kdispatch.MEASURED_BACKENDS:
        dt, (stf, iters) = time_call(
            lambda b=backend: batch.awac_batched(
                r, c, v, n, st, backend=b, row_ptr=rp, window_steps=ws),
            iters=3, warmup=1,
        )
        mean_iters = float(np.mean(np.asarray(iters)))
        us[backend] = dt / max(mean_iters, 1.0) * 1e6
        if backend.startswith("pallas"):
            interp[backend] = resolve_execution(None).interpret
    return us, interp


def bench_dispatch(single_large=None):
    """Measure every (shape class x backend) cell and persist the winners
    as the dispatch table (``BENCH_dispatch.json``) that
    ``backend="auto"`` consults (``repro.kernels.dispatch``). Reuses the
    ``bench_awac_sweep`` measurements for the large single class when
    provided. Emits one summary row per class."""
    platform = jax.default_backend()
    cells = {}
    if single_large is not None:
        cells["single_large"] = single_large
    else:
        cells["single_large"] = _measure_awac_single(2048, 8.0)[:2]
    cells["single_small"] = _measure_awac_single(96, 6.0)[:2]
    cells["batched_small"] = _measure_awac_batched(24, 8)
    cells["batched_large"] = _measure_awac_batched(512, 4)

    entries = {}
    for klass, (us, interp) in cells.items():
        winner = min(us, key=us.get)
        entries[f"{platform}/{klass}"] = {
            "winner": winner,
            "us_per_iter": {b: round(t, 1) for b, t in us.items()},
            "interpret": interp,
        }
        ranked = sorted(us, key=us.get)
        row(f"dispatch_{klass}", us[winner],
            f"winner={winner};runner_up={ranked[1]};"
            f"margin={us[ranked[1]] / us[winner]:.2f}x;platform={platform}")
    kdispatch.save_table(entries, {
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "jax": jax.__version__,
        "backend": platform,
        "measured_backends": list(kdispatch.MEASURED_BACKENDS),
    })
    return entries


def bench_awpm_batched(n: int = 24, avg_degree: float = 6.0,
                       batch_sizes=(1, 8, 32)):
    """Aggregate matching throughput: one batched ``api.solve`` dispatch for
    B instances vs a python loop of per-instance single-problem ``solve``
    calls (the pre-batching serving pattern). Sized for the
    many-small-instances regime the engine targets (MoE routing blocks,
    per-block pivot preprocessing) — at large n the per-instance compute
    dominates CPU dispatch and the lockstep batch loses its edge
    (DESIGN.md §4). Reports matchings/sec for both and the aggregate
    speedup at each B."""
    from repro.core import MatchingProblem, graph, solve

    b_max = max(batch_sizes)
    kinds = ("uniform", "circuit", "banded", "powerlaw", "antigreedy")
    gs = [graph.generate(n, avg_degree=avg_degree, kind=kinds[i % len(kinds)],
                         seed=i) for i in range(b_max)]
    stacked = MatchingProblem.stack(gs)
    row_all, col_all, val_all = stacked.row, stacked.col, stacked.val

    speedups = {}
    for b in batch_sizes:
        rows, cols, vals = row_all[:b], col_all[:b], val_all[:b]
        pb = MatchingProblem(row=rows, col=cols, val=vals, n=n)
        ps = [MatchingProblem(row=rows[i], col=cols[i], val=vals[i], n=n)
              for i in range(b)]
        dt_b, resB = time_call(lambda: solve(pb), iters=3, warmup=1)
        dt_l, outs = time_call(
            lambda: [solve(ps[i]) for i in range(b)], iters=3, warmup=1)
        wB = np.array(resB.weight)
        wL = np.array([float(r.weight) for r in outs])
        identical = bool((wB == wL).all())
        speedups[b] = dt_l / dt_b
        row(f"awpm_batched_B{b}_n{n}", dt_b / b * 1e6,
            f"matchings_per_s={b / dt_b:.1f};"
            f"loop_matchings_per_s={b / dt_l:.1f};"
            f"aggregate_speedup={dt_l / dt_b:.2f}x;"
            f"weights_identical={identical}")
    return speedups


def run():
    _, us_large, interp_large = bench_awac_sweep()
    bench_dispatch(single_large=(us_large, interp_large))
    bench_awpm_batched()
    rng = np.random.default_rng(0)
    # cycle_gain: M=N=512 dense tile
    m = n = 512
    a = jnp.asarray(rng.uniform(0.1, 1, (m, n)) * (rng.random((m, n)) < 0.3),
                    jnp.float32)
    a2 = jnp.asarray(rng.uniform(0.1, 1, (m, n)) * (rng.random((m, n)) < 0.3),
                     jnp.float32)
    u = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    v = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    dt_ref, _ = time_call(lambda: cycle_gain_ref(a, a2, u, v), iters=5)
    ai = (3 * m * n) / (2 * 4 * m * n)  # flops per byte (2 arrays in, f32)
    row("cycle_gain_ref_512", dt_ref * 1e6,
        f"arith_intensity={ai:.2f}flop/B;v5e_bound=memory")
    dt_k, _ = time_call(
        lambda: cycle_gain_padded(a, a2, u, v, tm=256, tn=256), iters=2)
    row("cycle_gain_pallas_interp_512", dt_k * 1e6, "interpret-mode")

    # flash attention S=512 D=64
    q = jnp.asarray(rng.normal(size=(1, 4, 512, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.bfloat16)
    vv = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.bfloat16)
    dt_ref, _ = time_call(lambda: attention_ref(q, k, vv), iters=3)
    flops = 4 * 1 * 4 * 512 * 512 * 64
    row("attention_ref_512", dt_ref * 1e6,
        f"flops={flops:.2e};v5e_us={flops / 197e12 * 1e6:.2f}")
    dt_k, _ = time_call(lambda: flash_attention(q, k, vv), iters=1)
    row("flash_attention_interp_512", dt_k * 1e6, "interpret-mode")

    # router_swap: T=512 tokens, E=64 experts
    from repro.kernels.router_swap import router_swap_padded, router_swap_ref

    aff = jnp.asarray(rng.normal(size=(512, 64)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, 64, 512), jnp.int32)
    cur = jnp.take_along_axis(aff, assign[:, None], axis=1)[:, 0]
    dt_ref, _ = time_call(lambda: router_swap_ref(aff, assign, cur), iters=3)
    row("router_swap_ref_512", dt_ref * 1e6, "materializes [T,T]")
    dt_k, _ = time_call(
        lambda: router_swap_padded(aff, assign, cur, ti=256, tj=256), iters=2)
    row("router_swap_pallas_interp_512", dt_k * 1e6,
        "tiled, no [T,T] in HBM")

    # embedding bag B=64 L=16 V=4096 D=64
    idx = jnp.asarray(rng.integers(-1, 4096, (64, 16)), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 1, (64, 16)), jnp.float32)
    tbl = jnp.asarray(rng.normal(size=(4096, 64)), jnp.float32)
    dt_ref, _ = time_call(lambda: embedding_bag_ref(idx, w, tbl), iters=5)
    row("embedding_bag_ref", dt_ref * 1e6, "take+segsum")
    dt_k, _ = time_call(
        lambda: embedding_bag_padded(idx, w, tbl, tb=8, tv=512), iters=2)
    row("embedding_bag_pallas_interp", dt_k * 1e6, "interpret-mode")
    return True


if __name__ == "__main__":
    run()
