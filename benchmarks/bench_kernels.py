"""Kernel micro-benchmarks: Pallas (interpret mode — correctness-grade
timings on CPU; the TPU perf story lives in the roofline analysis) vs jnp
reference, plus arithmetic-intensity derivations for the v5e roofline, plus
the end-to-end AWAC iterations/sec contest between the seed implementation
and the fused sparse sweep engine (DESIGN.md §3)."""
import numpy as np
import jax.numpy as jnp

from repro.kernels.cycle_gain import cycle_gain_padded, cycle_gain_ref
from repro.kernels.embedding_bag import embedding_bag_padded, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from benchmarks._util import row, time_call


def bench_awac_sweep(n: int = 2048, avg_degree: float = 8.0):
    """End-to-end AWAC on a synthetic n x n instance: seed reference path vs
    the fused sweep engine (CSR-windowed lookup + packed-key Step C). Both
    run the identical select/augment tail and must converge to the same
    matching weight; reports per-iteration time and iterations/sec."""
    from repro.core import graph, single

    g = graph.generate(n, avg_degree=avg_degree, kind="uniform", seed=0)
    r, c, v = jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val)
    st = single.greedy_maximal(r, c, v, g.n)
    st = single.mcm(r, c, v, g.n, st.mate_row, st.mate_col)

    results = {}
    for backend in ("reference", "xla", "pallas"):
        dt, (stf, iters) = time_call(
            lambda b=backend: single.awac(r, c, v, g.n, st, backend=b),
            iters=3, warmup=1,
        )
        iters = int(iters)
        w = float(single.matching_weight(stf, g.n))
        results[backend] = (dt / max(iters, 1), w)
        row(f"awac_iter_{backend}_n{n}", dt / max(iters, 1) * 1e6,
            f"iters={iters};iters_per_s={iters / dt:.1f};weight={w:.4f}")
    ref_it, ref_w = results["reference"]
    fused_it, fused_w = results["xla"]
    speedup = ref_it / fused_it
    row(f"awac_fused_speedup_n{n}", fused_it * 1e6,
        f"speedup_vs_reference={speedup:.2f}x;"
        f"weight_identical={abs(ref_w - fused_w) == 0.0}")
    return speedup


def bench_awpm_batched(n: int = 24, avg_degree: float = 6.0,
                       batch_sizes=(1, 8, 32)):
    """Aggregate matching throughput: one batched ``api.solve`` dispatch for
    B instances vs a python loop of per-instance single-problem ``solve``
    calls (the pre-batching serving pattern). Sized for the
    many-small-instances regime the engine targets (MoE routing blocks,
    per-block pivot preprocessing) — at large n the per-instance compute
    dominates CPU dispatch and the lockstep batch loses its edge
    (DESIGN.md §4). Reports matchings/sec for both and the aggregate
    speedup at each B."""
    from repro.core import MatchingProblem, graph, solve

    b_max = max(batch_sizes)
    kinds = ("uniform", "circuit", "banded", "powerlaw", "antigreedy")
    gs = [graph.generate(n, avg_degree=avg_degree, kind=kinds[i % len(kinds)],
                         seed=i) for i in range(b_max)]
    stacked = MatchingProblem.stack(gs)
    row_all, col_all, val_all = stacked.row, stacked.col, stacked.val

    speedups = {}
    for b in batch_sizes:
        rows, cols, vals = row_all[:b], col_all[:b], val_all[:b]
        pb = MatchingProblem(row=rows, col=cols, val=vals, n=n)
        ps = [MatchingProblem(row=rows[i], col=cols[i], val=vals[i], n=n)
              for i in range(b)]
        dt_b, resB = time_call(lambda: solve(pb), iters=3, warmup=1)
        dt_l, outs = time_call(
            lambda: [solve(ps[i]) for i in range(b)], iters=3, warmup=1)
        wB = np.array(resB.weight)
        wL = np.array([float(r.weight) for r in outs])
        identical = bool((wB == wL).all())
        speedups[b] = dt_l / dt_b
        row(f"awpm_batched_B{b}_n{n}", dt_b / b * 1e6,
            f"matchings_per_s={b / dt_b:.1f};"
            f"loop_matchings_per_s={b / dt_l:.1f};"
            f"aggregate_speedup={dt_l / dt_b:.2f}x;"
            f"weights_identical={identical}")
    return speedups


def run():
    bench_awac_sweep()
    bench_awpm_batched()
    rng = np.random.default_rng(0)
    # cycle_gain: M=N=512 dense tile
    m = n = 512
    a = jnp.asarray(rng.uniform(0.1, 1, (m, n)) * (rng.random((m, n)) < 0.3),
                    jnp.float32)
    a2 = jnp.asarray(rng.uniform(0.1, 1, (m, n)) * (rng.random((m, n)) < 0.3),
                     jnp.float32)
    u = jnp.asarray(rng.uniform(0, 1, m), jnp.float32)
    v = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    dt_ref, _ = time_call(lambda: cycle_gain_ref(a, a2, u, v), iters=5)
    ai = (3 * m * n) / (2 * 4 * m * n)  # flops per byte (2 arrays in, f32)
    row("cycle_gain_ref_512", dt_ref * 1e6,
        f"arith_intensity={ai:.2f}flop/B;v5e_bound=memory")
    dt_k, _ = time_call(
        lambda: cycle_gain_padded(a, a2, u, v, tm=256, tn=256), iters=2)
    row("cycle_gain_pallas_interp_512", dt_k * 1e6, "interpret-mode")

    # flash attention S=512 D=64
    q = jnp.asarray(rng.normal(size=(1, 4, 512, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.bfloat16)
    vv = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.bfloat16)
    dt_ref, _ = time_call(lambda: attention_ref(q, k, vv), iters=3)
    flops = 4 * 1 * 4 * 512 * 512 * 64
    row("attention_ref_512", dt_ref * 1e6,
        f"flops={flops:.2e};v5e_us={flops / 197e12 * 1e6:.2f}")
    dt_k, _ = time_call(lambda: flash_attention(q, k, vv), iters=1)
    row("flash_attention_interp_512", dt_k * 1e6, "interpret-mode")

    # router_swap: T=512 tokens, E=64 experts
    from repro.kernels.router_swap import router_swap_padded, router_swap_ref

    aff = jnp.asarray(rng.normal(size=(512, 64)), jnp.float32)
    assign = jnp.asarray(rng.integers(0, 64, 512), jnp.int32)
    cur = jnp.take_along_axis(aff, assign[:, None], axis=1)[:, 0]
    dt_ref, _ = time_call(lambda: router_swap_ref(aff, assign, cur), iters=3)
    row("router_swap_ref_512", dt_ref * 1e6, "materializes [T,T]")
    dt_k, _ = time_call(
        lambda: router_swap_padded(aff, assign, cur, ti=256, tj=256), iters=2)
    row("router_swap_pallas_interp_512", dt_k * 1e6,
        "tiled, no [T,T] in HBM")

    # embedding bag B=64 L=16 V=4096 D=64
    idx = jnp.asarray(rng.integers(-1, 4096, (64, 16)), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 1, (64, 16)), jnp.float32)
    tbl = jnp.asarray(rng.normal(size=(4096, 64)), jnp.float32)
    dt_ref, _ = time_call(lambda: embedding_bag_ref(idx, w, tbl), iters=5)
    row("embedding_bag_ref", dt_ref * 1e6, "take+segsum")
    dt_k, _ = time_call(
        lambda: embedding_bag_padded(idx, w, tbl, tb=8, tv=512), iters=2)
    row("embedding_bag_pallas_interp", dt_k * 1e6, "interpret-mode")
    return True


if __name__ == "__main__":
    run()
