"""Paper Table 6.2: AWPM weight vs the optimum (MC64 surrogate = scipy
Jonker-Volgenant). Paper claims: optimum on 10/16 matrices, avg 98.66%
(min 86%, max 100%) on an extended >=100-matrix suite."""
import numpy as np

from benchmarks._util import row, time_call
from repro.core import MatchingProblem, graph, ref, solve


def run(n_matrices=100, n=120, verbose=False):
    suite = graph.matrix_suite(n_matrices=n_matrices, n=n)
    # group by capacity bucket so jit caches across matrices
    ratios = []
    per_kind = {}
    t_total = 0.0
    for name, g in suite:
        dense = g.to_dense().astype(np.float32)
        struct = g.structure_dense()
        _, opt = ref.exact_mwpm(dense, struct)
        dt, res = time_call(
            lambda: solve(MatchingProblem.from_graph(g)), iters=1, warmup=0)
        t_total += dt
        mr = np.array(res.mate_row[: g.n])
        ref.check_matching(struct, mr)
        assert ref.is_perfect(mr, g.n)
        r = ref.matching_weight(dense, mr) / opt
        ratios.append(r)
        kind = name.split("_")[0]
        per_kind.setdefault(kind, []).append(r)
        if verbose:
            print(f"  {name}: ratio={r:.4f} iters={int(res.awac_iters)}")
    ratios = np.array(ratios)
    row("approx_ratio_mean", t_total / len(suite) * 1e6,
        f"mean={ratios.mean():.4f}")
    row("approx_ratio_min", 0.0, f"min={ratios.min():.4f}")
    row("approx_ratio_max", 0.0, f"max={ratios.max():.4f}")
    row("approx_ratio_optimal_count", 0.0,
        f"{int((ratios > 0.99999).sum())}/{len(ratios)} matrices at optimum")
    for kind, rs in sorted(per_kind.items()):
        row(f"approx_ratio_{kind}", 0.0,
            f"mean={np.mean(rs):.4f} min={np.min(rs):.4f}")
    return {"mean": float(ratios.mean()), "min": float(ratios.min()),
            "max": float(ratios.max())}


if __name__ == "__main__":
    run(verbose=True)
