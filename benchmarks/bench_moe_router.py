"""Beyond paper: AWPM MoE router vs top-k baseline — load balance (CV of
per-expert load, drop rate) and routing quality (mean selected affinity)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import row, time_call
from repro.models.moe import awpm_route, balanced_assign, swap_improve, topk_route


def run(t=1024, e=16, k=2, seed=0):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)

    # top-k baseline (capacity factor 1.25 -> drops)
    cap = int(1.25 * k * t / e) + 1
    dt_tk, (ti, sl, w, keep, aux) = time_call(
        jax.jit(lambda l: topk_route(l, k, cap)), logits, iters=3)
    load_tk = np.bincount(np.array(ti[np.array(keep)]).reshape(-1), minlength=e)
    drop = 1.0 - float(np.array(keep).mean())
    aff_tk = float(jnp.take_along_axis(logits, ti, axis=1).mean())

    # AWPM router (always balanced, never drops)
    cap_r = t // e
    dt_aw, (ti2, sl2, w2, keep2, _) = time_call(
        jax.jit(lambda l: awpm_route(l, k, cap_r, 4)), logits, iters=3)
    load_aw = np.bincount(np.array(ti2).reshape(-1), minlength=e)
    aff_aw = float(jnp.take_along_axis(logits, ti2, axis=1).mean())

    # greedy-only (no swaps) to isolate the 4-cycle improvement
    a0 = balanced_assign(logits, cap_r)
    aff0 = float(jnp.take_along_axis(logits, a0[:, None], axis=1).mean())
    a1 = swap_improve(logits, a0, 8)
    aff1 = float(jnp.take_along_axis(logits, a1[:, None], axis=1).mean())

    cv_tk = load_tk.std() / max(load_tk.mean(), 1e-9)
    cv_aw = load_aw.std() / max(load_aw.mean(), 1e-9)
    row("router_topk", dt_tk * 1e6,
        f"load_cv={cv_tk:.3f};drop={drop:.3%};affinity={aff_tk:.3f}")
    row("router_awpm", dt_aw * 1e6,
        f"load_cv={cv_aw:.3f};drop=0%;affinity={aff_aw:.3f}")
    row("router_awpm_swap_gain", 0.0,
        f"greedy_affinity={aff0:.3f};after_4cycles={aff1:.3f}")
    assert cv_aw < 1e-6, "AWPM router must be perfectly balanced"
    return {"cv_topk": cv_tk, "cv_awpm": cv_aw, "aff_gain": aff1 - aff0}


if __name__ == "__main__":
    run()
