"""Serving-tier benchmark (DESIGN.md §11): open-loop load + the
warm-vs-cold differential, persisted to ``BENCH_serving.json``.

Two measurements:

  1. **Open-loop stream** — ``repro.serving.loadgen`` drives a
     ``MatchingService`` with Poisson arrivals of perturbed repeat
     instances (the paper's motivating pivot-order stream). Rows:
     ``serving_throughput`` (served requests/s over the stream span) and
     ``serving_latency`` (p50/p95/p99, queueing + measured solve). The
     stream runs twice; the first pass is the compile warm-up (both the
     cold and warm lanes of the hot class compile there), only the second
     is reported — a serving process compiles once per class per life,
     not per stream.
  2. **Warm-vs-cold differential** — the acceptance story: on a batch of
     weight-perturbed repeats, ``matcher(p, warm_start=prev)`` must beat
     the cold ``matcher(p)`` (``serving_warm_vs_cold``: measured speedup,
     AWAC round counts, weight ratio), and a warm start from the
     problem's own converged mates must return bit-identically
     (``warm_identical=True`` — gated by ``check_regression.py`` like
     every other correctness flag).

Plus ``serving_plan_cache``: LRU hit/miss counters from the stream and
the measured cost of one cache hit vs the plan-and-compile a miss pays.

Standalone (the CI serving job): ``python benchmarks/bench_serving.py
[--quick]``. Also wired into ``benchmarks.run`` as suite "serving".
"""
from __future__ import annotations

import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

import jax
import numpy as np

from benchmarks._util import row
from repro.core import api, graph
from repro.serving import MatchingService, ServiceConfig
from repro.serving.loadgen import StreamSpec, run_stream


def _perturb_weights(problem: api.MatchingProblem, n: int, jitter: float,
                     seed: int) -> api.MatchingProblem:
    """Same structure, jittered positive weights (a repeat timestep)."""
    rng = np.random.default_rng(seed)
    val = np.asarray(problem.val).copy()
    real = np.asarray(problem.row) < n
    val[real] = np.abs(
        val[real] * (1.0 + jitter * rng.standard_normal(int(real.sum())))
    ).astype(np.float32)
    return api.MatchingProblem(row=problem.row, col=problem.col, val=val,
                               n=n)


def _time_solve(fn, iters: int) -> float:
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
        jax.block_until_ready((out.mate_row, out.mate_col))
    return (time.perf_counter() - t0) / iters * 1e6


def _bench_stream(quick: bool) -> None:
    spec = StreamSpec(
        requests=160 if quick else 640,
        users=8 if quick else 16,
        n=48, avg_degree=5.0,
        rate_rps=300.0 if quick else 600.0,
        weight_jitter=0.03, structure_churn=0.1, seed=0)
    config = ServiceConfig(num_shards=4, deadline_s=0.002, max_batch=8)
    # compile warm-up: one throwaway stream on a fresh service populates
    # the jit caches for this class's cold AND warm lanes (module-level
    # jax caches survive the service object)
    warmup = StreamSpec(requests=4 * config.max_batch, users=4,
                        n=spec.n, avg_degree=spec.avg_degree,
                        rate_rps=spec.rate_rps, weight_jitter=0.03, seed=1)
    run_stream(MatchingService(config), warmup)

    service = MatchingService(config)
    s = run_stream(service, spec)
    warm_frac = s["served_warm"] / max(s["served"], 1)
    row("serving_throughput", 1e6 / max(s["throughput_rps"], 1e-9),
        f"throughput_rps={s['throughput_rps']:.1f} served={s['served']} "
        f"offered_rps={spec.rate_rps:.0f} warm_frac={warm_frac:.2f} "
        f"mean_fill={s['mean_fill']:.2f} degraded={s['degraded']}")
    row("serving_latency", s["p50_us"],
        f"p50_us={s['p50_us']:.0f} p95_us={s['p95_us']:.0f} "
        f"p99_us={s['p99_us']:.0f} deadline_us="
        f"{config.deadline_s * 1e6:.0f} "
        f"mean_solve_us={s['mean_solve_us']:.0f}")

    stats = service.stats()
    # cache-hit lookup vs the plan a miss pays (compile excluded: it
    # lands on the first *call*, already counted in the stream latency)
    cls_key = service.plans.keys()[-1]
    t0 = time.perf_counter()
    for _ in range(100):
        service.plans.get(cls_key, lambda: None)
    hit_us = (time.perf_counter() - t0) / 100 * 1e6
    t0 = time.perf_counter()
    api.plan(api.ProblemSpec(n=cls_key[0], cap=cls_key[1],
                             batch=cls_key[2]))
    plan_us = (time.perf_counter() - t0) * 1e6
    pc = stats["plan_cache"]
    row("serving_plan_cache", hit_us,
        f"hits={pc['hits']} misses={pc['misses']} "
        f"evictions={pc['evictions']} plan_us={plan_us:.0f} "
        f"warm_seeds_served={stats['warm_cache']['served']}")


def _bench_warm_vs_cold(quick: bool) -> None:
    n, batch = (64, 8) if quick else (128, 8)
    bases = [graph.generate(n, 6.0, kind="uniform", seed=u)
             for u in range(batch)]
    p1 = api.MatchingProblem.stack(bases)
    matcher = api.plan(api.ProblemSpec(n=n, cap=p1.cap, batch=batch))
    r1 = matcher(p1)  # the "previous timestep" matching
    seed = (np.asarray(r1.mate_row), np.asarray(r1.mate_col))
    p2 = _perturb_weights(p1, n, jitter=0.03, seed=1)
    # compile both lanes before timing
    jax.block_until_ready(matcher(p2).mate_row)
    jax.block_until_ready(matcher(p2, warm_start=seed).mate_row)
    iters = 10 if quick else 30
    cold_us = _time_solve(lambda: matcher(p2), iters)
    warm_us = _time_solve(lambda: matcher(p2, warm_start=seed), iters)
    rc = matcher(p2)
    rw = matcher(p2, warm_start=seed)
    # bit-identity: warm-starting a problem from its OWN converged mates
    # must return them unchanged (the seed is an AWAC fixed point)
    rid = matcher(p2, warm_start=(np.asarray(rc.mate_row),
                                  np.asarray(rc.mate_col)))
    identical = bool(
        np.array_equal(np.asarray(rid.mate_row), np.asarray(rc.mate_row))
        and np.array_equal(np.asarray(rid.mate_col),
                           np.asarray(rc.mate_col))
        and np.allclose(np.asarray(rid.weight), np.asarray(rc.weight)))
    wc = float(np.asarray(rc.weight).sum())
    ww = float(np.asarray(rw.weight).sum())
    row("serving_warm_vs_cold", warm_us,
        f"cold_us={cold_us:.0f} speedup={cold_us / warm_us:.2f} "
        f"warm_identical={identical} "
        f"weight_ratio={ww / wc:.4f} "
        f"iters_cold={int(np.asarray(rc.awac_iters).sum())} "
        f"iters_warm={int(np.asarray(rw.awac_iters).sum())} "
        f"perfect={bool(np.asarray(rw.perfect).all())}")


def run(quick: bool = False) -> None:
    _bench_warm_vs_cold(quick)
    _bench_stream(quick)


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI sizing: smaller stream, fewer timing iters")
    ap.add_argument("--no-persist", action="store_true",
                    help="skip writing BENCH_serving.json")
    args = ap.parse_args(argv)
    from benchmarks import run as _run
    from benchmarks._util import drain_rows

    print("name,us_per_call,derived")
    drain_rows()
    t0 = time.perf_counter()
    run(quick=args.quick)
    if not args.no_persist:
        _run._persist("serving", drain_rows(), time.perf_counter() - t0,
                      ok=True, full=not args.quick)


if __name__ == "__main__":
    main()
