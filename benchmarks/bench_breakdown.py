"""Paper Fig 6.4: AWPM phase breakdown (maximal / MCM / AWAC)."""
import jax.numpy as jnp

from benchmarks._util import row, time_call
from repro.core import graph, single


def run(n=1024, deg=8.0):
    g = graph.generate(n, avg_degree=deg, kind="antigreedy", seed=2)
    args = (jnp.asarray(g.row), jnp.asarray(g.col), jnp.asarray(g.val))

    dt_g, st0 = time_call(lambda: single.greedy_maximal(*args, g.n), iters=3)
    dt_m, st1 = time_call(
        lambda: single.mcm(*args, g.n, st0.mate_row, st0.mate_col), iters=3)
    dt_a, (st2, iters) = time_call(
        lambda: single.awac(*args, g.n, st1), iters=3)
    total = dt_g + dt_m + dt_a
    row("phase_maximal", dt_g * 1e6, f"{dt_g / total * 100:.0f}%")
    row("phase_mcm", dt_m * 1e6, f"{dt_m / total * 100:.0f}%")
    row("phase_awac", dt_a * 1e6,
        f"{dt_a / total * 100:.0f}%;rounds={int(iters)}")
    return True


if __name__ == "__main__":
    run()
