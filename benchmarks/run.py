"""Benchmark harness — one module per paper table/figure (+ beyond-paper).
Prints ``name,us_per_call,derived`` CSV rows. See EXPERIMENTS.md for the
mapping to the paper's tables."""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (approx_ratio, scaling, "
                         "breakdown, pivot, moe_router, kernels)")
    ap.add_argument("--full", action="store_true",
                    help="larger problem sizes (slower)")
    args = ap.parse_args()

    from benchmarks import (
        bench_approx_ratio, bench_breakdown, bench_kernels, bench_moe_router,
        bench_pivot, bench_scaling,
    )

    benches = {
        "approx_ratio": lambda: bench_approx_ratio.run(
            n_matrices=100 if args.full else 50, n=120 if args.full else 96),
        "scaling": bench_scaling.run,
        "breakdown": bench_breakdown.run,
        "pivot": bench_pivot.run,
        "moe_router": bench_moe_router.run,
        "kernels": bench_kernels.run,
    }
    selected = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        print(f"# --- {name} ---", flush=True)
        try:
            benches[name]()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
