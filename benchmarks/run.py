"""Benchmark harness — one module per paper table/figure (+ beyond-paper).
Prints ``name,us_per_call,derived`` CSV rows and persists each suite's rows
to ``BENCH_<suite>.json`` at the repo root (suite name, wall-clock, row list,
environment metadata) so the perf trajectory is tracked across PRs. See
EXPERIMENTS.md for the mapping to the paper's tables."""
import argparse
import datetime
import json
import pathlib
import sys
import time
import traceback

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _persist(suite: str, rows, wall_clock_s: float, ok: bool, full: bool):
    import jax

    rec = {
        "suite": suite,
        "ok": ok,
        "wall_clock_s": round(wall_clock_s, 3),
        "rows": rows,
        "metadata": {
            "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "full": full,
        },
    }
    out = REPO_ROOT / f"BENCH_{suite}.json"
    out.write_text(json.dumps(rec, indent=1))
    print(f"# wrote {out.name} ({len(rows)} rows, {wall_clock_s:.1f}s)",
          flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (approx_ratio, scaling, "
                         "breakdown, pivot, moe_router, kernels, serving)")
    ap.add_argument("--full", action="store_true",
                    help="larger problem sizes (slower)")
    ap.add_argument("--no-persist", action="store_true",
                    help="skip writing BENCH_*.json files")
    args = ap.parse_args()

    from benchmarks import (
        bench_approx_ratio, bench_breakdown, bench_kernels, bench_moe_router,
        bench_pivot, bench_scaling, bench_serving,
    )
    from benchmarks._util import drain_rows

    benches = {
        "approx_ratio": lambda: bench_approx_ratio.run(
            n_matrices=100 if args.full else 50, n=120 if args.full else 96),
        "scaling": bench_scaling.run,
        "breakdown": bench_breakdown.run,
        "pivot": bench_pivot.run,
        "moe_router": bench_moe_router.run,
        "kernels": bench_kernels.run,
        "serving": lambda: bench_serving.run(quick=not args.full),
    }
    selected = (args.only.split(",") if args.only else list(benches))
    unknown = [s for s in selected if s not in benches]
    if unknown:
        ap.error(f"unknown bench name(s) {unknown}; "
                 f"choose from {sorted(benches)}")
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        print(f"# --- {name} ---", flush=True)
        drain_rows()  # discard anything a previous suite left behind
        t0 = time.perf_counter()
        ok = True
        try:
            benches[name]()
        except Exception:  # noqa: BLE001
            failures += 1
            ok = False
            traceback.print_exc()
        if not args.no_persist:
            _persist(name, drain_rows(), time.perf_counter() - t0, ok,
                     args.full)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
