"""Execute every fenced ```python block in the given markdown files so the
documented quickstarts can never silently rot (CI docs job, DESIGN.md §6).

Each snippet runs in its own namespace, in its own subprocess-free exec,
with the repo root as cwd (snippets reference e.g. tests/data/*.mtx
relatively) and src/ on sys.path. A failing snippet fails the run with
the file, block index, and traceback. Blocks in any other language
(```bash, ```text, ...) are ignored.

  PYTHONPATH=src python docs/check_snippets.py README.md docs/paper_mapping.md
"""
from __future__ import annotations

import os
import pathlib
import re
import sys
import traceback

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def extract(path: pathlib.Path) -> list[str]:
    return [m.group(1).strip() for m in FENCE_RE.finditer(path.read_text())]


def main(argv: list[str]) -> int:
    paths = [pathlib.Path(a) for a in argv] or [REPO_ROOT / "README.md"]
    os.chdir(REPO_ROOT)
    sys.path.insert(0, str(REPO_ROOT / "src"))
    failures = 0
    total = 0
    for path in paths:
        snippets = extract(path)
        if not snippets:
            print(f"# {path}: no python snippets")
            continue
        for i, code in enumerate(snippets, 1):
            total += 1
            label = f"{path}#{i}"
            print(f"# --- {label} ---", flush=True)
            try:
                exec(compile(code, label, "exec"), {"__name__": f"snippet_{i}"})
            except Exception:
                failures += 1
                print(f"FAIL {label}:")
                traceback.print_exc()
    print(f"# {total - failures}/{total} snippets OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
