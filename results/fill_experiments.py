"""Fill EXPERIMENTS.md placeholders from results/dryrun/*.json + bench logs.
Run after the sweep: PYTHONPATH=src python results/fill_experiments.py"""
import json
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.roofline.report import dryrun_table, load, roofline_table  # noqa: E402

RES = ROOT / "results" / "dryrun"


def rec(name):
    p = RES / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def fmt_terms(r):
    rl = r["roofline"]
    return (f"compute {rl['compute_s']:.3f}s / memory {rl['memory_s']:.3f}s / "
            f"collective {rl['collective_s']:.3f}s (dominant: {rl['dominant']})")


def perf_pair(name_base, name_var, cellname, hypothesis, change):
    b, v = rec(name_base), rec(name_var)
    if not (b and v and b.get("ok") and v.get("ok")):
        return f"### {cellname}: variant missing ({name_var})\n"
    rb, rv = b["roofline"], v["roofline"]
    dom = rb["dominant"]
    key = {"compute": "compute_s", "memory": "memory_s",
           "collective": "collective_s"}[dom]
    before, after = rb[key], rv[key]
    verdict = "CONFIRMED" if after < before * 0.95 else (
        "refuted (<5% or regression)" if after >= before else "small win")
    lines = [
        f"### {cellname}",
        f"- **Hypothesis**: {hypothesis}",
        f"- **Change**: {change}",
        f"- **Before**: {fmt_terms(b)}",
        f"- **After**:  {fmt_terms(v)}",
        f"- **Dominant term ({dom})**: {before:.3f}s -> {after:.3f}s "
        f"({before / max(after, 1e-12):.2f}x) — **{verdict}**",
        f"- collective bytes/dev: {b['collectives']['total'] / 2**30:.2f} GiB"
        f" -> {v['collectives']['total'] / 2**30:.2f} GiB; "
        f"counts {sum(b['collectives']['counts'].values())} -> "
        f"{sum(v['collectives']['counts'].values())}",
        "",
    ]
    return "\n".join(lines)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()

    recs = load("single")
    base = [r for r in recs if "__v_" not in json.dumps(r.get("variants", []))
            or not r.get("variants")]
    md = md.replace("<!-- ROOFLINE_TABLE -->",
                    roofline_table([r for r in recs if not r.get("variants")]))

    perf = []
    perf.append(perf_pair(
        "awpm-matching__match_4m__single",
        "awpm-matching__match_4m__single__v_packed_a2a",
        "Iteration M1+M2 — awpm-matching · match_4m (paper-representative)",
        "the A/B exchange pays 4 collective launches per routing stage "
        "(3 payload arrays + validity); packing into one int32-bitcast "
        "all_to_all with sentinel-derived validity cuts launches 4->1 with "
        "the same bytes; search depth ceil(log2(cap)) instead of 32 cuts "
        "join gather traffic ~40%",
        "core/dist.py a2a_bucketed(packed=True) + adaptive lex-search depth"))
    perf.append(perf_pair(
        "qwen1.5-110b__train_4k__single",
        "qwen1.5-110b__train_4k__single__v_fsdp_gather",
        "Iteration L1 — qwen1.5-110b · train_4k (largest dense LM)",
        "with embed FSDP-sharded over 'data', GSPMD all-reduces ACTIVATIONS "
        "([65k tok/dev, 3072] f32 per matmul) when contracting the sharded "
        "dim; napkin: gathering bf16 WEIGHTS instead costs ~340MB/layer/dev "
        "vs ~multi-GB activation reductions -> expect large collective drop",
        "explicit bf16 weight all-gather at use (w_fsdp constraint)"))
    perf.append(perf_pair(
        "deepseek-moe-16b__train_4k__single",
        "deepseek-moe-16b__train_4k__single__v_moe_ep",
        "Iteration E1 — deepseek-moe-16b · train_4k (most collective-bound LM)",
        "global capacity-based dispatch scatters T=1M tokens into a single "
        "[64, 123k, 2048] buffer across the mesh (giant cross-device "
        "scatter + gathers); grouped dispatch (2048-token data-local groups) "
        "+ EP over 'model' (64/16) turns routing into shard-local scatters "
        "+ the canonical token<->expert all_to_all",
        "moe_apply grouped dispatch + experts sharded over 'model'"))
    perf.append(perf_pair(
        "deepseek-moe-16b__train_4k__single",
        "deepseek-moe-16b__train_4k__single__v_moe_ep_fsdp_gather",
        "Iteration E2 — deepseek-moe-16b · train_4k (E1 + L1 composed)",
        "E1 leaves the dense-path activation all-reduces of L1 in place; "
        "composing both should stack",
        "moe_ep + fsdp_gather variants together"))
    perf.append(perf_pair(
        "equiformer-v2__ogb_products__single",
        "equiformer-v2__ogb_products__single__v_escn_sub",
        "Iteration Q1 — equiformer-v2 · ogb_products (worst roofline fraction)",
        "edge messages carry all 49 irrep components but only |m|<=2 ones "
        "(29/49) interact under the eSCN restriction; carrying the subspace "
        "only shrinks every gather/message/aggregate by 1.69x",
        "escn_subspace=True (state restricted to |m| <= m_max components)"))
    perf.append(perf_pair(
        "deepseek-moe-16b__train_4k__single__v_moe_ep",
        "deepseek-moe-16b__train_4k__single__v_moe_ep:8192",
        "Iteration E3 — deepseek-moe-16b · train_4k (new dominant term: memory)",
        "per-group expert GEMMs at gb=2048 re-read expert weights per group; "
        "4x larger groups should cut weight re-reads 4x",
        "dispatch group size 2048 -> 8192")
        + "\n> verdict detail: HLO bytes-accessed counts each einsum's "
          "operands once regardless of the group count, so the metric is "
          "blind to this effect — **not measurable in this environment** "
          "(<1% change); on TPU the win would appear in wall-clock. "
          "Counts toward the <5% stopping rule.\n")
    perf.append(perf_pair(
        "equiformer-v2__ogb_products__single__v_escn_sub",
        "equiformer-v2__ogb_products__single__v_escn_sub_gnn_bf16",
        "Iteration Q2 — equiformer-v2 · ogb_products (sub-space + bf16 messages)",
        "node states/messages in bf16 halve the dominant all-gathers of x "
        "[2.45M, 29, 128] (33.9 GiB -> 17 GiB each)",
        "gnn_bf16 variant (bf16 features end-to-end; verified numerically "
        "equivalent to f32 within 0.6% rel err)")
        + "\n> verdict detail: dtype propagation confirmed locally, but "
          "XLA:CPU upcasts bf16 arithmetic to f32 (convert fusions feed the "
          "all-gathers), so the dry-run metric shows no change — an "
          "environment artifact; a TPU compile gathers native bf16. Counts "
          "toward the <5% stopping rule on this backend.\n")
    md = md.replace("<!-- PERF_ITERATIONS -->", "\n".join(perf))

    # dry-run notes: compile time stats
    times = [r.get("compile_s", 0) for r in recs if r.get("ok")]
    multi = load("multi")
    ok_m = sum(1 for r in multi if r.get("ok"))
    md = md.replace(
        "<!-- DRYRUN_NOTES -->",
        f"Compile times (single-pod, 1 CPU core): median "
        f"{sorted(times)[len(times)//2]:.0f}s, max {max(times):.0f}s. "
        f"Multi-pod: {ok_m}/{len(multi)} OK — the 'pod' axis shards "
        f"(EP for MoE where divisible, batch/sequence elsewhere).")

    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("filled EXPERIMENTS.md")


if __name__ == "__main__":
    main()
