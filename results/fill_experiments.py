"""Fill-in / pivot-growth / refinement experiments for the static-pivoting
solver (DESIGN.md §12) — the end-to-end "does the matching replace
numerical pivoting?" measurement the paper motivates AWPM with.

Every checked-in ``tests/data/*.mtx`` fixture (plus planted synthetic
systems in the full sweep) is solved through
``repro.solver.solve_linear_system`` under four arms:

- **awpm**      — AWPM matching -> static pivots + MC64 scalings (the
  paper's pipeline);
- **reference** — exact MC64-style matching (scipy Hungarian oracle),
  same scalings: isolates matching quality (skipped without scipy);
- **none**      — no permutation, no scaling, static LU: the contrast arm
  that is ALLOWED to fail — its divergence on the ill-conditioned cases
  IS the reproduced result;
- **tpp**       — no matching, classical threshold partial pivoting: what
  a solver must do at factor time when nothing was done at match time.

Per (case, arm) row: fill ratio, pivot growth, perturbed pivots, scaled
diagonal min, refinement sweeps, the true float64 relative residual, and
convergence. Outputs ``results/fill_experiments.md`` and
``BENCH_solver.json`` (repo root), gated in CI by
``benchmarks/check_regression.py --solver``: every awpm row must converge
to <= 1e-10, and at least one case must show the none-fails/awpm-converges
contrast.

  PYTHONPATH=src python results/fill_experiments.py [--quick]
      [--no-persist] [--download [--instances N1,N2] [--cache-dir DIR]
      [--max-n 4096]]

``--quick`` (the CI smoke) sweeps the fixtures only; the full run adds the
planted instances. ``--download`` opt-in fetches SuiteSparse instances
(``repro.data.suitesparse``) and solves those small enough for the dense
triangular-sweep backend (> ``--max-n`` rows are skipped with a note — the
measurement here is numerical behavior, not HPC scale).
"""
import argparse
import dataclasses
import datetime
import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import numpy as np  # noqa: E402

FIXTURE_DIR = ROOT / "tests" / "data"
ARMS = ("awpm", "reference", "none", "tpp")


@dataclasses.dataclass(frozen=True)
class SolverRow:
    """One (case, arm) measurement — a row of the table and of
    BENCH_solver.json."""

    case: str
    source: str  # "fixture" | "planted" | "suitesparse"
    arm: str
    n: int
    nnz: int
    fill: float
    growth: float
    perturbed: int
    diag_min: float
    sweeps: int
    residual: float
    converged: bool
    wall_s: float


def fixture_systems():
    from repro.data.mtx import read_mtx

    for path in sorted(FIXTURE_DIR.glob("*.mtx")):
        coo = read_mtx(path)
        yield path.stem, "fixture", (coo.row, coo.col, coo.val, coo.nrows)


def planted_systems():
    """Parameterized synthetic systems extending the fixture story to
    larger n: the ill-conditioned family (near-zero diagonal under a
    heavy cyclic band — unpivoted growth compounds every step) and a
    diagonally dominant control where even the none arm should succeed."""
    for n, seed in ((32, 1), (64, 2)):
        rng = np.random.default_rng(seed)
        row, col, val = [], [], []
        for i in range(n):
            row += [i, i, i]
            col += [i, (i + 1) % n, (i + 3) % n]
            val += [1e-8 * (1.0 + rng.random()), 5.0 + 5.0 * rng.random(),
                    0.01 + 0.09 * rng.random()]
        yield (f"planted_illcond{n}", "planted",
               (np.array(row), np.array(col), np.array(val), n))
    n, rng = 48, np.random.default_rng(3)
    row, col, val = [], [], []
    for i in range(n):
        row.append(i)
        col.append(i)
        val.append(10.0 + 10.0 * rng.random())
        for j in rng.choice(n, size=3, replace=False):
            if j != i:
                row.append(i)
                col.append(int(j))
                val.append(float(rng.standard_normal()))
    yield (f"planted_dominant{n}", "planted",
           (np.array(row), np.array(col), np.array(val), n))


def suitesparse_systems(instances, cache, max_n):
    from repro.data import suitesparse
    from repro.data.mtx import read_mtx

    names = ([t.strip() for t in instances.split(",") if t.strip()]
             if instances else None)
    for name, path in sorted(
            suitesparse.fetch_paper_instances(names, cache=cache).items()):
        coo = read_mtx(path)
        if coo.nrows > max_n:
            print(f"# {name}: SKIPPED — n={coo.nrows} > --max-n {max_n} "
                  f"(dense triangular-sweep backend; raise --max-n at your "
                  f"own memory's risk)")
            continue
        yield name, "suitesparse", (coo.row, coo.col, coo.val, coo.nrows)


def run_case(name, source, system, arms=ARMS, rhs_seed=7):
    from repro.core import ref
    from repro.solver import solve_linear_system

    row, col, val, n = system
    rng = np.random.default_rng(rhs_seed)
    b = rng.standard_normal(n)
    if np.iscomplexobj(val):
        b = b + 1j * rng.standard_normal(n)
    rows = []
    for arm in arms:
        if arm == "reference" and not ref.HAVE_SCIPY:
            print(f"# {name}: reference arm skipped (no scipy)")
            continue
        kw = {"pivoting": "none", "lu_mode": "threshold"} if arm == "tpp" \
            else {"pivoting": arm}
        t0 = time.perf_counter()
        rep = solve_linear_system((row, col, val, n), b, **kw)
        wall = time.perf_counter() - t0
        s = rep.lu_stats
        rows.append(SolverRow(
            case=name, source=source, arm=arm, n=s.n, nnz=s.nnz_in,
            fill=s.fill_ratio, growth=s.pivot_growth,
            perturbed=s.perturbed_pivots,
            diag_min=rep.scaled_diag_min,
            sweeps=int(np.max(rep.refinement.iterations)),
            residual=float(np.max(rep.residual)),
            converged=bool(rep.ok), wall_s=wall))
        print(f"  {name:<22} {arm:<9} {rep.summary()}")
    return rows


def to_markdown(rows):
    contrasts = sorted(
        {r.case for r in rows if r.arm == "none" and not r.converged} &
        {r.case for r in rows if r.arm == "awpm" and r.converged})
    lines = [
        "# Fill / pivot-growth / refinement experiments",
        "",
        "Generated by `results/fill_experiments.py` (DESIGN.md §12). Arms: "
        "`awpm` = AWPM static pivoting + MC64 scalings; `reference` = exact "
        "matching, same scalings; `none` = unpivoted static LU (allowed to "
        "fail); `tpp` = threshold partial pivoting, no matching. `residual` "
        "is the true float64 relative residual after mixed-precision "
        "iterative refinement; `growth` = max|U|/max|A|; `perturbed` = "
        "GESP-floored pivots.",
        "",
        f"**The contrast result:** unpivoted static LU fails on "
        f"{', '.join(f'`{c}`' for c in contrasts) if contrasts else '(none)'}"
        f" while AWPM static pivoting converges on every case — the "
        f"matching replaces numerical pivoting, which is the paper's "
        f"motivating claim for AWPM inside SuperLU_DIST.",
        "",
        "| case | src | arm | n | nnz | fill | growth | perturbed "
        "| diag_min | sweeps | residual | converged | ms |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r.case} | {r.source} | {r.arm} | {r.n} | {r.nnz} "
            f"| {r.fill:.2f} | {r.growth:.3g} | {r.perturbed} "
            f"| {r.diag_min:.3g} | {r.sweeps} | {r.residual:.3e} "
            f"| {r.converged} | {r.wall_s * 1e3:.1f} |")
    return "\n".join(lines) + "\n"


def to_bench_rows(rows):
    out = []
    for r in rows:
        out.append({
            "name": f"solver_{r.case}_{r.arm}",
            "us_per_call": round(r.wall_s * 1e6, 1),
            "derived": (
                f"pivoting={r.arm};n={r.n};nnz={r.nnz};fill={r.fill:.3f};"
                f"growth={r.growth:.6g};perturbed={r.perturbed};"
                f"diag_min={r.diag_min:.6g};sweeps={r.sweeps};"
                f"residual={r.residual:.6e};converged={r.converged}"),
        })
    return out


def write_outputs(rows, wall_clock_s, quick):
    import jax

    md = ROOT / "results" / "fill_experiments.md"
    md.write_text(to_markdown(rows))
    rec = {
        "suite": "solver",
        "ok": True,
        "wall_clock_s": round(wall_clock_s, 3),
        "rows": to_bench_rows(rows),
        "metadata": {
            "date": datetime.datetime.now(datetime.timezone.utc).isoformat(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "quick": quick,
        },
    }
    bench = ROOT / "BENCH_solver.json"
    bench.write_text(json.dumps(rec, indent=1))
    return md, bench


def main():
    ap = argparse.ArgumentParser(
        description="static-pivoting solver experiments")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fixtures only (planted cases skipped)")
    ap.add_argument("--no-persist", action="store_true",
                    help="skip writing results/ + BENCH_solver.json")
    ap.add_argument("--download", action="store_true",
                    help="OPT-IN network: also solve cached SuiteSparse "
                         "instances small enough for the dense sweep "
                         "backend (CI never passes this)")
    ap.add_argument("--instances", default=None,
                    help="with --download: comma list of registry names or "
                         "Group/name specs")
    ap.add_argument("--cache-dir", default=None,
                    help="SuiteSparse cache dir override")
    ap.add_argument("--max-n", type=int, default=4096,
                    help="skip downloaded instances above this n")
    args = ap.parse_args()
    if args.instances and not args.download:
        raise SystemExit("--instances needs --download (no implicit network)")

    systems = list(fixture_systems())
    if not args.quick:
        systems += list(planted_systems())
    if args.download:
        systems += list(suitesparse_systems(args.instances, args.cache_dir,
                                            args.max_n))
    t0 = time.perf_counter()
    rows = []
    for name, source, system in systems:
        rows += run_case(name, source, system)
    wall = time.perf_counter() - t0

    n_awpm = sum(1 for r in rows if r.arm == "awpm")
    n_conv = sum(1 for r in rows if r.arm == "awpm" and r.converged)
    contrasts = sorted(
        {r.case for r in rows if r.arm == "none" and not r.converged} &
        {r.case for r in rows if r.arm == "awpm" and r.converged})
    print(f"# {len(rows)} rows in {wall:.1f}s: awpm converged {n_conv}/"
          f"{n_awpm}, none-fails/awpm-converges contrast on "
          f"{contrasts or 'NO CASE (gate will fail)'}")
    if not args.no_persist:
        md, bench = write_outputs(rows, wall, args.quick)
        print(f"# wrote {md.relative_to(ROOT)} and {bench.name} "
              f"({len(rows)} rows)")
    if n_conv < n_awpm:
        raise SystemExit("awpm arm failed to converge — see table above")


if __name__ == "__main__":
    main()
